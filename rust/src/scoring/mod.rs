//! The normalized multi-objective orchestration score (paper Eq. 2).
//!
//! `f(p, S_xy) = w_R·R̂(p, L_x) + w_T·T̂(S_xy) + w_C·Ĉ(S_xy)`
//!
//! with `(w_R, w_T, w_C)` the convex normalization of the operator
//! profile's `(α, λ, μ)` and each component min–max normalized over
//! historical system statistics — latency and cost become *goodness*
//! scores via `1 − norm(·)`.

use crate::config::Profile;
use crate::util::stats::HistoryNorm;

/// Convex weights derived from an operator profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Weights {
    pub w_r: f64,
    pub w_t: f64,
    pub w_c: f64,
}

impl Weights {
    /// Normalize (α, λ, μ) into convex weights. The all-zero baseline
    /// profile degenerates to pure relevance (routing disabled upstream).
    pub fn from_profile(p: &Profile) -> Weights {
        let total = p.alpha + p.lambda + p.mu;
        if total <= 0.0 {
            return Weights { w_r: 1.0, w_t: 0.0, w_c: 0.0 };
        }
        Weights {
            w_r: p.alpha / total,
            w_t: p.lambda / total,
            w_c: p.mu / total,
        }
    }

    pub fn sum(&self) -> f64 {
        self.w_r + self.w_t + self.w_c
    }
}

/// Normalized component scores for one (prompt, service) pair.
#[derive(Debug, Clone, Copy)]
pub struct Components {
    /// R̂ ∈ [0,1] — relevance of model to predicted complexity.
    pub relevance: f64,
    /// T̂ ∈ [0,1] — 1 − normalized expected latency.
    pub timeliness: f64,
    /// Ĉ ∈ [0,1] — 1 − normalized expected cost.
    pub economy: f64,
}

/// Eq. 2: convex combination, guaranteed in [0, 1].
pub fn score(w: Weights, c: Components) -> f64 {
    debug_assert!((w.sum() - 1.0).abs() < 1e-9);
    let f = w.w_r * c.relevance + w.w_t * c.timeliness + w.w_c * c.economy;
    debug_assert!((0.0..=1.0 + 1e-9).contains(&f));
    f.clamp(0.0, 1.0)
}

/// Rolling normalizers for the latency and cost components — "min–max or
/// distributional normalization computed over historical system
/// statistics" (paper §Problem). One instance is shared per registry.
#[derive(Debug)]
pub struct ScoreNormalizer {
    latency: HistoryNorm,
    cost: HistoryNorm,
}

impl ScoreNormalizer {
    pub fn new(window: usize) -> Self {
        Self {
            latency: HistoryNorm::new(window),
            cost: HistoryNorm::new(window),
        }
    }

    /// Record an observed (latency, cost) sample into history.
    pub fn observe(&mut self, latency_s: f64, cost_usd: f64) {
        self.latency.observe(latency_s);
        self.cost.observe(cost_usd);
    }

    /// T̂ = 1 − norm(T): higher is better.
    pub fn timeliness(&self, expected_latency_s: f64) -> f64 {
        1.0 - self.latency.normalize(expected_latency_s)
    }

    /// Ĉ = 1 − norm(C): higher is better.
    pub fn economy(&self, expected_cost_usd: f64) -> f64 {
        1.0 - self.cost.normalize(expected_cost_usd)
    }

    pub fn samples(&self) -> usize {
        self.latency.len()
    }
}

/// Relevance R̂(p, L_x): how well a model's capability matches the
/// predicted complexity class. A capability exactly matched to demand
/// scores 1; overkill decays mildly (wasted capacity), underkill decays
/// steeply (failures) — the asymmetry that pushes hard prompts to big
/// models without sending everything there.
pub fn relevance(capability: &[f64; 3], complexity: usize, confidence: f64) -> f64 {
    let c = complexity.min(2);
    // Expected capability under classification uncertainty: blend the
    // predicted class with its neighbours proportional to (1 - confidence).
    let mut need = [0.0f64; 3];
    need[c] = confidence;
    let spill = (1.0 - confidence) / 2.0;
    need[(c + 1).min(2)] += spill;
    need[c.saturating_sub(1)] += spill;
    // Renormalize (edge classes fold spill onto themselves).
    let total: f64 = need.iter().sum();
    let mut r = 0.0;
    for (k, n) in need.iter().enumerate() {
        r += (n / total) * capability[k];
    }
    r.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Profile;

    #[test]
    fn weights_are_convex() {
        for p in &Profile::ALL {
            let w = Weights::from_profile(p);
            assert!((w.sum() - 1.0).abs() < 1e-12, "{}", p.name);
            assert!(w.w_r >= 0.0 && w.w_t >= 0.0 && w.w_c >= 0.0);
        }
    }

    #[test]
    fn quality_profile_weights_match_paper() {
        // (1.0, 0.1, 0.1) → w_R = 1/1.2 ≈ 0.833
        let w = Weights::from_profile(&Profile::QUALITY);
        assert!((w.w_r - 1.0 / 1.2).abs() < 1e-12);
        assert!((w.w_t - 0.1 / 1.2).abs() < 1e-12);
    }

    #[test]
    fn score_bounded() {
        let w = Weights::from_profile(&Profile::BALANCED);
        for r in [0.0, 0.5, 1.0] {
            for t in [0.0, 0.5, 1.0] {
                for c in [0.0, 0.5, 1.0] {
                    let f = score(w, Components {
                        relevance: r,
                        timeliness: t,
                        economy: c,
                    });
                    assert!((0.0..=1.0).contains(&f));
                }
            }
        }
    }

    #[test]
    fn cost_profile_prefers_cheap() {
        let w = Weights::from_profile(&Profile::COST);
        let cheap = score(w, Components { relevance: 0.6, timeliness: 0.5, economy: 0.9 });
        let pricey = score(w, Components { relevance: 0.9, timeliness: 0.5, economy: 0.1 });
        assert!(cheap > pricey);
    }

    #[test]
    fn quality_profile_prefers_capable() {
        let w = Weights::from_profile(&Profile::QUALITY);
        let strong = score(w, Components { relevance: 0.95, timeliness: 0.2, economy: 0.2 });
        let weak = score(w, Components { relevance: 0.45, timeliness: 1.0, economy: 1.0 });
        assert!(strong > weak);
    }

    #[test]
    fn normalizer_learns_scale() {
        let mut n = ScoreNormalizer::new(64);
        for i in 0..32 {
            n.observe(1.0 + i as f64 / 10.0, 0.01 + i as f64 / 1000.0);
        }
        assert!(n.timeliness(1.0) > n.timeliness(4.0));
        assert!(n.economy(0.01) > n.economy(0.04));
    }

    #[test]
    fn relevance_matches_capability_under_certainty() {
        let cap = [0.97, 0.85, 0.50];
        assert!((relevance(&cap, 0, 1.0) - 0.97).abs() < 1e-12);
        assert!((relevance(&cap, 2, 1.0) - 0.50).abs() < 1e-12);
    }

    #[test]
    fn relevance_blends_under_uncertainty() {
        let cap = [0.9, 0.8, 0.4];
        let certain = relevance(&cap, 2, 1.0);
        let unsure = relevance(&cap, 2, 0.5);
        // Uncertainty about a hard prompt pulls in the medium capability.
        assert!(unsure > certain);
    }

    #[test]
    fn baseline_profile_degenerates_to_relevance() {
        let w = Weights::from_profile(&Profile::BASELINE);
        assert_eq!(w.w_r, 1.0);
        assert_eq!(w.w_t, 0.0);
    }
}
