//! Hashed-wordpiece tokenizer — bit-parity with `python/compile/tokenizer.py`.
//!
//! The semantic router tokenizes on the request path, so this is Rust;
//! the Python twin runs only at build time (training corpus, AOT). Parity
//! is enforced against `artifacts/tokenizer_parity.json` in the
//! integration tests.

use crate::util::rng::fnv1a64;

pub const VOCAB: u32 = 4096;
pub const PAD: u32 = 0;
pub const CLS: u32 = 1;
pub const SEP: u32 = 2;
pub const UNK: u32 = 3; // reserved, never emitted by the hash
pub const RESERVED: u32 = 4;

/// Classifier input length (must match `manifest.json` / SEQ_CLS).
pub const SEQ_CLS: usize = 48;

/// Lowercase and split into maximal ASCII-alphanumeric runs.
pub fn split_words(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        let ch = ch.to_ascii_lowercase();
        if ch.is_ascii_alphanumeric() {
            cur.push(ch);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Hash a word to its vocabulary id.
pub fn word_id(word: &str) -> u32 {
    RESERVED + (fnv1a64(word.as_bytes()) % (VOCAB - RESERVED) as u64) as u32
}

/// Encode to exactly `seq_len` ids: `[CLS] words... [SEP] PAD...`.
pub fn encode(text: &str, seq_len: usize) -> Vec<i32> {
    let mut ids: Vec<i32> = Vec::with_capacity(seq_len);
    ids.push(CLS as i32);
    for w in split_words(text).into_iter().take(seq_len - 2) {
        ids.push(word_id(&w) as i32);
    }
    ids.push(SEP as i32);
    while ids.len() < seq_len {
        ids.push(PAD as i32);
    }
    ids.truncate(seq_len);
    ids
}

/// Encode without CLS/SEP framing (LM prompt): word ids, PAD-padded.
pub fn encode_words(text: &str, max_words: usize) -> Vec<i32> {
    let mut ids: Vec<i32> = split_words(text)
        .into_iter()
        .take(max_words)
        .map(|w| word_id(&w) as i32)
        .collect();
    while ids.len() < max_words {
        ids.push(PAD as i32);
    }
    ids
}

/// Number of non-PAD positions (PAD only appears as right padding).
pub fn valid_len(ids: &[i32]) -> usize {
    let mut n = ids.len();
    while n > 0 && ids[n - 1] == PAD as i32 {
        n -= 1;
    }
    n
}

/// Token count of a prompt (before truncation) — the router's length
/// feature and the serving layer's prompt-size estimate.
pub fn word_count(text: &str) -> usize {
    split_words(text).len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_matches_python_semantics() {
        assert_eq!(split_words("Hello, World!"), vec!["hello", "world"]);
        assert_eq!(split_words("f(n) = 3n + 7"), vec!["f", "n", "3n", "7"]);
        assert!(split_words("").is_empty());
        assert!(split_words("  ... !!! ").is_empty());
        // non-ascii characters act as separators
        assert_eq!(split_words("Ünïcödé"), vec!["n", "c", "d"]);
    }

    #[test]
    fn encode_framing() {
        let ids = encode("hello world", 8);
        assert_eq!(ids[0], CLS as i32);
        assert_eq!(ids[3], SEP as i32);
        assert_eq!(&ids[4..], &[PAD as i32; 4]);
    }

    #[test]
    fn encode_truncates() {
        let long = vec!["w"; 100].join(" ");
        let ids = encode(&long, 16);
        assert_eq!(ids.len(), 16);
        assert!(!ids.contains(&(PAD as i32)));
    }

    #[test]
    fn empty_prompt() {
        assert_eq!(
            encode("", 6),
            vec![CLS as i32, SEP as i32, 0, 0, 0, 0]
        );
    }

    #[test]
    fn ids_in_range() {
        for w in ["sum", "prove", "the", "123abc", "a"] {
            let id = word_id(w);
            assert!(id >= RESERVED && id < VOCAB);
        }
    }

    #[test]
    fn valid_len_strips_padding() {
        assert_eq!(valid_len(&[1, 5, 2, 0, 0]), 3);
        assert_eq!(valid_len(&[0, 0]), 0);
        assert_eq!(valid_len(&[1, 2]), 2);
    }
}
