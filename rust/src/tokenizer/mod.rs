//! Hashed-wordpiece tokenizer — bit-parity with `python/compile/tokenizer.py`.
//!
//! The semantic router tokenizes on the request path, so this is Rust;
//! the Python twin runs only at build time (training corpus, AOT). Parity
//! is enforced against `artifacts/tokenizer_parity.json` in the
//! integration tests.

use crate::util::rng::fnv1a64;

pub const VOCAB: u32 = 4096;
pub const PAD: u32 = 0;
pub const CLS: u32 = 1;
pub const SEP: u32 = 2;
pub const UNK: u32 = 3; // reserved, never emitted by the hash
pub const RESERVED: u32 = 4;

/// Classifier input length (must match `manifest.json` / SEQ_CLS).
pub const SEQ_CLS: usize = 48;

/// Borrowing iterator over the maximal ASCII-alphanumeric runs of a
/// prompt — the words of [`split_words`] without a heap allocation per
/// word (the router classifies every request, so this is a hot path).
/// Yields subslices in original case; pair with [`word_id_of`], which
/// lowercases while hashing. Byte-wise scanning is char-boundary-safe
/// because multi-byte UTF-8 sequences never contain ASCII bytes.
pub fn words(text: &str) -> Words<'_> {
    Words { text, pos: 0 }
}

/// See [`words`].
pub struct Words<'a> {
    text: &'a str,
    pos: usize,
}

impl<'a> Iterator for Words<'a> {
    type Item = &'a str;

    fn next(&mut self) -> Option<&'a str> {
        let bytes = self.text.as_bytes();
        while self.pos < bytes.len() && !bytes[self.pos].is_ascii_alphanumeric() {
            self.pos += 1;
        }
        if self.pos >= bytes.len() {
            return None;
        }
        let start = self.pos;
        while self.pos < bytes.len() && bytes[self.pos].is_ascii_alphanumeric() {
            self.pos += 1;
        }
        Some(&self.text[start..self.pos])
    }
}

/// Lowercase and split into maximal ASCII-alphanumeric runs. Allocates
/// one `String` per word — build-time / test use; the request path runs
/// on [`words`] + [`word_id_of`] instead.
pub fn split_words(text: &str) -> Vec<String> {
    words(text).map(|w| w.to_ascii_lowercase()).collect()
}

/// Hash an (already-lowercased) word to its vocabulary id.
pub fn word_id(word: &str) -> u32 {
    RESERVED + (fnv1a64(word.as_bytes()) % (VOCAB - RESERVED) as u64) as u32
}

/// [`word_id`] for a raw original-case run from [`words`]: hashes the
/// ASCII-lowercased bytes without materializing a lowercase string
/// (bit-identical to `word_id(&run.to_ascii_lowercase())`).
pub fn word_id_of(run: &str) -> u32 {
    let mut h = crate::util::rng::FNV64_OFFSET;
    for b in run.bytes() {
        h = crate::util::rng::fnv1a64_step(h, b.to_ascii_lowercase());
    }
    RESERVED + (h % (VOCAB - RESERVED) as u64) as u32
}

/// Encode to exactly `seq_len` ids: `[CLS] words... [SEP] PAD...`.
pub fn encode(text: &str, seq_len: usize) -> Vec<i32> {
    let mut ids: Vec<i32> = Vec::with_capacity(seq_len);
    ids.push(CLS as i32);
    for w in words(text).take(seq_len - 2) {
        ids.push(word_id_of(w) as i32);
    }
    ids.push(SEP as i32);
    while ids.len() < seq_len {
        ids.push(PAD as i32);
    }
    ids.truncate(seq_len);
    ids
}

/// Encode without CLS/SEP framing (LM prompt): word ids, PAD-padded.
pub fn encode_words(text: &str, max_words: usize) -> Vec<i32> {
    let mut ids: Vec<i32> = words(text)
        .take(max_words)
        .map(|w| word_id_of(w) as i32)
        .collect();
    while ids.len() < max_words {
        ids.push(PAD as i32);
    }
    ids
}

/// Unpadded word-id stream of a prompt, truncated to `max_tokens` — the
/// serving layer's prefix-cache key (block hashes chain over these ids;
/// matches [`encode_words`]' ids minus the padding).
pub fn prompt_ids(text: &str, max_tokens: usize) -> Vec<i32> {
    words(text)
        .take(max_tokens)
        .map(|w| word_id_of(w) as i32)
        .collect()
}

/// Number of non-PAD positions (PAD only appears as right padding).
pub fn valid_len(ids: &[i32]) -> usize {
    let mut n = ids.len();
    while n > 0 && ids[n - 1] == PAD as i32 {
        n -= 1;
    }
    n
}

/// Token count of a prompt (before truncation) — the router's length
/// feature and the serving layer's prompt-size estimate. Allocation-free.
pub fn word_count(text: &str) -> usize {
    words(text).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_matches_python_semantics() {
        assert_eq!(split_words("Hello, World!"), vec!["hello", "world"]);
        assert_eq!(split_words("f(n) = 3n + 7"), vec!["f", "n", "3n", "7"]);
        assert!(split_words("").is_empty());
        assert!(split_words("  ... !!! ").is_empty());
        // non-ascii characters act as separators
        assert_eq!(split_words("Ünïcödé"), vec!["n", "c", "d"]);
    }

    #[test]
    fn encode_framing() {
        let ids = encode("hello world", 8);
        assert_eq!(ids[0], CLS as i32);
        assert_eq!(ids[3], SEP as i32);
        assert_eq!(&ids[4..], &[PAD as i32; 4]);
    }

    #[test]
    fn encode_truncates() {
        let long = vec!["w"; 100].join(" ");
        let ids = encode(&long, 16);
        assert_eq!(ids.len(), 16);
        assert!(!ids.contains(&(PAD as i32)));
    }

    #[test]
    fn empty_prompt() {
        assert_eq!(
            encode("", 6),
            vec![CLS as i32, SEP as i32, 0, 0, 0, 0]
        );
    }

    #[test]
    fn ids_in_range() {
        for w in ["sum", "prove", "the", "123abc", "a"] {
            let id = word_id(w);
            assert!(id >= RESERVED && id < VOCAB);
        }
    }

    #[test]
    fn valid_len_strips_padding() {
        assert_eq!(valid_len(&[1, 5, 2, 0, 0]), 3);
        assert_eq!(valid_len(&[0, 0]), 0);
        assert_eq!(valid_len(&[1, 2]), 2);
    }

    #[test]
    fn borrowing_words_match_split_words() {
        for text in [
            "Hello, World!",
            "f(n) = 3n + 7",
            "",
            "  ... !!! ",
            "Ünïcödé",
            "MiXeD CaSe 123abc",
            "trailing-word",
        ] {
            let borrowed: Vec<String> =
                words(text).map(|w| w.to_ascii_lowercase()).collect();
            assert_eq!(borrowed, split_words(text), "text: {text:?}");
            assert_eq!(word_count(text), split_words(text).len());
        }
    }

    #[test]
    fn word_id_of_matches_lowercased_word_id() {
        for run in ["Sum", "PROVE", "the", "123Abc", "A"] {
            assert_eq!(word_id_of(run), word_id(&run.to_ascii_lowercase()));
        }
    }

    #[test]
    fn prompt_ids_match_encode_words_prefix() {
        let text = "Solve for X: 3x = 9 please";
        let padded = encode_words(text, 16);
        let ids = prompt_ids(text, 16);
        assert_eq!(ids.len(), word_count(text));
        assert_eq!(&padded[..ids.len()], &ids[..]);
        assert_eq!(prompt_ids(text, 3).len(), 3);
    }
}
