//! End-to-end simulation driver — virtual-time execution of the whole
//! control plane.
//!
//! Every line of routing/selection/scaling/recovery logic here is the
//! same code the live server runs; only the data plane (service times,
//! completion sampling) comes from the calibrated model instead of PJRT,
//! which is what lets the paper's 155k-run tables finish in seconds
//! (DESIGN.md §Substitutions).

use std::collections::VecDeque;

use anyhow::Result;

use crate::backend::kv_cache::{chain_hash, ROOT_HASH};
use crate::backend::{
    request_cost_usd, service_time_with_prefix, spec_tokens_per_step, InferenceRequest,
};
use crate::baselines::{SelectionPolicy, Selector};
use crate::cluster::{events::EventQueue, Cluster, ClusterEvent};
use crate::config::{
    ClusterConfig, OrchestratorConfig, PoolConfig, Profile, RouterMode,
};
use crate::models::completion::CompletionModel;
use crate::models::{zoo, BackendKind};
use crate::orchestrator::recovery::RecoveryManager;
use crate::orchestrator::Scaler;
use crate::registry::{Registry, ServiceId};
use crate::router::bandit::{ArmStat, TierBandit};
use crate::router::hybrid::{HybridRouter, SemanticRouter};
use crate::router::keyword::KeywordRouter;
use crate::router::{Classification, Classifier, Router};
use crate::scoring::Weights;
use crate::telemetry::trace::{Span, SpanKind};
use crate::util::rng::SplitMix64;
use crate::workload::{Generator, TemplateLibrary};

/// Deployment mode under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deployment {
    /// All four models always on (one replica each, default backend); no
    /// scaling; failures restart from a cold image.
    Static,
    /// Pick-and-Spin: scale-to-zero, warm pools, reactive spin-up.
    /// `auto_recovery` additionally keeps warm standbys and redeploys
    /// failed pods immediately (the paper's "auto" row in Table 4).
    Dynamic { auto_recovery: bool },
}

/// Simulation configuration.
#[derive(Clone)]
pub struct SimConfig {
    pub router_mode: RouterMode,
    pub profile: Profile,
    pub policy: SelectionPolicy,
    pub deployment: Deployment,
    /// Poisson arrival rate.
    pub rate_qps: f64,
    /// Optional bursty override: (high qps, low qps, phase seconds).
    pub bursty: Option<(f64, f64, f64)>,
    pub n_requests: usize,
    pub seed: u64,
    /// Error rate of the oracle classifier standing in for the compiled
    /// model when running without artifacts (the compiled classifier's
    /// measured error ≈ 0–4%).
    pub classifier_error: f64,
    /// Inject a pod failure every N seconds (None = no failures).
    pub fail_every_s: Option<f64>,
    pub cluster: ClusterConfig,
    pub orchestrator: OrchestratorConfig,
    /// Request deadline (paper: success = completion within time limits).
    pub deadline_s: f64,
    /// Control-loop period.
    pub control_period_s: f64,
    /// Replicas per model for the static deployment (a static fleet must
    /// be provisioned for peak, not average, demand).
    pub static_replicas: usize,
    /// Serving-pool knobs the data-plane model reads: the prefix cache
    /// (`pool.prefix_cache`, block size `pool.kv_block_tokens`, capacity
    /// `pool.kv_blocks`) makes simulated prefill time hit-rate-dependent,
    /// mirroring the live radix cache.
    pub pool: PoolConfig,
}

impl SimConfig {
    pub fn defaults() -> SimConfig {
        SimConfig {
            router_mode: RouterMode::Hybrid,
            profile: Profile::BALANCED,
            policy: SelectionPolicy::MultiObjective,
            deployment: Deployment::Dynamic { auto_recovery: true },
            rate_qps: 20.0,
            bursty: None,
            n_requests: 20_000,
            seed: 42,
            classifier_error: 0.03,
            fail_every_s: None,
            cluster: ClusterConfig::default(),
            orchestrator: OrchestratorConfig::default(),
            deadline_s: 120.0,
            control_period_s: 5.0,
            static_replicas: 1,
            pool: PoolConfig::default(),
        }
    }
}

/// Block-hash prefix model for the simulated data plane: the same
/// chained block hashes as the live radix cache ([`chain_hash`]), with
/// LRU capped at the pool's block budget — but no per-block refcounts,
/// since the sim's services have no slot-level KV pool to share. Feeds
/// [`service_time_with_prefix`] so Table-style sweeps show the
/// hit-rate-dependent prefill win.
struct SimPrefixCache {
    block_tokens: usize,
    cap_blocks: usize,
    min_run: usize,
    tick: u64,
    /// chain hash → last-use tick.
    nodes: std::collections::BTreeMap<u64, u64>,
}

impl SimPrefixCache {
    fn new(pool: &PoolConfig) -> SimPrefixCache {
        SimPrefixCache {
            block_tokens: pool.kv_block_tokens.max(1),
            cap_blocks: pool.kv_blocks.max(1),
            min_run: pool.prefix_cache.min_block_run.max(1),
            tick: 0,
            nodes: std::collections::BTreeMap::new(),
        }
    }

    /// Cached prompt tokens for this prompt right now, then insert its
    /// full blocks (a request leaves its prefix behind, as prefill does
    /// on the live path).
    fn observe(&mut self, prompt: &str) -> usize {
        let ids = crate::tokenizer::prompt_ids(prompt, usize::MAX);
        self.tick += 1;
        let mut matched = 0usize;
        let mut unbroken = true;
        let mut parent = ROOT_HASH;
        let mut chain: Vec<u64> = Vec::new();
        for chunk in ids.chunks_exact(self.block_tokens) {
            let h = chain_hash(parent, chunk);
            if unbroken && self.nodes.contains_key(&h) {
                matched += 1;
            } else {
                unbroken = false;
            }
            chain.push(h);
            parent = h;
        }
        for &h in &chain {
            self.nodes.insert(h, self.tick);
        }
        if self.nodes.len() > self.cap_blocks {
            let mut by_age: Vec<(u64, u64)> =
                self.nodes.iter().map(|(k, t)| (*t, *k)).collect();
            by_age.sort_unstable();
            for &(_, k) in by_age.iter().take(self.nodes.len() - self.cap_blocks) {
                self.nodes.remove(&k);
            }
        }
        if matched < self.min_run {
            0
        } else {
            matched * self.block_tokens
        }
    }
}

/// One served request's record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub benchmark: String,
    pub true_complexity: usize,
    pub predicted_complexity: usize,
    pub model: &'static str,
    pub backend: BackendKind,
    pub success: bool,
    pub latency_s: f64,
    pub ttft_s: f64,
    pub wait_s: f64,
    pub router_overhead_s: f64,
    pub cost_usd: f64,
    pub in_tokens: usize,
    /// Prompt tokens served from the simulated prefix cache.
    pub prefix_cached_tokens: usize,
    /// Span timeline on virtual time (`pool.trace.enabled`) — the same
    /// kinds and ordering the live gateway's `/debug/traces` reports, so
    /// sim and live traces are schema-identical. Empty with tracing off.
    pub spans: Vec<Span>,
}

/// Synthesize the live span schema for one sim request on virtual time:
/// `admit` (routing overhead) → `queued` → `prefill` → `decode`, with
/// `started_s = None` for work that never reached a replica (the
/// timeline then ends in an open-ended `queued` span).
fn sim_request_spans(
    arrival_s: f64,
    overhead_s: f64,
    started_s: Option<f64>,
    ttft_s: f64,
    latency_s: f64,
) -> Vec<Span> {
    let a1 = arrival_s + overhead_s.max(0.0);
    let mut spans =
        vec![Span { kind: SpanKind::Admit, start_s: arrival_s, end_s: a1, n: 0 }];
    match started_s {
        Some(st) => {
            // The same contiguity the live path has: queue ends at
            // dispatch, prefill ends at first token, decode at finish.
            let q_end = (st + overhead_s).max(a1);
            let first = (arrival_s + ttft_s).max(q_end);
            let fin = (arrival_s + latency_s).max(first);
            spans.push(Span { kind: SpanKind::Queued, start_s: a1, end_s: q_end, n: 0 });
            spans.push(Span { kind: SpanKind::Prefill, start_s: q_end, end_s: first, n: 0 });
            spans.push(Span { kind: SpanKind::Decode, start_s: first, end_s: fin, n: 0 });
        }
        None => {
            let fin = (arrival_s + latency_s).max(a1);
            spans.push(Span { kind: SpanKind::Queued, start_s: a1, end_s: fin, n: 0 });
        }
    }
    spans
}

/// Aggregated simulation output.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub records: Vec<RequestRecord>,
    pub duration_s: f64,
    /// GPU-seconds held (allocation) and used (busy).
    pub gpu_seconds_held: f64,
    pub gpu_seconds_busy: f64,
    /// $ for all held GPU time (system cost).
    pub system_cost_usd: f64,
    /// Mean recovery seconds across injected failures.
    pub mean_recovery_s: Option<f64>,
    pub n_failures_injected: usize,
    /// Arrivals rejected by the admission gate (overload shedding);
    /// each one also appears in `records` as a failed request.
    pub n_shed: usize,
    /// Fraction of prompts the hybrid router refined semantically.
    pub semantic_refinement_rate: f64,
    /// Per-(class, tier) learner state at the end of the run — empty
    /// unless `pool.routing.bandit.enabled`.
    pub bandit_arms: Vec<ArmStat>,
}

impl SimReport {
    pub fn success_rate(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().filter(|r| r.success).count() as f64
            / self.records.len() as f64
    }

    pub fn mean_latency_s(&self) -> f64 {
        crate::util::stats::mean(
            &self.records.iter().map(|r| r.latency_s).collect::<Vec<_>>(),
        )
    }

    pub fn gpu_utilization(&self) -> f64 {
        if self.gpu_seconds_held <= 0.0 {
            0.0
        } else {
            (self.gpu_seconds_busy / self.gpu_seconds_held).clamp(0.0, 1.0)
        }
    }

    pub fn cost_per_query_usd(&self) -> f64 {
        if self.records.is_empty() {
            0.0
        } else {
            self.system_cost_usd / self.records.len() as f64
        }
    }

    /// Summed per-request serving cost per *successful* answer — the
    /// figure of merit learned routing optimizes (serving spend that
    /// bought a usable answer). Infinite when nothing succeeded.
    pub fn cost_per_success_usd(&self) -> f64 {
        let ok = self.records.iter().filter(|r| r.success).count();
        if ok == 0 {
            return f64::INFINITY;
        }
        self.records.iter().map(|r| r.cost_usd).sum::<f64>() / ok as f64
    }

    pub fn routing_accuracy(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records
            .iter()
            .filter(|r| r.predicted_complexity == r.true_complexity)
            .count() as f64
            / self.records.len() as f64
    }

    pub fn throughput_qps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            0.0
        } else {
            self.records.len() as f64 / self.duration_s
        }
    }

    /// Prompt tokens served from the prefix cache.
    pub fn prefix_hit_tokens(&self) -> usize {
        self.records.iter().map(|r| r.prefix_cached_tokens).sum()
    }

    /// Fraction of all prompt tokens served from the prefix cache (the
    /// sim analogue of `ps_prefix_hit_tokens_total` /
    /// (`hit` + `miss`)).
    pub fn prefix_hit_token_rate(&self) -> f64 {
        let total: usize = self.records.iter().map(|r| r.in_tokens).sum();
        if total == 0 {
            0.0
        } else {
            self.prefix_hit_tokens() as f64 / total as f64
        }
    }
}

enum Event {
    Arrival(usize),
    Finish { service: ServiceId, req: usize },
    Control,
    Fail,
}

struct ServiceState {
    queue: VecDeque<usize>,
    busy: usize,
    /// Busy-streams integral support.
    last_t: f64,
    busy_integral: f64, // stream-seconds
}

struct Pending {
    req: InferenceRequest,
    class: Classification,
    service: ServiceId,
    enqueued_s: f64,
    started_s: f64,
    ttft_s: f64,
    finish_total_s: f64,
    /// Prompt tokens the service's prefix cache held at dispatch.
    prefix_cached: usize,
}

/// Run one simulation.
pub fn run(
    cfg: &SimConfig,
    lib: &TemplateLibrary,
    classifier: Box<dyn Classifier>,
) -> Result<SimReport> {
    let zoo_models = zoo();
    let mut registry = Registry::new(&zoo_models, cfg.orchestrator.telemetry_window_s);
    let mut cluster = Cluster::new(cfg.cluster.clone());
    let mut scaler = Scaler::new(cfg.orchestrator.clone(), registry.services.len());
    let auto_recovery = matches!(cfg.deployment, Deployment::Dynamic { auto_recovery: true });
    // Every mode eventually redeploys (static restarts too, just from a
    // cold image); only the auto mode's standbys absorb failures.
    let mut recovery = RecoveryManager::with_standby(true, auto_recovery);
    let mut selector = Selector::new(
        cfg.policy,
        Weights::from_profile(&cfg.profile),
        cfg.seed ^ 0xABCD,
    );
    // Learned routing (`pool.routing.bandit.enabled`): the same learner
    // the live router thread arms, run on virtual time. Each tier's arm
    // dispatches to that tier's canonical Vllm cell (the sim mirror of
    // the live `tier_model` table). Off (the default) no learner exists
    // and every draw below is skipped — the legacy trace, bit for bit.
    let mut bandit: Option<(TierBandit, [ServiceId; 3])> =
        if cfg.pool.routing.bandit.enabled {
            let mut caps = [[0.0f64; 3]; 3];
            let mut cells = [ServiceId(0); 3];
            for (ti, cell) in cells.iter_mut().enumerate() {
                let mi = (0..registry.n_models)
                    .find(|&mi| zoo_models[mi].tier.index() == ti)
                    .expect("zoo covers every tier");
                caps[ti] = zoo_models[mi].capability;
                *cell = registry.cell(mi, BackendKind::Vllm).id;
            }
            Some((
                TierBandit::new(
                    &cfg.pool.routing.bandit,
                    Weights::from_profile(&cfg.profile),
                    caps,
                    [true; 3],
                    cfg.seed ^ 0x00BA_4D17,
                ),
                cells,
            ))
        } else {
            None
        };
    let mut router: Box<dyn Router> = match cfg.router_mode {
        RouterMode::Keyword => Box::new(KeywordRouter::new()),
        RouterMode::Semantic => Box::new(SemanticRouter::new(
            classifier,
            crate::config::RouterConfig::default().semantic_overhead_s,
        )),
        RouterMode::Hybrid => Box::new(HybridRouter::new(
            classifier,
            &crate::config::RouterConfig::default(),
        )),
    };

    // Completion model calibrated to Table 1 over the real template mixes.
    let bench_info: Vec<(String, [f64; 3], f64)> = lib
        .benchmarks
        .iter()
        .map(|b| {
            (
                b.name.clone(),
                b.complexity_mix(),
                b.baseline_success as f64 / b.runs as f64,
            )
        })
        .collect();
    let completion = CompletionModel::calibrate(&zoo_models, &bench_info);

    // Initial deployment.
    let mut now = 0.0f64;
    match cfg.deployment {
        Deployment::Static => {
            // Fixed replicas per model on the default backend, always on
            // (sized for peak demand — a static fleet cannot adapt).
            for mi in 0..registry.n_models {
                let id = registry.cell(mi, BackendKind::Vllm).id;
                for _ in 0..cfg.static_replicas.max(1) {
                    let spec = registry.get(id).spec.clone();
                    cluster.schedule(id, mi, &spec, BackendKind::Vllm, now);
                    registry.get_mut(id).pending_replicas += 1;
                }
            }
        }
        Deployment::Dynamic { auto_recovery } => {
            // Warm pools on the default backend per tier floor.
            for mi in 0..registry.n_models {
                let id = registry.cell(mi, BackendKind::Vllm).id;
                let tier = registry.get(id).spec.tier;
                let mut floor = cfg.orchestrator.warm_pool[tier.index()];
                if auto_recovery {
                    // Standby capacity for instant failover: two replicas
                    // on the small/medium tiers (cheap), one on large —
                    // failures are absorbed by the standby and traffic
                    // reroutes at detection time.
                    floor = floor.max(match tier {
                        crate::models::Tier::Large => 1,
                        _ => 2,
                    });
                }
                for _ in 0..floor {
                    let spec = registry.get(id).spec.clone();
                    if cluster.schedule(id, mi, &spec, BackendKind::Vllm, now).is_some() {
                        registry.get_mut(id).pending_replicas += 1;
                    }
                }
            }
        }
    }
    // Let the initial pods come up before traffic starts (t=0 is after
    // warm-up, matching how the paper measures steady state).
    let warmup = 240.0;
    for ev in cluster.poll(warmup) {
        apply_cluster_event(&ev, &mut registry);
    }
    now = warmup;

    // Generate arrivals.
    let mut gen = Generator::new(lib, cfg.seed);
    let mut arr_rng = SplitMix64::new(cfg.seed ^ 0x77);
    let mut requests: Vec<InferenceRequest> = Vec::with_capacity(cfg.n_requests);
    let mut events: EventQueue<Event> = EventQueue::new();
    {
        let mut t = now;
        for i in 0..cfg.n_requests {
            let dt = match cfg.bursty {
                None => arr_rng.exp(cfg.rate_qps),
                Some((hi, lo, phase)) => {
                    let in_high = (((t - warmup) / phase) as u64) % 2 == 0;
                    arr_rng.exp(if in_high { hi } else { lo })
                }
            };
            t += dt;
            requests.push(gen.request(i as u64, t));
            events.push((t * 1e9) as u64, Event::Arrival(i));
        }
    }
    // Hard horizon: last arrival + generous drain window. Requests still
    // unfinished at the horizon are recorded as deadline failures — this
    // both models the paper's time-limit semantics and guarantees the
    // event loop terminates even if a cell can never be scheduled.
    let horizon_s = requests
        .last()
        .map(|r| r.arrival_s)
        .unwrap_or(now)
        + 4.0 * cfg.deadline_s;
    events.push((now * 1e9) as u64 + 1, Event::Control);
    if let Some(every) = cfg.fail_every_s {
        let mut t = now + every;
        while t < now + 20.0 * every {
            events.push((t * 1e9) as u64, Event::Fail);
            t += every;
        }
    }

    let mut states: Vec<ServiceState> = (0..registry.services.len())
        .map(|_| ServiceState {
            queue: VecDeque::new(),
            busy: 0,
            last_t: now,
            busy_integral: 0.0,
        })
        .collect();
    let mut pendings: Vec<Option<Pending>> = (0..cfg.n_requests).map(|_| None).collect();
    let mut records: Vec<RequestRecord> = Vec::with_capacity(cfg.n_requests);
    let mut svc_rng = SplitMix64::new(cfg.seed ^ 0x5151);
    // Per-service prefix caches (None when pool.prefix_cache is off —
    // the prefill model then matches the pre-cache behaviour exactly).
    let mut prefix_caches: Vec<Option<SimPrefixCache>> = (0..registry.services.len())
        .map(|_| cfg.pool.prefix_cache.enabled.then(|| SimPrefixCache::new(&cfg.pool)))
        .collect();
    let mut n_failures = 0usize;
    let mut n_shed = 0usize;
    let mut done = 0usize;

    // Helper: update a service's busy integral to `t`.
    macro_rules! integrate {
        ($sid:expr, $t:expr) => {{
            let st = &mut states[$sid.0];
            if $t > st.last_t {
                st.busy_integral += st.busy as f64 * ($t - st.last_t);
                st.last_t = $t;
            }
        }};
    }

    // Helper: start queued work on a service while capacity remains.
    macro_rules! try_start {
        ($sid:expr, $t:expr) => {{
            loop {
                let cap = registry.get($sid).capacity();
                let st = &mut states[$sid.0];
                if st.busy >= cap || st.queue.is_empty() {
                    break;
                }
                let req_idx = st.queue.pop_front().unwrap();
                integrate!($sid, $t);
                states[$sid.0].busy += 1;
                let svc = registry.get_mut($sid);
                svc.telemetry.on_dispatch($t, cap as f64);
                let p = pendings[req_idx].as_mut().unwrap();
                let spec = &zoo_models[registry.get($sid).model_idx];
                // Prefix-cache lookup at dispatch: cached prompt tokens
                // skip prefill compute (and the prompt's blocks are left
                // behind for the next request, as live prefill does).
                let cached = prefix_caches[$sid.0]
                    .as_mut()
                    .map_or(0, |c| c.observe(&p.req.prompt))
                    .min(p.req.in_tokens);
                p.prefix_cached = cached;
                let mut stime = service_time_with_prefix(
                    spec,
                    registry.get($sid).backend,
                    p.req.in_tokens,
                    cached,
                    p.req.max_new_tokens,
                    &mut svc_rng,
                );
                // Speculative decoding on a paired verify tier: each
                // batched verify step lands the expected geometric run of
                // accepted draft tokens plus the correction token, so the
                // big model's decode time divides by that multiplier
                // (`spec_tokens_per_step`). Prefill is untouched — drafts
                // only ever amortize decode steps.
                if cfg.pool.speculative.pairs_with(spec.tier.index()) {
                    stime.decode_s /= spec_tokens_per_step(
                        cfg.pool.speculative.sim_accept,
                        cfg.pool.speculative.draft_tokens,
                    );
                }
                p.started_s = $t;
                p.ttft_s = ($t - p.req.arrival_s) + p.class.overhead_s + stime.prefill_s;
                p.finish_total_s = stime.total();
                events.push(
                    (($t + stime.total()) * 1e9) as u64,
                    Event::Finish { service: $sid, req: req_idx },
                );
            }
        }};
    }

    while let Some((t_ns, ev)) = events.pop() {
        let t = t_ns as f64 / 1e9;
        if t > horizon_s {
            break;
        }
        now = t;
        match ev {
            Event::Arrival(i) => {
                let req = requests[i].clone();
                let class = router.route(&req.prompt)?;
                let out_est = crate::registry::Registry::estimate_out_tokens(
                    &req.benchmark,
                    class.complexity,
                );
                let sid = match selector.select(
                    &registry,
                    &class,
                    req.in_tokens as f64,
                    out_est,
                    |s| {
                        if s.ready_replicas > 0 {
                            0.0
                        } else {
                            cluster.estimate_cold_start_s(&s.spec, s.backend)
                        }
                    },
                ) {
                    Some(s) => s,
                    None => continue,
                };
                // Learned override: the bandit's arm replaces the static
                // pick (which remains its fallback), exactly as the live
                // router thread does after `route_one`.
                let sid = match bandit.as_mut() {
                    Some((b, cells)) => {
                        let fallback =
                            zoo_models[registry.get(sid).model_idx].tier.index();
                        cells[b.select(class.complexity, fallback)]
                    }
                    None => sid,
                };
                // Overload admission (the sim analogue of the router's
                // admission gate): when enabled, an arrival that finds
                // the selected service's backlog at or past the shed
                // watermark is rejected on the spot instead of queued.
                // Deterministic — queue depth only, no RNG draw — so
                // admission off reproduces the pre-admission trace
                // bit-for-bit.
                if cfg.pool.admission.enabled {
                    let limit = ((cfg.pool.queue_capacity as f64)
                        * cfg.pool.admission.watermark.clamp(0.0, 1.0))
                    .ceil() as usize;
                    if states[sid.0].queue.len() >= limit.max(1) {
                        let svc = registry.get(sid);
                        records.push(RequestRecord {
                            benchmark: req.benchmark.clone(),
                            true_complexity: req.true_complexity,
                            predicted_complexity: class.complexity,
                            model: zoo_models[svc.model_idx].name,
                            backend: svc.backend,
                            success: false,
                            latency_s: 0.0,
                            ttft_s: 0.0,
                            wait_s: 0.0,
                            router_overhead_s: class.overhead_s,
                            cost_usd: 0.0,
                            in_tokens: req.in_tokens,
                            prefix_cached_tokens: 0,
                            spans: if cfg.pool.trace.enabled {
                                // Admit then a zero-length shed marker —
                                // the same shape a live gate rejection
                                // records.
                                let a1 = req.arrival_s + class.overhead_s;
                                vec![
                                    Span {
                                        kind: SpanKind::Admit,
                                        start_s: req.arrival_s,
                                        end_s: a1,
                                        n: 0,
                                    },
                                    Span {
                                        kind: SpanKind::Shed,
                                        start_s: a1,
                                        end_s: a1,
                                        n: 0,
                                    },
                                ]
                            } else {
                                Vec::new()
                            },
                        });
                        n_shed += 1;
                        done += 1;
                        if let Some((b, _)) = bandit.as_mut() {
                            // A shed is a real outcome for the chosen
                            // tier: zero reward, normalizers untouched.
                            b.feedback(
                                class.complexity,
                                zoo_models[svc.model_idx].tier.index(),
                                class.confidence,
                                false,
                                0.0,
                                0.0,
                            );
                        }
                        continue;
                    }
                }
                // Reactive spin-up when routed to a scaled-to-zero cell.
                if matches!(cfg.deployment, Deployment::Dynamic { .. }) {
                    let svc = registry.get(sid);
                    if svc.ready_replicas == 0 && svc.pending_replicas == 0 {
                        let (mi, spec, backend) =
                            (svc.model_idx, svc.spec.clone(), svc.backend);
                        if cluster.schedule(sid, mi, &spec, backend, t).is_some() {
                            registry.get_mut(sid).pending_replicas += 1;
                        }
                    }
                }
                pendings[i] = Some(Pending {
                    req,
                    class,
                    service: sid,
                    enqueued_s: t,
                    started_s: 0.0,
                    ttft_s: 0.0,
                    finish_total_s: 0.0,
                    prefix_cached: 0,
                });
                states[sid.0].queue.push_back(i);
                try_start!(sid, t);
            }
            Event::Finish { service, req } => {
                integrate!(service, t);
                states[service.0].busy = states[service.0].busy.saturating_sub(1);
                let cap = registry.get(service).capacity().max(1);
                let p = pendings[req].take().unwrap();
                let spec = &zoo_models[registry.get(service).model_idx];
                let backend = registry.get(service).backend;
                let latency =
                    (t - p.req.arrival_s) + p.class.overhead_s;
                let deadline_ok = latency <= cfg.deadline_s;
                let p_success = completion.success_prob(
                    &p.req.benchmark,
                    spec,
                    p.req.true_complexity,
                );
                let success = deadline_ok && svc_rng.chance(p_success);
                let sharing = (backend.max_concurrency() / 2).max(1);
                let cost = request_cost_usd(spec, backend, p.finish_total_s, sharing);
                registry.get_mut(service).telemetry.on_complete(
                    t,
                    cap as f64,
                    latency,
                    p.ttft_s,
                    success,
                );
                records.push(RequestRecord {
                    benchmark: p.req.benchmark.clone(),
                    true_complexity: p.req.true_complexity,
                    predicted_complexity: p.class.complexity,
                    model: spec.name,
                    backend,
                    success,
                    latency_s: latency,
                    ttft_s: p.ttft_s,
                    wait_s: p.started_s - p.enqueued_s,
                    router_overhead_s: p.class.overhead_s,
                    cost_usd: cost,
                    in_tokens: p.req.in_tokens,
                    prefix_cached_tokens: p.prefix_cached,
                    spans: if cfg.pool.trace.enabled {
                        sim_request_spans(
                            p.req.arrival_s,
                            p.class.overhead_s,
                            Some(p.started_s),
                            p.ttft_s,
                            latency,
                        )
                    } else {
                        Vec::new()
                    },
                });
                if let Some((b, _)) = bandit.as_mut() {
                    // Credit the serving tier with the realized outcome —
                    // the sim's exact latency and per-request dollar cost
                    // (live uses a replica-rate × latency proxy).
                    b.feedback(
                        p.class.complexity,
                        spec.tier.index(),
                        p.class.confidence,
                        success,
                        latency,
                        cost,
                    );
                }
                done += 1;
                try_start!(service, t);
            }
            Event::Control => {
                // Cluster lifecycle first.
                for ev in cluster.poll(t) {
                    apply_cluster_event(&ev, &mut registry);
                    let spawned =
                        recovery.on_events(&[ev.clone()], &mut registry, &mut cluster, t);
                    let _ = spawned;
                    if let ClusterEvent::ReplicaReady { service, .. } = ev {
                        try_start!(service, t);
                    }
                }
                // Retry scheduling for starved cells (queued work, no
                // replica, and an earlier schedule attempt failed for
                // lack of GPUs that may since have freed).
                if matches!(cfg.deployment, Deployment::Dynamic { .. }) {
                    for i in 0..registry.services.len() {
                        let sid = ServiceId(i);
                        if !states[i].queue.is_empty() {
                            let svc = registry.get(sid);
                            if svc.ready_replicas == 0 && svc.pending_replicas == 0 {
                                let (mi, spec, backend) =
                                    (svc.model_idx, svc.spec.clone(), svc.backend);
                                if cluster.schedule(sid, mi, &spec, backend, t).is_some() {
                                    registry.get_mut(sid).pending_replicas += 1;
                                }
                            }
                        }
                    }
                }
                // Alg. 1 only under dynamic orchestration. Actions are
                // applied through the Substrate trait — the same `apply`
                // the live gateway's control loop runs.
                if matches!(cfg.deployment, Deployment::Dynamic { .. }) {
                    let actions = scaler.plan(&mut registry, t);
                    crate::orchestrator::scaling::apply(
                        &actions,
                        &mut registry,
                        &mut cluster,
                        t,
                    );
                }
                if done < cfg.n_requests {
                    events.push(
                        ((t + cfg.control_period_s) * 1e9) as u64,
                        Event::Control,
                    );
                }
            }
            Event::Fail => {
                // Kill a pod of the medium-tier service (the paper's
                // recovery experiment restarts one model deployment; the
                // mid-size model is the representative case), falling
                // back to the busiest service with ready pods.
                let victim = registry
                    .services
                    .iter()
                    .filter(|s| s.ready_replicas > 0)
                    .filter(|s| s.spec.tier == crate::models::Tier::Medium)
                    .map(|s| s.id)
                    .next()
                    .or_else(|| {
                        registry
                            .services
                            .iter()
                            .filter(|s| s.ready_replicas > 0)
                            .max_by_key(|s| states[s.id.0].busy)
                            .map(|s| s.id)
                    });
                if let Some(sid) = victim {
                    if let Some(pod) = cluster.ready_pods(sid).first().copied() {
                        // Static deployments restart from an uncached image
                        // (full redeploy); evict the cache entry first.
                        if matches!(cfg.deployment, Deployment::Static) {
                            let mi = registry.get(sid).model_idx;
                            for node in &mut cluster.nodes {
                                node.image_cache.retain(|&m| m != mi);
                                node.weight_cache.retain(|&m| m != mi);
                            }
                        }
                        // Detection delay: failures surface at the next
                        // health check (instant with auto standbys).
                        let detect = if auto_recovery {
                            1.0
                        } else {
                            cfg.orchestrator.health_period_s
                        };
                        if let Some(ev) = cluster.fail(pod, t) {
                            n_failures += 1;
                            let shifted = match ev {
                                ClusterEvent::ReplicaFailed {
                                    replica, service, ..
                                } => ClusterEvent::ReplicaFailed {
                                    replica,
                                    service,
                                    at_s: t,
                                },
                                other => other,
                            };
                            // Recovery acts after the detection delay.
                            let _ = detect;
                            recovery.on_events(
                                &[shifted],
                                &mut registry,
                                &mut cluster,
                                t + detect,
                            );
                        }
                    }
                }
            }
        }
        if done >= cfg.n_requests {
            break;
        }
    }

    // Drain: anything still pending at the horizon failed its deadline.
    for p in pendings.into_iter().flatten() {
        if let Some((b, _)) = bandit.as_mut() {
            b.feedback(
                p.class.complexity,
                zoo_models[registry.get(p.service).model_idx].tier.index(),
                p.class.confidence,
                false,
                0.0,
                0.0,
            );
        }
        records.push(RequestRecord {
            benchmark: p.req.benchmark.clone(),
            true_complexity: p.req.true_complexity,
            predicted_complexity: p.class.complexity,
            model: zoo_models[registry.get(p.service).model_idx].name,
            backend: registry.get(p.service).backend,
            success: false,
            latency_s: cfg.deadline_s,
            ttft_s: cfg.deadline_s,
            wait_s: now - p.enqueued_s,
            router_overhead_s: p.class.overhead_s,
            cost_usd: 0.0,
            in_tokens: p.req.in_tokens,
            prefix_cached_tokens: p.prefix_cached,
            spans: if cfg.pool.trace.enabled {
                sim_request_spans(
                    p.req.arrival_s,
                    p.class.overhead_s,
                    (p.finish_total_s > 0.0).then_some(p.started_s),
                    p.ttft_s,
                    cfg.deadline_s,
                )
            } else {
                Vec::new()
            },
        });
    }

    // Final integrate.
    let mut busy_stream_seconds = 0.0;
    for (i, st) in states.iter_mut().enumerate() {
        if now > st.last_t {
            st.busy_integral += st.busy as f64 * (now - st.last_t);
            st.last_t = now;
        }
        let svc = registry.get(ServiceId(i));
        let conc = svc.backend.max_concurrency() as f64;
        // A replica is effectively GPU-busy once its decode batch is half
        // full (decode is memory-bandwidth-bound; extra streams in the
        // paged batch add little GPU time). Utilization = busy
        // replica-GPU-seconds / held GPU-seconds (clamped downstream).
        let replica_equiv = st.busy_integral / (conc / 2.0).max(1.0);
        busy_stream_seconds += replica_equiv * svc.spec.gpus as f64;
    }
    let gpu_held = cluster.gpu_seconds(now);
    let rate_per_gpu_s = zoo_models[0].cost_per_gpu_hour / 3600.0;
    let refinement = 0.0; // HybridRouter stats are boxed away; derive below

    Ok(SimReport {
        semantic_refinement_rate: refinement,
        duration_s: now - warmup,
        gpu_seconds_held: gpu_held,
        gpu_seconds_busy: busy_stream_seconds,
        system_cost_usd: gpu_held * rate_per_gpu_s,
        mean_recovery_s: recovery.mean_recovery_s(),
        n_failures_injected: n_failures,
        n_shed,
        bandit_arms: bandit.map(|(b, _)| b.arm_stats()).unwrap_or_default(),
        records,
    })
}

fn apply_cluster_event(ev: &ClusterEvent, registry: &mut Registry) {
    match ev {
        ClusterEvent::ReplicaReady { service, .. } => {
            let svc = registry.get_mut(*service);
            svc.pending_replicas = svc.pending_replicas.saturating_sub(1);
            svc.ready_replicas += 1;
        }
        ClusterEvent::ReplicaGone { service, .. } => {
            let svc = registry.get_mut(*service);
            svc.ready_replicas = svc.ready_replicas.saturating_sub(1);
        }
        ClusterEvent::ReplicaFailed { .. } => {
            // RecoveryManager adjusts counts/health for failures.
        }
    }
}

impl Classifier for Box<dyn Classifier> {
    fn probs(&mut self, text: &str) -> Result<[f64; 3]> {
        (**self).probs(text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::OracleClassifier;

    pub fn lib() -> TemplateLibrary {
        // The shared built-in miniature library (fast tests); the real
        // library is exercised by the integration suite.
        TemplateLibrary::synthetic()
    }

    pub fn quick_cfg() -> SimConfig {
        let mut cluster = ClusterConfig::default();
        cluster.nodes = 8; // 64 GPUs — capacity for the mixed load
        SimConfig {
            n_requests: 800,
            rate_qps: 8.0,
            cluster,
            ..SimConfig::defaults()
        }
    }

    fn oracle(lib: &TemplateLibrary, err: f64) -> Box<dyn Classifier> {
        Box::new(OracleClassifier::new(lib.clone(), err, 9))
    }

    #[test]
    fn sim_completes_all_requests() {
        let l = lib();
        let rep = run(&quick_cfg(), &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(rep.records.len(), 800);
        assert!(rep.duration_s > 0.0);
        assert!(rep.success_rate() > 0.5);
        assert!(rep.gpu_seconds_held > 0.0);
    }

    #[test]
    fn sim_is_deterministic() {
        let l = lib();
        let a = run(&quick_cfg(), &l, oracle(&l, 0.03)).unwrap();
        let b = run(&quick_cfg(), &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(a.records.len(), b.records.len());
        assert_eq!(a.success_rate(), b.success_rate());
        assert!((a.mean_latency_s() - b.mean_latency_s()).abs() < 1e-12);
    }

    #[test]
    fn multi_objective_beats_random_on_success() {
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.n_requests = 2000;
        cfg.policy = SelectionPolicy::MultiObjective;
        let smart = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        cfg.policy = SelectionPolicy::Random;
        let rand = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert!(
            smart.success_rate() > rand.success_rate(),
            "smart {:.3} vs random {:.3}",
            smart.success_rate(),
            rand.success_rate()
        );
    }

    #[test]
    fn static_deployment_costs_more_per_query() {
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.rate_qps = 5.0; // light load → idle static GPUs burn money
        cfg.n_requests = 500;
        cfg.deployment = Deployment::Static;
        cfg.policy = SelectionPolicy::RoundRobin;
        let stat = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        cfg.deployment = Deployment::Dynamic { auto_recovery: false };
        cfg.policy = SelectionPolicy::MultiObjective;
        let dynamic = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert!(
            dynamic.cost_per_query_usd() < stat.cost_per_query_usd(),
            "dynamic {:.5} vs static {:.5}",
            dynamic.cost_per_query_usd(),
            stat.cost_per_query_usd()
        );
    }

    #[test]
    fn bandit_off_by_default_and_learner_arms_when_enabled() {
        let l = lib();
        // Default config: no learner, no arm stats — the legacy trace.
        let plain = run(&quick_cfg(), &l, oracle(&l, 0.03)).unwrap();
        assert!(plain.bandit_arms.is_empty());
        // Enabled: every class accumulates selections and real feedback.
        let mut cfg = quick_cfg();
        cfg.pool.routing.bandit.enabled = true;
        let learned = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(learned.records.len(), plain.records.len());
        assert!(!learned.bandit_arms.is_empty());
        let fed: u64 = learned
            .bandit_arms
            .iter()
            .map(|a| a.successes + a.failures)
            .sum();
        assert_eq!(fed as usize, learned.records.len());
        for class in [0usize, 1, 2] {
            assert!(
                learned
                    .bandit_arms
                    .iter()
                    .any(|a| a.class == class && a.selections > 0),
                "class {class} never routed"
            );
        }
    }

    #[test]
    fn bandit_sim_is_seed_deterministic() {
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.pool.routing.bandit.enabled = true;
        let a = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        let b = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(a.records.len(), b.records.len());
        assert_eq!(a.success_rate(), b.success_rate());
        assert!((a.mean_latency_s() - b.mean_latency_s()).abs() < 1e-12);
        let key = |r: &SimReport| {
            r.bandit_arms
                .iter()
                .map(|s| (s.class, s.tier, s.selections, s.successes, s.failures))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&a), key(&b));
    }

    #[test]
    fn bandit_beats_tier_directed_on_cost_per_success() {
        // The pinned routing scenario (also the CI `-- routing` bench):
        // TierDirected statically sends every class-2 prompt to the large
        // tier — high success, very expensive. The learner discovers that
        // cheaper tiers buy more successes per dollar and shifts traffic,
        // so summed request cost per successful answer must drop.
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.n_requests = 3000;
        cfg.policy = SelectionPolicy::TierDirected;
        let stat = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        cfg.pool.routing.bandit.enabled = true;
        let learned = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert!(
            learned.cost_per_success_usd() < stat.cost_per_success_usd(),
            "bandit {:.6} vs static {:.6} $/success",
            learned.cost_per_success_usd(),
            stat.cost_per_success_usd()
        );
        assert!(
            learned.success_rate() > 0.4,
            "learned routing must still answer: {:.3}",
            learned.success_rate()
        );
    }

    #[test]
    fn failures_recover_faster_with_auto() {
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.n_requests = 3000;
        cfg.rate_qps = 20.0;
        cfg.fail_every_s = Some(30.0);
        cfg.deployment = Deployment::Static;
        cfg.policy = SelectionPolicy::RoundRobin;
        let stat = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        cfg.deployment = Deployment::Dynamic { auto_recovery: true };
        cfg.policy = SelectionPolicy::MultiObjective;
        let auto = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        let (rs, ra) = (
            stat.mean_recovery_s.unwrap_or(f64::INFINITY),
            auto.mean_recovery_s.unwrap_or(f64::INFINITY),
        );
        assert!(stat.n_failures_injected > 0);
        assert!(ra < rs, "auto {ra:.1}s vs static {rs:.1}s");
    }

    #[test]
    fn keyword_router_is_lower_overhead() {
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.router_mode = RouterMode::Keyword;
        let kw = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        cfg.router_mode = RouterMode::Semantic;
        let sem = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        let kw_overhead: f64 =
            kw.records.iter().map(|r| r.router_overhead_s).sum();
        let sem_overhead: f64 =
            sem.records.iter().map(|r| r.router_overhead_s).sum();
        assert_eq!(kw_overhead, 0.0);
        assert!(sem_overhead > 0.0);
    }

    #[test]
    fn prefix_cache_cuts_simulated_prefill_ttft() {
        // Static fleet + round-robin: service assignment is a counter,
        // so both runs route identically and differ only in prefill
        // time — cached runs can only start (FIFO) and finish earlier.
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.deployment = Deployment::Static;
        cfg.policy = SelectionPolicy::RoundRobin;
        cfg.router_mode = RouterMode::Keyword;
        cfg.static_replicas = 2;
        cfg.rate_qps = 4.0;
        cfg.n_requests = 600;
        // Template prompts are short; small blocks make their shared
        // heads (and full repeats — 2 slot values per template) cacheable.
        cfg.pool.kv_block_tokens = 2;
        cfg.pool.prefix_cache.enabled = false;
        let cold = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        cfg.pool.prefix_cache.enabled = true;
        let warm = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(cold.records.len(), warm.records.len());
        assert_eq!(cold.prefix_hit_tokens(), 0);
        assert!(warm.prefix_hit_tokens() > 0, "templated traffic must hit");
        assert!(warm.prefix_hit_token_rate() > 0.0);
        let mean_ttft = |r: &SimReport| {
            crate::util::stats::mean(
                &r.records.iter().map(|x| x.ttft_s).collect::<Vec<_>>(),
            )
        };
        assert!(
            mean_ttft(&warm) < mean_ttft(&cold),
            "warm {:.4}s vs cold {:.4}s",
            mean_ttft(&warm),
            mean_ttft(&cold)
        );
    }

    #[test]
    fn speculative_decoding_cuts_simulated_decode_latency() {
        // Static fleet + round-robin + keyword router: both runs route
        // identically and draw the same service-time jitter, so the only
        // difference is the verify tiers' decode multiplier.
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.deployment = Deployment::Static;
        cfg.policy = SelectionPolicy::RoundRobin;
        cfg.router_mode = RouterMode::Keyword;
        cfg.static_replicas = 2;
        cfg.rate_qps = 4.0;
        cfg.n_requests = 600;
        let plain = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        cfg.pool.speculative.enabled = true;
        cfg.pool.speculative.draft_tier = 0;
        cfg.pool.speculative.draft_tokens = 4;
        cfg.pool.speculative.sim_accept = 0.75;
        let spec = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(plain.records.len(), spec.records.len());
        let mean_lat = |r: &SimReport| {
            crate::util::stats::mean(
                &r.records.iter().map(|x| x.latency_s).collect::<Vec<_>>(),
            )
        };
        assert!(
            mean_lat(&spec) < mean_lat(&plain),
            "spec {:.4}s vs plain {:.4}s",
            mean_lat(&spec),
            mean_lat(&plain)
        );
        // Zero acceptance divides decode by exactly 1.0 — bit-for-bit
        // the plain run, the enabled-but-useless degenerate case.
        cfg.pool.speculative.sim_accept = 0.0;
        let zero = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(mean_lat(&zero), mean_lat(&plain));
    }

    #[test]
    fn admission_shedding_sheds_overload_and_off_is_identical() {
        // Static fleet + round-robin + keyword router: routing is a
        // counter, so every run sees the same arrival-to-service map
        // and the only difference is the admission gate.
        let l = lib();
        let mut cfg = quick_cfg();
        cfg.deployment = Deployment::Static;
        cfg.policy = SelectionPolicy::RoundRobin;
        cfg.router_mode = RouterMode::Keyword;
        cfg.static_replicas = 1;
        cfg.rate_qps = 30.0; // far past one replica per tier — queues build
        cfg.n_requests = 600;
        let plain = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(plain.n_shed, 0);
        // Enabled with an unreachable watermark: the gate never fires,
        // and the trace is bit-for-bit the admission-off run.
        cfg.pool.admission.enabled = true;
        cfg.pool.admission.watermark = 1.0;
        cfg.pool.queue_capacity = 1_000_000;
        let loose = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert_eq!(loose.n_shed, 0);
        assert_eq!(plain.records.len(), loose.records.len());
        assert_eq!(plain.success_rate(), loose.success_rate());
        assert_eq!(plain.mean_latency_s(), loose.mean_latency_s());
        // Tight watermark under the same overload: arrivals shed, and
        // every request is still accounted for exactly once.
        cfg.pool.queue_capacity = 16;
        cfg.pool.admission.watermark = 0.5;
        let shed = run(&cfg, &l, oracle(&l, 0.03)).unwrap();
        assert!(shed.n_shed > 0, "overloaded queues must shed");
        assert_eq!(shed.records.len(), plain.records.len());
        let shed_records = shed
            .records
            .iter()
            .filter(|r| !r.success && r.latency_s == 0.0 && r.cost_usd == 0.0)
            .count();
        assert!(shed_records >= shed.n_shed);
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;
    use crate::workload::OracleClassifier;

    #[test]
    fn debug_success_breakdown() {
        let l = tests::lib();
        let cfg = tests::quick_cfg();
        let rep = run(&cfg, &l, Box::new(OracleClassifier::new(l.clone(), 0.03, 9))).unwrap();
        let n = rep.records.len();
        let succ = rep.records.iter().filter(|r| r.success).count();
        let deadline_fails = rep.records.iter().filter(|r| r.latency_s >= cfg.deadline_s).count();
        let mean_wait = crate::util::stats::mean(&rep.records.iter().map(|r| r.wait_s).collect::<Vec<_>>());
        eprintln!("n={n} succ={succ} deadline_fails={deadline_fails} mean_wait={mean_wait:.2} mean_lat={:.2} dur={:.1}", rep.mean_latency_s(), rep.duration_s);
        let mut by_model = std::collections::BTreeMap::new();
        for r in &rep.records { *by_model.entry(r.model).or_insert(0usize) += 1; }
        eprintln!("{by_model:?}");
    }
}
