//! API Gateway — the entry point of Fig. 1, plus the live serving stack.
//!
//! Two layers:
//! * [`http`] — the from-scratch HTTP/1.1 substrate.
//! * [`LiveStack`] — the continuous-batching engine pool. A router thread
//!   owns the classifier (PJRT handles are not `Send`, so each thread
//!   *creates* its engines) and fans jobs out to bounded per-tier queues;
//!   N replica threads per tier each run a
//!   [`crate::backend::scheduler::Scheduler`] that drains its queue into
//!   prefill/decode batches at the compiled ladder sizes, interleaves
//!   decode across in-flight sequences, and frees slots the moment a
//!   short completion finishes. A [`PoolScaler`] parks idle replicas
//!   (scale-to-zero down to the warm-pool floor) from per-tier queue
//!   depth + slot occupancy; the next enqueue is a "cold wake".
//!
//! Requests: `POST /v1/completions {"prompt": "...", "max_tokens": N}` →
//! routed by the hybrid router, executed on the tier the matrix picks,
//! answered with token ids + timing. `GET /healthz`, `GET /metrics`.

pub mod http;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::batcher::{BatchPolicy, DECODE_BATCHES, N_DECODE_BATCHES};
use crate::backend::scheduler::{
    Admit, Finished, Scheduler, SchedulerConfig, SimStepEngine, StepEngine,
};
use crate::config::{Config, PoolConfig, RouterMode};
use crate::models::{zoo, Tier};
use crate::orchestrator::{PoolScaler, TierLoad};
use crate::registry::Registry;
use crate::router::hybrid::HybridRouter;
use crate::router::keyword::KeywordRouter;
use crate::router::{Classification, Router};
use crate::runtime::Runtime;
use crate::scoring::Weights;
use crate::util::json::Json;
use crate::util::threadpool::{Channel, OneShot};

/// A live completion response.
#[derive(Debug, Clone)]
pub struct LiveResponse {
    pub tokens: Vec<i32>,
    pub tier: String,
    pub model: &'static str,
    pub complexity: usize,
    pub confidence: f64,
    pub ttft_s: f64,
    pub latency_s: f64,
    /// Time spent in the per-tier queue before prefill started.
    pub queue_wait_s: f64,
    pub prompt_tokens: usize,
}

/// An unrouted job, as `complete()` hands it to the router thread.
struct Job {
    prompt: String,
    max_tokens: usize,
    reply: OneShot<Result<LiveResponse, String>>,
}

/// A routed job queued for one tier's replicas.
struct TierJob {
    prompt: String,
    max_tokens: usize,
    /// Seconds (pool epoch) when routing enqueued the job.
    enqueue_s: f64,
    /// Stamped at admission (prefill complete = first token).
    ttft_s: f64,
    queue_wait_s: f64,
    reply: OneShot<Result<LiveResponse, String>>,
    tier: Tier,
    model: &'static str,
    complexity: usize,
    confidence: f64,
}

/// Counters exported at `/metrics`.
#[derive(Default)]
pub struct GatewayMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub tokens_out: AtomicU64,
    /// Decode steps that ran with batch size > 1 — the proof that
    /// continuous batching actually engaged.
    pub batched: AtomicU64,
    pub decode_steps: AtomicU64,
    pub prefills: AtomicU64,
    /// Total queue-wait across requests, in microseconds (exported as
    /// `ps_queue_wait_seconds_total`).
    pub queue_wait_us: AtomicU64,
    /// Enqueues that un-parked a scaled-to-zero tier.
    pub cold_wakes: AtomicU64,
    /// Callers that gave up waiting (the work itself is not cancelled —
    /// see [`LiveStack::complete`]).
    pub timeouts: AtomicU64,
    /// Formed-batch histogram: one counter per compiled rung, in
    /// [`DECODE_BATCHES`] order.
    pub batch_counts: [AtomicU64; N_DECODE_BATCHES],
}

impl GatewayMetrics {
    /// Record one executed decode batch of size `b`.
    pub fn observe_batch(&self, b: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        if b > 1 {
            self.batched.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(i) = DECODE_BATCHES.iter().position(|&x| x == b) {
            self.batch_counts[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn add_queue_wait_s(&self, s: f64) {
        self.queue_wait_us
            .fetch_add((s.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn queue_wait_total_s(&self) -> f64 {
        self.queue_wait_us.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Per-tier pool control shared between the router (scaler) and the
/// tier's replica threads.
struct TierControl {
    /// Replicas with index < target actively pull work; the rest drain
    /// and park (scale-to-zero keeps engines warm but idle).
    target: AtomicUsize,
    /// Occupied decode slots across the tier's replicas.
    slots_in_use: AtomicUsize,
    /// Last enqueue, µs since the pool epoch (idle tracking).
    last_enqueue_us: AtomicU64,
}

/// The live serving stack: hybrid router + a continuous-batching engine
/// pool (N replica threads per compiled tier).
pub struct LiveStack {
    jobs: Channel<Job>,
    pub metrics: Arc<GatewayMetrics>,
    tier_queues: Vec<Channel<TierJob>>,
    ctls: Vec<Arc<TierControl>>,
    threads: Vec<JoinHandle<()>>,
    request_timeout_s: f64,
}

impl LiveStack {
    /// Spin up the engine pool over the compiled PJRT artifacts
    /// (compiles each tier per replica — takes a few seconds; returns
    /// after every engine is warm).
    pub fn start(cfg: &Config) -> Result<LiveStack> {
        let router_artifacts = cfg.paths.artifacts.clone();
        let router_cfg = cfg.router.clone();
        let engine_artifacts = cfg.paths.artifacts.clone();
        let max_batch = cfg.pool.max_decode_batch;
        Self::start_pool(
            cfg,
            move || {
                let mut rt = Runtime::load(&router_artifacts)
                    .map_err(|e| format!("runtime: {e:#}"))?;
                let router: Box<dyn Router> = match router_cfg.mode {
                    RouterMode::Keyword => Box::new(KeywordRouter::new()),
                    _ => {
                        let classifier = rt
                            .classifier_engine()
                            .map_err(|e| format!("classifier: {e:#}"))?;
                        Box::new(HybridRouter::new(classifier, &router_cfg))
                    }
                };
                Ok(router)
            },
            move |tier: Tier, _replica: usize| {
                let mut rt = Runtime::load(&engine_artifacts)
                    .map_err(|e| format!("runtime: {e:#}"))?;
                // Compile a *prefix* of the ladder (stop at the first
                // missing rung): the scheduler may form any compiled
                // rung ≤ its max, so a gap (say b4 absent but b8
                // present) would make it form batches the engine can't
                // execute.
                let mut ladder: Vec<usize> = Vec::new();
                for &b in DECODE_BATCHES.iter() {
                    let have = rt
                        .manifest
                        .module(&format!("lm_{}_decode_b{b}", tier.name()))
                        .is_ok();
                    if b > max_batch.max(1) || !have {
                        break;
                    }
                    ladder.push(b);
                }
                if ladder.is_empty() {
                    ladder.push(1);
                }
                rt.lm_engine(tier.name(), &ladder)
                    .map_err(|e| format!("lm {}: {e:#}", tier.name()))
            },
        )
    }

    /// The same pool wired to the deterministic synthetic engine and the
    /// keyword router — no artifacts or PJRT needed. Used by integration
    /// tests and benches to exercise queueing, batching, scaling and
    /// metrics end-to-end.
    pub fn start_sim(cfg: &Config) -> Result<LiveStack> {
        Self::start_pool(
            cfg,
            || Ok(Box::new(KeywordRouter::new()) as Box<dyn Router>),
            |_tier: Tier, _replica: usize| Ok(SimStepEngine::calibrated()),
        )
    }

    /// Generic pool bring-up: `router_factory` runs on the router thread,
    /// `engine_factory` once per replica on its own thread (PJRT objects
    /// live and die on the thread that made them).
    fn start_pool<E, RF, EF>(
        cfg: &Config,
        router_factory: RF,
        engine_factory: EF,
    ) -> Result<LiveStack>
    where
        E: StepEngine,
        RF: FnOnce() -> std::result::Result<Box<dyn Router>, String> + Send + 'static,
        EF: Fn(Tier, usize) -> std::result::Result<E, String> + Send + Sync + 'static,
    {
        let epoch = Instant::now();
        let jobs: Channel<Job> = Channel::bounded(cfg.gateway.queue_capacity);
        let metrics = Arc::new(GatewayMetrics::default());
        let tier_queues: Vec<Channel<TierJob>> = (0..3)
            .map(|_| Channel::bounded(cfg.pool.queue_capacity.max(1)))
            .collect();
        let ctls: Vec<Arc<TierControl>> = (0..3)
            .map(|i| {
                Arc::new(TierControl {
                    target: AtomicUsize::new(cfg.pool.replicas[i]),
                    slots_in_use: AtomicUsize::new(0),
                    last_enqueue_us: AtomicU64::new(0),
                })
            })
            .collect();
        let mut threads = Vec::new();
        let factory = Arc::new(engine_factory);
        let total_replicas: usize = cfg.pool.replicas.iter().sum();
        // Sized so every thread can report without blocking even when
        // start aborts early on the first failure.
        let ready: Channel<std::result::Result<(), String>> =
            Channel::bounded(total_replicas + 2);

        for (ti, &tier) in Tier::ALL.iter().enumerate() {
            for r in 0..cfg.pool.replicas[ti] {
                let ctx = ReplicaCtx {
                    index: r,
                    queue: tier_queues[ti].clone(),
                    ctl: Arc::clone(&ctls[ti]),
                    metrics: Arc::clone(&metrics),
                    epoch,
                    pool: cfg.pool.clone(),
                };
                let factory = Arc::clone(&factory);
                let ready_tx = ready.clone();
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("engine-{}-{r}", tier.name()))
                        .spawn(move || {
                            // Engines are built on this thread (not Send).
                            match (*factory)(tier, r) {
                                Ok(engine) => {
                                    let _ = ready_tx.send(Ok(()));
                                    replica_loop(engine, ctx);
                                }
                                Err(e) => {
                                    let _ = ready_tx.send(Err(e));
                                }
                            }
                        })?,
                );
            }
        }

        {
            let jobs_rx = jobs.clone();
            let tqs = tier_queues.clone();
            let ctls = ctls.clone();
            let metrics = Arc::clone(&metrics);
            let pool = cfg.pool.clone();
            let orch = cfg.orchestrator.clone();
            let profile = cfg.profile;
            let ready_tx = ready.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("router".into())
                    .spawn(move || {
                        let router = match router_factory() {
                            Ok(r) => {
                                let _ = ready_tx.send(Ok(()));
                                r
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                for q in &tqs {
                                    q.close();
                                }
                                return;
                            }
                        };
                        router_loop(
                            router, jobs_rx, tqs, ctls, metrics, epoch, pool, orch,
                            profile,
                        );
                    })?,
            );
        }

        // Wait until the router and every replica report warm (or fail).
        for _ in 0..(total_replicas + 1) {
            match ready.recv() {
                Some(Ok(())) => {}
                Some(Err(e)) => {
                    jobs.close();
                    for q in &tier_queues {
                        q.close();
                    }
                    for t in threads {
                        let _ = t.join();
                    }
                    return Err(anyhow!("engine pool failed to start: {e}"));
                }
                None => return Err(anyhow!("engine pool start interrupted")),
            }
        }
        // Sanitize: Duration::from_secs_f64 panics on negative/NaN/∞.
        let timeout = cfg.gateway.request_timeout_s;
        let request_timeout_s = if timeout.is_finite() {
            timeout.clamp(0.001, 86_400.0)
        } else {
            crate::config::GatewayConfig::default().request_timeout_s
        };
        Ok(LiveStack {
            jobs,
            metrics,
            tier_queues,
            ctls,
            threads,
            request_timeout_s,
        })
    }

    /// Serve one prompt (blocks until a replica answers or the request
    /// timeout elapses).
    ///
    /// A timeout abandons the *reply*, not the work: the sequence has no
    /// mid-flight cancellation yet, so it decodes to completion server
    /// side and still counts in `completed`/`tokens_out`; the timeout
    /// itself is counted in `timeouts`.
    pub fn complete(&self, prompt: &str, max_tokens: usize) -> Result<LiveResponse> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let reply: OneShot<Result<LiveResponse, String>> = OneShot::new();
        let job = Job {
            prompt: prompt.to_string(),
            max_tokens,
            reply: reply.clone(),
        };
        if self.jobs.try_send(job).is_err() {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("queue full (backpressure)"));
        }
        match reply.wait_timeout(Duration::from_secs_f64(self.request_timeout_s)) {
            Some(out) => out.map_err(|e| anyhow!(e)),
            None => {
                self.metrics.timeouts.fetch_add(1, Ordering::Relaxed);
                Err(anyhow!("request timed out"))
            }
        }
    }

    /// Active (unparked) replicas across all tiers — the scale-to-zero
    /// observable.
    pub fn active_replicas(&self) -> usize {
        self.ctls
            .iter()
            .map(|c| c.target.load(Ordering::Relaxed))
            .sum()
    }

    /// Occupied decode slots across the pool.
    pub fn slots_in_use(&self) -> usize {
        self.ctls
            .iter()
            .map(|c| c.slots_in_use.load(Ordering::Relaxed))
            .sum()
    }

    /// The `/metrics` exposition snapshot.
    pub fn metrics_snapshot(&self) -> Vec<(String, f64)> {
        let m = &self.metrics;
        let c = |v: &AtomicU64| v.load(Ordering::Relaxed) as f64;
        let mut out = vec![
            ("ps_requests_total".to_string(), c(&m.requests)),
            ("ps_completed_total".to_string(), c(&m.completed)),
            ("ps_errors_total".to_string(), c(&m.errors)),
            ("ps_rejected_total".to_string(), c(&m.rejected)),
            ("ps_tokens_out_total".to_string(), c(&m.tokens_out)),
            ("ps_batched_total".to_string(), c(&m.batched)),
            ("ps_decode_steps_total".to_string(), c(&m.decode_steps)),
            ("ps_prefill_total".to_string(), c(&m.prefills)),
            (
                "ps_queue_wait_seconds_total".to_string(),
                m.queue_wait_total_s(),
            ),
            ("ps_cold_wakes_total".to_string(), c(&m.cold_wakes)),
            ("ps_timeouts_total".to_string(), c(&m.timeouts)),
        ];
        for (i, &b) in DECODE_BATCHES.iter().enumerate() {
            out.push((format!("ps_decode_b{b}_total"), c(&m.batch_counts[i])));
        }
        out.push((
            "ps_queue_depth".to_string(),
            self.tier_queues.iter().map(|q| q.len()).sum::<usize>() as f64,
        ));
        out.push(("ps_slots_in_use".to_string(), self.slots_in_use() as f64));
        out.push((
            "ps_active_replicas".to_string(),
            self.active_replicas() as f64,
        ));
        out
    }

    pub fn shutdown(self) {
        // Dropping joins everything (Drop below).
    }
}

impl Drop for LiveStack {
    fn drop(&mut self) {
        self.jobs.close();
        // The router (the last thread spawned) drains buffered jobs and
        // then closes the tier queues itself — join it first so those
        // jobs route normally instead of bouncing off closed queues.
        if let Some(router) = self.threads.pop() {
            let _ = router.join();
        }
        // Normally a no-op; guarantees replica exit if the router died
        // without closing the queues.
        for q in &self.tier_queues {
            q.close();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Route one prompt against the matrix (Alg. 2): returns the execution
/// tier, the logical model picked, and the classification.
fn route_one(
    router: &mut dyn Router,
    registry: &Registry,
    weights: Weights,
    prompt: &str,
    max_tokens: usize,
) -> Result<(Tier, &'static str, Classification)> {
    let class: Classification = router.route(prompt)?;
    let in_tokens = crate::tokenizer::word_count(prompt).max(1) as f64;
    let out_est = 0.5 * max_tokens as f64;
    let sel = crate::orchestrator::select(
        registry, weights, &class, in_tokens, out_est, |_| 0.0,
    )
    .ok_or_else(|| anyhow!("no routable service"))?;
    let svc = registry.get(sel.service);
    Ok((svc.spec.tier, svc.spec.name, class))
}

/// The router thread: drain gateway jobs → classify → per-tier queues,
/// and run the pool scaler every `scale_interval_s` (also while idle, so
/// scale-to-zero fires without traffic).
#[allow(clippy::too_many_arguments)]
fn router_loop(
    mut router: Box<dyn Router>,
    jobs: Channel<Job>,
    tier_queues: Vec<Channel<TierJob>>,
    ctls: Vec<Arc<TierControl>>,
    metrics: Arc<GatewayMetrics>,
    epoch: Instant,
    pool: PoolConfig,
    orch: crate::config::OrchestratorConfig,
    profile: crate::config::Profile,
) {
    let zoo_models = zoo();
    let mut registry = Registry::new(&zoo_models, orch.telemetry_window_s);
    for s in &mut registry.services {
        // Live replicas are the pool's engine threads for that tier. A
        // tier provisioned with zero replicas can never serve: mark its
        // services unhealthy so Alg. 2 routes around them instead of
        // hard-failing every request it sends there.
        let n = pool.replicas[s.spec.tier.index()];
        s.ready_replicas = n;
        if n == 0 {
            s.health = crate::registry::Health::Unhealthy;
        }
    }
    let weights = Weights::from_profile(&profile);
    let mut scaler = PoolScaler::new(orch, pool.max_inflight);
    let mut last_scale = 0.0f64;
    loop {
        let job = jobs.recv_timeout(Duration::from_millis(100));
        let now = epoch.elapsed().as_secs_f64();
        if let Some(job) = job {
            match route_one(&mut *router, &registry, weights, &job.prompt, job.max_tokens)
            {
                Err(e) => {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    job.reply.put(Err(format!("{e:#}")));
                }
                Ok((tier, model, class)) => {
                    // Zero-replica tiers were marked Unhealthy at
                    // registry init, so Alg. 2 cannot select one here.
                    let ti = tier.index();
                    let tj = TierJob {
                        prompt: job.prompt,
                        max_tokens: job.max_tokens,
                        enqueue_s: now,
                        ttft_s: 0.0,
                        queue_wait_s: 0.0,
                        reply: job.reply,
                        tier,
                        model,
                        complexity: class.complexity,
                        confidence: class.confidence,
                    };
                    match tier_queues[ti].try_send(tj) {
                        Ok(()) => {
                            ctls[ti]
                                .last_enqueue_us
                                .store((now * 1e6) as u64, Ordering::Relaxed);
                            // Scale-from-zero: wake a parked tier now
                            // rather than waiting for the next plan.
                            if ctls[ti].target.fetch_max(1, Ordering::Relaxed) == 0 {
                                metrics.cold_wakes.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(tj) => {
                            metrics.rejected.fetch_add(1, Ordering::Relaxed);
                            tj.reply
                                .put(Err("tier queue full (backpressure)".to_string()));
                        }
                    }
                }
            }
        } else if jobs.is_closed() && jobs.is_empty() {
            break;
        }
        if now - last_scale >= pool.scale_interval_s {
            last_scale = now;
            for ti in 0..3 {
                let load = TierLoad {
                    queue_depth: tier_queues[ti].len(),
                    slots_in_use: ctls[ti].slots_in_use.load(Ordering::Relaxed),
                    active_replicas: ctls[ti].target.load(Ordering::Relaxed),
                    idle_s: now
                        - ctls[ti].last_enqueue_us.load(Ordering::Relaxed) as f64 / 1e6,
                };
                let target = scaler.target(ti, load, pool.replicas[ti], now);
                ctls[ti].target.store(target, Ordering::Relaxed);
            }
        }
    }
    for q in &tier_queues {
        q.close();
    }
}

/// Everything one replica thread needs besides its engine.
struct ReplicaCtx {
    index: usize,
    queue: Channel<TierJob>,
    ctl: Arc<TierControl>,
    metrics: Arc<GatewayMetrics>,
    epoch: Instant,
    pool: PoolConfig,
}

/// Publish this replica's slot occupancy into the tier aggregate.
fn sync_occupancy(ctl: &TierControl, reported: &mut usize, current: usize) {
    if current > *reported {
        ctl.slots_in_use
            .fetch_add(current - *reported, Ordering::Relaxed);
    } else if current < *reported {
        ctl.slots_in_use
            .fetch_sub(*reported - current, Ordering::Relaxed);
    }
    *reported = current;
}

/// Try to move one routed job into the scheduler. Returns the job back
/// when the replica has no slot/KV headroom right now.
fn admit_job<E: StepEngine>(
    sched: &mut Scheduler<E, TierJob>,
    mut job: TierJob,
    ctx: &ReplicaCtx,
) -> Option<TierJob> {
    let now = ctx.epoch.elapsed().as_secs_f64();
    let est = crate::tokenizer::word_count(&job.prompt).max(1) + 1;
    job.queue_wait_s = (now - job.enqueue_s).max(0.0);
    // The payload moves into the scheduler while the prompt is borrowed
    // for prefill; restore it if the job bounces.
    let prompt = std::mem::take(&mut job.prompt);
    match sched.admit(&prompt, job.max_tokens, est, job) {
        Admit::Admitted => {
            let done = ctx.epoch.elapsed().as_secs_f64();
            ctx.metrics.prefills.fetch_add(1, Ordering::Relaxed);
            if let Some(p) = sched.last_admitted_mut() {
                ctx.metrics.add_queue_wait_s(p.queue_wait_s);
                // Prefill produced the first token: that's TTFT.
                p.ttft_s = (done - p.enqueue_s).max(0.0);
            }
            None
        }
        Admit::Rejected(mut job) => {
            job.prompt = prompt;
            Some(job)
        }
        Admit::Failed(job, e) => {
            ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
            job.reply.put(Err(format!("admission failed: {e:#}")));
            None
        }
    }
}

/// Complete a finished request back to its caller.
fn finish_job(f: Finished<TierJob>, ctx: &ReplicaCtx) {
    let now = ctx.epoch.elapsed().as_secs_f64();
    let job = f.payload;
    ctx.metrics.completed.fetch_add(1, Ordering::Relaxed);
    ctx.metrics
        .tokens_out
        .fetch_add(f.tokens.len() as u64, Ordering::Relaxed);
    job.reply.put(Ok(LiveResponse {
        tokens: f.tokens,
        tier: job.tier.name().to_string(),
        model: job.model,
        complexity: job.complexity,
        confidence: job.confidence,
        ttft_s: job.ttft_s,
        latency_s: (now - job.enqueue_s).max(0.0),
        queue_wait_s: job.queue_wait_s,
        prompt_tokens: f.prompt_tokens,
    }));
}

/// One replica's serving loop: admit → batch-decode → retire, with
/// flush-timeout holds that wake early on new arrivals, and parking when
/// the scaler's target drops below this replica's index.
fn replica_loop<E: StepEngine>(engine: E, ctx: ReplicaCtx) {
    // Clamp the batch target to the slot count too: with fewer slots
    // than the biggest rung, a full replica could otherwise never
    // "fill" a batch and would eat the flush timeout while saturated.
    let max_batch = ctx
        .pool
        .max_decode_batch
        .min(engine.max_batch())
        .min(ctx.pool.max_inflight.max(1))
        .max(1);
    let policy = BatchPolicy::custom(max_batch, 1, ctx.pool.flush_timeout_s);
    let mut sched: Scheduler<E, TierJob> = Scheduler::new(
        engine,
        SchedulerConfig {
            policy,
            max_inflight: ctx.pool.max_inflight.max(1),
            kv_blocks: ctx.pool.kv_blocks.max(1),
            kv_block_tokens: ctx.pool.kv_block_tokens.max(1),
        },
    );
    let mut held: Option<TierJob> = None;
    let mut reported = 0usize;
    loop {
        let active = ctx.index < ctx.ctl.target.load(Ordering::Relaxed);
        // Admit as much as fits. A parked replica stops pulling from the
        // queue but still finishes a held job and drains its slots.
        if active || held.is_some() {
            loop {
                let job = match held.take().or_else(|| {
                    if active {
                        ctx.queue.try_recv()
                    } else {
                        None
                    }
                }) {
                    Some(j) => j,
                    None => break,
                };
                match admit_job(&mut sched, job, &ctx) {
                    None => continue,
                    Some(back) => {
                        held = Some(back);
                        break;
                    }
                }
            }
        }
        if sched.inflight() == 0 {
            sync_occupancy(&ctx.ctl, &mut reported, 0);
            // Break even with a job still held — the post-loop cleanup
            // fails it back to its caller instead of spinning forever.
            if ctx.queue.is_closed() && ctx.queue.is_empty() {
                break;
            }
            if active && held.is_none() {
                if let Some(j) = ctx.queue.recv_timeout(Duration::from_millis(20)) {
                    held = Some(j);
                }
            } else {
                // Parked (scale-to-zero): poll coarsely — this bounds
                // cold-wake latency at ~50 ms while keeping an idle
                // tier's CPU cost negligible. (A held job cannot persist
                // at zero inflight — admission fails unserveable
                // requests outright rather than bouncing them.)
                std::thread::sleep(Duration::from_millis(50));
            }
            continue;
        }
        match sched.tick(ctx.epoch.elapsed().as_secs_f64()) {
            Ok(tick) => {
                if tick.stepped > 0 {
                    ctx.metrics.observe_batch(tick.stepped);
                }
                for f in tick.finished {
                    finish_job(f, &ctx);
                }
                sync_occupancy(&ctx.ctl, &mut reported, sched.inflight());
                if tick.stepped == 0 {
                    if let Some(wait) = tick.wait_s {
                        // Holding for batch-mates: sleep out the flush
                        // window, but wake immediately on a new arrival.
                        let wait = Duration::from_secs_f64(wait.clamp(0.0002, 0.1));
                        if active && held.is_none() {
                            if let Some(j) = ctx.queue.recv_timeout(wait) {
                                held = Some(j);
                            }
                        } else {
                            std::thread::sleep(wait);
                        }
                    }
                }
            }
            Err(e) => {
                let msg = format!("engine step failed: {e:#}");
                for job in sched.fail_all() {
                    ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    job.reply.put(Err(msg.clone()));
                }
                sync_occupancy(&ctx.ctl, &mut reported, 0);
            }
        }
    }
    // Never strand a caller on shutdown.
    if let Some(job) = held.take() {
        job.reply.put(Err("gateway shutting down".to_string()));
    }
    for job in sched.fail_all() {
        job.reply.put(Err("gateway shutting down".to_string()));
    }
    sync_occupancy(&ctx.ctl, &mut reported, 0);
}

/// Start the HTTP gateway over a live stack. Returns the bound server.
pub fn serve_http(stack: Arc<LiveStack>, port: u16, threads: usize) -> Result<http::HttpServer> {
    http::HttpServer::start(port, threads, move |req| {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => (200, "text/plain".into(), b"ok".to_vec()),
            ("GET", "/metrics") => {
                let body =
                    crate::telemetry::export_prometheus(&stack.metrics_snapshot());
                (200, "text/plain".into(), body.into_bytes())
            }
            ("POST", "/v1/completions") => match handle_completion(&stack, req) {
                Ok(body) => (200, "application/json".into(), body.into_bytes()),
                Err(e) => (
                    500,
                    "application/json".into(),
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))])
                        .dump()
                        .into_bytes(),
                ),
            },
            _ => (404, "text/plain".into(), b"not found".to_vec()),
        }
    })
}

fn handle_completion(stack: &LiveStack, req: &http::Request) -> Result<String> {
    let j = Json::parse(req.body_str()?)?;
    let prompt = j.rstr("prompt")?;
    let max_tokens = j.usize_or("max_tokens", 16).min(64);
    let r = stack.complete(prompt, max_tokens)?;
    Ok(Json::obj(vec![
        ("model", Json::str(r.model)),
        ("tier", Json::str(r.tier.clone())),
        ("complexity", Json::num(r.complexity as f64)),
        ("confidence", Json::num(r.confidence)),
        ("ttft_s", Json::num(r.ttft_s)),
        ("latency_s", Json::num(r.latency_s)),
        ("queue_wait_s", Json::num(r.queue_wait_s)),
        ("prompt_tokens", Json::num(r.prompt_tokens as f64)),
        (
            "tokens",
            Json::arr(r.tokens.iter().map(|&t| Json::num(t as f64))),
        ),
    ])
    .dump())
}
