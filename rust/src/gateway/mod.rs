//! API Gateway — the entry point of Fig. 1, plus the live serving stack.
//!
//! Three layers:
//! * [`http`] — the from-scratch HTTP/1.1 substrate.
//! * [`pool`] — the data plane: `LocalSubstrate`, the continuous-batching
//!   engine pool behind the unified [`crate::substrate::Substrate`]
//!   trait. N replica threads per tier each run a
//!   [`crate::backend::scheduler::Scheduler`] that drains its tier queue
//!   into prefill/decode batches at the compiled ladder sizes and frees
//!   slots the moment a completion (or cancellation) finishes.
//! * [`LiveStack`] — the control plane: a router thread owns the
//!   classifier (PJRT handles are not `Send`), routes jobs to bounded
//!   per-tier queues, and drives the substrate with the *same*
//!   orchestrator the simulator uses — Alg. 1 scaling
//!   ([`crate::orchestrator::Scaler`] over observed tier load, applied
//!   through `scaling::apply`), Alg. 2 selection with substrate-measured
//!   cold starts, and the [`RecoveryManager`]: replica threads that
//!   panic, stall past the health deadline, or are killed by fault
//!   injection are detected, terminated, redeployed, and recorded as
//!   `Incident`s with measured recovery seconds exported at `/metrics`.
//!
//! Requests: `POST /v1/completions {"prompt": "...", "max_tokens": N}` →
//! routed by the hybrid router, executed on the tier the matrix picks,
//! answered with token ids + timing. `GET /healthz`, `GET /metrics`.

pub mod http;
pub(crate) mod pool;
pub mod worker;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::batcher::{DECODE_BATCHES, N_DECODE_BATCHES};
use crate::backend::scheduler::{CancelToken, SimStepEngine, StepEngine};
use crate::config::{
    Config, OrchestratorConfig, PoolConfig, Profile, RouterMode, SubstrateKind,
};
use crate::models::{zoo, Tier};
use crate::orchestrator::recovery::RecoveryManager;
use crate::orchestrator::{ScaleAction, Scaler, TierLoad};
use crate::registry::{Health, Registry, ServiceId};
use crate::router::hybrid::HybridRouter;
use crate::router::keyword::KeywordRouter;
use crate::router::{Classification, Router};
use crate::runtime::Runtime;
use crate::scoring::Weights;
use crate::substrate::nodes::NodeRegistry;
use crate::substrate::remote::{ProcessSubstrate, WorkerSpec};
use crate::substrate::Substrate;
use crate::util::json::Json;
use crate::util::threadpool::{Channel, OneShot};

use pool::{LocalSubstrate, PoolShared, ReplicaCell, TierJob, S_READY};

/// A live completion response.
#[derive(Debug, Clone)]
pub struct LiveResponse {
    pub tokens: Vec<i32>,
    pub tier: String,
    pub model: &'static str,
    pub complexity: usize,
    pub confidence: f64,
    pub ttft_s: f64,
    pub latency_s: f64,
    /// Time spent in the per-tier queue before prefill started.
    pub queue_wait_s: f64,
    pub prompt_tokens: usize,
}

/// An unrouted job, as `complete_request()` hands it to the router thread.
struct Job {
    prompt: String,
    max_tokens: usize,
    /// Session/tenant key for cache-affinity routing: requests sharing a
    /// key rendezvous on the same replica even before their prefix is
    /// cached anywhere, so the cache warms in one place.
    affinity_key: Option<String>,
    cancel: CancelToken,
    reply: OneShot<Result<LiveResponse, String>>,
}

/// One completion request, builder-style — the gateway's entry API.
///
/// ```no_run
/// # use pick_and_spin::gateway::{CompletionRequest, LiveStack};
/// # fn go(stack: &LiveStack) -> anyhow::Result<()> {
/// let r = stack.complete_request(
///     CompletionRequest::new("summarize this ticket")
///         .max_tokens(32)
///         .affinity_key("tenant-7")
///         .deadline_s(2.5),
/// )?;
/// # Ok(()) }
/// ```
///
/// `prompt` and `max_tokens` are what [`LiveStack::complete`] always
/// took; the optional fields are new: `affinity_key` steers
/// cache-affinity routing (`pool.affinity.*`), `deadline_s` overrides
/// the gateway-wide request timeout for this call, and `cancel` lets a
/// caller abort from another thread (timeout and cancel both evict the
/// sequence mid-flight, freeing its slot and KV reservation).
#[derive(Clone)]
pub struct CompletionRequest {
    pub prompt: String,
    pub max_tokens: usize,
    pub affinity_key: Option<String>,
    pub deadline_s: Option<f64>,
    pub cancel: Option<CancelToken>,
}

impl CompletionRequest {
    pub fn new(prompt: impl Into<String>) -> CompletionRequest {
        CompletionRequest {
            prompt: prompt.into(),
            max_tokens: 16,
            affinity_key: None,
            deadline_s: None,
            cancel: None,
        }
    }

    pub fn max_tokens(mut self, n: usize) -> CompletionRequest {
        self.max_tokens = n;
        self
    }

    pub fn affinity_key(mut self, key: impl Into<String>) -> CompletionRequest {
        self.affinity_key = Some(key.into());
        self
    }

    pub fn deadline_s(mut self, seconds: f64) -> CompletionRequest {
        self.deadline_s = Some(seconds);
        self
    }

    pub fn cancel_token(mut self, token: CancelToken) -> CompletionRequest {
        self.cancel = Some(token);
        self
    }
}

/// Counters exported at `/metrics`.
#[derive(Default)]
pub struct GatewayMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub tokens_out: AtomicU64,
    /// Decode steps that ran with batch size > 1 — the proof that
    /// continuous batching actually engaged.
    pub batched: AtomicU64,
    pub decode_steps: AtomicU64,
    pub prefills: AtomicU64,
    /// Prefill dispatches that covered more than one sequence (batched
    /// prefill at the ladder rungs).
    pub prefill_batched: AtomicU64,
    /// Total queue-wait across requests, in microseconds (exported as
    /// `ps_queue_wait_seconds_total`).
    pub queue_wait_us: AtomicU64,
    /// Enqueues that un-parked a scaled-to-zero tier.
    pub cold_wakes: AtomicU64,
    /// Callers that gave up waiting; their sequences are cancelled
    /// mid-flight (see `cancelled`).
    pub timeouts: AtomicU64,
    /// Sequences evicted mid-flight by their cancel token, freeing the
    /// slot early instead of decoding to completion.
    pub cancelled: AtomicU64,
    /// In-flight jobs requeued off a failed replica (drained without
    /// loss onto its replacement).
    pub requeued: AtomicU64,
    /// Failure incidents observed by the recovery manager.
    pub incidents: AtomicU64,
    /// Incidents closed by a replacement replica reaching Ready.
    pub recovered: AtomicU64,
    /// Sum of measured recovery times, µs (exported as
    /// `ps_recovery_seconds_total`).
    pub recovery_us_total: AtomicU64,
    /// Prompt tokens served from the replicas' radix prefix caches
    /// (prefill work skipped).
    pub prefix_hit_tokens: AtomicU64,
    /// Prompt tokens that had to be prefilled.
    pub prefix_miss_tokens: AtomicU64,
    /// Unreferenced prefix-cache blocks reclaimed (LRU).
    pub prefix_evicted_blocks: AtomicU64,
    /// Frames the process-substrate supervisor wrote to workers.
    pub rpc_frames_sent: AtomicU64,
    /// Frames received from workers.
    pub rpc_frames_recv: AtomicU64,
    /// Completed Ping→Pong round trips.
    pub rpc_pings: AtomicU64,
    /// Summed Ping→Pong round-trip time, µs (exported as
    /// `ps_rpc_rtt_seconds_total`; with `ps_rpc_pings_total` it yields
    /// the mean RPC latency of the process data plane).
    pub rpc_rtt_us_total: AtomicU64,
    /// Requests the affinity router placed on the replica advertising
    /// the longest matching cached prefix.
    pub affinity_hits: AtomicU64,
    /// Affinity-enabled dispatches that fell back to the shared tier
    /// queue (no match, or the matching replica was saturated).
    pub affinity_fallbacks: AtomicU64,
    /// Summed matched chain length across affinity hits, in KV blocks.
    pub affinity_match_blocks: AtomicU64,
    /// Cross-replica prefix transfers brokered (donor export → target
    /// import).
    pub kv_transfers: AtomicU64,
    /// KV blocks moved by those transfers.
    pub kv_transfer_blocks: AtomicU64,
    /// Draft tokens proposed by the speculative decode path.
    pub spec_drafted_tokens: AtomicU64,
    /// Draft tokens the verify pass accepted (landed without a big-tier
    /// decode step of their own).
    pub spec_accepted_tokens: AtomicU64,
    /// Draft tokens rejected and rolled back.
    pub spec_rejected_tokens: AtomicU64,
    /// Batched verify steps executed.
    pub spec_verify_steps: AtomicU64,
    /// Formed-batch histogram: one counter per compiled rung, in
    /// [`DECODE_BATCHES`] order.
    pub batch_counts: [AtomicU64; N_DECODE_BATCHES],
}

impl GatewayMetrics {
    /// Record one executed decode batch of size `b`.
    pub fn observe_batch(&self, b: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        if b > 1 {
            self.batched.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(i) = DECODE_BATCHES.iter().position(|&x| x == b) {
            self.batch_counts[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn add_queue_wait_s(&self, s: f64) {
        self.queue_wait_us
            .fetch_add((s.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn queue_wait_total_s(&self) -> f64 {
        self.queue_wait_us.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// The live serving stack: hybrid router + a continuous-batching engine
/// pool driven by the unified control plane.
pub struct LiveStack {
    jobs: Channel<Job>,
    pub metrics: Arc<GatewayMetrics>,
    shared: Arc<PoolShared>,
    /// Multi-host node plane, when `pool.nodes` is configured on the
    /// process substrate (per-node gauges at `/metrics`).
    nodes: Option<Arc<NodeRegistry>>,
    /// The router/control thread; it owns the substrate and joins every
    /// replica thread on shutdown.
    router: Option<JoinHandle<()>>,
    request_timeout_s: f64,
}

/// What the gateway needs from a replica substrate beyond the
/// orchestrator-facing [`Substrate`] trait: the shared pool state the
/// router samples, the canonical service per tier, warm-up blocking, and
/// teardown. Implemented by the thread pool (`LocalSubstrate`) and the
/// process supervisor (`ProcessSubstrate`) so the router/control thread
/// is written once against both data planes.
pub(crate) trait PoolBackend: Substrate + Send {
    fn pool_shared(&self) -> Arc<PoolShared>;
    fn service_of_tier(&self, tier: usize) -> ServiceId;
    fn warm(&mut self) -> std::result::Result<(), String>;
    fn stop_all(&mut self);
    /// The multi-host node registry, when this backend has one.
    fn node_registry(&self) -> Option<Arc<NodeRegistry>> {
        None
    }
}

impl<E, F> PoolBackend for LocalSubstrate<E, F>
where
    E: StepEngine,
    F: Fn(Tier, usize) -> std::result::Result<E, String> + Send + Sync + 'static,
{
    fn pool_shared(&self) -> Arc<PoolShared> {
        self.shared()
    }

    fn service_of_tier(&self, tier: usize) -> ServiceId {
        self.tier_service(tier)
    }

    fn warm(&mut self) -> std::result::Result<(), String> {
        self.wait_warm()
    }

    fn stop_all(&mut self) {
        self.shutdown();
    }
}

impl PoolBackend for ProcessSubstrate {
    fn pool_shared(&self) -> Arc<PoolShared> {
        self.shared()
    }

    fn service_of_tier(&self, tier: usize) -> ServiceId {
        self.tier_service(tier)
    }

    fn warm(&mut self) -> std::result::Result<(), String> {
        self.wait_warm()
    }

    fn stop_all(&mut self) {
        self.shutdown();
    }

    fn node_registry(&self) -> Option<Arc<NodeRegistry>> {
        self.nodes()
    }
}

/// Build one tier's compiled PJRT engine: compile a *prefix* of the
/// decode ladder (stop at the first missing rung — the scheduler may
/// form any compiled rung ≤ its max, so a gap would make it form batches
/// the engine can't execute). Shared by the thread substrate's replica
/// factories and the `ps-replica` worker's `--engine pjrt` mode.
pub fn build_pjrt_engine(
    artifacts: &str,
    tier: Tier,
    max_batch: usize,
) -> std::result::Result<crate::runtime::LmEngine, String> {
    let mut rt = Runtime::load(artifacts).map_err(|e| format!("runtime: {e:#}"))?;
    let mut ladder: Vec<usize> = Vec::new();
    for &b in DECODE_BATCHES.iter() {
        let have = rt
            .manifest
            .module(&format!("lm_{}_decode_b{b}", tier.name()))
            .is_ok();
        if b > max_batch.max(1) || !have {
            break;
        }
        ladder.push(b);
    }
    if ladder.is_empty() {
        ladder.push(1);
    }
    rt.lm_engine(tier.name(), &ladder)
        .map_err(|e| format!("lm {}: {e:#}", tier.name()))
}

impl LiveStack {
    /// Spin up the engine pool over the compiled PJRT artifacts
    /// (compiles each tier per replica — takes a few seconds; returns
    /// after every engine is warm).
    pub fn start(cfg: &Config) -> Result<LiveStack> {
        let router_artifacts = cfg.paths.artifacts.clone();
        let router_cfg = cfg.router.clone();
        let engine_artifacts = cfg.paths.artifacts.clone();
        let max_batch = cfg.pool.max_decode_batch;
        Self::start_pool(
            cfg,
            move || {
                let mut rt = Runtime::load(&router_artifacts)
                    .map_err(|e| format!("runtime: {e:#}"))?;
                let router: Box<dyn Router> = match router_cfg.mode {
                    RouterMode::Keyword => Box::new(KeywordRouter::new()),
                    _ => {
                        let classifier = rt
                            .classifier_engine()
                            .map_err(|e| format!("classifier: {e:#}"))?;
                        Box::new(HybridRouter::new(classifier, &router_cfg))
                    }
                };
                Ok(router)
            },
            move |tier: Tier, _replica: usize| {
                build_pjrt_engine(&engine_artifacts, tier, max_batch)
            },
            &["--engine", "pjrt", "--artifacts", cfg.paths.artifacts.as_str()],
        )
    }

    /// The same pool wired to the deterministic synthetic engine and the
    /// keyword router — no artifacts or PJRT needed. Used by integration
    /// tests and benches to exercise queueing, batching, scaling,
    /// recovery and metrics end-to-end. With `pool.substrate = "process"`
    /// the workers run `ps-replica --engine sim`, so the whole RPC data
    /// plane is exercised hermetically too.
    pub fn start_sim(cfg: &Config) -> Result<LiveStack> {
        let spec = cfg.pool.speculative;
        Self::start_pool(
            cfg,
            || Ok(Box::new(KeywordRouter::new()) as Box<dyn Router>),
            move |tier: Tier, replica: usize| {
                let mut e = SimStepEngine::calibrated();
                if spec.enabled {
                    // Deterministic per-replica verdict stream at the
                    // configured acceptance rate (pool.speculative
                    // .sim_accept). Harmless on unpaired tiers — their
                    // schedulers run with speculation disabled and never
                    // call verify_batch.
                    let seed =
                        0x5BEC ^ ((tier.index() as u64) << 32) ^ replica as u64;
                    e = e.with_acceptance(spec.sim_accept, seed);
                }
                Ok(e)
            },
            &["--engine", "sim"],
        )
    }

    /// Generic pool bring-up: `router_factory` runs on the router thread;
    /// `engine_factory` once per replica on its own thread (PJRT objects
    /// live and die on the thread that made them) for the thread
    /// substrate, while the process substrate spawns `ps-replica`
    /// workers with `worker_engine_args` instead.
    fn start_pool<E, RF, EF>(
        cfg: &Config,
        router_factory: RF,
        engine_factory: EF,
        worker_engine_args: &[&str],
    ) -> Result<LiveStack>
    where
        E: StepEngine,
        RF: FnOnce() -> std::result::Result<Box<dyn Router>, String> + Send + 'static,
        EF: Fn(Tier, usize) -> std::result::Result<E, String> + Send + Sync + 'static,
    {
        let epoch = Instant::now();
        let jobs: Channel<Job> = Channel::bounded(cfg.gateway.queue_capacity);
        let metrics = Arc::new(GatewayMetrics::default());
        let shared = Arc::new(PoolShared::new(epoch, cfg.pool.queue_capacity));
        let zoo_models = zoo();
        let registry = Registry::new(&zoo_models, cfg.orchestrator.telemetry_window_s);
        match cfg.pool.substrate {
            SubstrateKind::Thread => {
                let substrate = LocalSubstrate::new(
                    Arc::clone(&shared),
                    cfg.pool.clone(),
                    Arc::clone(&metrics),
                    engine_factory,
                    &registry,
                );
                Self::finish_start(cfg, router_factory, substrate, registry, shared, metrics, jobs)
            }
            SubstrateKind::Process => {
                let spec = WorkerSpec::from_pool(&cfg.pool, worker_engine_args)
                    .map_err(|e| anyhow!("process substrate: {e}"))?;
                // Bring the node plane up (dial static agents, bind the
                // registration listener) before any replica provisions,
                // so placement sees the fleet. A bad node config is a
                // startup error, not a silently single-host pool.
                let nodes = NodeRegistry::from_config(&cfg.pool.nodes)
                    .map_err(|e| anyhow!("process substrate: {e}"))?;
                let substrate = ProcessSubstrate::new(
                    Arc::clone(&shared),
                    cfg.pool.clone(),
                    Arc::clone(&metrics),
                    spec,
                    &registry,
                    nodes,
                );
                Self::finish_start(cfg, router_factory, substrate, registry, shared, metrics, jobs)
            }
        }
    }

    /// Substrate-agnostic bring-up: provision the initial fleet through
    /// the same lifecycle every later replica takes (the measured cold
    /// starts seed Alg. 2's scaled-to-zero estimates), wait until every
    /// replica is warm, then hand the substrate to the router thread.
    fn finish_start<S, RF>(
        cfg: &Config,
        router_factory: RF,
        mut substrate: S,
        registry: Registry,
        shared: Arc<PoolShared>,
        metrics: Arc<GatewayMetrics>,
        jobs: Channel<Job>,
    ) -> Result<LiveStack>
    where
        S: PoolBackend + 'static,
        RF: FnOnce() -> std::result::Result<Box<dyn Router>, String> + Send + 'static,
    {
        let nodes = substrate.node_registry();
        let requested: usize = cfg.pool.replicas.iter().sum();
        let mut provisioned = 0usize;
        for ti in 0..3 {
            let sid = substrate.service_of_tier(ti);
            let (mi, spec, backend) = {
                let s = registry.get(sid);
                (s.model_idx, s.spec.clone(), s.backend)
            };
            for _ in 0..cfg.pool.replicas[ti] {
                if substrate.provision(sid, mi, &spec, backend, 0.0).is_some() {
                    provisioned += 1;
                }
            }
        }
        if provisioned == 0 && requested > 0 {
            // A fleet that failed to even spawn (bad worker binary, say)
            // must be a startup error, not a pool that times out every
            // request.
            substrate.stop_all();
            return Err(anyhow!(
                "engine pool failed to start: no replica could be provisioned"
            ));
        }
        if let Err(e) = substrate.warm() {
            substrate.stop_all();
            return Err(anyhow!("engine pool failed to start: {e}"));
        }

        let ready: Channel<std::result::Result<(), String>> = Channel::bounded(2);
        let router_handle = {
            let jobs_rx = jobs.clone();
            let metrics = Arc::clone(&metrics);
            let pool_cfg = cfg.pool.clone();
            let orch = cfg.orchestrator.clone();
            let profile = cfg.profile;
            let ready_tx = ready.clone();
            std::thread::Builder::new().name("router".into()).spawn(move || {
                let router = match router_factory() {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        substrate.stop_all();
                        return;
                    }
                };
                router_loop(
                    router, jobs_rx, substrate, registry, metrics, pool_cfg, orch,
                    profile,
                );
            })?
        };
        match ready.recv() {
            Some(Ok(())) => {}
            Some(Err(e)) => {
                jobs.close();
                let _ = router_handle.join();
                return Err(anyhow!("engine pool failed to start: {e}"));
            }
            None => return Err(anyhow!("engine pool start interrupted")),
        }
        // Sanitize: Duration::from_secs_f64 panics on negative/NaN/∞.
        let timeout = cfg.gateway.request_timeout_s;
        let request_timeout_s = if timeout.is_finite() {
            timeout.clamp(0.001, 86_400.0)
        } else {
            crate::config::GatewayConfig::default().request_timeout_s
        };
        Ok(LiveStack {
            jobs,
            metrics,
            shared,
            nodes,
            router: Some(router_handle),
            request_timeout_s,
        })
    }

    /// Serve one request (blocks until a replica answers, the deadline
    /// elapses, or the caller's cancel token fires).
    ///
    /// A timeout fires the job's cancel token: the sequence is evicted
    /// at the scheduler's next tick, freeing its slot and KV reservation
    /// early instead of decoding to completion (`ps_cancelled_total`
    /// counts the evictions, `ps_timeouts_total` the abandonments).
    pub fn complete_request(&self, req: CompletionRequest) -> Result<LiveResponse> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let reply: OneShot<Result<LiveResponse, String>> = OneShot::new();
        let cancel = req.cancel.unwrap_or_else(CancelToken::new);
        // A per-request deadline overrides the gateway-wide timeout;
        // same sanitization (from_secs_f64 panics on negative/NaN/∞).
        let timeout_s = match req.deadline_s {
            Some(d) if d.is_finite() => d.clamp(0.001, 86_400.0),
            _ => self.request_timeout_s,
        };
        let job = Job {
            prompt: req.prompt,
            max_tokens: req.max_tokens,
            affinity_key: req.affinity_key,
            cancel: cancel.clone(),
            reply: reply.clone(),
        };
        if self.jobs.try_send(job).is_err() {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("queue full (backpressure)"));
        }
        match reply.wait_timeout(Duration::from_secs_f64(timeout_s)) {
            Some(out) => out.map_err(|e| anyhow!(e)),
            None => {
                self.metrics.timeouts.fetch_add(1, Ordering::Relaxed);
                cancel.cancel();
                Err(anyhow!("request timed out"))
            }
        }
    }

    /// Positional back-compat wrapper over [`Self::complete_request`].
    pub fn complete(&self, prompt: &str, max_tokens: usize) -> Result<LiveResponse> {
        self.complete_request(CompletionRequest::new(prompt).max_tokens(max_tokens))
    }

    /// Live (provisioned) replicas across all tiers — the scale-to-zero
    /// observable. Counts Scheduled/Loading/Ready; terminated replicas
    /// leave the count the moment they drain.
    pub fn active_replicas(&self) -> usize {
        self.shared.live_total()
    }

    /// Occupied decode slots across the pool.
    pub fn slots_in_use(&self) -> usize {
        self.shared.slots_in_use()
    }

    /// Fault-injection hook for recovery experiments: abruptly kill one
    /// Ready replica of `tier` (0 = small, 1 = medium, 2 = large). On
    /// the thread substrate the replica dies at its next heartbeat; on
    /// the process substrate its worker is SIGKILLed — a true `kill -9`.
    /// Its in-flight jobs requeue, the control plane records an
    /// `Incident` and redeploys. Returns whether a victim existed.
    pub fn inject_replica_failure(&self, tier: usize) -> bool {
        self.shared.inject_failure(tier.min(2))
    }

    /// Graceful-drain hook: one Ready replica of `tier` stops pulling
    /// work, hands its buffered jobs back through the requeue path,
    /// finishes its decoding slots, and exits — the scale-down path,
    /// triggerable deterministically for tests. Returns whether a victim
    /// existed.
    pub fn drain_replica(&self, tier: usize) -> bool {
        self.shared.drain_one(tier.min(2))
    }

    /// The `/metrics` exposition snapshot.
    pub fn metrics_snapshot(&self) -> Vec<(String, f64)> {
        let m = &self.metrics;
        let c = |v: &AtomicU64| v.load(Ordering::Relaxed) as f64;
        let mut out = vec![
            ("ps_requests_total".to_string(), c(&m.requests)),
            ("ps_completed_total".to_string(), c(&m.completed)),
            ("ps_errors_total".to_string(), c(&m.errors)),
            ("ps_rejected_total".to_string(), c(&m.rejected)),
            ("ps_tokens_out_total".to_string(), c(&m.tokens_out)),
            ("ps_batched_total".to_string(), c(&m.batched)),
            ("ps_decode_steps_total".to_string(), c(&m.decode_steps)),
            ("ps_prefill_total".to_string(), c(&m.prefills)),
            ("ps_prefill_batched_total".to_string(), c(&m.prefill_batched)),
            (
                "ps_queue_wait_seconds_total".to_string(),
                m.queue_wait_total_s(),
            ),
            ("ps_cold_wakes_total".to_string(), c(&m.cold_wakes)),
            ("ps_timeouts_total".to_string(), c(&m.timeouts)),
            ("ps_cancelled_total".to_string(), c(&m.cancelled)),
            ("ps_requeued_total".to_string(), c(&m.requeued)),
            ("ps_incidents_total".to_string(), c(&m.incidents)),
            ("ps_recovered_total".to_string(), c(&m.recovered)),
            (
                "ps_recovery_seconds_total".to_string(),
                m.recovery_us_total.load(Ordering::Relaxed) as f64 / 1e6,
            ),
            (
                "ps_prefix_hit_tokens_total".to_string(),
                c(&m.prefix_hit_tokens),
            ),
            (
                "ps_prefix_miss_tokens_total".to_string(),
                c(&m.prefix_miss_tokens),
            ),
            (
                "ps_prefix_evicted_blocks_total".to_string(),
                c(&m.prefix_evicted_blocks),
            ),
            (
                "ps_rpc_frames_sent_total".to_string(),
                c(&m.rpc_frames_sent),
            ),
            (
                "ps_rpc_frames_recv_total".to_string(),
                c(&m.rpc_frames_recv),
            ),
            ("ps_rpc_pings_total".to_string(), c(&m.rpc_pings)),
            (
                "ps_rpc_rtt_seconds_total".to_string(),
                m.rpc_rtt_us_total.load(Ordering::Relaxed) as f64 / 1e6,
            ),
            ("ps_affinity_hit_total".to_string(), c(&m.affinity_hits)),
            (
                "ps_affinity_fallback_total".to_string(),
                c(&m.affinity_fallbacks),
            ),
            (
                "ps_affinity_match_blocks_total".to_string(),
                c(&m.affinity_match_blocks),
            ),
            ("ps_kv_transfer_total".to_string(), c(&m.kv_transfers)),
            (
                "ps_kv_transfer_blocks_total".to_string(),
                c(&m.kv_transfer_blocks),
            ),
            (
                "ps_spec_drafted_tokens_total".to_string(),
                c(&m.spec_drafted_tokens),
            ),
            (
                "ps_spec_accepted_tokens_total".to_string(),
                c(&m.spec_accepted_tokens),
            ),
            (
                "ps_spec_rejected_tokens_total".to_string(),
                c(&m.spec_rejected_tokens),
            ),
            (
                "ps_spec_verify_steps_total".to_string(),
                c(&m.spec_verify_steps),
            ),
        ];
        for (i, &b) in DECODE_BATCHES.iter().enumerate() {
            out.push((format!("ps_decode_b{b}_total"), c(&m.batch_counts[i])));
        }
        out.push((
            "ps_queue_depth".to_string(),
            self.shared.queues.iter().map(|q| q.len()).sum::<usize>() as f64,
        ));
        out.push((
            "ps_prefix_cache_blocks".to_string(),
            self.shared.prefix_cache_blocks() as f64,
        ));
        out.push(("ps_slots_in_use".to_string(), self.slots_in_use() as f64));
        out.push((
            "ps_active_replicas".to_string(),
            self.active_replicas() as f64,
        ));
        // Per-replica affinity placement series (one family at a time —
        // the exposition format wants samples of a family contiguous).
        // Quiet with affinity off: counters only move when the affinity
        // router places work.
        let mut hit_series = Vec::new();
        let mut match_series = Vec::new();
        for (ti, tier) in Tier::ALL.iter().enumerate() {
            for (id, cell) in self.shared.cells[ti].lock().unwrap().iter() {
                let h = cell.affinity_hits.load(Ordering::Relaxed);
                let b = cell.affinity_match_blocks.load(Ordering::Relaxed);
                if h == 0 && b == 0 {
                    continue;
                }
                let labels = format!("tier=\"{}\",replica=\"{}\"", tier.name(), id.0);
                hit_series
                    .push((format!("ps_replica_affinity_hits{{{labels}}}"), h as f64));
                match_series.push((
                    format!("ps_replica_affinity_match_blocks{{{labels}}}"),
                    b as f64,
                ));
            }
        }
        out.extend(hit_series);
        out.extend(match_series);
        // Per-tier cumulative speculative acceptance rate. Quiet with
        // speculation off: a tier that never drafted has no sample.
        for (ti, tier) in Tier::ALL.iter().enumerate() {
            let (accepted, drafted) = self.shared.tier_spec_totals(ti);
            if drafted == 0 {
                continue;
            }
            out.push((
                format!("ps_spec_accept_rate{{tier=\"{}\"}}", tier.name()),
                accepted as f64 / drafted as f64,
            ));
        }
        if let Some(reg) = &self.nodes {
            out.push(("ps_node_lost_total".to_string(), reg.lost_total() as f64));
            // One pass per family: the Prometheus exposition format
            // requires all samples of a metric in one contiguous group.
            // Node names are operator input (`ps-node --name`) — escape
            // them, or one hostile name breaks the whole exposition.
            let nodes: Vec<_> = reg
                .snapshot()
                .into_iter()
                .map(|n| (prom_label_escape(&n.name), n))
                .collect();
            for (name, n) in &nodes {
                out.push((
                    format!("ps_node_replicas{{node=\"{name}\"}}"),
                    n.hosted as f64,
                ));
            }
            for (name, n) in &nodes {
                out.push((
                    format!("ps_node_capacity{{node=\"{name}\"}}"),
                    n.slots as f64,
                ));
            }
            for (name, n) in &nodes {
                out.push((
                    format!("ps_node_up{{node=\"{name}\"}}"),
                    if n.alive { 1.0 } else { 0.0 },
                ));
            }
        }
        out
    }

    /// Per-node placement/liveness view (`None` unless `pool.nodes` is
    /// configured on the process substrate).
    pub fn node_registry(&self) -> Option<Arc<NodeRegistry>> {
        self.nodes.as_ref().map(Arc::clone)
    }

    pub fn shutdown(self) {
        // Dropping joins everything (Drop below).
    }
}

/// Escape a string for use as a Prometheus label value (the exposition
/// format requires `\\`, `\"`, and `\n` escapes).
fn prom_label_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

impl Drop for LiveStack {
    fn drop(&mut self) {
        self.jobs.close();
        // The router drains buffered jobs, then shuts the substrate down
        // (closing tier queues and joining every replica thread).
        if let Some(router) = self.router.take() {
            let _ = router.join();
        }
    }
}

/// Route one prompt against the matrix (Alg. 2): returns the execution
/// tier, the logical model picked, and the classification. Cold-start
/// penalties come from the substrate's measured provision→Ready times.
fn route_one(
    router: &mut dyn Router,
    registry: &Registry,
    substrate: &dyn Substrate,
    weights: Weights,
    prompt: &str,
    max_tokens: usize,
) -> Result<(Tier, &'static str, Classification)> {
    let class: Classification = router.route(prompt)?;
    let in_tokens = crate::tokenizer::word_count(prompt).max(1) as f64;
    let out_est = 0.5 * max_tokens as f64;
    let sel = crate::orchestrator::select_on(
        registry, substrate, weights, &class, in_tokens, out_est,
    )
    .ok_or_else(|| anyhow!("no routable service"))?;
    let svc = registry.get(sel.service);
    Ok((svc.spec.tier, svc.spec.name, class))
}

/// Mirror the substrate's per-tier replica counts into every service of
/// the registry (the live registry is a routing view; replica state is
/// owned by the substrate). A tier with a zero thread budget can never
/// serve and is marked Unhealthy so Alg. 2 routes around it.
fn sync_registry(registry: &mut Registry, shared: &PoolShared, pool: &PoolConfig) {
    for ti in 0..3 {
        let health = if pool.replicas[ti] == 0 {
            Health::Unhealthy
        } else {
            Health::Healthy
        };
        registry.set_tier_state(
            ti,
            shared.ready_count(ti),
            shared.pending_count(ti),
            health,
        );
    }
}

/// Token cap when scoring a prompt for affinity. Chain hashes are
/// cumulative per block, so truncation never produces a *wrong* match —
/// it only stops scoring extremely long prompts past this depth.
const AFFINITY_SCORE_TOKEN_CAP: usize = 4096;

/// FNV-1a over a session key (rendezvous placement for keys whose
/// prefix isn't cached anywhere yet).
fn session_hash(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Cache-affinity dispatch (`pool.affinity.enabled`): score the prompt's
/// block-hash chain against every ready replica's advertised hot-prefix
/// summary and place the job on the longest match's private queue. On a
/// saturated match the job goes to the least-loaded replica instead and
/// a prefix transfer is brokered so the blocks follow it. Requests with
/// no match but a session key rendezvous on a stable replica so their
/// cache warms in one place. Returns the job back when nothing could be
/// placed directly — the caller takes the legacy tier-queue path.
fn affinity_place(
    shared: &PoolShared,
    pool: &PoolConfig,
    metrics: &GatewayMetrics,
    ti: usize,
    affinity_key: Option<&str>,
    mut tj: TierJob,
) -> Option<TierJob> {
    let aff = &pool.affinity;
    let cells: Vec<Arc<ReplicaCell>> = shared.cells[ti]
        .lock()
        .unwrap()
        .iter()
        .filter(|(_, c)| {
            c.state.load(Ordering::Acquire) == S_READY
                && !c.stop.load(Ordering::Relaxed)
        })
        .map(|(_, c)| Arc::clone(c))
        .collect();
    if cells.is_empty() {
        metrics.affinity_fallbacks.fetch_add(1, Ordering::Relaxed);
        return Some(tj);
    }
    // The prompt's cumulative block-boundary chain hashes — the same
    // chain the replicas' radix caches key on, so an advertised
    // `(tip, len)` matches iff our hash at `len` blocks equals the tip.
    let bt = pool.kv_block_tokens.max(1);
    let ids = crate::tokenizer::prompt_ids(&tj.prompt, AFFINITY_SCORE_TOKEN_CAP);
    let mut hashes: Vec<u64> = Vec::with_capacity(ids.len() / bt);
    let mut ph = crate::backend::kv_cache::ROOT_HASH;
    for chunk in ids.chunks_exact(bt) {
        ph = crate::backend::kv_cache::chain_hash(ph, chunk);
        hashes.push(ph);
    }
    // Longest advertised match across the tier's ready replicas.
    let mut best: Option<(usize, u32, u64)> = None; // (cell, len, tip)
    for (i, c) in cells.iter().enumerate() {
        for &(tip, len) in c.hot.lock().unwrap().iter() {
            let l = len as usize;
            if l >= 1
                && l <= hashes.len()
                && hashes[l - 1] == tip
                && best.map(|(_, bl, _)| len > bl).unwrap_or(true)
            {
                best = Some((i, len, tip));
            }
        }
    }
    match best.filter(|&(_, l, _)| l as usize >= aff.min_match_blocks.max(1)) {
        Some((bi, len, tip)) => {
            match cells[bi].direct.try_send(tj) {
                Ok(()) => {
                    metrics.affinity_hits.fetch_add(1, Ordering::Relaxed);
                    metrics
                        .affinity_match_blocks
                        .fetch_add(len as u64, Ordering::Relaxed);
                    cells[bi].affinity_hits.fetch_add(1, Ordering::Relaxed);
                    cells[bi]
                        .affinity_match_blocks
                        .fetch_add(len as u64, Ordering::Relaxed);
                    return None;
                }
                Err(back) => {
                    // The hot replica is saturated: pick the least-loaded
                    // peer and broker a transfer so the prefix follows
                    // the job instead of being recomputed.
                    tj = back;
                    if aff.transfer && cells.len() > 1 {
                        let (tix, _) = cells
                            .iter()
                            .enumerate()
                            .filter(|(i, _)| *i != bi)
                            .min_by_key(|(_, c)| c.inflight.load(Ordering::Relaxed))
                            .expect("len > 1 after excluding one");
                        cells[bi]
                            .fetch_reqs
                            .lock()
                            .unwrap()
                            .push((tip, Arc::clone(&cells[tix])));
                        match cells[tix].direct.try_send(tj) {
                            Ok(()) => {
                                metrics
                                    .affinity_fallbacks
                                    .fetch_add(1, Ordering::Relaxed);
                                return None;
                            }
                            Err(back) => tj = back,
                        }
                    }
                }
            }
        }
        None => {
            if let Some(key) = affinity_key {
                // No cached match anywhere: rendezvous on a stable
                // replica for this key. Counted as a fallback — it is a
                // placement bet, not a cache hit.
                let i = (session_hash(key) % cells.len() as u64) as usize;
                match cells[i].direct.try_send(tj) {
                    Ok(()) => {
                        metrics.affinity_fallbacks.fetch_add(1, Ordering::Relaxed);
                        return None;
                    }
                    Err(back) => tj = back,
                }
            }
        }
    }
    metrics.affinity_fallbacks.fetch_add(1, Ordering::Relaxed);
    Some(tj)
}

/// Scale-from-zero: provision one replica for a tier that has queued
/// work but no live capacity (counted as a cold wake).
fn cold_wake<S: PoolBackend>(
    substrate: &mut S,
    registry: &mut Registry,
    metrics: &GatewayMetrics,
    shared: &PoolShared,
    ti: usize,
    now_s: f64,
) {
    let sid = substrate.service_of_tier(ti);
    {
        // `apply` provisions up from the registry's current counts;
        // refresh them for the canonical cell first.
        let svc = registry.get_mut(sid);
        svc.ready_replicas = shared.ready_count(ti);
        svc.pending_replicas = shared.pending_count(ti);
    }
    let spawned = crate::orchestrator::scaling::apply(
        &[ScaleAction::Up { service: sid, target: 1 }],
        registry,
        substrate,
        now_s,
    );
    if !spawned.is_empty() {
        metrics.cold_wakes.fetch_add(1, Ordering::Relaxed);
    }
}

/// The router/control thread: drain gateway jobs → classify → per-tier
/// queues, and every `scale_interval_s` run one control pass — substrate
/// lifecycle poll → recovery → Alg. 1 per tier — also while idle, so
/// scale-to-zero fires without traffic.
#[allow(clippy::too_many_arguments)]
fn router_loop<S: PoolBackend>(
    mut router: Box<dyn Router>,
    jobs: Channel<Job>,
    mut substrate: S,
    mut registry: Registry,
    metrics: Arc<GatewayMetrics>,
    pool: PoolConfig,
    orch: OrchestratorConfig,
    profile: Profile,
) {
    let shared = substrate.pool_shared();
    let weights = Weights::from_profile(&profile);
    // Alg. 1 over the three tiers, demand = queue depth + slot occupancy.
    let mut scaler = Scaler::for_pool(orch, 3, pool.max_inflight.max(1));
    let mut recovery = RecoveryManager::new(true);
    sync_registry(&mut registry, &shared, &pool);
    let mut last_ctl = f64::NEG_INFINITY;
    // Last-sampled per-tier prefix hit/miss totals: successive deltas
    // give a per-interval hit rate (recent traffic only).
    let mut prefix_last: [(u64, u64); 3] = [(0, 0); 3];
    // Same windowing for speculative accepted/drafted token totals — the
    // scaler's acceptance-rate demand discount tracks recent traffic.
    let mut spec_last: [(u64, u64); 3] = [(0, 0); 3];
    loop {
        let job = jobs.recv_timeout(Duration::from_millis(100));
        let now = shared.epoch.elapsed().as_secs_f64();
        if let Some(job) = job {
            if job.cancel.is_cancelled() {
                // The caller gave up while the job sat in the gateway
                // queue; don't spend routing on it.
                metrics.cancelled.fetch_add(1, Ordering::Relaxed);
            } else {
                match route_one(
                    &mut *router,
                    &registry,
                    &substrate,
                    weights,
                    &job.prompt,
                    job.max_tokens,
                ) {
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        job.reply.put(Err(format!("{e:#}")));
                    }
                    Ok((tier, model, class)) => {
                        // Zero-budget tiers are Unhealthy in the synced
                        // registry, so Alg. 2 cannot select one here.
                        let ti = tier.index();
                        let tj = TierJob {
                            prompt: job.prompt,
                            max_tokens: job.max_tokens,
                            enqueue_s: now,
                            ttft_s: 0.0,
                            queue_wait_s: 0.0,
                            counted_wait_s: 0.0,
                            reply: job.reply,
                            cancel: job.cancel,
                            tier,
                            model,
                            complexity: class.complexity,
                            confidence: class.confidence,
                        };
                        // Cache-affinity placement first (off = the
                        // exact legacy tier fan-out below, bit for bit).
                        let pending = if pool.affinity.enabled {
                            affinity_place(
                                &shared,
                                &pool,
                                &metrics,
                                ti,
                                job.affinity_key.as_deref(),
                                tj,
                            )
                        } else {
                            Some(tj)
                        };
                        match pending {
                            None => {
                                // Placed on a ready replica's private
                                // queue; ready ⇒ the tier is live, no
                                // cold wake to consider.
                                shared.last_enqueue_us[ti]
                                    .store((now * 1e6) as u64, Ordering::Relaxed);
                            }
                            Some(tj) => match shared.queues[ti].try_send(tj) {
                                Ok(()) => {
                                    shared.last_enqueue_us[ti]
                                        .store((now * 1e6) as u64, Ordering::Relaxed);
                                    if shared.live_count(ti) == 0 {
                                        cold_wake(
                                            &mut substrate,
                                            &mut registry,
                                            &metrics,
                                            &shared,
                                            ti,
                                            now,
                                        );
                                    }
                                }
                                Err(tj) => {
                                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                                    tj.reply.put(Err(
                                        "tier queue full (backpressure)".to_string(),
                                    ));
                                }
                            },
                        }
                    }
                }
            }
        } else if jobs.is_closed() && jobs.is_empty() {
            break;
        }
        if now - last_ctl >= pool.scale_interval_s {
            last_ctl = now;
            // Lifecycle first: failures (panic / stall / injected) become
            // Incidents; recovery redeploys through the Substrate trait —
            // the same code path the simulator's Table 4 runs take.
            let events = substrate.poll(now);
            if !events.is_empty() {
                recovery.on_events(&events, &mut registry, &mut substrate, now);
            }
            metrics
                .incidents
                .store(recovery.incidents.len() as u64, Ordering::Relaxed);
            metrics
                .recovered
                .store(recovery.recovered() as u64, Ordering::Relaxed);
            metrics.recovery_us_total.store(
                (recovery.total_recovery_s() * 1e6) as u64,
                Ordering::Relaxed,
            );
            sync_registry(&mut registry, &shared, &pool);
            // Draft-tier availability for the speculative path: verify
            // tiers fall back to plain decode (loss-free) whenever the
            // draft tier is parked, unhealthy, or saturated. Published
            // once per control pass; the thread substrate's replica
            // loops sample the flag directly, the process substrate's
            // pumps relay edges as SpecDraft frames.
            if pool.speculative.enabled {
                let dt = pool.speculative.draft_tier.min(2);
                let ready = shared.ready_count(dt);
                let ok = registry.draft_tier_ready(dt)
                    && ready > 0
                    && shared.slots_in_tier(dt) < ready * pool.max_inflight.max(1);
                shared.spec_draft_ok.store(ok, Ordering::Relaxed);
            }
            for ti in 0..3 {
                // Windowed prefix hit rate: tokens served from cache vs
                // prefilled since the last control pass (replica churn
                // can shrink the cumulative sums — resync on regression).
                let (h, m) = shared.tier_prefix_totals(ti);
                let (lh, lm) = prefix_last[ti];
                let (dh, dm) = if h >= lh && m >= lm {
                    (h - lh, m - lm)
                } else {
                    (h, m)
                };
                prefix_last[ti] = (h, m);
                let (sa, sd) = shared.tier_spec_totals(ti);
                let (lsa, lsd) = spec_last[ti];
                let (dsa, dsd) =
                    if sa >= lsa && sd >= lsd { (sa - lsa, sd - lsd) } else { (sa, sd) };
                spec_last[ti] = (sa, sd);
                let load = TierLoad {
                    queue_depth: shared.queues[ti].len(),
                    slots_in_use: shared.slots_in_tier(ti),
                    active_replicas: shared.live_count(ti),
                    idle_s: now
                        - shared.last_enqueue_us[ti].load(Ordering::Relaxed) as f64
                            / 1e6,
                    prefix_hit_rate: if dh + dm == 0 {
                        0.0
                    } else {
                        dh as f64 / (dh + dm) as f64
                    },
                    spec_accept_rate: if dsd == 0 {
                        0.0
                    } else {
                        dsa as f64 / dsd as f64
                    },
                };
                if let Some(action) = scaler.plan_tier(
                    ti,
                    substrate.service_of_tier(ti),
                    load,
                    pool.replicas[ti],
                    now,
                ) {
                    crate::orchestrator::scaling::apply(
                        &[action],
                        &mut registry,
                        &mut substrate,
                        now,
                    );
                }
                // Orphan guard: queued work must never sit in front of a
                // fully-parked tier (a job can land between the load
                // sample and a terminate draining the last replica).
                if !shared.queues[ti].is_empty() && shared.live_count(ti) == 0 {
                    cold_wake(&mut substrate, &mut registry, &metrics, &shared, ti, now);
                }
            }
            sync_registry(&mut registry, &shared, &pool);
        }
    }
    substrate.stop_all();
}

/// Start the HTTP gateway over a live stack. Returns the bound server.
pub fn serve_http(stack: Arc<LiveStack>, port: u16, threads: usize) -> Result<http::HttpServer> {
    http::HttpServer::start(port, threads, move |req| {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => (200, "text/plain".into(), b"ok".to_vec()),
            ("GET", "/metrics") => {
                let body =
                    crate::telemetry::export_prometheus(&stack.metrics_snapshot());
                (200, "text/plain".into(), body.into_bytes())
            }
            ("POST", "/v1/completions") => match handle_completion(&stack, req) {
                Ok(body) => (200, "application/json".into(), body.into_bytes()),
                Err(e) => (
                    500,
                    "application/json".into(),
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))])
                        .dump()
                        .into_bytes(),
                ),
            },
            _ => (404, "text/plain".into(), b"not found".to_vec()),
        }
    })
}

fn handle_completion(stack: &LiveStack, req: &http::Request) -> Result<String> {
    let j = Json::parse(req.body_str()?)?;
    let prompt = j.rstr("prompt")?;
    let max_tokens = j.usize_or("max_tokens", 16).min(64);
    let mut creq = CompletionRequest::new(prompt).max_tokens(max_tokens);
    // Optional affinity/session key and per-request deadline — the same
    // fields the builder API takes, reachable over HTTP.
    if let Some(key) = j
        .get("affinity_key")
        .and_then(Json::as_str)
        .or_else(|| j.get("session").and_then(Json::as_str))
    {
        creq = creq.affinity_key(key);
    }
    if let Some(d) = j.get("deadline_s").and_then(Json::as_f64) {
        creq = creq.deadline_s(d);
    }
    let r = stack.complete_request(creq)?;
    Ok(Json::obj(vec![
        ("model", Json::str(r.model)),
        ("tier", Json::str(r.tier.clone())),
        ("complexity", Json::num(r.complexity as f64)),
        ("confidence", Json::num(r.confidence)),
        ("ttft_s", Json::num(r.ttft_s)),
        ("latency_s", Json::num(r.latency_s)),
        ("queue_wait_s", Json::num(r.queue_wait_s)),
        ("prompt_tokens", Json::num(r.prompt_tokens as f64)),
        (
            "tokens",
            Json::arr(r.tokens.iter().map(|&t| Json::num(t as f64))),
        ),
    ])
    .dump())
}
