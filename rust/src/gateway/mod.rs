//! API Gateway — the entry point of Fig. 1, plus the live serving stack.
//!
//! Three layers:
//! * [`http`] — the from-scratch HTTP/1.1 substrate.
//! * [`pool`] — the data plane: `LocalSubstrate`, the continuous-batching
//!   engine pool behind the unified [`crate::substrate::Substrate`]
//!   trait. N replica threads per tier each run a
//!   [`crate::backend::scheduler::Scheduler`] that drains its tier queue
//!   into prefill/decode batches at the compiled ladder sizes and frees
//!   slots the moment a completion (or cancellation) finishes.
//! * [`LiveStack`] — the control plane: a router thread owns the
//!   classifier (PJRT handles are not `Send`), routes jobs to bounded
//!   per-tier queues, and drives the substrate with the *same*
//!   orchestrator the simulator uses — Alg. 1 scaling
//!   ([`crate::orchestrator::Scaler`] over observed tier load, applied
//!   through `scaling::apply`), Alg. 2 selection with substrate-measured
//!   cold starts, and the [`RecoveryManager`]: replica threads that
//!   panic, stall past the health deadline, or are killed by fault
//!   injection are detected, terminated, redeployed, and recorded as
//!   `Incident`s with measured recovery seconds exported at `/metrics`.
//!
//! Requests: `POST /v1/completions {"prompt": "...", "max_tokens": N}` →
//! routed by the hybrid router, executed on the tier the matrix picks,
//! answered with token ids + timing. `GET /healthz`, `GET /readyz`,
//! `GET /metrics`, `GET /debug/traces` (the flight recorder ring, when
//! `pool.trace.enabled`).

pub mod http;
pub(crate) mod pool;
pub mod worker;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::batcher::{DECODE_BATCHES, N_DECODE_BATCHES};
use crate::backend::scheduler::{CancelToken, SimStepEngine, StepEngine};
use crate::config::{
    Config, OrchestratorConfig, PoolConfig, Priority, Profile, RouterMode,
    SubstrateKind,
};
use crate::models::{zoo, Tier};
use crate::orchestrator::recovery::RecoveryManager;
use crate::orchestrator::{ScaleAction, Scaler, TierLoad};
use crate::registry::{Health, Registry, ServiceId};
use crate::router::bandit::{SharedBandit, TierBandit};
use crate::router::hybrid::HybridRouter;
use crate::router::keyword::KeywordRouter;
use crate::router::{Classification, Router};
use crate::runtime::Runtime;
use crate::scoring::Weights;
use crate::telemetry::trace::{
    parse_traceparent, AccessLog, FlightRecorder, SpanKind, TraceCtx,
    TraceRecord, TraceState,
};
use crate::telemetry::Histogram;
use crate::substrate::nodes::NodeRegistry;
use crate::substrate::remote::{ProcessSubstrate, WorkerSpec};
use crate::substrate::Substrate;
use crate::util::json::Json;
use crate::util::threadpool::{Channel, OneShot};

use pool::{LocalSubstrate, PoolShared, ReplicaCell, TierJob, S_READY};

/// A live completion response.
#[derive(Debug, Clone)]
pub struct LiveResponse {
    pub tokens: Vec<i32>,
    pub tier: String,
    pub model: &'static str,
    pub complexity: usize,
    pub confidence: f64,
    pub ttft_s: f64,
    pub latency_s: f64,
    /// Time spent in the per-tier queue before prefill started.
    pub queue_wait_s: f64,
    pub prompt_tokens: usize,
}

/// Why a completion failed — typed end to end so the HTTP layer can
/// answer 429 vs 503 vs 504 instead of a blanket 500.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// The caller's wait elapsed (gateway timeout fired the cancel).
    Timeout,
    /// The per-request deadline expired before a replica ever started on
    /// it — dropped at dequeue instead of burning replica steps.
    DeadlineExpired,
    /// Shed by admission control (over the watermark, or the deadline
    /// was infeasible given the measured drain rate).
    Shed,
    /// A bounded queue was full (backpressure).
    QueueFull,
    /// The serving replica was lost and the job could not be requeued.
    ReplicaLost,
    /// The fallback chain ran out of targets or retry budget.
    ChainExhausted,
    /// Orderly pool teardown.
    Shutdown,
    /// Everything else (routing errors, engine failures).
    Internal,
}

impl FailureKind {
    /// The HTTP status a failure of this kind maps to: 429 for load
    /// rejections the client should retry later, 503 for capacity loss,
    /// 504 for deadlines, 500 for internal faults.
    pub fn http_status(self) -> u16 {
        match self {
            FailureKind::Shed | FailureKind::QueueFull => 429,
            FailureKind::ReplicaLost
            | FailureKind::ChainExhausted
            | FailureKind::Shutdown => 503,
            FailureKind::Timeout | FailureKind::DeadlineExpired => 504,
            FailureKind::Internal => 500,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FailureKind::Timeout => "timeout",
            FailureKind::DeadlineExpired => "deadline_expired",
            FailureKind::Shed => "shed",
            FailureKind::QueueFull => "queue_full",
            FailureKind::ReplicaLost => "replica_lost",
            FailureKind::ChainExhausted => "chain_exhausted",
            FailureKind::Shutdown => "shutdown",
            FailureKind::Internal => "internal",
        }
    }
}

/// A typed completion failure. `Display` is the bare message, so error
/// text observed by callers is unchanged from the untyped era.
#[derive(Debug, Clone)]
pub struct CompletionError {
    pub kind: FailureKind,
    pub msg: String,
    /// Client back-off hint for 429s, from the observed drain rate.
    pub retry_after_s: Option<f64>,
}

impl CompletionError {
    pub fn new(kind: FailureKind, msg: impl Into<String>) -> CompletionError {
        CompletionError { kind, msg: msg.into(), retry_after_s: None }
    }

    pub fn retry_after(mut self, seconds: f64) -> CompletionError {
        self.retry_after_s = Some(seconds.max(0.0));
        self
    }

    pub(crate) fn internal(msg: impl Into<String>) -> CompletionError {
        CompletionError::new(FailureKind::Internal, msg)
    }
}

impl std::fmt::Display for CompletionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for CompletionError {}

/// An unrouted job, as `complete_request()` hands it to the router thread.
struct Job {
    prompt: String,
    max_tokens: usize,
    /// Session/tenant key for cache-affinity routing: requests sharing a
    /// key rendezvous on the same replica even before their prefix is
    /// cached anywhere, so the cache warms in one place.
    affinity_key: Option<String>,
    /// Admission class (weighted-fair dequeue, shed order).
    priority: Priority,
    /// Absolute deadline, seconds since the pool epoch (`f64::INFINITY`
    /// when the caller set none) — stamped at submit so queue time
    /// counts against it.
    deadline_abs_s: f64,
    /// Span accumulator when this request is traced (`None` = off: a
    /// null pointer rides along and no tracing work happens anywhere).
    trace: Option<Box<TraceState>>,
    cancel: CancelToken,
    reply: OneShot<Result<LiveResponse, CompletionError>>,
}

/// One completion request, builder-style — the gateway's entry API.
///
/// ```no_run
/// # use pick_and_spin::gateway::{CompletionRequest, LiveStack};
/// # fn go(stack: &LiveStack) -> anyhow::Result<()> {
/// let r = stack.complete_request(
///     CompletionRequest::new("summarize this ticket")
///         .max_tokens(32)
///         .affinity_key("tenant-7")
///         .deadline_s(2.5),
/// )?;
/// # Ok(()) }
/// ```
///
/// `prompt` and `max_tokens` are what [`LiveStack::complete`] always
/// took; the optional fields are new: `affinity_key` steers
/// cache-affinity routing (`pool.affinity.*`), `deadline_s` overrides
/// the gateway-wide request timeout for this call, and `cancel` lets a
/// caller abort from another thread (timeout and cancel both evict the
/// sequence mid-flight, freeing its slot and KV reservation).
#[derive(Clone)]
pub struct CompletionRequest {
    pub prompt: String,
    pub max_tokens: usize,
    pub affinity_key: Option<String>,
    pub deadline_s: Option<f64>,
    /// Admission class under overload control (`pool.admission.*`):
    /// weighted-fair dequeue weight, and shed order when queues pass the
    /// watermark (batch sheds first, interactive last). Defaults to
    /// `Standard`; inert while admission is disabled.
    pub priority: Priority,
    pub cancel: Option<CancelToken>,
    /// Inbound trace context (parsed from a W3C `traceparent`, or set
    /// directly). `None` lets the gateway mint one when tracing is on.
    pub trace: Option<TraceCtx>,
}

impl CompletionRequest {
    pub fn new(prompt: impl Into<String>) -> CompletionRequest {
        CompletionRequest {
            prompt: prompt.into(),
            max_tokens: 16,
            affinity_key: None,
            deadline_s: None,
            priority: Priority::default(),
            cancel: None,
            trace: None,
        }
    }

    pub fn max_tokens(mut self, n: usize) -> CompletionRequest {
        self.max_tokens = n;
        self
    }

    pub fn affinity_key(mut self, key: impl Into<String>) -> CompletionRequest {
        self.affinity_key = Some(key.into());
        self
    }

    pub fn deadline_s(mut self, seconds: f64) -> CompletionRequest {
        self.deadline_s = Some(seconds);
        self
    }

    pub fn priority(mut self, p: Priority) -> CompletionRequest {
        self.priority = p;
        self
    }

    pub fn cancel_token(mut self, token: CancelToken) -> CompletionRequest {
        self.cancel = Some(token);
        self
    }

    /// Join an upstream trace by W3C `traceparent` header. Malformed
    /// headers are ignored (the gateway mints its own id instead).
    pub fn traceparent(mut self, header: &str) -> CompletionRequest {
        self.trace = parse_traceparent(header);
        self
    }

    pub fn trace_ctx(mut self, ctx: TraceCtx) -> CompletionRequest {
        self.trace = Some(ctx);
        self
    }
}

/// Counters exported at `/metrics`.
#[derive(Default)]
pub struct GatewayMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub tokens_out: AtomicU64,
    /// Decode steps that ran with batch size > 1 — the proof that
    /// continuous batching actually engaged.
    pub batched: AtomicU64,
    pub decode_steps: AtomicU64,
    pub prefills: AtomicU64,
    /// Prefill dispatches that covered more than one sequence (batched
    /// prefill at the ladder rungs).
    pub prefill_batched: AtomicU64,
    /// Total queue-wait across requests, in microseconds (exported as
    /// `ps_queue_wait_seconds_total`).
    pub queue_wait_us: AtomicU64,
    /// Enqueues that un-parked a scaled-to-zero tier.
    pub cold_wakes: AtomicU64,
    /// Callers that gave up waiting; their sequences are cancelled
    /// mid-flight (see `cancelled`).
    pub timeouts: AtomicU64,
    /// Sequences evicted mid-flight by their cancel token, freeing the
    /// slot early instead of decoding to completion.
    pub cancelled: AtomicU64,
    /// In-flight jobs requeued off a failed replica (drained without
    /// loss onto its replacement).
    pub requeued: AtomicU64,
    /// Failure incidents observed by the recovery manager.
    pub incidents: AtomicU64,
    /// Incidents closed by a replacement replica reaching Ready.
    pub recovered: AtomicU64,
    /// Sum of measured recovery times, µs (exported as
    /// `ps_recovery_seconds_total`).
    pub recovery_us_total: AtomicU64,
    /// Prompt tokens served from the replicas' radix prefix caches
    /// (prefill work skipped).
    pub prefix_hit_tokens: AtomicU64,
    /// Prompt tokens that had to be prefilled.
    pub prefix_miss_tokens: AtomicU64,
    /// Unreferenced prefix-cache blocks reclaimed (LRU).
    pub prefix_evicted_blocks: AtomicU64,
    /// Frames the process-substrate supervisor wrote to workers.
    pub rpc_frames_sent: AtomicU64,
    /// Frames received from workers.
    pub rpc_frames_recv: AtomicU64,
    /// Completed Ping→Pong round trips.
    pub rpc_pings: AtomicU64,
    /// Summed Ping→Pong round-trip time, µs (exported as
    /// `ps_rpc_rtt_seconds_total`; with `ps_rpc_pings_total` it yields
    /// the mean RPC latency of the process data plane).
    pub rpc_rtt_us_total: AtomicU64,
    /// Requests the affinity router placed on the replica advertising
    /// the longest matching cached prefix.
    pub affinity_hits: AtomicU64,
    /// Affinity-enabled dispatches that fell back to the shared tier
    /// queue (no match, or the matching replica was saturated).
    pub affinity_fallbacks: AtomicU64,
    /// Summed matched chain length across affinity hits, in KV blocks.
    pub affinity_match_blocks: AtomicU64,
    /// Cross-replica prefix transfers brokered (donor export → target
    /// import).
    pub kv_transfers: AtomicU64,
    /// KV blocks moved by those transfers.
    pub kv_transfer_blocks: AtomicU64,
    /// Draft tokens proposed by the speculative decode path.
    pub spec_drafted_tokens: AtomicU64,
    /// Draft tokens the verify pass accepted (landed without a big-tier
    /// decode step of their own).
    pub spec_accepted_tokens: AtomicU64,
    /// Draft tokens rejected and rolled back.
    pub spec_rejected_tokens: AtomicU64,
    /// Batched verify steps executed.
    pub spec_verify_steps: AtomicU64,
    /// Requests shed by admission control, `[priority][tier]`
    /// (`ps_shed_total{priority,tier}`).
    pub shed_total: [[AtomicU64; 3]; 3],
    /// Queued jobs dropped at dequeue because their deadline had already
    /// elapsed (`ps_shed_total{reason="expired"}`).
    pub shed_expired: AtomicU64,
    /// Admission-gate rejections: the deadline was infeasible given the
    /// measured drain rate.
    pub admission_rejected_deadline: AtomicU64,
    /// Admission-gate rejections: the tier's whole backlog (buffer plus
    /// queue) was at capacity.
    pub admission_rejected_backlog: AtomicU64,
    /// Chain hops escalated to a bigger tier, per origin route.
    pub chain_escalated: [AtomicU64; 3],
    /// Chain hops degraded to a smaller tier (targets saturated).
    pub chain_degraded: [AtomicU64; 3],
    /// Requests whose fallback chain ran out of targets or budget.
    pub chain_exhausted: [AtomicU64; 3],
    /// Chain re-dispatches issued (the retry-budget numerator).
    pub retries_issued: AtomicU64,
    /// Fresh jobs dispatched (the retry-budget denominator).
    pub fresh_jobs: AtomicU64,
    /// Per-priority queue-wait histograms, [`Priority::ALL`] order.
    pub queue_wait_hist: [WaitHist; 3],
    /// Formed-batch histogram: one counter per compiled rung, in
    /// [`DECODE_BATCHES`] order.
    pub batch_counts: [AtomicU64; N_DECODE_BATCHES],
    /// Completed-trace ring behind `/debug/traces` (`pool.trace.*`;
    /// disabled by default — `record` is a no-op until configured).
    pub recorder: FlightRecorder,
    /// Structured per-request JSON log (`pool.trace.access_log`).
    pub access_log: AccessLog,
    /// Latency-breakdown histograms fed by the span stream,
    /// `[span kind][tier]` (`ps_span_seconds{span,tier,le}`). Only
    /// traced requests observe, so the family is quiet with tracing off.
    pub span_hist: SpanHists,
    /// Per-tier time-to-first-token histograms (`ps_ttft_seconds`).
    pub ttft_hist: [TtftHist; 3],
    /// Per-tier inter-token-latency histograms (`ps_tpot_seconds`).
    pub tpot_hist: [TpotHist; 3],
    /// Learned tier selection (`pool.routing.bandit.enabled`). Set once
    /// by the router thread at startup when enabled; unset (the default)
    /// every hook below is a null-pointer check and routing is the exact
    /// legacy static path.
    pub bandit: OnceLock<SharedBandit>,
}

/// A mutex-wrapped queue-wait [`Histogram`] with overload-relevant
/// bounds (1 ms … 10 s), newtyped so `GatewayMetrics` keeps deriving
/// `Default`.
pub struct WaitHist(pub Mutex<Histogram>);

impl Default for WaitHist {
    fn default() -> WaitHist {
        WaitHist(Mutex::new(Histogram::new(&[
            0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
        ])))
    }
}

/// `[span kind][tier]` span-duration histograms, newtyped so
/// `GatewayMetrics` keeps deriving `Default`.
pub struct SpanHists(pub [[Mutex<Histogram>; 3]; SpanKind::ALL.len()]);

impl Default for SpanHists {
    fn default() -> SpanHists {
        SpanHists(std::array::from_fn(|_| {
            std::array::from_fn(|_| {
                Mutex::new(Histogram::new(&[
                    0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
                    10.0,
                ]))
            })
        }))
    }
}

/// Time-to-first-token histogram (5 ms … 10 s — queue wait dominates the
/// tail, so the upper bounds match the queue-wait histogram's).
pub struct TtftHist(pub Mutex<Histogram>);

impl Default for TtftHist {
    fn default() -> TtftHist {
        TtftHist(Mutex::new(Histogram::new(&[
            0.005, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
        ])))
    }
}

/// Inter-token-latency histogram (0.5 ms … 1 s — decode steps are short;
/// the resolution sits where per-token latency actually lands).
pub struct TpotHist(pub Mutex<Histogram>);

impl Default for TpotHist {
    fn default() -> TpotHist {
        TpotHist(Mutex::new(Histogram::new(&[
            0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.25, 1.0,
        ])))
    }
}

impl GatewayMetrics {
    /// Record one executed decode batch of size `b`.
    pub fn observe_batch(&self, b: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        if b > 1 {
            self.batched.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(i) = DECODE_BATCHES.iter().position(|&x| x == b) {
            self.batch_counts[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn add_queue_wait_s(&self, s: f64) {
        self.queue_wait_us
            .fetch_add((s.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn queue_wait_total_s(&self) -> f64 {
        self.queue_wait_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Record one request's end-to-end queue wait into its priority's
    /// histogram (first admission only — requeues don't observe twice).
    pub fn observe_queue_wait(&self, priority: Priority, wait_s: f64) {
        self.queue_wait_hist[priority.index()]
            .0
            .lock()
            .unwrap()
            .observe(wait_s.max(0.0));
    }

    /// Record one completed request's time to first token.
    pub fn observe_ttft(&self, tier: usize, s: f64) {
        self.ttft_hist[tier.min(2)].0.lock().unwrap().observe(s.max(0.0));
    }

    /// Record one completed request's mean inter-token latency.
    pub fn observe_tpot(&self, tier: usize, s: f64) {
        self.tpot_hist[tier.min(2)].0.lock().unwrap().observe(s.max(0.0));
    }

    /// The single terminal step every resolved request takes, traced or
    /// not: one access-log line when the log is on, and — when the
    /// request carried a trace — span-histogram observations plus a
    /// finished [`TraceRecord`] in the flight recorder ring. With
    /// tracing and the access log both off this is two relaxed atomic
    /// loads and a null-pointer check.
    pub fn finish_request(
        &self,
        trace: Option<Box<TraceState>>,
        tier: Tier,
        priority: Priority,
        outcome: &'static str,
        now_s: f64,
        tokens: usize,
    ) {
        if self.access_log.enabled() {
            let mut kv = vec![
                ("tier", Json::str(tier.name())),
                ("priority", Json::str(priority.name())),
                ("outcome", Json::str(outcome)),
                ("tokens", Json::num(tokens as f64)),
                ("ts", Json::num(now_s)),
            ];
            if let Some(st) = trace.as_deref() {
                kv.push(("trace_id", Json::str(st.ctx.id_hex())));
                kv.push(("total_s", Json::num((now_s - st.start_s).max(0.0))));
            }
            self.access_log.write_line(Json::obj(kv).dump());
        }
        let Some(st) = trace else { return };
        let st = *st;
        for s in &st.spans {
            self.span_hist.0[s.kind.index()][tier.index()]
                .lock()
                .unwrap()
                .observe(s.dur_s());
        }
        self.recorder.record(TraceRecord {
            trace_id: st.ctx.trace_id,
            tier: tier.name(),
            priority: priority.name(),
            outcome,
            start_s: st.start_s,
            total_s: (now_s - st.start_s).max(0.0),
            tokens,
            spans: st.spans,
        });
    }

    /// Close the routing feedback loop for one resolved request (or
    /// chain hop — each hop carries its own class/tier label, so credit
    /// lands on the tier that actually served it). Called at the same
    /// terminal sites as [`finish_request`](Self::finish_request) for
    /// real outcomes — completions, sheds, expiries, losses — and *not*
    /// for caller cancellations or orderly shutdown, which say nothing
    /// about the tier's fitness. No-op until the router thread arms the
    /// learner.
    pub fn bandit_feedback(
        &self,
        tier: Tier,
        complexity: usize,
        confidence: f64,
        ok: bool,
        latency_s: f64,
    ) {
        if let Some(b) = self.bandit.get() {
            b.feedback(complexity, tier.index(), confidence, ok, latency_s);
        }
    }
}

/// The live serving stack: hybrid router + a continuous-batching engine
/// pool driven by the unified control plane.
pub struct LiveStack {
    jobs: Channel<Job>,
    pub metrics: Arc<GatewayMetrics>,
    shared: Arc<PoolShared>,
    /// Pool configuration view — per-tier readiness (`/readyz`) needs
    /// the configured replica budgets.
    pool: PoolConfig,
    /// Multi-host node plane, when `pool.nodes` is configured on the
    /// process substrate (per-node gauges at `/metrics`).
    nodes: Option<Arc<NodeRegistry>>,
    /// The router/control thread; it owns the substrate and joins every
    /// replica thread on shutdown.
    router: Option<JoinHandle<()>>,
    request_timeout_s: f64,
}

/// What the gateway needs from a replica substrate beyond the
/// orchestrator-facing [`Substrate`] trait: the shared pool state the
/// router samples, the canonical service per tier, warm-up blocking, and
/// teardown. Implemented by the thread pool (`LocalSubstrate`) and the
/// process supervisor (`ProcessSubstrate`) so the router/control thread
/// is written once against both data planes.
pub(crate) trait PoolBackend: Substrate + Send {
    fn pool_shared(&self) -> Arc<PoolShared>;
    fn service_of_tier(&self, tier: usize) -> ServiceId;
    fn warm(&mut self) -> std::result::Result<(), String>;
    fn stop_all(&mut self);
    /// The multi-host node registry, when this backend has one.
    fn node_registry(&self) -> Option<Arc<NodeRegistry>> {
        None
    }
}

impl<E, F> PoolBackend for LocalSubstrate<E, F>
where
    E: StepEngine,
    F: Fn(Tier, usize) -> std::result::Result<E, String> + Send + Sync + 'static,
{
    fn pool_shared(&self) -> Arc<PoolShared> {
        self.shared()
    }

    fn service_of_tier(&self, tier: usize) -> ServiceId {
        self.tier_service(tier)
    }

    fn warm(&mut self) -> std::result::Result<(), String> {
        self.wait_warm()
    }

    fn stop_all(&mut self) {
        self.shutdown();
    }
}

impl PoolBackend for ProcessSubstrate {
    fn pool_shared(&self) -> Arc<PoolShared> {
        self.shared()
    }

    fn service_of_tier(&self, tier: usize) -> ServiceId {
        self.tier_service(tier)
    }

    fn warm(&mut self) -> std::result::Result<(), String> {
        self.wait_warm()
    }

    fn stop_all(&mut self) {
        self.shutdown();
    }

    fn node_registry(&self) -> Option<Arc<NodeRegistry>> {
        self.nodes()
    }
}

/// Build one tier's compiled PJRT engine: compile a *prefix* of the
/// decode ladder (stop at the first missing rung — the scheduler may
/// form any compiled rung ≤ its max, so a gap would make it form batches
/// the engine can't execute). Shared by the thread substrate's replica
/// factories and the `ps-replica` worker's `--engine pjrt` mode.
pub fn build_pjrt_engine(
    artifacts: &str,
    tier: Tier,
    max_batch: usize,
) -> std::result::Result<crate::runtime::LmEngine, String> {
    let mut rt = Runtime::load(artifacts).map_err(|e| format!("runtime: {e:#}"))?;
    let mut ladder: Vec<usize> = Vec::new();
    for &b in DECODE_BATCHES.iter() {
        let have = rt
            .manifest
            .module(&format!("lm_{}_decode_b{b}", tier.name()))
            .is_ok();
        if b > max_batch.max(1) || !have {
            break;
        }
        ladder.push(b);
    }
    if ladder.is_empty() {
        ladder.push(1);
    }
    rt.lm_engine(tier.name(), &ladder)
        .map_err(|e| format!("lm {}: {e:#}", tier.name()))
}

impl LiveStack {
    /// Spin up the engine pool over the compiled PJRT artifacts
    /// (compiles each tier per replica — takes a few seconds; returns
    /// after every engine is warm).
    pub fn start(cfg: &Config) -> Result<LiveStack> {
        let router_artifacts = cfg.paths.artifacts.clone();
        let router_cfg = cfg.router.clone();
        let engine_artifacts = cfg.paths.artifacts.clone();
        let max_batch = cfg.pool.max_decode_batch;
        Self::start_pool(
            cfg,
            move || {
                let mut rt = Runtime::load(&router_artifacts)
                    .map_err(|e| format!("runtime: {e:#}"))?;
                let router: Box<dyn Router> = match router_cfg.mode {
                    RouterMode::Keyword => Box::new(KeywordRouter::new()),
                    _ => {
                        let classifier = rt
                            .classifier_engine()
                            .map_err(|e| format!("classifier: {e:#}"))?;
                        Box::new(HybridRouter::new(classifier, &router_cfg))
                    }
                };
                Ok(router)
            },
            move |tier: Tier, _replica: usize| {
                build_pjrt_engine(&engine_artifacts, tier, max_batch)
            },
            &["--engine", "pjrt", "--artifacts", cfg.paths.artifacts.as_str()],
        )
    }

    /// The same pool wired to the deterministic synthetic engine and the
    /// keyword router — no artifacts or PJRT needed. Used by integration
    /// tests and benches to exercise queueing, batching, scaling,
    /// recovery and metrics end-to-end. With `pool.substrate = "process"`
    /// the workers run `ps-replica --engine sim`, so the whole RPC data
    /// plane is exercised hermetically too.
    pub fn start_sim(cfg: &Config) -> Result<LiveStack> {
        let spec = cfg.pool.speculative;
        Self::start_pool(
            cfg,
            || Ok(Box::new(KeywordRouter::new()) as Box<dyn Router>),
            move |tier: Tier, replica: usize| {
                let mut e = SimStepEngine::calibrated();
                if spec.enabled {
                    // Deterministic per-replica verdict stream at the
                    // configured acceptance rate (pool.speculative
                    // .sim_accept). Harmless on unpaired tiers — their
                    // schedulers run with speculation disabled and never
                    // call verify_batch.
                    let seed =
                        0x5BEC ^ ((tier.index() as u64) << 32) ^ replica as u64;
                    e = e.with_acceptance(spec.sim_accept, seed);
                }
                Ok(e)
            },
            &["--engine", "sim"],
        )
    }

    /// Generic pool bring-up: `router_factory` runs on the router thread;
    /// `engine_factory` once per replica on its own thread (PJRT objects
    /// live and die on the thread that made them) for the thread
    /// substrate, while the process substrate spawns `ps-replica`
    /// workers with `worker_engine_args` instead.
    fn start_pool<E, RF, EF>(
        cfg: &Config,
        router_factory: RF,
        engine_factory: EF,
        worker_engine_args: &[&str],
    ) -> Result<LiveStack>
    where
        E: StepEngine,
        RF: FnOnce() -> std::result::Result<Box<dyn Router>, String> + Send + 'static,
        EF: Fn(Tier, usize) -> std::result::Result<E, String> + Send + Sync + 'static,
    {
        let epoch = Instant::now();
        let jobs: Channel<Job> = Channel::bounded(cfg.gateway.queue_capacity);
        let metrics = Arc::new(GatewayMetrics::default());
        let shared = Arc::new(PoolShared::new(epoch, cfg.pool.queue_capacity));
        let zoo_models = zoo();
        let registry = Registry::new(&zoo_models, cfg.orchestrator.telemetry_window_s);
        match cfg.pool.substrate {
            SubstrateKind::Thread => {
                let substrate = LocalSubstrate::new(
                    Arc::clone(&shared),
                    cfg.pool.clone(),
                    Arc::clone(&metrics),
                    engine_factory,
                    &registry,
                );
                Self::finish_start(cfg, router_factory, substrate, registry, shared, metrics, jobs)
            }
            SubstrateKind::Process => {
                let spec = WorkerSpec::from_pool(&cfg.pool, worker_engine_args)
                    .map_err(|e| anyhow!("process substrate: {e}"))?;
                // Bring the node plane up (dial static agents, bind the
                // registration listener) before any replica provisions,
                // so placement sees the fleet. A bad node config is a
                // startup error, not a silently single-host pool.
                let nodes = NodeRegistry::from_config(&cfg.pool.nodes)
                    .map_err(|e| anyhow!("process substrate: {e}"))?;
                let substrate = ProcessSubstrate::new(
                    Arc::clone(&shared),
                    cfg.pool.clone(),
                    Arc::clone(&metrics),
                    spec,
                    &registry,
                    nodes,
                );
                Self::finish_start(cfg, router_factory, substrate, registry, shared, metrics, jobs)
            }
        }
    }

    /// Substrate-agnostic bring-up: provision the initial fleet through
    /// the same lifecycle every later replica takes (the measured cold
    /// starts seed Alg. 2's scaled-to-zero estimates), wait until every
    /// replica is warm, then hand the substrate to the router thread.
    fn finish_start<S, RF>(
        cfg: &Config,
        router_factory: RF,
        mut substrate: S,
        registry: Registry,
        shared: Arc<PoolShared>,
        metrics: Arc<GatewayMetrics>,
        jobs: Channel<Job>,
    ) -> Result<LiveStack>
    where
        S: PoolBackend + 'static,
        RF: FnOnce() -> std::result::Result<Box<dyn Router>, String> + Send + 'static,
    {
        let nodes = substrate.node_registry();
        let tr = &cfg.pool.trace;
        if tr.enabled {
            // Wall-clock nanos perturb minted trace ids so concurrent
            // gateways don't collide; minting stays deterministic within
            // one stack.
            let seed = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs() ^ d.subsec_nanos() as u64)
                .unwrap_or(0x5BEC);
            metrics.recorder.configure(true, tr.ring_size, tr.sample_rate, seed);
        }
        metrics.access_log.configure(&tr.access_log);
        let requested: usize = cfg.pool.replicas.iter().sum();
        let mut provisioned = 0usize;
        for ti in 0..3 {
            let sid = substrate.service_of_tier(ti);
            let (mi, spec, backend) = {
                let s = registry.get(sid);
                (s.model_idx, s.spec.clone(), s.backend)
            };
            for _ in 0..cfg.pool.replicas[ti] {
                if substrate.provision(sid, mi, &spec, backend, 0.0).is_some() {
                    provisioned += 1;
                }
            }
        }
        if provisioned == 0 && requested > 0 {
            // A fleet that failed to even spawn (bad worker binary, say)
            // must be a startup error, not a pool that times out every
            // request.
            substrate.stop_all();
            return Err(anyhow!(
                "engine pool failed to start: no replica could be provisioned"
            ));
        }
        if let Err(e) = substrate.warm() {
            substrate.stop_all();
            return Err(anyhow!("engine pool failed to start: {e}"));
        }

        let ready: Channel<std::result::Result<(), String>> = Channel::bounded(2);
        let router_handle = {
            let jobs_rx = jobs.clone();
            let metrics = Arc::clone(&metrics);
            let pool_cfg = cfg.pool.clone();
            let orch = cfg.orchestrator.clone();
            let profile = cfg.profile;
            let ready_tx = ready.clone();
            std::thread::Builder::new().name("router".into()).spawn(move || {
                let router = match router_factory() {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        substrate.stop_all();
                        return;
                    }
                };
                router_loop(
                    router, jobs_rx, substrate, registry, metrics, pool_cfg, orch,
                    profile,
                );
            })?
        };
        match ready.recv() {
            Some(Ok(())) => {}
            Some(Err(e)) => {
                jobs.close();
                let _ = router_handle.join();
                return Err(anyhow!("engine pool failed to start: {e}"));
            }
            None => return Err(anyhow!("engine pool start interrupted")),
        }
        // Sanitize: Duration::from_secs_f64 panics on negative/NaN/∞.
        let timeout = cfg.gateway.request_timeout_s;
        let request_timeout_s = if timeout.is_finite() {
            timeout.clamp(0.001, 86_400.0)
        } else {
            crate::config::GatewayConfig::default().request_timeout_s
        };
        Ok(LiveStack {
            jobs,
            metrics,
            shared,
            pool: cfg.pool.clone(),
            nodes,
            router: Some(router_handle),
            request_timeout_s,
        })
    }

    /// Serve one request (blocks until a replica answers, the deadline
    /// elapses, or the caller's cancel token fires).
    ///
    /// A timeout fires the job's cancel token: the sequence is evicted
    /// at the scheduler's next tick, freeing its slot and KV reservation
    /// early instead of decoding to completion (`ps_cancelled_total`
    /// counts the evictions, `ps_timeouts_total` the abandonments).
    /// Failures carry a typed [`CompletionError`] (downcastable from the
    /// returned `anyhow::Error`) so callers — the HTTP layer above all —
    /// can distinguish shed/queue-full (429) from capacity loss (503)
    /// from deadlines (504).
    pub fn complete_request(&self, req: CompletionRequest) -> Result<LiveResponse> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let reply: OneShot<Result<LiveResponse, CompletionError>> = OneShot::new();
        let cancel = req.cancel.unwrap_or_else(CancelToken::new);
        // A per-request deadline overrides the gateway-wide timeout;
        // same sanitization (from_secs_f64 panics on negative/NaN/∞).
        let (timeout_s, explicit_deadline) = match req.deadline_s {
            Some(d) if d.is_finite() => (d.clamp(0.001, 86_400.0), true),
            _ => (self.request_timeout_s, false),
        };
        // Anchor the absolute deadline at submit, not at routing: time
        // spent queued in the gateway counts against it.
        let submit_s = self.shared.epoch.elapsed().as_secs_f64();
        let deadline_abs_s =
            if explicit_deadline { submit_s + timeout_s } else { f64::INFINITY };
        // Trace admission: honor a caller-provided context, else mint
        // one. The sampling decision is deterministic in the trace id.
        let trace = if self.metrics.recorder.enabled() {
            let ctx = req.trace.unwrap_or_else(|| self.metrics.recorder.mint());
            ctx.sampled.then(|| Box::new(TraceState::new(ctx, submit_s)))
        } else {
            None
        };
        let job = Job {
            prompt: req.prompt,
            max_tokens: req.max_tokens,
            affinity_key: req.affinity_key,
            priority: req.priority,
            deadline_abs_s,
            trace,
            cancel: cancel.clone(),
            reply: reply.clone(),
        };
        if self.jobs.try_send(job).is_err() {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(CompletionError::new(
                FailureKind::QueueFull,
                "queue full (backpressure)",
            )));
        }
        match reply.wait_timeout(Duration::from_secs_f64(timeout_s)) {
            Some(out) => out.map_err(anyhow::Error::new),
            None => {
                self.metrics.timeouts.fetch_add(1, Ordering::Relaxed);
                cancel.cancel();
                Err(anyhow::Error::new(CompletionError::new(
                    FailureKind::Timeout,
                    "request timed out",
                )))
            }
        }
    }

    /// Positional back-compat wrapper over [`Self::complete_request`].
    pub fn complete(&self, prompt: &str, max_tokens: usize) -> Result<LiveResponse> {
        self.complete_request(CompletionRequest::new(prompt).max_tokens(max_tokens))
    }

    /// Resolve the trace context an inbound request will carry: parse
    /// the caller's `traceparent` when present, else mint one. `None`
    /// when tracing is off or the request is unsampled — the HTTP layer
    /// uses this to echo `x-trace-id` before dispatching.
    pub fn trace_ctx(&self, traceparent: Option<&str>) -> Option<TraceCtx> {
        if !self.metrics.recorder.enabled() {
            return None;
        }
        let ctx = traceparent
            .and_then(parse_traceparent)
            .unwrap_or_else(|| self.metrics.recorder.mint());
        ctx.sampled.then_some(ctx)
    }

    /// Live (provisioned) replicas across all tiers — the scale-to-zero
    /// observable. Counts Scheduled/Loading/Ready; terminated replicas
    /// leave the count the moment they drain.
    pub fn active_replicas(&self) -> usize {
        self.shared.live_total()
    }

    /// Occupied decode slots across the pool.
    pub fn slots_in_use(&self) -> usize {
        self.shared.slots_in_use()
    }

    /// Fault-injection hook for recovery experiments: abruptly kill one
    /// Ready replica of `tier` (0 = small, 1 = medium, 2 = large). On
    /// the thread substrate the replica dies at its next heartbeat; on
    /// the process substrate its worker is SIGKILLed — a true `kill -9`.
    /// Its in-flight jobs requeue, the control plane records an
    /// `Incident` and redeploys. Returns whether a victim existed.
    pub fn inject_replica_failure(&self, tier: usize) -> bool {
        self.shared.inject_failure(tier.min(2))
    }

    /// Graceful-drain hook: one Ready replica of `tier` stops pulling
    /// work, hands its buffered jobs back through the requeue path,
    /// finishes its decoding slots, and exits — the scale-down path,
    /// triggerable deterministically for tests. Returns whether a victim
    /// existed.
    pub fn drain_replica(&self, tier: usize) -> bool {
        self.shared.drain_one(tier.min(2))
    }

    /// The `/metrics` exposition snapshot.
    pub fn metrics_snapshot(&self) -> Vec<(String, f64)> {
        let m = &self.metrics;
        let c = |v: &AtomicU64| v.load(Ordering::Relaxed) as f64;
        let mut out = vec![
            ("ps_requests_total".to_string(), c(&m.requests)),
            ("ps_completed_total".to_string(), c(&m.completed)),
            ("ps_errors_total".to_string(), c(&m.errors)),
            ("ps_rejected_total".to_string(), c(&m.rejected)),
            ("ps_tokens_out_total".to_string(), c(&m.tokens_out)),
            ("ps_batched_total".to_string(), c(&m.batched)),
            ("ps_decode_steps_total".to_string(), c(&m.decode_steps)),
            ("ps_prefill_total".to_string(), c(&m.prefills)),
            ("ps_prefill_batched_total".to_string(), c(&m.prefill_batched)),
            (
                "ps_queue_wait_seconds_total".to_string(),
                m.queue_wait_total_s(),
            ),
            ("ps_cold_wakes_total".to_string(), c(&m.cold_wakes)),
            ("ps_timeouts_total".to_string(), c(&m.timeouts)),
            ("ps_cancelled_total".to_string(), c(&m.cancelled)),
            ("ps_requeued_total".to_string(), c(&m.requeued)),
            ("ps_incidents_total".to_string(), c(&m.incidents)),
            ("ps_recovered_total".to_string(), c(&m.recovered)),
            (
                "ps_recovery_seconds_total".to_string(),
                m.recovery_us_total.load(Ordering::Relaxed) as f64 / 1e6,
            ),
            (
                "ps_prefix_hit_tokens_total".to_string(),
                c(&m.prefix_hit_tokens),
            ),
            (
                "ps_prefix_miss_tokens_total".to_string(),
                c(&m.prefix_miss_tokens),
            ),
            (
                "ps_prefix_evicted_blocks_total".to_string(),
                c(&m.prefix_evicted_blocks),
            ),
            (
                "ps_rpc_frames_sent_total".to_string(),
                c(&m.rpc_frames_sent),
            ),
            (
                "ps_rpc_frames_recv_total".to_string(),
                c(&m.rpc_frames_recv),
            ),
            ("ps_rpc_pings_total".to_string(), c(&m.rpc_pings)),
            (
                "ps_rpc_rtt_seconds_total".to_string(),
                m.rpc_rtt_us_total.load(Ordering::Relaxed) as f64 / 1e6,
            ),
            ("ps_affinity_hit_total".to_string(), c(&m.affinity_hits)),
            (
                "ps_affinity_fallback_total".to_string(),
                c(&m.affinity_fallbacks),
            ),
            (
                "ps_affinity_match_blocks_total".to_string(),
                c(&m.affinity_match_blocks),
            ),
            ("ps_kv_transfer_total".to_string(), c(&m.kv_transfers)),
            (
                "ps_kv_transfer_blocks_total".to_string(),
                c(&m.kv_transfer_blocks),
            ),
            (
                "ps_spec_drafted_tokens_total".to_string(),
                c(&m.spec_drafted_tokens),
            ),
            (
                "ps_spec_accepted_tokens_total".to_string(),
                c(&m.spec_accepted_tokens),
            ),
            (
                "ps_spec_rejected_tokens_total".to_string(),
                c(&m.spec_rejected_tokens),
            ),
            (
                "ps_spec_verify_steps_total".to_string(),
                c(&m.spec_verify_steps),
            ),
        ];
        for (i, &b) in DECODE_BATCHES.iter().enumerate() {
            out.push((format!("ps_decode_b{b}_total"), c(&m.batch_counts[i])));
        }
        out.push((
            "ps_queue_depth".to_string(),
            self.shared.queues.iter().map(|q| q.len()).sum::<usize>() as f64,
        ));
        out.push((
            "ps_prefix_cache_blocks".to_string(),
            self.shared.prefix_cache_blocks() as f64,
        ));
        out.push(("ps_slots_in_use".to_string(), self.slots_in_use() as f64));
        out.push((
            "ps_active_replicas".to_string(),
            self.active_replicas() as f64,
        ));
        // Per-replica affinity placement series (one family at a time —
        // the exposition format wants samples of a family contiguous).
        // Quiet with affinity off: counters only move when the affinity
        // router places work.
        let mut hit_series = Vec::new();
        let mut match_series = Vec::new();
        for (ti, tier) in Tier::ALL.iter().enumerate() {
            for (id, cell) in self.shared.cells[ti].lock().unwrap().iter() {
                let h = cell.affinity_hits.load(Ordering::Relaxed);
                let b = cell.affinity_match_blocks.load(Ordering::Relaxed);
                if h == 0 && b == 0 {
                    continue;
                }
                let labels = format!("tier=\"{}\",replica=\"{}\"", tier.name(), id.0);
                hit_series
                    .push((format!("ps_replica_affinity_hits{{{labels}}}"), h as f64));
                match_series.push((
                    format!("ps_replica_affinity_match_blocks{{{labels}}}"),
                    b as f64,
                ));
            }
        }
        out.extend(hit_series);
        out.extend(match_series);
        // Per-tier cumulative speculative acceptance rate. Quiet with
        // speculation off: a tier that never drafted has no sample.
        for (ti, tier) in Tier::ALL.iter().enumerate() {
            let (accepted, drafted) = self.shared.tier_spec_totals(ti);
            if drafted == 0 {
                continue;
            }
            out.push((
                format!("ps_spec_accept_rate{{tier=\"{}\"}}", tier.name()),
                accepted as f64 / drafted as f64,
            ));
        }
        // Overload-control series. Quiet with admission and chains off:
        // labeled samples appear only once their counters move, so a
        // plain pool's exposition is unchanged.
        for (pi, p) in Priority::ALL.iter().enumerate() {
            for (ti, tier) in Tier::ALL.iter().enumerate() {
                let v = m.shed_total[pi][ti].load(Ordering::Relaxed);
                if v == 0 {
                    continue;
                }
                out.push((
                    format!(
                        "ps_shed_total{{priority=\"{}\",tier=\"{}\"}}",
                        p.name(),
                        tier.name()
                    ),
                    v as f64,
                ));
            }
        }
        let expired = m.shed_expired.load(Ordering::Relaxed);
        if expired > 0 {
            out.push(("ps_shed_total{reason=\"expired\"}".to_string(), expired as f64));
        }
        for (reason, v) in [
            ("deadline_infeasible", m.admission_rejected_deadline.load(Ordering::Relaxed)),
            ("backlog", m.admission_rejected_backlog.load(Ordering::Relaxed)),
        ] {
            if v == 0 {
                continue;
            }
            out.push((
                format!("ps_admission_rejected_total{{reason=\"{reason}\"}}"),
                v as f64,
            ));
        }
        for (family, counters) in [
            ("ps_chain_escalated_total", &m.chain_escalated),
            ("ps_chain_degraded_total", &m.chain_degraded),
            ("ps_chain_exhausted_total", &m.chain_exhausted),
        ] {
            for (ti, tier) in Tier::ALL.iter().enumerate() {
                let v = counters[ti].load(Ordering::Relaxed);
                if v == 0 {
                    continue;
                }
                out.push((
                    format!("{family}{{route=\"{}\"}}", tier.name()),
                    v as f64,
                ));
            }
        }
        let fresh = m.fresh_jobs.load(Ordering::Relaxed);
        let retries = m.retries_issued.load(Ordering::Relaxed);
        out.push((
            "ps_retry_budget_ratio".to_string(),
            if fresh == 0 { 0.0 } else { retries as f64 / fresh as f64 },
        ));
        // Per-priority queue-wait histograms, cumulative `le` buckets in
        // the exposition convention (only priorities that saw traffic).
        for (pi, p) in Priority::ALL.iter().enumerate() {
            let h = m.queue_wait_hist[pi].0.lock().unwrap();
            if h.count() == 0 {
                continue;
            }
            let mut cum = 0u64;
            for (le, n) in h.buckets() {
                cum += n;
                let le = if le.is_finite() { format!("{le}") } else { "+Inf".into() };
                out.push((
                    format!(
                        "ps_queue_wait_hist_seconds{{priority=\"{}\",le=\"{le}\"}}",
                        p.name()
                    ),
                    cum as f64,
                ));
            }
        }
        // Per-tier TTFT / TPOT histograms — always on, quiet until a
        // tier completes its first request.
        for (ti, tier) in Tier::ALL.iter().enumerate() {
            let h = m.ttft_hist[ti].0.lock().unwrap();
            if h.count() == 0 {
                continue;
            }
            let mut cum = 0u64;
            for (le, n) in h.buckets() {
                cum += n;
                let le = if le.is_finite() { format!("{le}") } else { "+Inf".into() };
                out.push((
                    format!("ps_ttft_seconds{{tier=\"{}\",le=\"{le}\"}}", tier.name()),
                    cum as f64,
                ));
            }
        }
        for (ti, tier) in Tier::ALL.iter().enumerate() {
            let h = m.tpot_hist[ti].0.lock().unwrap();
            if h.count() == 0 {
                continue;
            }
            let mut cum = 0u64;
            for (le, n) in h.buckets() {
                cum += n;
                let le = if le.is_finite() { format!("{le}") } else { "+Inf".into() };
                out.push((
                    format!("ps_tpot_seconds{{tier=\"{}\",le=\"{le}\"}}", tier.name()),
                    cum as f64,
                ));
            }
        }
        // Latency-breakdown histograms from the span stream. Quiet with
        // tracing off: only traced requests observe spans, so a plain
        // pool exports no `ps_span_seconds` series at all.
        for kind in SpanKind::ALL {
            for (ti, tier) in Tier::ALL.iter().enumerate() {
                let h = m.span_hist.0[kind.index()][ti].lock().unwrap();
                if h.count() == 0 {
                    continue;
                }
                let mut cum = 0u64;
                for (le, n) in h.buckets() {
                    cum += n;
                    let le =
                        if le.is_finite() { format!("{le}") } else { "+Inf".into() };
                    out.push((
                        format!(
                            "ps_span_seconds{{span=\"{}\",tier=\"{}\",le=\"{le}\"}}",
                            kind.name(),
                            tier.name()
                        ),
                        cum as f64,
                    ));
                }
            }
        }
        let trace_dropped = m.recorder.dropped.load(Ordering::Relaxed)
            + m.access_log.dropped.load(Ordering::Relaxed);
        if trace_dropped > 0 {
            out.push(("ps_trace_dropped_total".to_string(), trace_dropped as f64));
        }
        // Learned-routing series (`ps_bandit_*`). Quiet with the bandit
        // off: the learner is never armed, so no series exist at all.
        if let Some(b) = m.bandit.get() {
            out.extend(b.metric_series());
        }
        if let Some(reg) = &self.nodes {
            out.push(("ps_node_lost_total".to_string(), reg.lost_total() as f64));
            // One pass per family: the Prometheus exposition format
            // requires all samples of a metric in one contiguous group.
            // Node names are operator input (`ps-node --name`) — escape
            // them, or one hostile name breaks the whole exposition.
            let nodes: Vec<_> = reg
                .snapshot()
                .into_iter()
                .map(|n| (prom_label_escape(&n.name), n))
                .collect();
            for (name, n) in &nodes {
                out.push((
                    format!("ps_node_replicas{{node=\"{name}\"}}"),
                    n.hosted as f64,
                ));
            }
            for (name, n) in &nodes {
                out.push((
                    format!("ps_node_capacity{{node=\"{name}\"}}"),
                    n.slots as f64,
                ));
            }
            for (name, n) in &nodes {
                out.push((
                    format!("ps_node_up{{node=\"{name}\"}}"),
                    if n.alive { 1.0 } else { 0.0 },
                ));
            }
        }
        out
    }

    /// Per-node placement/liveness view (`None` unless `pool.nodes` is
    /// configured on the process substrate).
    pub fn node_registry(&self) -> Option<Arc<NodeRegistry>> {
        self.nodes.as_ref().map(Arc::clone)
    }

    pub fn shutdown(self) {
        // Dropping joins everything (Drop below).
    }
}

/// Escape a string for use as a Prometheus label value (the exposition
/// format requires `\\`, `\"`, and `\n` escapes).
fn prom_label_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

impl Drop for LiveStack {
    fn drop(&mut self) {
        self.jobs.close();
        // The router drains buffered jobs, then shuts the substrate down
        // (closing tier queues and joining every replica thread).
        if let Some(router) = self.router.take() {
            let _ = router.join();
        }
    }
}

/// Route one prompt against the matrix (Alg. 2): returns the execution
/// tier, the logical model picked, and the classification. Cold-start
/// penalties come from the substrate's measured provision→Ready times.
fn route_one(
    router: &mut dyn Router,
    registry: &Registry,
    substrate: &dyn Substrate,
    weights: Weights,
    prompt: &str,
    max_tokens: usize,
) -> Result<(Tier, &'static str, Classification)> {
    let class: Classification = router.route(prompt)?;
    let in_tokens = crate::tokenizer::word_count(prompt).max(1) as f64;
    let out_est = 0.5 * max_tokens as f64;
    let sel = crate::orchestrator::select_on(
        registry, substrate, weights, &class, in_tokens, out_est,
    )
    .ok_or_else(|| anyhow!("no routable service"))?;
    let svc = registry.get(sel.service);
    Ok((svc.spec.tier, svc.spec.name, class))
}

/// Mirror the substrate's per-tier replica counts into every service of
/// the registry (the live registry is a routing view; replica state is
/// owned by the substrate). A tier with a zero thread budget can never
/// serve and is marked Unhealthy so Alg. 2 routes around it.
fn sync_registry(registry: &mut Registry, shared: &PoolShared, pool: &PoolConfig) {
    for ti in 0..3 {
        let health = if pool.replicas[ti] == 0 {
            Health::Unhealthy
        } else {
            Health::Healthy
        };
        registry.set_tier_state(
            ti,
            shared.ready_count(ti),
            shared.pending_count(ti),
            health,
        );
    }
}

/// Token cap when scoring a prompt for affinity. Chain hashes are
/// cumulative per block, so truncation never produces a *wrong* match —
/// it only stops scoring extremely long prompts past this depth.
const AFFINITY_SCORE_TOKEN_CAP: usize = 4096;

/// FNV-1a over a session key (rendezvous placement for keys whose
/// prefix isn't cached anywhere yet).
fn session_hash(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Cache-affinity dispatch (`pool.affinity.enabled`): score the prompt's
/// block-hash chain against every ready replica's advertised hot-prefix
/// summary and place the job on the longest match's private queue. On a
/// saturated match the job goes to the least-loaded replica instead and
/// a prefix transfer is brokered so the blocks follow it. Requests with
/// no match but a session key rendezvous on a stable replica so their
/// cache warms in one place. Returns the job back when nothing could be
/// placed directly — the caller takes the legacy tier-queue path.
fn affinity_place(
    shared: &PoolShared,
    pool: &PoolConfig,
    metrics: &GatewayMetrics,
    ti: usize,
    affinity_key: Option<&str>,
    now: f64,
    mut tj: TierJob,
) -> Option<TierJob> {
    if let Some(st) = tj.trace.as_deref_mut() {
        // The placement decision itself (scoring + queue pick) —
        // normally sub-millisecond.
        st.phase(SpanKind::AffinityPlace, now);
    }
    let aff = &pool.affinity;
    let cells: Vec<Arc<ReplicaCell>> = shared.cells[ti]
        .lock()
        .unwrap()
        .iter()
        .filter(|(_, c)| {
            c.state.load(Ordering::Acquire) == S_READY
                && !c.stop.load(Ordering::Relaxed)
        })
        .map(|(_, c)| Arc::clone(c))
        .collect();
    if cells.is_empty() {
        metrics.affinity_fallbacks.fetch_add(1, Ordering::Relaxed);
        return Some(tj);
    }
    // The prompt's cumulative block-boundary chain hashes — the same
    // chain the replicas' radix caches key on, so an advertised
    // `(tip, len)` matches iff our hash at `len` blocks equals the tip.
    let bt = pool.kv_block_tokens.max(1);
    let ids = crate::tokenizer::prompt_ids(&tj.prompt, AFFINITY_SCORE_TOKEN_CAP);
    let mut hashes: Vec<u64> = Vec::with_capacity(ids.len() / bt);
    let mut ph = crate::backend::kv_cache::ROOT_HASH;
    for chunk in ids.chunks_exact(bt) {
        ph = crate::backend::kv_cache::chain_hash(ph, chunk);
        hashes.push(ph);
    }
    // Longest advertised match across the tier's ready replicas.
    let mut best: Option<(usize, u32, u64)> = None; // (cell, len, tip)
    for (i, c) in cells.iter().enumerate() {
        for &(tip, len) in c.hot.lock().unwrap().iter() {
            let l = len as usize;
            if l >= 1
                && l <= hashes.len()
                && hashes[l - 1] == tip
                && best.map(|(_, bl, _)| len > bl).unwrap_or(true)
            {
                best = Some((i, len, tip));
            }
        }
    }
    match best.filter(|&(_, l, _)| l as usize >= aff.min_match_blocks.max(1)) {
        Some((bi, len, tip)) => {
            match cells[bi].direct.try_send(tj) {
                Ok(()) => {
                    metrics.affinity_hits.fetch_add(1, Ordering::Relaxed);
                    metrics
                        .affinity_match_blocks
                        .fetch_add(len as u64, Ordering::Relaxed);
                    cells[bi].affinity_hits.fetch_add(1, Ordering::Relaxed);
                    cells[bi]
                        .affinity_match_blocks
                        .fetch_add(len as u64, Ordering::Relaxed);
                    return None;
                }
                Err(back) => {
                    // The hot replica is saturated: pick the least-loaded
                    // peer and broker a transfer so the prefix follows
                    // the job instead of being recomputed.
                    tj = back;
                    if aff.transfer && cells.len() > 1 {
                        let (tix, _) = cells
                            .iter()
                            .enumerate()
                            .filter(|(i, _)| *i != bi)
                            .min_by_key(|(_, c)| c.inflight.load(Ordering::Relaxed))
                            .expect("len > 1 after excluding one");
                        cells[bi]
                            .fetch_reqs
                            .lock()
                            .unwrap()
                            .push((tip, Arc::clone(&cells[tix])));
                        if let Some(st) = tj.trace.as_deref_mut() {
                            // Marker: a prefix transfer was brokered for
                            // this job (`n` = matched chain blocks).
                            st.phase_n(SpanKind::KvTransfer, now, len);
                        }
                        match cells[tix].direct.try_send(tj) {
                            Ok(()) => {
                                metrics
                                    .affinity_fallbacks
                                    .fetch_add(1, Ordering::Relaxed);
                                return None;
                            }
                            Err(back) => tj = back,
                        }
                    }
                }
            }
        }
        None => {
            if let Some(key) = affinity_key {
                // No cached match anywhere: rendezvous on a stable
                // replica for this key. Counted as a fallback — it is a
                // placement bet, not a cache hit.
                let i = (session_hash(key) % cells.len() as u64) as usize;
                match cells[i].direct.try_send(tj) {
                    Ok(()) => {
                        metrics.affinity_fallbacks.fetch_add(1, Ordering::Relaxed);
                        return None;
                    }
                    Err(back) => tj = back,
                }
            }
        }
    }
    metrics.affinity_fallbacks.fetch_add(1, Ordering::Relaxed);
    Some(tj)
}

/// Scale-from-zero: provision one replica for a tier that has queued
/// work but no live capacity (counted as a cold wake).
fn cold_wake<S: PoolBackend>(
    substrate: &mut S,
    registry: &mut Registry,
    metrics: &GatewayMetrics,
    shared: &PoolShared,
    ti: usize,
    now_s: f64,
) {
    let sid = substrate.service_of_tier(ti);
    {
        // `apply` provisions up from the registry's current counts;
        // refresh them for the canonical cell first.
        let svc = registry.get_mut(sid);
        svc.ready_replicas = shared.ready_count(ti);
        svc.pending_replicas = shared.pending_count(ti);
    }
    let spawned = crate::orchestrator::scaling::apply(
        &[ScaleAction::Up { service: sid, target: 1 }],
        registry,
        substrate,
        now_s,
    );
    if !spawned.is_empty() {
        metrics.cold_wakes.fetch_add(1, Ordering::Relaxed);
    }
}

/// Router-side admission gate (`pool.admission.enabled`): per-tier,
/// per-priority buffers sit between routing and the bounded tier queues,
/// drained by weighted-fair round-robin across priorities; the lowest
/// priority sheds past the watermark; a drain-rate EMA prices deadline
/// feasibility and the `Retry-After` hint. With admission off (the
/// default) the gate never enters the dispatch path and routing is the
/// exact legacy tier fan-out, bit for bit.
struct AdmissionGate {
    watermark: f64,
    weights: [usize; 3],
    cap: usize,
    /// Tier-queue feed depth per live replica: the pump keeps at most
    /// this many jobs in the FIFO tier queue per replica, so priority
    /// ordering stays in the gate's buffers instead of being flattened
    /// into a deep first-come queue.
    feed: usize,
    /// Buffered jobs awaiting dispatch, `[tier][priority]`.
    buf: [[VecDeque<TierJob>; 3]; 3],
    /// Weighted-fair cursor (current priority class) per tier.
    cls: [usize; 3],
    /// Dispatch credit left for the cursor's class, per tier.
    credit: [usize; 3],
    /// Jobs handed to each tier queue since boot (drain accounting).
    dispatched: [u64; 3],
    /// Observed per-tier drain rate, jobs/sec.
    rate: [crate::util::stats::Ema; 3],
    /// Last control-pass sample: (time, dispatched, queue length).
    last_sample: [(f64, u64, usize); 3],
}

impl AdmissionGate {
    fn new(pool: &PoolConfig) -> AdmissionGate {
        AdmissionGate {
            watermark: pool.admission.watermark.clamp(0.0, 1.0),
            weights: pool.admission.weights,
            cap: pool.queue_capacity.max(1),
            feed: pool.max_inflight.max(1),
            buf: std::array::from_fn(|_| std::array::from_fn(|_| VecDeque::new())),
            cls: [0; 3],
            credit: [pool.admission.weights[0].max(1); 3],
            dispatched: [0; 3],
            rate: std::array::from_fn(|_| crate::util::stats::Ema::new(0.3)),
            last_sample: [(0.0, 0, 0); 3],
        }
    }

    fn buffered(&self, ti: usize) -> usize {
        self.buf[ti].iter().map(|q| q.len()).sum()
    }

    fn has_buffered(&self) -> bool {
        (0..3).any(|ti| self.buffered(ti) > 0)
    }

    /// Predicted queue wait for work arriving at `ti` now, from the
    /// drain-rate EMA. `None` until a drain has been observed — the
    /// gate never rejects on a guess.
    fn est_wait(&self, ti: usize, backlog: usize) -> Option<f64> {
        let r = self.rate[ti].get()?;
        if r <= 1e-9 {
            return None;
        }
        Some((backlog as f64 + 1.0) / r)
    }

    /// The client back-off hint attached to 429s.
    fn retry_after(&self, ti: usize, backlog: usize) -> f64 {
        self.est_wait(ti, backlog).unwrap_or(1.0).clamp(0.05, 60.0)
    }

    /// Jobs that will be served before a priority-`pi` arrival at tier
    /// `ti`: the tier queue, every buffered job of the same or higher
    /// priority, and only the weighted-fair interleave share of
    /// lower-priority work — an interactive request does not wait behind
    /// a batch flood it is entitled to overtake.
    fn work_ahead(&self, ti: usize, pi: usize, queue_len: usize) -> usize {
        let cohort: usize = (0..=pi).map(|p| self.buf[ti][p].len()).sum();
        let wp = self.weights[pi].max(1);
        let mut ahead = queue_len + cohort;
        for q in (pi + 1)..3 {
            let share = (cohort * self.weights[q].max(1)).div_ceil(wp);
            ahead += self.buf[ti][q].len().min(share);
        }
        ahead
    }

    /// Gate one routed job: reject an infeasible deadline or a full
    /// backlog immediately, otherwise buffer it and shed the lowest
    /// priority past the watermark. Every outcome resolves the job
    /// exactly once — buffered, or replied with a typed error.
    fn admit(
        &mut self,
        ti: usize,
        mut tj: TierJob,
        now: f64,
        metrics: &GatewayMetrics,
        shared: &PoolShared,
        pressure: &mut [f64; 3],
    ) {
        let backlog = shared.queues[ti].len() + self.buffered(ti);
        if tj.deadline_abs_s.is_finite() {
            let ahead = self.work_ahead(ti, tj.priority.index(), shared.queues[ti].len());
            if let Some(wait) = self.est_wait(ti, ahead) {
                if now + wait > tj.deadline_abs_s {
                    // The deadline cannot be met at the measured drain
                    // rate: reject now instead of burning it in a queue.
                    metrics
                        .admission_rejected_deadline
                        .fetch_add(1, Ordering::Relaxed);
                    if let Some(st) = tj.trace.as_deref_mut() {
                        st.phase(SpanKind::Shed, now);
                    }
                    tj.reply.put(Err(CompletionError::new(
                        FailureKind::Shed,
                        format!(
                            "deadline infeasible: predicted queue wait {wait:.3}s"
                        ),
                    )
                    .retry_after(self.retry_after(ti, ahead))));
                    metrics.finish_request(
                        tj.trace.take(),
                        tj.tier,
                        tj.priority,
                        "shed",
                        now,
                        0,
                    );
                    metrics.bandit_feedback(
                        tj.tier,
                        tj.complexity,
                        tj.confidence,
                        false,
                        (now - tj.enqueue_s).max(0.0),
                    );
                    return;
                }
            }
        }
        if backlog >= self.cap {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            metrics.admission_rejected_backlog.fetch_add(1, Ordering::Relaxed);
            if let Some(st) = tj.trace.as_deref_mut() {
                st.phase(SpanKind::Shed, now);
            }
            tj.reply.put(Err(CompletionError::new(
                FailureKind::QueueFull,
                "tier queue full (backpressure)",
            )
            .retry_after(self.retry_after(ti, backlog))));
            metrics.finish_request(
                tj.trace.take(),
                tj.tier,
                tj.priority,
                "queue_full",
                now,
                0,
            );
            metrics.bandit_feedback(
                tj.tier,
                tj.complexity,
                tj.confidence,
                false,
                (now - tj.enqueue_s).max(0.0),
            );
            return;
        }
        self.buf[ti][tj.priority.index()].push_back(tj);
        // Watermark shed: protect interactive latency by dropping the
        // newest batch (then standard) work. Interactive is never shed —
        // it is bounded by the hard backlog cap instead.
        let wm = (self.watermark * self.cap as f64).ceil() as usize;
        while shared.queues[ti].len() + self.buffered(ti) > wm {
            let Some(pi) =
                [2usize, 1].into_iter().find(|&p| !self.buf[ti][p].is_empty())
            else {
                break;
            };
            let mut victim = self.buf[ti][pi].pop_back().expect("class non-empty");
            metrics.shed_total[pi][ti].fetch_add(1, Ordering::Relaxed);
            pressure[ti] += 1.0;
            let hint = self.retry_after(ti, self.buffered(ti));
            if let Some(st) = victim.trace.as_deref_mut() {
                st.phase(SpanKind::Shed, now);
            }
            victim.reply.put(Err(CompletionError::new(
                FailureKind::Shed,
                "shed: tier over watermark",
            )
            .retry_after(hint)));
            metrics.finish_request(
                victim.trace.take(),
                victim.tier,
                victim.priority,
                "shed",
                now,
                0,
            );
            metrics.bandit_feedback(
                victim.tier,
                victim.complexity,
                victim.confidence,
                false,
                (now - victim.enqueue_s).max(0.0),
            );
        }
    }

    /// Drain buffers into the tier queues, weighted-fair across
    /// priorities. Returns the tiers that received work while fully
    /// parked (the caller cold-wakes them).
    fn pump(
        &mut self,
        now: f64,
        metrics: &GatewayMetrics,
        shared: &PoolShared,
    ) -> Vec<usize> {
        let mut wake = Vec::new();
        for ti in 0..3 {
            loop {
                // Keep the FIFO tier queue shallow — enough to saturate
                // every live replica's slots, no more. The rest waits in
                // the priority buffers where weighted-fair order (and
                // shedding) still apply.
                let depth = self.feed * shared.live_count(ti).max(1);
                if shared.queues[ti].len() >= depth {
                    break;
                }
                let Some(pi) = self.next_class(ti) else { break };
                let mut tj = self.buf[ti][pi].pop_front().expect("class non-empty");
                if let Some(st) = tj.trace.as_deref_mut() {
                    // Residence in the priority buffers ends here —
                    // whatever comes next (dispatch, expiry, cancel).
                    st.phase(SpanKind::GateBuffered, now);
                }
                if now > tj.deadline_abs_s {
                    // Expired while buffered — the same dead-work drop
                    // the replicas apply at dequeue (expiry outranks
                    // cancellation; an abandoned deadline fires both).
                    metrics.shed_expired.fetch_add(1, Ordering::Relaxed);
                    tj.reply.put(Err(CompletionError::new(
                        FailureKind::DeadlineExpired,
                        "deadline expired before dispatch",
                    )));
                    metrics.finish_request(
                        tj.trace.take(),
                        tj.tier,
                        tj.priority,
                        "deadline_expired",
                        now,
                        0,
                    );
                    metrics.bandit_feedback(
                        tj.tier,
                        tj.complexity,
                        tj.confidence,
                        false,
                        (now - tj.enqueue_s).max(0.0),
                    );
                    continue;
                }
                if tj.cancel.is_cancelled() {
                    metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    metrics.finish_request(
                        tj.trace.take(),
                        tj.tier,
                        tj.priority,
                        "cancelled",
                        now,
                        0,
                    );
                    continue;
                }
                match shared.queues[ti].try_send(tj) {
                    Ok(()) => {
                        self.credit[ti] = self.credit[ti].saturating_sub(1);
                        self.dispatched[ti] += 1;
                        shared.last_enqueue_us[ti]
                            .store((now * 1e6) as u64, Ordering::Relaxed);
                        if shared.live_count(ti) == 0 && !wake.contains(&ti) {
                            wake.push(ti);
                        }
                    }
                    Err(back) => {
                        self.buf[ti][pi].push_front(back);
                        break;
                    }
                }
            }
        }
        wake
    }

    /// Weighted-fair class pick: serve the cursor's class while it has
    /// credit and work; cycling on resets each class's credit from its
    /// weight. `None` when every buffer for the tier is empty.
    fn next_class(&mut self, ti: usize) -> Option<usize> {
        if (0..3).all(|p| self.buf[ti][p].is_empty()) {
            return None;
        }
        for _ in 0..4 {
            let c = self.cls[ti];
            if self.credit[ti] > 0 && !self.buf[ti][c].is_empty() {
                return Some(c);
            }
            self.cls[ti] = (c + 1) % 3;
            self.credit[ti] = self.weights[self.cls[ti]].max(1);
        }
        None
    }

    /// Control-pass hook: difference dispatch/queue samples into the
    /// per-tier drain-rate EMA (jobs the tier consumed per second).
    fn sample_rates(&mut self, now: f64, shared: &PoolShared) {
        for ti in 0..3 {
            let qlen = shared.queues[ti].len();
            let (t0, d0, q0) = self.last_sample[ti];
            self.last_sample[ti] = (now, self.dispatched[ti], qlen);
            let dt = now - t0;
            if dt <= 0.0 {
                continue;
            }
            let fed = (self.dispatched[ti] - d0) as i64;
            let consumed = fed - (qlen as i64 - q0 as i64);
            if consumed > 0 {
                self.rate[ti].observe(consumed as f64 / dt);
            } else if qlen + self.buffered(ti) > 0 {
                // Backlogged but nothing drained: decay toward zero so
                // feasibility stops promising waits the tier can't meet.
                self.rate[ti].observe(0.0);
            }
        }
    }

    /// Teardown: every still-buffered job is answered the way draining
    /// replicas answer theirs — an orderly shutdown, not a serving
    /// error.
    fn fail_all_shutdown(&mut self, metrics: &GatewayMetrics, now: f64) {
        for tier in self.buf.iter_mut() {
            for q in tier.iter_mut() {
                for mut tj in q.drain(..) {
                    tj.reply.put(Err(CompletionError::new(
                        FailureKind::Shutdown,
                        "gateway shutting down",
                    )));
                    metrics.finish_request(
                        tj.trace.take(),
                        tj.tier,
                        tj.priority,
                        "shutdown",
                        now,
                        0,
                    );
                }
            }
        }
    }
}

/// One request riding a fallback chain (`pool.chains.*`): the caller's
/// reply is parked here while each hop dispatches with a private
/// rendezvous, so the machine decides after every hop — deliver,
/// escalate, degrade, or exhaust — and the caller is answered exactly
/// once.
struct PendingChain {
    caller: OneShot<Result<LiveResponse, CompletionError>>,
    cancel: CancelToken,
    /// The current hop's private rendezvous (polled, never parked on).
    hop: OneShot<Result<LiveResponse, CompletionError>>,
    /// Origin tier: the route label and the escalation-list key.
    origin: usize,
    /// Tier currently serving the hop.
    current: usize,
    /// Next unconsumed position in `routes[origin]`.
    next_idx: usize,
    /// Per-request retry budget remaining.
    hops_left: usize,
    /// A decided re-dispatch waiting out its exponential backoff:
    /// (target tier, not-before seconds).
    redispatch: Option<(usize, f64)>,
    /// The failure behind the last hop decision (what the caller sees
    /// if the chain exhausts with nothing in hand).
    last_err: Option<CompletionError>,
    /// A low-score completion kept while escalating for quality: if the
    /// upgrade hop dies, the caller still gets an answer, never an
    /// error.
    fallback: Option<LiveResponse>,
    prompt: String,
    max_tokens: usize,
    priority: Priority,
    deadline_abs_s: f64,
    complexity: usize,
    confidence: f64,
    /// Shared trace id across every hop (each hop runs its own span
    /// timeline; the `chain_hop{n}` marker links them).
    trace: Option<TraceCtx>,
    /// Hops dispatched so far — the `n` on the next hop's marker.
    hop_n: u32,
}

/// Pick the next chain hop: the first unconsumed escalation target with
/// a serving budget and queue headroom, else — under `chains.degrade` —
/// the least-backlogged smaller tier. Consumes one unit of per-request
/// budget and one of the gateway-wide retry-budget ratio; `None` means
/// the chain is exhausted. Returns (tier, degraded).
fn chain_pick_target(
    pc: &mut PendingChain,
    pool: &PoolConfig,
    shared: &PoolShared,
    metrics: &GatewayMetrics,
) -> Option<(usize, bool)> {
    if pc.hops_left == 0 {
        return None;
    }
    // Gateway-wide retry-budget ratio: retries never exceed the
    // configured fraction of fresh traffic, so a retry storm cannot
    // amplify an outage into a bigger one.
    let fresh = metrics.fresh_jobs.load(Ordering::Relaxed).max(1);
    let retries = metrics.retries_issued.load(Ordering::Relaxed);
    if retries as f64 >= pool.chains.retry_budget_ratio * fresh as f64 {
        return None;
    }
    let cap = pool.queue_capacity.max(1);
    let route = &pool.chains.routes[pc.origin];
    let mut pick: Option<(usize, bool)> = None;
    while pc.next_idx < route.len() {
        let t = route[pc.next_idx];
        pc.next_idx += 1;
        // A zero-budget tier can never serve; a full queue is saturated.
        // Skipped rungs are consumed — the chain moves up, never back.
        if pool.replicas[t] > 0 && shared.queues[t].len() < cap {
            pick = Some((t, false));
            break;
        }
    }
    if pick.is_none() && pool.chains.degrade {
        // Every remaining escalation target is saturated: degrade to
        // the least-backlogged smaller tier instead of failing outright.
        pick = (0..pc.current)
            .filter(|&t| pool.replicas[t] > 0 && shared.queues[t].len() < cap)
            .min_by_key(|&t| shared.queues[t].len())
            .map(|t| (t, true));
    }
    let (t, degraded) = pick?;
    pc.hops_left -= 1;
    metrics.retries_issued.fetch_add(1, Ordering::Relaxed);
    if degraded {
        metrics.chain_degraded[pc.origin].fetch_add(1, Ordering::Relaxed);
    } else {
        metrics.chain_escalated[pc.origin].fetch_add(1, Ordering::Relaxed);
    }
    Some((t, degraded))
}

/// Exponential backoff for the re-dispatch just consumed from the
/// budget: base, 2·base, 4·base, …
fn chain_backoff_s(pool: &PoolConfig, pc: &PendingChain) -> f64 {
    let used = (pool.chains.max_retries.saturating_sub(pc.hops_left)).max(1);
    pool.chains.backoff_base_s.max(0.0) * 2f64.powi(used as i32 - 1)
}

/// Dispatch a chain hop to tier `t` with a fresh rendezvous. False when
/// the target queue filled since it was picked — the caller re-advances
/// the chain (budget already spent on this pick).
fn chain_dispatch(
    pc: &mut PendingChain,
    t: usize,
    now: f64,
    shared: &PoolShared,
    tier_model: &[&'static str; 3],
) -> bool {
    let hop: OneShot<Result<LiveResponse, CompletionError>> = OneShot::new();
    // Each hop gets a fresh timeline under the shared trace id, opened
    // with a zero-length `chain_hop{n}` marker — the flight recorder
    // then holds one record per hop, all filterable by that id.
    pc.hop_n += 1;
    let hop_n = pc.hop_n;
    let trace = pc.trace.map(|ctx| {
        let mut st = Box::new(TraceState::new(ctx, now));
        st.phase_n(SpanKind::ChainHop, now, hop_n);
        st
    });
    let tj = TierJob {
        prompt: pc.prompt.clone(),
        max_tokens: pc.max_tokens,
        enqueue_s: now,
        ttft_s: 0.0,
        queue_wait_s: 0.0,
        counted_wait_s: 0.0,
        reply: hop.clone(),
        cancel: pc.cancel.clone(),
        tier: Tier::ALL[t],
        model: tier_model[t],
        complexity: pc.complexity,
        confidence: pc.confidence,
        priority: pc.priority,
        deadline_abs_s: pc.deadline_abs_s,
        trace,
    };
    match shared.queues[t].try_send(tj) {
        Ok(()) => {
            pc.hop = hop;
            pc.current = t;
            shared.last_enqueue_us[t].store((now * 1e6) as u64, Ordering::Relaxed);
            true
        }
        Err(_) => false,
    }
}

/// Resolve an exhausted chain: a kept low-score completion beats an
/// error; otherwise the caller gets a typed `ChainExhausted` carrying
/// the last hop failure.
fn chain_exhaust(pc: &mut PendingChain, metrics: &GatewayMetrics) {
    if let Some(resp) = pc.fallback.take() {
        pc.caller.put(Ok(resp));
        return;
    }
    metrics.chain_exhausted[pc.origin].fetch_add(1, Ordering::Relaxed);
    let last = pc
        .last_err
        .take()
        .map(|e| e.msg)
        .unwrap_or_else(|| "no remaining target".to_string());
    pc.caller.put(Err(CompletionError::new(
        FailureKind::ChainExhausted,
        format!("fallback chain exhausted: {last}"),
    )));
}

/// One poll of a chain entry: forward a resolved hop, escalate on
/// failure or a score below the floor, dispatch a matured backoff.
/// Returns whether the entry is still pending.
#[allow(clippy::too_many_arguments)]
fn chain_step<S: PoolBackend>(
    pc: &mut PendingChain,
    now: f64,
    pool: &PoolConfig,
    shared: &PoolShared,
    metrics: &GatewayMetrics,
    tier_model: &[&'static str; 3],
    tier_cap: &[[f64; 3]; 3],
    pressure: &mut [f64; 3],
    substrate: &mut S,
    registry: &mut Registry,
) -> bool {
    if pc.cancel.is_cancelled() {
        // The caller gave up; the shared token evicts the hop wherever
        // it is (the replica counts that), and nobody awaits the reply.
        return false;
    }
    if let Some((t, at)) = pc.redispatch {
        if now < at {
            return true;
        }
        pc.redispatch = None;
        let mut target = Some(t);
        while let Some(t) = target {
            if chain_dispatch(pc, t, now, shared, tier_model) {
                // Escalation pressure is extra demand on the target tier
                // — fold it into the scaler's next control pass.
                pressure[t] += 1.0;
                if shared.live_count(t) == 0 {
                    cold_wake(substrate, registry, metrics, shared, t, now);
                }
                return true;
            }
            // The picked queue filled during the backoff: advance.
            target = chain_pick_target(pc, pool, shared, metrics).map(|(t, _)| t);
        }
        chain_exhaust(pc, metrics);
        return false;
    }
    match pc.hop.try_take() {
        None => true,
        Some(Ok(resp)) => {
            let floor = pool.chains.score_floor;
            let low = floor > 0.0
                && crate::scoring::relevance(
                    &tier_cap[pc.current],
                    pc.complexity,
                    pc.confidence,
                ) < floor
                && pc.next_idx < pool.chains.routes[pc.origin].len();
            if low {
                if let Some((t, _)) = chain_pick_target(pc, pool, shared, metrics) {
                    // Quality escalation redispatches immediately (no
                    // backoff — this is an upgrade, not a failure storm)
                    // and keeps the in-hand answer as the floor.
                    pc.fallback = Some(resp);
                    pc.redispatch = Some((t, now));
                    return true;
                }
            }
            pc.caller.put(Ok(resp));
            false
        }
        Some(Err(e)) => {
            if e.kind == FailureKind::Shutdown {
                // Never retry across an orderly teardown.
                pc.caller.put(Err(e));
                return false;
            }
            pc.last_err = Some(e);
            match chain_pick_target(pc, pool, shared, metrics) {
                Some((t, _)) => {
                    pc.redispatch = Some((t, now + chain_backoff_s(pool, pc)));
                    true
                }
                None => {
                    chain_exhaust(pc, metrics);
                    false
                }
            }
        }
    }
}

/// Fixed selection-RNG seed for the live learner: bandit decisions are
/// reproducible run to run given the same outcome stream.
const BANDIT_SEED: u64 = 0x00ba_4d17_5eed;

/// The router/control thread: drain gateway jobs → classify → per-tier
/// queues, and every `scale_interval_s` run one control pass — substrate
/// lifecycle poll → recovery → Alg. 1 per tier — also while idle, so
/// scale-to-zero fires without traffic.
#[allow(clippy::too_many_arguments)]
fn router_loop<S: PoolBackend>(
    mut router: Box<dyn Router>,
    jobs: Channel<Job>,
    mut substrate: S,
    mut registry: Registry,
    metrics: Arc<GatewayMetrics>,
    pool: PoolConfig,
    orch: OrchestratorConfig,
    profile: Profile,
) {
    let shared = substrate.pool_shared();
    let weights = Weights::from_profile(&profile);
    // Alg. 1 over the three tiers, demand = queue depth + slot occupancy.
    let mut scaler = Scaler::for_pool(orch, 3, pool.max_inflight.max(1));
    let mut recovery = RecoveryManager::new(true);
    sync_registry(&mut registry, &shared, &pool);
    let mut last_ctl = f64::NEG_INFINITY;
    // Last-sampled per-tier prefix hit/miss totals: successive deltas
    // give a per-interval hit rate (recent traffic only).
    let mut prefix_last: [(u64, u64); 3] = [(0, 0); 3];
    // Same windowing for speculative accepted/drafted token totals — the
    // scaler's acceptance-rate demand discount tracks recent traffic.
    let mut spec_last: [(u64, u64); 3] = [(0, 0); 3];
    // Overload-control state. Both default off: with admission disabled
    // and no chains configured the arrival path below is the exact
    // legacy dispatch, bit for bit.
    let admission_on = pool.admission.enabled;
    let chains_on = pool.chains.any();
    let mut gate = AdmissionGate::new(&pool);
    let mut chains: Vec<PendingChain> = Vec::new();
    // Sheds + chain escalations per tier since the last control pass —
    // extra demand the scaler folds into Alg. 1.
    let mut pressure: [f64; 3] = [0.0; 3];
    // Per-tier model identity and capability vector: chain hops re-label
    // re-dispatched jobs, and the score floor consults the serving
    // tier's capability.
    let mut tier_model: [&'static str; 3] = ["", "", ""];
    let mut tier_cap: [[f64; 3]; 3] = [[0.0; 3]; 3];
    let mut tier_cost_rate: [f64; 3] = [0.0; 3];
    for ti in 0..3 {
        let svc = registry.get(substrate.service_of_tier(ti));
        tier_model[ti] = svc.spec.name;
        tier_cap[ti] = svc.spec.capability;
        tier_cost_rate[ti] = svc.spec.cost_per_replica_second();
    }
    // Learned routing (`pool.routing.bandit.enabled`): arm the learner
    // once, here, where tier capabilities and replica budgets are known.
    // The seed is fixed — selection is reproducible run to run given the
    // same outcome stream. Off (the default) the cell stays empty and
    // every bandit hook in the stack is a null-pointer check.
    if pool.routing.bandit.enabled {
        let allowed = [
            pool.replicas[0] > 0,
            pool.replicas[1] > 0,
            pool.replicas[2] > 0,
        ];
        let learner = TierBandit::new(
            &pool.routing.bandit,
            weights,
            tier_cap,
            allowed,
            BANDIT_SEED,
        );
        let _ = metrics.bandit.set(SharedBandit::new(learner, tier_cost_rate));
    }
    loop {
        // Poll fast while the gate holds buffered work or chains are in
        // flight; otherwise the legacy 100ms idle tick.
        let busy = (admission_on && gate.has_buffered())
            || (chains_on && !chains.is_empty());
        let job =
            jobs.recv_timeout(Duration::from_millis(if busy { 5 } else { 100 }));
        let now = shared.epoch.elapsed().as_secs_f64();
        if let Some(mut job) = job {
            if job.cancel.is_cancelled() {
                // The caller gave up while the job sat in the gateway
                // queue; don't spend routing on it.
                metrics.cancelled.fetch_add(1, Ordering::Relaxed);
            } else {
                match route_one(
                    &mut *router,
                    &registry,
                    &substrate,
                    weights,
                    &job.prompt,
                    job.max_tokens,
                ) {
                    Err(e) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        job.reply
                            .put(Err(CompletionError::internal(format!("{e:#}"))));
                    }
                    Ok((tier, model, class)) => {
                        // Zero-budget tiers are Unhealthy in the synced
                        // registry, so Alg. 2 cannot select one here.
                        // With the bandit armed the learned arm overrides
                        // the static pick (the static choice remains the
                        // fallback when no arm is eligible); eligibility
                        // excludes zero-budget tiers by construction.
                        let (tier, model) = match metrics.bandit.get() {
                            Some(b) => {
                                let bi =
                                    b.select(class.complexity, tier.index());
                                (Tier::ALL[bi], tier_model[bi])
                            }
                            None => (tier, model),
                        };
                        let ti = tier.index();
                        metrics.fresh_jobs.fetch_add(1, Ordering::Relaxed);
                        let mut trace = job.trace.take();
                        if let Some(st) = trace.as_deref_mut() {
                            // Admission + routing closed: a tier is
                            // chosen; everything before this is `admit`.
                            st.phase(SpanKind::Admit, now);
                        }
                        // A configured chain for this route parks the
                        // caller's reply in the chain machine and gives
                        // the first hop a private rendezvous.
                        let mut reply = job.reply;
                        if chains_on && !pool.chains.routes[ti].is_empty() {
                            let hop: OneShot<Result<LiveResponse, CompletionError>> =
                                OneShot::new();
                            chains.push(PendingChain {
                                caller: reply,
                                cancel: job.cancel.clone(),
                                hop: hop.clone(),
                                origin: ti,
                                current: ti,
                                next_idx: 0,
                                hops_left: pool.chains.max_retries,
                                redispatch: None,
                                last_err: None,
                                fallback: None,
                                prompt: job.prompt.clone(),
                                max_tokens: job.max_tokens,
                                priority: job.priority,
                                deadline_abs_s: job.deadline_abs_s,
                                complexity: class.complexity,
                                confidence: class.confidence,
                                trace: trace.as_deref().map(|st| st.ctx),
                                hop_n: 0,
                            });
                            reply = hop;
                        }
                        let tj = TierJob {
                            prompt: job.prompt,
                            max_tokens: job.max_tokens,
                            enqueue_s: now,
                            ttft_s: 0.0,
                            queue_wait_s: 0.0,
                            counted_wait_s: 0.0,
                            reply,
                            cancel: job.cancel,
                            tier,
                            model,
                            complexity: class.complexity,
                            confidence: class.confidence,
                            priority: job.priority,
                            deadline_abs_s: job.deadline_abs_s,
                            trace,
                        };
                        // Cache-affinity placement first (off = the
                        // exact legacy tier fan-out below, bit for bit).
                        let pending = if pool.affinity.enabled {
                            affinity_place(
                                &shared,
                                &pool,
                                &metrics,
                                ti,
                                job.affinity_key.as_deref(),
                                now,
                                tj,
                            )
                        } else {
                            Some(tj)
                        };
                        match pending {
                            None => {
                                // Placed on a ready replica's private
                                // queue; ready ⇒ the tier is live, no
                                // cold wake to consider.
                                shared.last_enqueue_us[ti]
                                    .store((now * 1e6) as u64, Ordering::Relaxed);
                            }
                            Some(tj) if admission_on => {
                                // Through the admission gate: feasibility
                                // + backlog checks, priority buffers,
                                // watermark shedding. Dispatch happens in
                                // the pump below.
                                gate.admit(
                                    ti,
                                    tj,
                                    now,
                                    &metrics,
                                    &shared,
                                    &mut pressure,
                                );
                            }
                            Some(tj) => match shared.queues[ti].try_send(tj) {
                                Ok(()) => {
                                    shared.last_enqueue_us[ti]
                                        .store((now * 1e6) as u64, Ordering::Relaxed);
                                    if shared.live_count(ti) == 0 {
                                        cold_wake(
                                            &mut substrate,
                                            &mut registry,
                                            &metrics,
                                            &shared,
                                            ti,
                                            now,
                                        );
                                    }
                                }
                                Err(mut tj) => {
                                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                                    if let Some(st) = tj.trace.as_deref_mut() {
                                        st.phase(SpanKind::Shed, now);
                                    }
                                    tj.reply.put(Err(CompletionError::new(
                                        FailureKind::QueueFull,
                                        "tier queue full (backpressure)",
                                    )));
                                    metrics.finish_request(
                                        tj.trace.take(),
                                        tj.tier,
                                        tj.priority,
                                        "queue_full",
                                        now,
                                        0,
                                    );
                                    metrics.bandit_feedback(
                                        tj.tier,
                                        tj.complexity,
                                        tj.confidence,
                                        false,
                                        0.0,
                                    );
                                }
                            },
                        }
                    }
                }
            }
        } else if jobs.is_closed() && jobs.is_empty() {
            if chains.is_empty() && !(admission_on && gate.has_buffered()) {
                break;
            }
            // Work is still in flight through the gate or a chain; the
            // closed jobs channel returns immediately now, so pace the
            // polling instead of spinning.
            std::thread::sleep(Duration::from_millis(2));
        }
        if admission_on {
            // Weighted-fair drain of the priority buffers into the tier
            // queues; tiers fed while fully parked get a cold wake.
            for ti in gate.pump(now, &metrics, &shared) {
                cold_wake(&mut substrate, &mut registry, &metrics, &shared, ti, now);
            }
        }
        if chains_on && !chains.is_empty() {
            let mut i = 0;
            while i < chains.len() {
                let keep = chain_step(
                    &mut chains[i],
                    now,
                    &pool,
                    &shared,
                    &metrics,
                    &tier_model,
                    &tier_cap,
                    &mut pressure,
                    &mut substrate,
                    &mut registry,
                );
                if keep {
                    i += 1;
                } else {
                    chains.swap_remove(i);
                }
            }
        }
        if now - last_ctl >= pool.scale_interval_s {
            last_ctl = now;
            // Lifecycle first: failures (panic / stall / injected) become
            // Incidents; recovery redeploys through the Substrate trait —
            // the same code path the simulator's Table 4 runs take.
            let events = substrate.poll(now);
            if !events.is_empty() {
                recovery.on_events(&events, &mut registry, &mut substrate, now);
            }
            metrics
                .incidents
                .store(recovery.incidents.len() as u64, Ordering::Relaxed);
            metrics
                .recovered
                .store(recovery.recovered() as u64, Ordering::Relaxed);
            metrics.recovery_us_total.store(
                (recovery.total_recovery_s() * 1e6) as u64,
                Ordering::Relaxed,
            );
            sync_registry(&mut registry, &shared, &pool);
            if admission_on {
                // Refresh the drain-rate EMAs behind deadline
                // feasibility and Retry-After hints.
                gate.sample_rates(now, &shared);
            }
            // Draft-tier availability for the speculative path: verify
            // tiers fall back to plain decode (loss-free) whenever the
            // draft tier is parked, unhealthy, or saturated. Published
            // once per control pass; the thread substrate's replica
            // loops sample the flag directly, the process substrate's
            // pumps relay edges as SpecDraft frames.
            if pool.speculative.enabled {
                let dt = pool.speculative.draft_tier.min(2);
                let ready = shared.ready_count(dt);
                let ok = registry.draft_tier_ready(dt)
                    && ready > 0
                    && shared.slots_in_tier(dt) < ready * pool.max_inflight.max(1);
                shared.spec_draft_ok.store(ok, Ordering::Relaxed);
            }
            for ti in 0..3 {
                // Windowed prefix hit rate: tokens served from cache vs
                // prefilled since the last control pass (replica churn
                // can shrink the cumulative sums — resync on regression).
                let (h, m) = shared.tier_prefix_totals(ti);
                let (lh, lm) = prefix_last[ti];
                let (dh, dm) = if h >= lh && m >= lm {
                    (h - lh, m - lm)
                } else {
                    (h, m)
                };
                prefix_last[ti] = (h, m);
                let (sa, sd) = shared.tier_spec_totals(ti);
                let (lsa, lsd) = spec_last[ti];
                let (dsa, dsd) =
                    if sa >= lsa && sd >= lsd { (sa - lsa, sd - lsd) } else { (sa, sd) };
                spec_last[ti] = (sa, sd);
                let load = TierLoad {
                    // Buffered work in the admission gate is queued
                    // demand the scaler must see, even though it has not
                    // reached the tier channel yet.
                    queue_depth: shared.queues[ti].len()
                        + if admission_on { gate.buffered(ti) } else { 0 },
                    slots_in_use: shared.slots_in_tier(ti),
                    active_replicas: shared.live_count(ti),
                    idle_s: now
                        - shared.last_enqueue_us[ti].load(Ordering::Relaxed) as f64
                            / 1e6,
                    prefix_hit_rate: if dh + dm == 0 {
                        0.0
                    } else {
                        dh as f64 / (dh + dm) as f64
                    },
                    spec_accept_rate: if dsd == 0 {
                        0.0
                    } else {
                        dsa as f64 / dsd as f64
                    },
                    pressure: pressure[ti],
                };
                pressure[ti] = 0.0;
                if let Some(action) = scaler.plan_tier(
                    ti,
                    substrate.service_of_tier(ti),
                    load,
                    pool.replicas[ti],
                    now,
                ) {
                    crate::orchestrator::scaling::apply(
                        &[action],
                        &mut registry,
                        &mut substrate,
                        now,
                    );
                }
                // Orphan guard: queued work must never sit in front of a
                // fully-parked tier (a job can land between the load
                // sample and a terminate draining the last replica).
                if !shared.queues[ti].is_empty() && shared.live_count(ti) == 0 {
                    cold_wake(&mut substrate, &mut registry, &metrics, &shared, ti, now);
                }
            }
            sync_registry(&mut registry, &shared, &pool);
        }
    }
    substrate.stop_all();
    // Final drain: anything the teardown left unresolved is answered
    // exactly once — a hop that finished during stop_all is forwarded, a
    // kept low-score completion beats an error, the rest get Shutdown.
    for mut pc in chains.drain(..) {
        if pc.cancel.is_cancelled() {
            continue;
        }
        match pc.hop.try_take() {
            Some(out) => pc.caller.put(out),
            None => {
                if let Some(resp) = pc.fallback.take() {
                    pc.caller.put(Ok(resp));
                } else {
                    pc.caller.put(Err(CompletionError::new(
                        FailureKind::Shutdown,
                        "gateway shutting down",
                    )));
                }
            }
        }
    }
    gate.fail_all_shutdown(&metrics, shared.epoch.elapsed().as_secs_f64());
}

/// Start the HTTP gateway over a live stack. Returns the bound server.
pub fn serve_http(stack: Arc<LiveStack>, port: u16, threads: usize) -> Result<http::HttpServer> {
    http::HttpServer::start(port, threads, move |req| {
        let (path, query) =
            req.path.split_once('?').unwrap_or((req.path.as_str(), ""));
        match (req.method.as_str(), path) {
            ("GET", "/healthz") => {
                http::Response::new(200, "text/plain", b"ok".to_vec())
            }
            ("GET", "/readyz") => handle_readyz(&stack),
            ("GET", "/debug/traces") => handle_traces(&stack, query),
            ("GET", "/metrics") => {
                let body =
                    crate::telemetry::export_prometheus(&stack.metrics_snapshot());
                http::Response::new(200, "text/plain", body.into_bytes())
            }
            ("POST", "/v1/completions") => {
                // Resolve the trace context here so the id can be echoed
                // on every response, success or failure.
                let ctx = stack.trace_ctx(req.header("traceparent"));
                let resp = match handle_completion(&stack, req, ctx) {
                    Ok(body) => http::Response::new(
                        200,
                        "application/json",
                        body.into_bytes(),
                    ),
                    Err(e) => {
                        // Typed failures map to honest status codes — 429
                        // for shed/queue-full (with a Retry-After hint from
                        // the observed drain rate), 503 for lost capacity,
                        // 504 for deadlines — instead of a blanket 500.
                        let (status, retry_after) =
                            match e.downcast_ref::<CompletionError>() {
                                Some(ce) => (ce.kind.http_status(), ce.retry_after_s),
                                None => (500, None),
                            };
                        let body = Json::obj(vec![(
                            "error",
                            Json::str(format!("{e:#}")),
                        )])
                        .dump()
                        .into_bytes();
                        let mut resp =
                            http::Response::new(status, "application/json", body);
                        if let Some(s) = retry_after {
                            resp = resp
                                .header("Retry-After", format!("{}", s.ceil().max(1.0)));
                        }
                        resp
                    }
                };
                match ctx {
                    Some(c) => resp.header("x-trace-id", c.id_hex()),
                    None => resp,
                }
            }
            _ => http::Response::new(404, "text/plain", b"not found".to_vec()),
        }
    })
}

/// `/readyz`: per-tier readiness. A tier is ready when it has at least
/// one Ready replica, is configured away (zero replica budget), or is
/// idle-parked by scale-to-zero with nothing queued; 503 until every
/// tier is.
fn handle_readyz(stack: &LiveStack) -> http::Response {
    let mut tiers = Vec::new();
    let mut all = true;
    for (ti, tier) in Tier::ALL.iter().enumerate() {
        let ready = stack.shared.ready_count(ti);
        let queued = stack.shared.queues[ti].len();
        let ok = stack.pool.replicas[ti] == 0
            || ready > 0
            || (stack.shared.live_count(ti) == 0 && queued == 0);
        all &= ok;
        tiers.push(Json::obj(vec![
            ("tier", Json::str(tier.name())),
            ("ready", Json::Bool(ok)),
            ("ready_replicas", Json::num(ready as f64)),
            ("queued", Json::num(queued as f64)),
        ]));
    }
    let body = Json::obj(vec![
        ("ready", Json::Bool(all)),
        ("tiers", Json::arr(tiers)),
    ])
    .dump();
    http::Response::new(
        if all { 200 } else { 503 },
        "application/json",
        body.into_bytes(),
    )
}

/// `/debug/traces`: newest-first JSON dump of the flight recorder ring.
/// Filters compose: `?tier=small`, `?outcome=ok`, `?slow_ms=250` (keep
/// only traces at least this slow end to end).
fn handle_traces(stack: &LiveStack, query: &str) -> http::Response {
    let mut tier: Option<&str> = None;
    let mut outcome: Option<&str> = None;
    let mut slow_s = 0.0f64;
    for kv in query.split('&') {
        let Some((k, v)) = kv.split_once('=') else { continue };
        match k {
            "tier" => tier = Some(v),
            "outcome" => outcome = Some(v),
            "slow_ms" => slow_s = v.parse::<f64>().unwrap_or(0.0) / 1e3,
            _ => {}
        }
    }
    let recs = stack.metrics.recorder.snapshot();
    let body = Json::arr(
        recs.iter()
            .filter(|r| tier.map_or(true, |t| r.tier == t))
            .filter(|r| outcome.map_or(true, |o| r.outcome == o))
            .filter(|r| r.total_s >= slow_s)
            .map(|r| r.to_json()),
    )
    .dump();
    http::Response::new(200, "application/json", body.into_bytes())
}

fn handle_completion(
    stack: &LiveStack,
    req: &http::Request,
    trace: Option<TraceCtx>,
) -> Result<String> {
    let j = Json::parse(req.body_str()?)?;
    let prompt = j.rstr("prompt")?;
    let max_tokens = j.usize_or("max_tokens", 16).min(64);
    let mut creq = CompletionRequest::new(prompt).max_tokens(max_tokens);
    if let Some(ctx) = trace {
        creq = creq.trace_ctx(ctx);
    }
    // Optional affinity/session key and per-request deadline — the same
    // fields the builder API takes, reachable over HTTP.
    if let Some(key) = j
        .get("affinity_key")
        .and_then(Json::as_str)
        .or_else(|| j.get("session").and_then(Json::as_str))
    {
        creq = creq.affinity_key(key);
    }
    if let Some(d) = j.get("deadline_s").and_then(Json::as_f64) {
        creq = creq.deadline_s(d);
    }
    if let Some(p) = j.get("priority").and_then(Json::as_str) {
        let p = Priority::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown priority {p:?}"))?;
        creq = creq.priority(p);
    }
    let r = stack.complete_request(creq)?;
    Ok(Json::obj(vec![
        ("model", Json::str(r.model)),
        ("tier", Json::str(r.tier.clone())),
        ("complexity", Json::num(r.complexity as f64)),
        ("confidence", Json::num(r.confidence)),
        ("ttft_s", Json::num(r.ttft_s)),
        ("latency_s", Json::num(r.latency_s)),
        ("queue_wait_s", Json::num(r.queue_wait_s)),
        ("prompt_tokens", Json::num(r.prompt_tokens as f64)),
        (
            "tokens",
            Json::arr(r.tokens.iter().map(|&t| Json::num(t as f64))),
        ),
    ])
    .dump())
}
