//! API Gateway — the entry point of Fig. 1, plus the live serving stack.
//!
//! Two layers:
//! * [`http`] — the from-scratch HTTP/1.1 substrate.
//! * [`LiveStack`] — the real serving path: an engine thread that owns
//!   the PJRT runtime (classifier + the three compiled LM tiers; PJRT
//!   handles are not `Send`, so the thread *creates* them) and serves
//!   jobs from a bounded channel (admission control / backpressure).
//!
//! Requests: `POST /v1/completions {"prompt": "...", "max_tokens": N}` →
//! routed by the hybrid router, executed on the tier the matrix picks,
//! answered with token ids + timing. `GET /healthz`, `GET /metrics`.

pub mod http;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{Config, RouterMode};
use crate::models::{zoo, Tier};
use crate::registry::Registry;
use crate::router::hybrid::HybridRouter;
use crate::router::keyword::KeywordRouter;
use crate::router::{Classification, Router};
use crate::runtime::Runtime;
use crate::scoring::Weights;
use crate::util::json::Json;
use crate::util::threadpool::{Channel, OneShot};

/// A live completion response.
#[derive(Debug, Clone)]
pub struct LiveResponse {
    pub tokens: Vec<i32>,
    pub tier: String,
    pub model: &'static str,
    pub complexity: usize,
    pub confidence: f64,
    pub ttft_s: f64,
    pub latency_s: f64,
    pub prompt_tokens: usize,
}

struct Job {
    prompt: String,
    max_tokens: usize,
    reply: OneShot<Result<LiveResponse, String>>,
}

/// Counters exported at `/metrics`.
#[derive(Default)]
pub struct GatewayMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub rejected: AtomicU64,
    pub tokens_out: AtomicU64,
}

/// The live serving stack: hybrid router + three compiled LM tiers on a
/// dedicated engine thread.
pub struct LiveStack {
    jobs: Channel<Job>,
    pub metrics: Arc<GatewayMetrics>,
    engine: Option<std::thread::JoinHandle<()>>,
}

impl LiveStack {
    /// Spin up the engine thread (compiles artifacts — takes a few
    /// seconds; returns after the engines are warm).
    pub fn start(cfg: &Config) -> Result<LiveStack> {
        let jobs: Channel<Job> = Channel::bounded(cfg.gateway.queue_capacity);
        let metrics = Arc::new(GatewayMetrics::default());
        let rx = jobs.clone();
        let artifacts = cfg.paths.artifacts.clone();
        let router_cfg = cfg.router.clone();
        let profile = cfg.profile;
        let ready: OneShot<Result<(), String>> = OneShot::new();
        let ready_tx = ready.clone();
        let metrics2 = Arc::clone(&metrics);
        let engine = std::thread::Builder::new()
            .name("engine".into())
            .spawn(move || {
                // PJRT objects live and die on this thread.
                let mut rt = match Runtime::load(&artifacts) {
                    Ok(rt) => rt,
                    Err(e) => {
                        ready_tx.put(Err(format!("runtime: {e:#}")));
                        return;
                    }
                };
                let classifier = match rt.classifier_engine() {
                    Ok(c) => c,
                    Err(e) => {
                        ready_tx.put(Err(format!("classifier: {e:#}")));
                        return;
                    }
                };
                let mut engines = Vec::new();
                for tier in ["small", "medium", "large"] {
                    match rt.lm_engine(tier, &[1, 4]) {
                        Ok(e) => engines.push(e),
                        Err(e) => {
                            ready_tx.put(Err(format!("lm {tier}: {e:#}")));
                            return;
                        }
                    }
                }
                // Routing state: the registry scores the matrix; live
                // replicas are the in-process engines (1 each).
                let zoo_models = zoo();
                let mut registry = Registry::new(&zoo_models, 300.0);
                for s in &mut registry.services {
                    s.ready_replicas = 1;
                }
                let weights = Weights::from_profile(&profile);
                let mut router: Box<dyn Router> = match router_cfg.mode {
                    RouterMode::Keyword => Box::new(KeywordRouter::new()),
                    _ => Box::new(HybridRouter::new(classifier, &router_cfg)),
                };
                ready_tx.put(Ok(()));
                while let Some(job) = rx.recv() {
                    let out = serve_one(
                        &mut *router,
                        &registry,
                        weights,
                        &engines,
                        &job.prompt,
                        job.max_tokens,
                    );
                    match &out {
                        Ok(r) => {
                            metrics2.completed.fetch_add(1, Ordering::Relaxed);
                            metrics2
                                .tokens_out
                                .fetch_add(r.tokens.len() as u64, Ordering::Relaxed);
                        }
                        Err(_) => {
                            metrics2.errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    job.reply.put(out.map_err(|e| format!("{e:#}")));
                }
            })?;
        match ready.wait() {
            Ok(()) => Ok(LiveStack { jobs, metrics, engine: Some(engine) }),
            Err(e) => Err(anyhow!("engine thread failed to start: {e}")),
        }
    }

    /// Serve one prompt (blocks until the engine thread answers).
    pub fn complete(&self, prompt: &str, max_tokens: usize) -> Result<LiveResponse> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let reply: OneShot<Result<LiveResponse, String>> = OneShot::new();
        let job = Job {
            prompt: prompt.to_string(),
            max_tokens,
            reply: reply.clone(),
        };
        if self.jobs.try_send(job).is_err() {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("queue full (backpressure)"));
        }
        reply.wait().map_err(|e| anyhow!(e))
    }

    pub fn shutdown(mut self) {
        self.jobs.close();
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }
}

impl Drop for LiveStack {
    fn drop(&mut self) {
        self.jobs.close();
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }
}

/// Route + execute one prompt on the in-process engines.
fn serve_one(
    router: &mut dyn Router,
    registry: &Registry,
    weights: Weights,
    engines: &[crate::runtime::LmEngine],
    prompt: &str,
    max_tokens: usize,
) -> Result<LiveResponse> {
    let class: Classification = router.route(prompt)?;
    // Alg. 2 over the matrix picks the model; its engine tier executes.
    let in_tokens = crate::tokenizer::word_count(prompt).max(1) as f64;
    let out_est = 0.5 * max_tokens as f64;
    let sel = crate::orchestrator::select(
        registry, weights, &class, in_tokens, out_est, |_| 0.0,
    )
    .ok_or_else(|| anyhow!("no routable service"))?;
    let svc = registry.get(sel.service);
    let tier: Tier = svc.spec.tier;
    let engine = &engines[tier.index()];
    let gen = engine.generate(prompt, max_tokens)?;
    Ok(LiveResponse {
        tokens: gen.tokens,
        tier: tier.name().to_string(),
        model: svc.spec.name,
        complexity: class.complexity,
        confidence: class.confidence,
        ttft_s: gen.ttft_s,
        latency_s: gen.latency_s,
        prompt_tokens: gen.prompt_tokens,
    })
}

/// Start the HTTP gateway over a live stack. Returns the bound server.
pub fn serve_http(stack: Arc<LiveStack>, port: u16, threads: usize) -> Result<http::HttpServer> {
    http::HttpServer::start(port, threads, move |req| {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => (200, "text/plain".into(), b"ok".to_vec()),
            ("GET", "/metrics") => {
                let m = &stack.metrics;
                let body = crate::telemetry::export_prometheus(&[
                    ("ps_requests_total".into(),
                     m.requests.load(Ordering::Relaxed) as f64),
                    ("ps_completed_total".into(),
                     m.completed.load(Ordering::Relaxed) as f64),
                    ("ps_errors_total".into(),
                     m.errors.load(Ordering::Relaxed) as f64),
                    ("ps_rejected_total".into(),
                     m.rejected.load(Ordering::Relaxed) as f64),
                    ("ps_tokens_out_total".into(),
                     m.tokens_out.load(Ordering::Relaxed) as f64),
                ]);
                (200, "text/plain".into(), body.into_bytes())
            }
            ("POST", "/v1/completions") => match handle_completion(&stack, req) {
                Ok(body) => (200, "application/json".into(), body.into_bytes()),
                Err(e) => (
                    500,
                    "application/json".into(),
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))])
                        .dump()
                        .into_bytes(),
                ),
            },
            _ => (404, "text/plain".into(), b"not found".to_vec()),
        }
    })
}

fn handle_completion(stack: &LiveStack, req: &http::Request) -> Result<String> {
    let j = Json::parse(req.body_str()?)?;
    let prompt = j.rstr("prompt")?;
    let max_tokens = j.usize_or("max_tokens", 16).min(64);
    let r = stack.complete(prompt, max_tokens)?;
    Ok(Json::obj(vec![
        ("model", Json::str(r.model)),
        ("tier", Json::str(r.tier.clone())),
        ("complexity", Json::num(r.complexity as f64)),
        ("confidence", Json::num(r.confidence)),
        ("ttft_s", Json::num(r.ttft_s)),
        ("latency_s", Json::num(r.latency_s)),
        ("prompt_tokens", Json::num(r.prompt_tokens as f64)),
        (
            "tokens",
            Json::arr(r.tokens.iter().map(|&t| Json::num(t as f64))),
        ),
    ])
    .dump())
}
