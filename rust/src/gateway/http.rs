//! Minimal HTTP/1.1 substrate (no hyper/axum offline): request parser,
//! response writer, and a threadpool-backed listener loop.
//!
//! Supports exactly what the gateway needs: GET/POST, Content-Length
//! bodies, JSON payloads, keep-alive off (connection: close per
//! response) — deliberately boring and easy to audit.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::util::threadpool::ThreadPool;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|e| anyhow!("body utf8: {e}"))
    }
}

/// Parse one request from a stream.
pub fn parse_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("missing path"))?.to_string();
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h
            .split_once(':')
            .ok_or_else(|| anyhow!("bad header `{h}`"))?;
        let k = k.trim().to_string();
        let v = v.trim().to_string();
        if k.eq_ignore_ascii_case("content-length") {
            content_length = v.parse()?;
        }
        headers.push((k, v));
    }
    if content_length > 8 * 1024 * 1024 {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, headers, body })
}

/// A response a handler hands back: status, content type, body, and any
/// extra headers (`Retry-After` on 429s). Handlers that only need the
/// basics can keep returning the `(status, content-type, body)` tuple —
/// it converts.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
    pub headers: Vec<(String, String)>,
}

impl Response {
    pub fn new(
        status: u16,
        content_type: impl Into<String>,
        body: Vec<u8>,
    ) -> Response {
        Response {
            status,
            content_type: content_type.into(),
            body,
            headers: Vec::new(),
        }
    }

    /// Attach an extra header.
    pub fn header(
        mut self,
        name: impl Into<String>,
        value: impl Into<String>,
    ) -> Response {
        self.headers.push((name.into(), value.into()));
        self
    }
}

impl From<(u16, String, Vec<u8>)> for Response {
    fn from((status, content_type, body): (u16, String, Vec<u8>)) -> Response {
        Response::new(status, content_type, body)
    }
}

/// Write a response.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    write_response_headers(stream, status, content_type, body, &[])
}

/// Write a response with extra headers.
pub fn write_response_headers(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
    extra: &[(String, String)],
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\ncontent-type: {content_type}\r\n\
         content-length: {}\r\nconnection: close\r\n",
        body.len()
    )?;
    for (k, v) in extra {
        write!(stream, "{k}: {v}\r\n")?;
    }
    write!(stream, "\r\n")?;
    stream.write_all(body)?;
    Ok(())
}

/// A running HTTP server; `stop()` makes `serve` return.
pub struct HttpServer {
    pub port: u16,
    stop: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind and serve on a threadpool; `handler` maps requests to a
    /// [`Response`] (or a `(status, content-type, body)` tuple). Returns
    /// once bound, serving on a background thread.
    pub fn start<F, R>(port: u16, threads: usize, handler: F) -> Result<HttpServer>
    where
        F: Fn(&Request) -> R + Send + Sync + 'static,
        R: Into<Response>,
    {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let actual_port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler = Arc::new(handler);
        std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(threads, "http");
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            let h = Arc::clone(&handler);
                            pool.execute(move || {
                                let _ = stream.set_nodelay(true);
                                match parse_request(&mut stream) {
                                    Ok(req) => {
                                        let resp: Response = h(&req).into();
                                        let _ = write_response_headers(
                                            &mut stream,
                                            resp.status,
                                            &resp.content_type,
                                            &resp.body,
                                            &resp.headers,
                                        );
                                    }
                                    Err(e) => {
                                        let _ = write_response(
                                            &mut stream,
                                            400,
                                            "text/plain",
                                            e.to_string().as_bytes(),
                                        );
                                    }
                                }
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                pool.shutdown();
            })?;
        Ok(HttpServer { port: actual_port, stop })
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Tiny HTTP client for tests/examples (same substrate, reversed).
pub fn http_request(
    port: u16,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, String)> {
    let (status, _, body) = http_request_full(port, method, path, body)?;
    Ok((status, body))
}

/// Like [`http_request`] but also returns the response headers, so
/// callers can inspect `Retry-After` and friends.
pub fn http_request_full(
    port: u16,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, Vec<(String, String)>, String)> {
    http_request_with_headers(port, method, path, &[], body)
}

/// Full-control variant: send extra request headers (`traceparent` and
/// friends) alongside the standard set.
pub fn http_request_with_headers(
    port: u16,
    method: &str,
    path: &str,
    extra_headers: &[(&str, &str)],
    body: Option<&str>,
) -> Result<(u16, Vec<(String, String)>, String)> {
    let mut stream = TcpStream::connect(("127.0.0.1", port))?;
    let body = body.unwrap_or("");
    let mut extra = String::new();
    for (k, v) in extra_headers {
        extra.push_str(&format!("{k}: {v}\r\n"));
    }
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nhost: localhost\r\n\
         content-length: {}\r\ncontent-type: application/json\r\n{extra}\r\n{body}",
        body.len()
    )?;
    let mut reader = BufReader::new(&mut stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("bad status line"))?
        .parse()?;
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let (k, v) = (k.trim().to_string(), v.trim().to_string());
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse()?;
            }
            headers.push((k, v));
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, headers, String::from_utf8(body)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let srv = HttpServer::start(0, 2, |req| {
            assert_eq!(req.method, "POST");
            let echo = format!("path={} body={}", req.path, req.body_str().unwrap());
            (200, "text/plain".into(), echo.into_bytes())
        })
        .unwrap();
        let (status, body) =
            http_request(srv.port, "POST", "/echo", Some("hello")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "path=/echo body=hello");
        srv.stop();
    }

    #[test]
    fn get_without_body() {
        let srv = HttpServer::start(0, 2, |req| match req.path.as_str() {
            "/healthz" => (200, "text/plain".into(), b"ok".to_vec()),
            _ => (404, "text/plain".into(), b"nope".to_vec()),
        })
        .unwrap();
        let (s1, b1) = http_request(srv.port, "GET", "/healthz", None).unwrap();
        assert_eq!((s1, b1.as_str()), (200, "ok"));
        let (s2, _) = http_request(srv.port, "GET", "/missing", None).unwrap();
        assert_eq!(s2, 404);
    }

    #[test]
    fn response_extra_headers_round_trip() {
        let srv = HttpServer::start(0, 2, |_req| {
            Response::new(429, "text/plain", b"slow down".to_vec())
                .header("Retry-After", "3")
        })
        .unwrap();
        let (status, headers, body) =
            http_request_full(srv.port, "GET", "/", None).unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, "slow down");
        let ra = headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("retry-after"))
            .map(|(_, v)| v.as_str());
        assert_eq!(ra, Some("3"));
    }

    #[test]
    fn concurrent_requests() {
        let srv = HttpServer::start(0, 4, |_req| {
            std::thread::sleep(std::time::Duration::from_millis(20));
            (200, "text/plain".into(), b"done".to_vec())
        })
        .unwrap();
        let port = srv.port;
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(move || {
                    http_request(port, "GET", "/", None).unwrap().0
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
    }
}
