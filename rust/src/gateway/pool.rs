//! LocalSubstrate — the live engine pool as a [`Substrate`].
//!
//! Each replica is one OS thread that builds its own engine (PJRT
//! handles are not `Send`) and runs a
//! [`crate::backend::scheduler::Scheduler`] over its tier's queue. The
//! thread publishes its lifecycle through a shared [`ReplicaCell`]:
//! Scheduled (spawned) → Loading (engine compile/warm-up) → Ready
//! (scheduler loop running) → Terminating/Gone, or Failed (panic, kill
//! hook, stalled heartbeat). The router thread owns the substrate and
//! drives it exactly like the simulator drives its cluster: provision,
//! terminate, poll for events, hand failures to the
//! [`crate::orchestrator::recovery::RecoveryManager`].
//!
//! Cold-wake latency on the live path is therefore *real*: a
//! scaled-to-zero tier's next replica pays engine construction in
//! Loading, and the measured provision→Ready time feeds the same
//! cold-start estimate Alg. 2 uses for scaled-to-zero penalties.

use std::collections::BTreeMap;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::batcher::BatchPolicy;
use crate::backend::scheduler::{
    Admit, CancelToken, Finished, Scheduler, SchedulerConfig, StepEngine,
};
use crate::config::{PoolConfig, Priority};
use crate::models::{BackendKind, ModelSpec, Tier};
use crate::registry::{Registry, ServiceId};
use crate::substrate::{ReplicaId, ReplicaState, Substrate, SubstrateEvent};
use crate::telemetry::trace::{SpanKind, TraceState};
use crate::util::stats::Ema;
use crate::util::threadpool::{Channel, OneShot};

use super::{CompletionError, FailureKind, GatewayMetrics, LiveResponse};

/// A routed job queued for one tier's replicas.
pub(crate) struct TierJob {
    pub prompt: String,
    pub max_tokens: usize,
    /// Seconds (pool epoch) when routing enqueued the job.
    pub enqueue_s: f64,
    /// Stamped when prefill completes (first token).
    pub ttft_s: f64,
    pub queue_wait_s: f64,
    /// Wait seconds already added to `ps_queue_wait_seconds_total` —
    /// a job requeued off a failed replica re-admits, and only the
    /// delta may count again.
    pub counted_wait_s: f64,
    pub reply: OneShot<Result<LiveResponse, CompletionError>>,
    /// Set by a timed-out caller; checked at admission and every tick.
    pub cancel: CancelToken,
    pub tier: Tier,
    pub model: &'static str,
    pub complexity: usize,
    pub confidence: f64,
    /// Admission class (shed order, weighted-fair dequeue, wait
    /// histograms). `Standard` for unlabelled work.
    pub priority: Priority,
    /// Absolute per-request deadline, seconds since the pool epoch;
    /// `f64::INFINITY` when the caller set none. Work past its deadline
    /// is dropped at dequeue instead of charged to a replica.
    pub deadline_abs_s: f64,
    /// Per-request span accumulator (`None` = untraced: the trace-off
    /// path carries a null pointer and does no tracing work at all).
    pub trace: Option<Box<TraceState>>,
}

// Replica lifecycle wire encoding (`ReplicaCell::state`) — shared with
// the process substrate's supervisor (`substrate::remote`), whose pump
// threads publish the same lifecycle through the same cells.
pub(crate) const S_SCHEDULED: u8 = 0;
pub(crate) const S_LOADING: u8 = 1;
pub(crate) const S_READY: u8 = 2;
pub(crate) const S_TERMINATING: u8 = 3;
pub(crate) const S_FAILED: u8 = 4;
pub(crate) const S_GONE: u8 = 5;

pub(crate) fn decode_state(raw: u8) -> Option<ReplicaState> {
    match raw {
        S_SCHEDULED => Some(ReplicaState::Scheduled),
        S_LOADING => Some(ReplicaState::Loading),
        S_READY => Some(ReplicaState::Ready),
        S_TERMINATING => Some(ReplicaState::Terminating),
        S_FAILED => Some(ReplicaState::Failed),
        _ => None, // S_GONE: replica no longer exists
    }
}

/// One exported prefix: the cached token blocks of a chain, root-first.
pub(crate) type BlockRun = Vec<Vec<i32>>;

/// Bound on one replica's private affinity queue. Shallow on purpose:
/// affinity should steer work, not pile it up behind one hot replica —
/// when the direct queue is full the router falls back to the shared
/// tier queue and another replica serves (and then warms up) the prefix.
pub(crate) const DIRECT_QUEUE_CAP: usize = 32;

/// Lifecycle mailbox between one replica thread and the control plane.
pub(crate) struct ReplicaCell {
    pub state: AtomicU8,
    /// Last loop heartbeat, µs since the pool epoch (stall detection).
    pub heartbeat_us: AtomicU64,
    /// When the replica reached Ready, µs since the pool epoch.
    pub ready_us: AtomicU64,
    /// Fault-injection hook: the replica dies abruptly at its next
    /// heartbeat, requeueing its in-flight work.
    pub kill: AtomicBool,
    /// Graceful stop: drain in-flight work, then exit.
    pub stop: AtomicBool,
    /// Occupied decode slots (buffered prefills included).
    pub inflight: AtomicUsize,
    /// Prompt tokens this replica served from its prefix cache
    /// (cumulative — the control loop's cache-adjusted demand signal).
    pub prefix_hit_tokens: AtomicU64,
    /// Prompt tokens this replica had to prefill (cumulative).
    pub prefix_miss_tokens: AtomicU64,
    /// Blocks resident in this replica's prefix cache (gauge).
    pub prefix_cache_blocks: AtomicU64,
    /// Hot-prefix summary this replica last advertised: top-K cached
    /// chain tips as `(chain_hash, chain_len_blocks)`, recency-ordered.
    /// Published by the replica thread (or the supervisor pump from
    /// heartbeat/`PrefixAd` frames); read by the router's affinity
    /// scorer. Empty when affinity is off.
    pub hot: Mutex<Vec<(u64, u32)>>,
    /// Private affinity queue, drained ahead of the shared tier queue.
    /// Only the affinity router enqueues here — with affinity off it
    /// stays empty and dispatch is exactly the legacy tier fan-out.
    pub direct: Channel<TierJob>,
    /// Donor-side transfer inbox: `(chain_tip_hash, target cell)` pairs
    /// posted by the router. The replica exports the cached run and
    /// pushes it into the target's `incoming`.
    pub fetch_reqs: Mutex<Vec<(u64, Arc<ReplicaCell>)>>,
    /// Target-side transfer inbox: block runs awaiting import into this
    /// replica's prefix cache.
    pub incoming: Mutex<Vec<BlockRun>>,
    /// Requests the affinity router placed here for a prefix match
    /// (cumulative; the per-replica `/metrics` series).
    pub affinity_hits: AtomicU64,
    /// Summed matched chain length, in KV blocks, across those hits.
    pub affinity_match_blocks: AtomicU64,
    /// Speculative-decoding counters (cumulative, mirroring the prefix
    /// counters): draft tokens proposed, accepted, rejected, and verify
    /// steps run. The control loop differences accepted/drafted into the
    /// windowed acceptance rate that tempers `Scaler::plan_tier`.
    pub spec_drafted_tokens: AtomicU64,
    pub spec_accepted_tokens: AtomicU64,
    pub spec_rejected_tokens: AtomicU64,
    pub spec_verify_steps: AtomicU64,
    /// Engine-factory error (set when Loading fails).
    pub error: Mutex<Option<String>>,
}

impl ReplicaCell {
    pub(crate) fn new() -> ReplicaCell {
        ReplicaCell {
            state: AtomicU8::new(S_SCHEDULED),
            heartbeat_us: AtomicU64::new(0),
            ready_us: AtomicU64::new(0),
            kill: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            prefix_hit_tokens: AtomicU64::new(0),
            prefix_miss_tokens: AtomicU64::new(0),
            prefix_cache_blocks: AtomicU64::new(0),
            hot: Mutex::new(Vec::new()),
            direct: Channel::bounded(DIRECT_QUEUE_CAP),
            fetch_reqs: Mutex::new(Vec::new()),
            incoming: Mutex::new(Vec::new()),
            affinity_hits: AtomicU64::new(0),
            affinity_match_blocks: AtomicU64::new(0),
            spec_drafted_tokens: AtomicU64::new(0),
            spec_accepted_tokens: AtomicU64::new(0),
            spec_rejected_tokens: AtomicU64::new(0),
            spec_verify_steps: AtomicU64::new(0),
            error: Mutex::new(None),
        }
    }
}

/// One tier's replica cells, in provision order.
type TierCells = Mutex<Vec<(ReplicaId, Arc<ReplicaCell>)>>;

/// State shared between the [`super::LiveStack`] handle (introspection,
/// fault injection), the router thread (control plane) and the replica
/// threads (data plane).
pub(crate) struct PoolShared {
    pub epoch: Instant,
    /// Per-tier bounded job queues (router → replicas).
    pub queues: Vec<Channel<TierJob>>,
    /// Per-tier replica cells.
    pub cells: Vec<TierCells>,
    /// Last enqueue per tier, µs since the pool epoch (idle tracking).
    pub last_enqueue_us: [AtomicU64; 3],
    /// Draft-tier availability for cross-tier speculation: the router's
    /// control pass sets it true while the paired draft tier is live and
    /// unsaturated; replica threads sample it every loop and fall back
    /// to plain decode the moment it drops. Starts false — verify tiers
    /// never speculate before the draft tier is confirmed warm.
    pub spec_draft_ok: Arc<AtomicBool>,
}

impl PoolShared {
    pub fn new(epoch: Instant, queue_capacity: usize) -> PoolShared {
        PoolShared {
            epoch,
            queues: (0..3).map(|_| Channel::bounded(queue_capacity.max(1))).collect(),
            cells: (0..3).map(|_| Mutex::new(Vec::new())).collect(),
            last_enqueue_us: std::array::from_fn(|_| AtomicU64::new(0)),
            spec_draft_ok: Arc::new(AtomicBool::new(false)),
        }
    }

    fn count_states(&self, tier: usize, pred: impl Fn(u8) -> bool) -> usize {
        self.cells[tier]
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, c)| pred(c.state.load(Ordering::Acquire)))
            .count()
    }

    /// Replicas holding capacity in a tier (pre-Ready or Ready).
    pub fn live_count(&self, tier: usize) -> usize {
        self.count_states(tier, |s| s <= S_READY)
    }

    pub fn ready_count(&self, tier: usize) -> usize {
        self.count_states(tier, |s| s == S_READY)
    }

    pub fn pending_count(&self, tier: usize) -> usize {
        self.count_states(tier, |s| s == S_SCHEDULED || s == S_LOADING)
    }

    /// Live replicas across the pool — the scale-to-zero observable.
    pub fn live_total(&self) -> usize {
        (0..3).map(|t| self.live_count(t)).sum()
    }

    /// Occupied decode slots across the pool.
    pub fn slots_in_use(&self) -> usize {
        self.cells
            .iter()
            .map(|m| {
                m.lock()
                    .unwrap()
                    .iter()
                    .map(|(_, c)| c.inflight.load(Ordering::Relaxed))
                    .sum::<usize>()
            })
            .sum()
    }

    pub fn slots_in_tier(&self, tier: usize) -> usize {
        self.cells[tier]
            .lock()
            .unwrap()
            .iter()
            .map(|(_, c)| c.inflight.load(Ordering::Relaxed))
            .sum()
    }

    /// Cumulative (prefix-hit, prefix-miss) prompt-token totals across
    /// the tier's live replicas. The control loop differences successive
    /// samples into a *windowed* hit rate for `Scaler::plan_tier` — a
    /// since-boot rate would keep discounting demand long after the
    /// workload shifts away from cached prefixes.
    pub fn tier_prefix_totals(&self, tier: usize) -> (u64, u64) {
        let (mut hit, mut miss) = (0u64, 0u64);
        for (_, c) in self.cells[tier].lock().unwrap().iter() {
            hit += c.prefix_hit_tokens.load(Ordering::Relaxed);
            miss += c.prefix_miss_tokens.load(Ordering::Relaxed);
        }
        (hit, miss)
    }

    /// Cumulative (accepted, drafted) speculative-token totals across
    /// the tier's live replicas — windowed by the control loop into the
    /// acceptance rate for `Scaler::plan_tier`, exactly like
    /// [`Self::tier_prefix_totals`].
    pub fn tier_spec_totals(&self, tier: usize) -> (u64, u64) {
        let (mut accepted, mut drafted) = (0u64, 0u64);
        for (_, c) in self.cells[tier].lock().unwrap().iter() {
            accepted += c.spec_accepted_tokens.load(Ordering::Relaxed);
            drafted += c.spec_drafted_tokens.load(Ordering::Relaxed);
        }
        (accepted, drafted)
    }

    /// Blocks resident in prefix caches across the pool (the
    /// `ps_prefix_cache_blocks` gauge).
    pub fn prefix_cache_blocks(&self) -> usize {
        self.cells
            .iter()
            .map(|m| {
                m.lock()
                    .unwrap()
                    .iter()
                    .map(|(_, c)| c.prefix_cache_blocks.load(Ordering::Relaxed) as usize)
                    .sum::<usize>()
            })
            .sum()
    }

    /// Fault-injection hook: kill one Ready replica of `tier` abruptly
    /// (its in-flight work is requeued, the control plane detects the
    /// failure and redeploys). On the thread substrate the victim dies at
    /// its next heartbeat; on the process substrate its worker process is
    /// SIGKILLed — a true `kill -9`. Returns whether a victim existed.
    pub fn inject_failure(&self, tier: usize) -> bool {
        for (_, c) in self.cells[tier].lock().unwrap().iter() {
            if c.state.load(Ordering::Acquire) == S_READY
                && !c.kill.swap(true, Ordering::Relaxed)
            {
                return true;
            }
        }
        false
    }

    /// Gracefully drain one Ready replica of `tier` (test/ops hook): it
    /// stops pulling new work, hands buffered jobs back through the
    /// requeue path, finishes its decoding slots, and exits. Returns
    /// whether a victim existed.
    pub fn drain_one(&self, tier: usize) -> bool {
        for (_, c) in self.cells[tier].lock().unwrap().iter() {
            if c.state.load(Ordering::Acquire) == S_READY
                && !c.stop.swap(true, Ordering::Relaxed)
            {
                let _ = c.state.compare_exchange(
                    S_READY,
                    S_TERMINATING,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                );
                return true;
            }
        }
        false
    }
}

struct ReplicaMeta {
    tier: usize,
    service: ServiceId,
    cell: Arc<ReplicaCell>,
    created_s: f64,
    /// Last state surfaced through `poll` (transition edge detection).
    reported: ReplicaState,
}

/// The live engine pool behind the [`Substrate`] trait. Owned by the
/// router thread; `E` is the engine type its replica threads build.
pub(crate) struct LocalSubstrate<E, F>
where
    E: StepEngine,
    F: Fn(Tier, usize) -> Result<E, String> + Send + Sync + 'static,
{
    shared: Arc<PoolShared>,
    pool: PoolConfig,
    metrics: Arc<GatewayMetrics>,
    factory: Arc<F>,
    /// ServiceId.0 → tier index (from the registry's model zoo).
    svc_tier: Vec<usize>,
    /// Canonical registry cell per tier (events are keyed by it).
    tier_service: [ServiceId; 3],
    meta: BTreeMap<ReplicaId, ReplicaMeta>,
    handles: BTreeMap<ReplicaId, JoinHandle<()>>,
    next_id: u64,
    next_index: [usize; 3],
    /// Measured provision→Ready seconds per tier (Alg. 2's cold-start
    /// estimate for scaled-to-zero tiers).
    cold_start_ema: [Ema; 3],
    _engine: PhantomData<fn() -> E>,
}

impl<E, F> LocalSubstrate<E, F>
where
    E: StepEngine,
    F: Fn(Tier, usize) -> Result<E, String> + Send + Sync + 'static,
{
    pub fn new(
        shared: Arc<PoolShared>,
        pool: PoolConfig,
        metrics: Arc<GatewayMetrics>,
        factory: F,
        registry: &Registry,
    ) -> LocalSubstrate<E, F> {
        let svc_tier: Vec<usize> =
            registry.services.iter().map(|s| s.spec.tier.index()).collect();
        let tier_service = std::array::from_fn(|ti| {
            registry
                .services
                .iter()
                .find(|s| s.spec.tier.index() == ti)
                .map(|s| s.id)
                .unwrap_or(ServiceId(0))
        });
        LocalSubstrate {
            shared,
            pool,
            metrics,
            factory: Arc::new(factory),
            svc_tier,
            tier_service,
            meta: BTreeMap::new(),
            handles: BTreeMap::new(),
            next_id: 0,
            next_index: [0; 3],
            cold_start_ema: std::array::from_fn(|_| Ema::new(0.3)),
            _engine: PhantomData,
        }
    }

    pub fn shared(&self) -> Arc<PoolShared> {
        Arc::clone(&self.shared)
    }

    /// The canonical registry cell a tier's replicas report under.
    pub fn tier_service(&self, tier: usize) -> ServiceId {
        self.tier_service[tier.min(2)]
    }

    fn tier_of(&self, service: ServiceId) -> usize {
        self.svc_tier.get(service.0).copied().unwrap_or(0)
    }

    /// Block until every provisioned replica reports Ready; an engine
    /// factory failure (or a replica thread dying during warm-up)
    /// surfaces as the error.
    pub fn wait_warm(&mut self) -> Result<(), String> {
        loop {
            let mut all_ready = true;
            for (id, m) in &self.meta {
                match m.cell.state.load(Ordering::Acquire) {
                    S_READY => {}
                    S_FAILED => {
                        return Err(m
                            .cell
                            .error
                            .lock()
                            .unwrap()
                            .take()
                            .unwrap_or_else(|| "replica died during warm-up".into()));
                    }
                    _ => {
                        if self.handles.get(id).map(|h| h.is_finished()).unwrap_or(true)
                        {
                            return Err(
                                "replica thread exited during warm-up".to_string()
                            );
                        }
                        all_ready = false;
                    }
                }
            }
            if all_ready {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Close the queues, stop every replica, and join the threads.
    pub fn shutdown(&mut self) {
        for q in &self.shared.queues {
            q.close();
        }
        for m in self.meta.values() {
            m.cell.stop.store(true, Ordering::Relaxed);
        }
        for (_, h) in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
        self.meta.clear();
        for c in &self.shared.cells {
            c.lock().unwrap().clear();
        }
    }

    fn remove_replica(&mut self, id: ReplicaId, tier: usize) {
        self.meta.remove(&id);
        self.shared.cells[tier].lock().unwrap().retain(|(rid, _)| *rid != id);
        if let Some(h) = self.handles.remove(&id) {
            if h.is_finished() {
                let _ = h.join();
            }
            // A live (stalled) thread is detached: its kill flag is set,
            // so it exits the moment it unsticks.
        }
    }
}

impl<E, F> Substrate for LocalSubstrate<E, F>
where
    E: StepEngine,
    F: Fn(Tier, usize) -> Result<E, String> + Send + Sync + 'static,
{
    fn provision(
        &mut self,
        service: ServiceId,
        _model_idx: usize,
        spec: &ModelSpec,
        _backend: BackendKind,
        now_s: f64,
    ) -> Option<ReplicaId> {
        let ti = spec.tier.index();
        // The tier's configured replica count is its provisioned ceiling
        // (thread budget); zero means the tier cannot serve at all.
        if self.shared.live_count(ti) >= self.pool.replicas[ti] {
            return None;
        }
        let cell = Arc::new(ReplicaCell::new());
        let id = ReplicaId(self.next_id);
        self.next_id += 1;
        let index = self.next_index[ti];
        self.next_index[ti] += 1;
        let tier = Tier::ALL[ti];
        let ctx = ReplicaCtx {
            queue: self.shared.queues[ti].clone(),
            cell: Arc::clone(&cell),
            metrics: Arc::clone(&self.metrics),
            epoch: self.shared.epoch,
            pool: self.pool.clone(),
            tier: ti,
            spec_draft_ok: Arc::clone(&self.shared.spec_draft_ok),
        };
        let factory = Arc::clone(&self.factory);
        let handle = std::thread::Builder::new()
            .name(format!("engine-{}-{index}", tier.name()))
            .spawn(move || {
                // Engines are built on this thread (not Send).
                ctx.cell.state.store(S_LOADING, Ordering::Release);
                match (*factory)(tier, index) {
                    Ok(engine) => replica_loop(engine, ctx),
                    Err(e) => {
                        *ctx.cell.error.lock().unwrap() = Some(e);
                        ctx.cell.state.store(S_FAILED, Ordering::Release);
                    }
                }
            })
            .ok()?;
        self.shared.cells[ti].lock().unwrap().push((id, Arc::clone(&cell)));
        self.meta.insert(id, ReplicaMeta {
            tier: ti,
            service,
            cell,
            created_s: now_s,
            reported: ReplicaState::Scheduled,
        });
        self.handles.insert(id, handle);
        Some(id)
    }

    fn terminate(&mut self, replica: ReplicaId, _now_s: f64) {
        if let Some(m) = self.meta.get(&replica) {
            m.cell.stop.store(true, Ordering::Relaxed);
            // Control-side state so Ready counts drop immediately; the
            // thread overwrites with Gone once drained.
            let _ = m.cell.state.compare_exchange(
                S_READY,
                S_TERMINATING,
                Ordering::AcqRel,
                Ordering::Relaxed,
            );
        }
    }

    /// Failure is asynchronous on the live substrate: the kill hook
    /// fires at the replica's next heartbeat and the `ReplicaFailed`
    /// event surfaces through [`Self::poll`], mirroring how a real crash
    /// is observed.
    fn fail(&mut self, replica: ReplicaId, _now_s: f64) -> Option<SubstrateEvent> {
        if let Some(m) = self.meta.get(&replica) {
            m.cell.kill.store(true, Ordering::Relaxed);
        }
        None
    }

    fn poll(&mut self, now_s: f64) -> Vec<SubstrateEvent> {
        let mut out = Vec::new();
        let ids: Vec<ReplicaId> = self.meta.keys().copied().collect();
        for id in ids {
            let (tier, service, created_s, reported, cell) = {
                let m = &self.meta[&id];
                (m.tier, m.service, m.created_s, m.reported, Arc::clone(&m.cell))
            };
            let raw = cell.state.load(Ordering::Acquire);
            let thread_dead = self
                .handles
                .get(&id)
                .map(|h| h.is_finished())
                .unwrap_or(true);
            let stalled = raw == S_READY && {
                let hb = cell.heartbeat_us.load(Ordering::Relaxed) as f64 / 1e6;
                now_s - hb > self.pool.health_deadline_s.max(0.001)
            };
            let failed = raw == S_FAILED
                || stalled
                || (thread_dead && raw != S_GONE && raw != S_FAILED);
            if failed {
                if stalled {
                    // If the thread is merely stuck it exits (and
                    // requeues its work) the moment it unsticks.
                    cell.kill.store(true, Ordering::Relaxed);
                }
                out.push(SubstrateEvent::ReplicaFailed {
                    replica: id,
                    service,
                    at_s: now_s,
                });
                self.remove_replica(id, tier);
                continue;
            }
            if raw == S_GONE {
                out.push(SubstrateEvent::ReplicaGone {
                    replica: id,
                    service,
                    at_s: now_s,
                });
                self.remove_replica(id, tier);
                continue;
            }
            if raw == S_READY && reported != ReplicaState::Ready {
                let ready_s = cell.ready_us.load(Ordering::Relaxed) as f64 / 1e6;
                let cold = (ready_s - created_s).max(0.0);
                self.cold_start_ema[tier].observe(cold);
                out.push(SubstrateEvent::ReplicaReady {
                    replica: id,
                    service,
                    at_s: ready_s.max(created_s),
                    cold_start_s: cold,
                });
                if let Some(m) = self.meta.get_mut(&id) {
                    m.reported = ReplicaState::Ready;
                }
            }
        }
        out
    }

    fn replica_state(&self, replica: ReplicaId) -> Option<ReplicaState> {
        self.meta
            .get(&replica)
            .and_then(|m| decode_state(m.cell.state.load(Ordering::Acquire)))
    }

    fn ready_replicas(&self, service: ServiceId) -> Vec<ReplicaId> {
        let ti = self.tier_of(service);
        self.meta
            .iter()
            .filter(|(_, m)| {
                m.tier == ti
                    && m.cell.state.load(Ordering::Acquire) == S_READY
                    && !m.cell.stop.load(Ordering::Relaxed)
            })
            .map(|(id, _)| *id)
            .collect()
    }

    fn pending_replicas(&self, service: ServiceId) -> usize {
        self.shared.pending_count(self.tier_of(service))
    }

    fn estimate_cold_start_s(&self, spec: &ModelSpec, _backend: BackendKind) -> f64 {
        // Prior before the first measured cold start: a conservative
        // engine-construction guess.
        self.cold_start_ema[spec.tier.index()].get_or(0.5)
    }
}

/// Everything one replica thread needs besides its engine.
pub(crate) struct ReplicaCtx {
    pub queue: Channel<TierJob>,
    pub cell: Arc<ReplicaCell>,
    pub metrics: Arc<GatewayMetrics>,
    pub epoch: Instant,
    pub pool: PoolConfig,
    /// This replica's tier (speculative pairing rule input).
    pub tier: usize,
    /// Live draft-tier-availability signal (see `PoolShared::spec_draft_ok`).
    pub spec_draft_ok: Arc<AtomicBool>,
}

/// Try to move one routed job into the scheduler. Returns the job back
/// when the replica has no slot/KV headroom right now.
fn admit_job<E: StepEngine>(
    sched: &mut Scheduler<E, TierJob>,
    mut job: TierJob,
    ctx: &ReplicaCtx,
) -> Option<TierJob> {
    let now = ctx.epoch.elapsed().as_secs_f64();
    // Expiry is checked before cancellation: a caller abandoning its
    // deadline fires both signals at once, and the expired-shed counter
    // is the one that must account for the dead work.
    if now > job.deadline_abs_s {
        // Dead work: the deadline elapsed while the job sat queued.
        // Dropping it here — before prefill/KV admission — is what keeps
        // overload from spending replica steps on answers nobody can
        // use.
        ctx.metrics.shed_expired.fetch_add(1, Ordering::Relaxed);
        if let Some(st) = job.trace.as_deref_mut() {
            st.phase(SpanKind::Shed, now);
        }
        job.reply.put(Err(CompletionError::new(
            FailureKind::DeadlineExpired,
            "deadline expired before dispatch",
        )));
        ctx.metrics.finish_request(
            job.trace.take(),
            job.tier,
            job.priority,
            "deadline_expired",
            now,
            0,
        );
        ctx.metrics.bandit_feedback(
            job.tier,
            job.complexity,
            job.confidence,
            false,
            (now - job.enqueue_s).max(0.0),
        );
        return None;
    }
    if job.cancel.is_cancelled() {
        // The caller already timed out; don't spend prefill on it.
        ctx.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
        ctx.metrics.finish_request(
            job.trace.take(),
            job.tier,
            job.priority,
            "cancelled",
            now,
            0,
        );
        return None;
    }
    let est = crate::tokenizer::word_count(&job.prompt).max(1) + 1;
    job.queue_wait_s = (now - job.enqueue_s).max(0.0);
    // The scheduler buffers its own copy of the prompt for the prefill
    // rung; the payload keeps the original so a dying replica can
    // requeue the job intact.
    let prompt = std::mem::take(&mut job.prompt);
    let cancel = job.cancel.clone();
    match sched.admit_cancellable(&prompt, job.max_tokens, est, job, cancel) {
        Admit::Admitted => {
            if let Some(p) = sched.last_admitted_mut() {
                if p.counted_wait_s == 0.0 {
                    // First admission only (requeues re-admit): the
                    // per-priority wait distribution behind
                    // `ps_queue_wait_hist_seconds`.
                    ctx.metrics.observe_queue_wait(p.priority, p.queue_wait_s);
                }
                ctx.metrics
                    .add_queue_wait_s((p.queue_wait_s - p.counted_wait_s).max(0.0));
                p.counted_wait_s = p.queue_wait_s;
                p.prompt = prompt;
                if let Some(st) = p.trace.as_deref_mut() {
                    // Close the queue phase (a re-admitted requeue's mark
                    // sits at its requeue time, so the span is the re-wait).
                    st.phase(SpanKind::Queued, now);
                }
            }
            None
        }
        Admit::Rejected(mut job) => {
            job.prompt = prompt;
            Some(job)
        }
        Admit::Failed(mut job, e) => {
            ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
            job.reply
                .put(Err(CompletionError::internal(format!("admission failed: {e:#}"))));
            ctx.metrics.finish_request(
                job.trace.take(),
                job.tier,
                job.priority,
                "internal",
                now,
                0,
            );
            ctx.metrics.bandit_feedback(
                job.tier,
                job.complexity,
                job.confidence,
                false,
                (now - job.enqueue_s).max(0.0),
            );
            None
        }
    }
}

/// Complete a finished request back to its caller.
fn finish_job(f: Finished<TierJob>, ctx: &ReplicaCtx) {
    let now = ctx.epoch.elapsed().as_secs_f64();
    let mut job = f.payload;
    let tokens = f.tokens.len();
    let latency_s = (now - job.enqueue_s).max(0.0);
    ctx.metrics.completed.fetch_add(1, Ordering::Relaxed);
    ctx.metrics.tokens_out.fetch_add(tokens as u64, Ordering::Relaxed);
    ctx.metrics.observe_ttft(ctx.tier, job.ttft_s);
    if tokens > 1 {
        ctx.metrics.observe_tpot(
            ctx.tier,
            (latency_s - job.ttft_s).max(0.0) / (tokens - 1) as f64,
        );
    }
    if let Some(st) = job.trace.as_deref_mut() {
        st.phase(SpanKind::Decode, now);
        if f.spec_steps > 0 {
            // Zero-length marker carrying the verify-step count.
            st.phase_n(SpanKind::SpecVerify, now, f.spec_steps);
        }
    }
    job.reply.put(Ok(LiveResponse {
        tokens: f.tokens,
        tier: job.tier.name().to_string(),
        model: job.model,
        complexity: job.complexity,
        confidence: job.confidence,
        ttft_s: job.ttft_s,
        latency_s,
        queue_wait_s: job.queue_wait_s,
        prompt_tokens: f.prompt_tokens,
    }));
    ctx.metrics.finish_request(
        job.trace.take(),
        job.tier,
        job.priority,
        "ok",
        now,
        tokens,
    );
    ctx.metrics
        .bandit_feedback(job.tier, job.complexity, job.confidence, true, latency_s);
}

/// Derive one replica's scheduler knobs from the pool config and its
/// engine's compiled ceiling — shared by the thread substrate's replica
/// threads and the `ps-replica` worker processes, so both data planes
/// batch identically. The batch target is clamped to the slot count too:
/// with fewer slots than the biggest rung, a full replica could
/// otherwise never "fill" a batch and would eat the flush timeout while
/// saturated. `tier` applies the speculative pairing rule: only tiers
/// that verify against a configured draft tier get the draft/verify
/// state machine; everyone else (the draft tier included) runs plain
/// decode bit-for-bit.
pub(crate) fn sched_config(
    pool: &PoolConfig,
    engine_max_batch: usize,
    tier: usize,
) -> SchedulerConfig {
    let max_batch = pool
        .max_decode_batch
        .min(engine_max_batch)
        .min(pool.max_inflight.max(1))
        .max(1);
    let max_prefill = pool.max_prefill_batch.min(pool.max_inflight.max(1)).max(1);
    SchedulerConfig {
        policy: BatchPolicy::custom(max_batch, max_prefill, pool.flush_timeout_s),
        max_inflight: pool.max_inflight.max(1),
        kv_blocks: pool.kv_blocks.max(1),
        kv_block_tokens: pool.kv_block_tokens.max(1),
        prefix_cache: pool.prefix_cache,
        speculative: if pool.speculative.pairs_with(tier) {
            pool.speculative
        } else {
            crate::config::SpeculativeConfig::disabled()
        },
    }
}

/// Route a job back to the tier queue off a dying/draining replica —
/// shared by the thread substrate's replica loops and the process
/// substrate's pump threads (the loss-free recovery path both data
/// planes funnel through). A momentarily full queue gets a brief bounded
/// retry (another replica or the cold-wake path drains it) before the
/// caller is failed — dropping a live caller because the queue was full
/// for one tick is exactly the loss the requeue path exists to prevent.
/// Returns whether the job was requeued.
pub(crate) fn requeue_to(
    queue: &Channel<TierJob>,
    metrics: &GatewayMetrics,
    mut job: TierJob,
    fail_msg: &str,
    now_s: f64,
) -> bool {
    if job.cancel.is_cancelled() {
        metrics.cancelled.fetch_add(1, Ordering::Relaxed);
        metrics.finish_request(job.trace.take(), job.tier, job.priority, "cancelled", now_s, 0);
        return false;
    }
    if let Some(st) = job.trace.as_deref_mut() {
        // The doomed attempt, dispatch mark → loss detection.
        st.phase(SpanKind::Requeue, now_s);
    }
    for attempt in 0..50 {
        if queue.is_closed() {
            // Orderly shutdown: the caller is told, but this is not a
            // serving error — `ps_errors_total` must stay quiet for a
            // clean teardown.
            job.reply.put(Err(CompletionError::new(
                FailureKind::Shutdown,
                "gateway shutting down",
            )));
            metrics.finish_request(job.trace.take(), job.tier, job.priority, "shutdown", now_s, 0);
            return false;
        }
        match queue.try_send(job) {
            Ok(()) => {
                metrics.requeued.fetch_add(1, Ordering::Relaxed);
                return true;
            }
            Err(back) => {
                job = back;
                if attempt < 49 {
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }
    }
    metrics.errors.fetch_add(1, Ordering::Relaxed);
    job.reply
        .put(Err(CompletionError::new(FailureKind::ReplicaLost, fail_msg)));
    metrics.finish_request(job.trace.take(), job.tier, job.priority, "replica_lost", now_s, 0);
    metrics.bandit_feedback(
        job.tier,
        job.complexity,
        job.confidence,
        false,
        (now_s - job.enqueue_s).max(0.0),
    );
    false
}

fn requeue_job(job: TierJob, ctx: &ReplicaCtx, fail_msg: &str) -> bool {
    let now = ctx.epoch.elapsed().as_secs_f64();
    requeue_to(&ctx.queue, &ctx.metrics, job, fail_msg, now)
}

/// Abrupt death (kill hook / injected fault): requeue in-flight jobs so
/// traffic drains without loss on the replacement replica, then report
/// Failed.
fn die_abruptly<E: StepEngine>(
    sched: &mut Scheduler<E, TierJob>,
    held: Option<TierJob>,
    ctx: &ReplicaCtx,
) {
    for job in held.into_iter().chain(sched.fail_all()) {
        requeue_job(job, ctx, "replica failed");
    }
    // Affinity-routed jobs waiting in the private queue requeue to the
    // shared tier queue — they lose their placement, never their answer.
    while let Some(job) = ctx.cell.direct.try_recv() {
        requeue_job(job, ctx, "replica failed");
    }
    ctx.cell.inflight.store(0, Ordering::Relaxed);
    ctx.cell.state.store(S_FAILED, Ordering::Release);
}

/// Service the fleet prefix-cache plane for one tick: publish this
/// replica's hot-prefix summary, export cached runs requested by the
/// router on behalf of cold peers, and import runs peers sent us.
fn service_affinity<E: StepEngine>(
    sched: &mut Scheduler<E, TierJob>,
    ctx: &ReplicaCtx,
) {
    let aff = &ctx.pool.affinity;
    if !aff.enabled {
        return;
    }
    *ctx.cell.hot.lock().unwrap() = sched.hot_prefixes(aff.top_k);
    if !aff.transfer {
        return;
    }
    let reqs: Vec<(u64, Arc<ReplicaCell>)> =
        std::mem::take(&mut *ctx.cell.fetch_reqs.lock().unwrap());
    for (hash, target) in reqs {
        // An evicted prefix simply yields nothing; the cold replica
        // recomputes, which is the pre-transfer behavior.
        if let Some(blocks) = sched.export_prefix(hash) {
            ctx.metrics.kv_transfers.fetch_add(1, Ordering::Relaxed);
            ctx.metrics
                .kv_transfer_blocks
                .fetch_add(blocks.len() as u64, Ordering::Relaxed);
            target.incoming.lock().unwrap().push(blocks);
        }
    }
    let runs: Vec<BlockRun> = std::mem::take(&mut *ctx.cell.incoming.lock().unwrap());
    for run in runs {
        let _ = sched.import_prefix(&run);
    }
}

/// One replica's serving loop: admit → prefill rungs → batch-decode →
/// retire, with flush-timeout holds that wake early on new arrivals.
/// Runs until killed, stopped (graceful drain), or the queue closes.
pub(crate) fn replica_loop<E: StepEngine>(engine: E, ctx: ReplicaCtx) {
    let cfg = sched_config(&ctx.pool, engine.max_batch(), ctx.tier);
    let mut sched: Scheduler<E, TierJob> = Scheduler::new(engine, cfg);
    let mut held: Option<TierJob> = None;
    // Graceful-drain edge: on the tick `stop` is first observed, buffered
    // (admitted but not yet prefilled) jobs are handed back through the
    // requeue path so a surviving replica serves them — a draining
    // replica only finishes the slots it is already decoding.
    let mut drained_pending = false;
    // Last prefix-cache counters forwarded to the gateway (deltas feed
    // the global `ps_prefix_*` counters; the cell publishes cumulatives
    // for the per-tier hit-rate signal).
    let mut prefix_seen = crate::backend::kv_cache::PrefixStats::default();
    // Last speculative counters forwarded, same split: deltas into the
    // global `ps_spec_*` counters, cumulatives into the cell for the
    // per-tier acceptance-rate signal.
    let mut spec_seen = (0u64, 0u64, 0u64, 0u64);
    // A replica whose engine errors on every step must not stay Ready
    // and black-hole the tier queue: after this many consecutive failed
    // ticks it reports Failed and the recovery manager redeploys it.
    const MAX_CONSECUTIVE_ENGINE_ERRORS: usize = 3;
    let mut engine_errors = 0usize;
    // Seed the heartbeat before publishing Ready: stall detection runs
    // `now - heartbeat` the moment the state reads Ready, and a zero
    // heartbeat would look minutes stale on a long-lived pool.
    let warm_us = ctx.epoch.elapsed().as_micros() as u64;
    ctx.cell.heartbeat_us.store(warm_us, Ordering::Relaxed);
    ctx.cell.ready_us.store(warm_us, Ordering::Relaxed);
    ctx.cell.state.store(S_READY, Ordering::Release);
    loop {
        ctx.cell
            .heartbeat_us
            .store(ctx.epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
        if ctx.cell.kill.load(Ordering::Relaxed) {
            die_abruptly(&mut sched, held.take(), &ctx);
            return;
        }
        let stopping = ctx.cell.stop.load(Ordering::Relaxed);
        if stopping && !drained_pending {
            drained_pending = true;
            for job in sched.drain_pending() {
                requeue_job(job, &ctx, "replica draining");
            }
            while let Some(job) = ctx.cell.direct.try_recv() {
                requeue_job(job, &ctx, "replica draining");
            }
            if let Some(job) = held.take() {
                requeue_job(job, &ctx, "replica draining");
            }
            ctx.cell.inflight.store(sched.inflight(), Ordering::Relaxed);
        }
        if !stopping {
            // Import transferred prefixes before admitting so an
            // affinity-routed job lands on an already-warm cache.
            service_affinity(&mut sched, &ctx);
        }
        // Sample the draft-tier signal every loop: a cold, saturated, or
        // mid-recovery draft tier drops the next tick to plain decode.
        sched.set_draft_available(ctx.spec_draft_ok.load(Ordering::Relaxed));
        // Admit as much as fits. A stopping replica drains its slots but
        // pulls nothing new. The private affinity queue drains ahead of
        // the shared tier queue — those jobs were placed *here* for
        // their prefix.
        if !stopping {
            loop {
                let job = match held
                    .take()
                    .or_else(|| ctx.cell.direct.try_recv())
                    .or_else(|| ctx.queue.try_recv())
                {
                    Some(j) => j,
                    None => break,
                };
                match admit_job(&mut sched, job, &ctx) {
                    None => continue,
                    Some(back) => {
                        held = Some(back);
                        break;
                    }
                }
            }
        }
        if sched.inflight() == 0 {
            ctx.cell.inflight.store(0, Ordering::Relaxed);
            if stopping {
                break;
            }
            // Break even with a job still held — the post-loop cleanup
            // fails it back to its caller instead of spinning forever.
            if ctx.queue.is_closed() && ctx.queue.is_empty() {
                break;
            }
            if held.is_none() {
                if let Some(j) = ctx.queue.recv_timeout(Duration::from_millis(20)) {
                    held = Some(j);
                }
            } else {
                // A held job cannot persist at zero inflight — admission
                // fails unserveable requests outright rather than
                // bouncing them — but guard the spin anyway.
                std::thread::sleep(Duration::from_millis(5));
            }
            continue;
        }
        let now = ctx.epoch.elapsed().as_secs_f64();
        let batched_prefills_before = sched.stats.prefill_batched;
        // A panic inside the engine (as opposed to an Err) must not
        // strand the in-flight callers until their timeout: treat it as
        // a crash — requeue the work and report Failed so the control
        // plane redeploys. (Payloads inside a mid-panic prefill batch
        // are unwound with the stack and cannot be recovered; everything
        // buffered or decoding requeues.)
        let tick = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sched.tick_with(now, &mut |job| {
                // Prefill produced the first token: that's TTFT.
                job.ttft_s = (now - job.enqueue_s).max(0.0);
                if let Some(st) = job.trace.as_deref_mut() {
                    st.phase(SpanKind::Prefill, now);
                }
            })
        })) {
            Ok(t) => t,
            Err(_) => {
                die_abruptly(&mut sched, held.take(), &ctx);
                return;
            }
        };
        match tick {
            Ok(tick) => {
                engine_errors = 0;
                if tick.prefilled > 0 {
                    ctx.metrics
                        .prefills
                        .fetch_add(tick.prefilled as u64, Ordering::Relaxed);
                    ctx.metrics.prefill_batched.fetch_add(
                        sched.stats.prefill_batched - batched_prefills_before,
                        Ordering::Relaxed,
                    );
                }
                if tick.stepped > 0 {
                    ctx.metrics.observe_batch(tick.stepped);
                }
                for f in tick.finished {
                    finish_job(f, &ctx);
                }
                for mut job in tick.cancelled {
                    // The caller already gave up; just free the slot.
                    ctx.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    ctx.metrics.finish_request(
                        job.trace.take(),
                        job.tier,
                        job.priority,
                        "cancelled",
                        now,
                        0,
                    );
                }
                for (mut job, msg) in tick.failed {
                    ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    job.reply.put(Err(CompletionError::internal(msg)));
                    ctx.metrics.finish_request(
                        job.trace.take(),
                        job.tier,
                        job.priority,
                        "internal",
                        now,
                        0,
                    );
                    ctx.metrics.bandit_feedback(
                        job.tier,
                        job.complexity,
                        job.confidence,
                        false,
                        (now - job.enqueue_s).max(0.0),
                    );
                }
                ctx.cell.inflight.store(sched.inflight(), Ordering::Relaxed);
                let ps = sched.prefix_stats();
                ctx.metrics.prefix_hit_tokens.fetch_add(
                    ps.hit_tokens - prefix_seen.hit_tokens,
                    Ordering::Relaxed,
                );
                ctx.metrics.prefix_miss_tokens.fetch_add(
                    ps.miss_tokens - prefix_seen.miss_tokens,
                    Ordering::Relaxed,
                );
                ctx.metrics.prefix_evicted_blocks.fetch_add(
                    ps.evicted_blocks - prefix_seen.evicted_blocks,
                    Ordering::Relaxed,
                );
                prefix_seen = ps;
                let ss = &sched.stats;
                let spec_now = (
                    ss.spec_drafted_tokens,
                    ss.spec_accepted_tokens,
                    ss.spec_rejected_tokens,
                    ss.spec_verify_steps,
                );
                if spec_now != spec_seen {
                    ctx.metrics
                        .spec_drafted_tokens
                        .fetch_add(spec_now.0 - spec_seen.0, Ordering::Relaxed);
                    ctx.metrics
                        .spec_accepted_tokens
                        .fetch_add(spec_now.1 - spec_seen.1, Ordering::Relaxed);
                    ctx.metrics
                        .spec_rejected_tokens
                        .fetch_add(spec_now.2 - spec_seen.2, Ordering::Relaxed);
                    ctx.metrics
                        .spec_verify_steps
                        .fetch_add(spec_now.3 - spec_seen.3, Ordering::Relaxed);
                    spec_seen = spec_now;
                    ctx.cell
                        .spec_drafted_tokens
                        .store(spec_now.0, Ordering::Relaxed);
                    ctx.cell
                        .spec_accepted_tokens
                        .store(spec_now.1, Ordering::Relaxed);
                    ctx.cell
                        .spec_rejected_tokens
                        .store(spec_now.2, Ordering::Relaxed);
                    ctx.cell
                        .spec_verify_steps
                        .store(spec_now.3, Ordering::Relaxed);
                }
                ctx.cell
                    .prefix_hit_tokens
                    .store(ps.hit_tokens, Ordering::Relaxed);
                ctx.cell
                    .prefix_miss_tokens
                    .store(ps.miss_tokens, Ordering::Relaxed);
                ctx.cell
                    .prefix_cache_blocks
                    .store(sched.kv_cached_blocks() as u64, Ordering::Relaxed);
                if tick.stepped == 0 && tick.prefilled == 0 {
                    if let Some(wait) = tick.wait_s {
                        // Holding for batch-mates: sleep out the flush
                        // window, but wake immediately on a new arrival.
                        let wait = Duration::from_secs_f64(wait.clamp(0.0002, 0.1));
                        if !stopping && held.is_none() {
                            if let Some(j) = ctx.queue.recv_timeout(wait) {
                                held = Some(j);
                            }
                        } else {
                            std::thread::sleep(wait);
                        }
                    }
                }
            }
            Err(e) => {
                let msg = format!("engine step failed: {e:#}");
                for mut job in sched.fail_all() {
                    ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    job.reply.put(Err(CompletionError::internal(msg.clone())));
                    ctx.metrics.finish_request(
                        job.trace.take(),
                        job.tier,
                        job.priority,
                        "internal",
                        now,
                        0,
                    );
                    ctx.metrics.bandit_feedback(
                        job.tier,
                        job.complexity,
                        job.confidence,
                        false,
                        (now - job.enqueue_s).max(0.0),
                    );
                }
                ctx.cell.inflight.store(0, Ordering::Relaxed);
                engine_errors += 1;
                if engine_errors >= MAX_CONSECUTIVE_ENGINE_ERRORS {
                    // The engine is persistently broken: die so the
                    // control plane records an Incident and redeploys
                    // instead of letting this replica eat the queue.
                    die_abruptly(&mut sched, held.take(), &ctx);
                    return;
                }
            }
        }
    }
    // Never strand a caller: a job held at exit goes back to the queue
    // for a surviving replica (graceful terminate), or errors out when
    // the whole pool is shutting down. The private affinity queue is
    // drained the same way.
    if let Some(job) = held.take() {
        requeue_job(job, &ctx, "gateway shutting down");
    }
    while let Some(job) = ctx.cell.direct.try_recv() {
        requeue_job(job, &ctx, "gateway shutting down");
    }
    let now = ctx.epoch.elapsed().as_secs_f64();
    for mut job in sched.fail_all() {
        job.reply.put(Err(CompletionError::new(
            FailureKind::Shutdown,
            "gateway shutting down",
        )));
        ctx.metrics.finish_request(
            job.trace.take(),
            job.tier,
            job.priority,
            "shutdown",
            now,
            0,
        );
    }
    ctx.cell.inflight.store(0, Ordering::Relaxed);
    ctx.cell.state.store(S_GONE, Ordering::Release);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::scheduler::SimStepEngine;
    use crate::models::zoo;
    use crate::testkit::substrate_conformance::{check, Driver};

    #[test]
    fn local_substrate_passes_conformance() {
        // The thread substrate against the shared lifecycle contract
        // (same suite as MockSubstrate and ProcessSubstrate).
        let z = zoo();
        let registry = Registry::new(&z, 300.0);
        let pool = PoolConfig { replicas: [2, 2, 2], ..PoolConfig::default() };
        let epoch = Instant::now();
        let shared = Arc::new(PoolShared::new(epoch, pool.queue_capacity));
        let metrics = Arc::new(GatewayMetrics::default());
        let mut sub = LocalSubstrate::new(
            Arc::clone(&shared),
            pool,
            metrics,
            |_tier: Tier, _i: usize| -> Result<SimStepEngine, String> {
                Ok(SimStepEngine::instant())
            },
            &registry,
        );
        let sid = sub.tier_service(0);
        let (spec, backend) = {
            let s = registry.get(sid);
            (s.spec.clone(), s.backend)
        };
        let mut d = Driver {
            substrate: &mut sub,
            service: sid,
            model_idx: 0,
            spec,
            backend,
            clock: Box::new(move || {
                std::thread::sleep(Duration::from_millis(2));
                epoch.elapsed().as_secs_f64()
            }),
            timeout_s: 15.0,
        };
        check(&mut d);
        drop(d);
        sub.shutdown();
    }
}
