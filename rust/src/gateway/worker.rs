//! `ps-replica` worker loop — one replica as a supervised OS process.
//!
//! The process-substrate worker end of [`crate::substrate::proto`]: it
//! connects to the supervisor's data listener — a Unix socket path, or
//! `tcp:host:port` when a `ps-node` agent on another machine spawned it
//! — announces itself (`Hello`),
//! receives the pool's scheduling knobs (`HelloAck`), builds its engine
//! (the supervisor's `Loading` phase), and then runs the *same*
//! [`crate::backend::scheduler::Scheduler`] the thread substrate runs —
//! admitting jobs received as RPC frames, streaming newly decoded tokens
//! back as `TokenChunk`s, and answering `Done`/`JobFailed`/`Cancelled`
//! per request. Heartbeats carry the scheduler's cumulative counters so
//! the gateway's `/metrics` and the scaler's cache-adjusted demand
//! signal work identically across substrates.
//!
//! Shutdown paths:
//! * `Terminate` frame or SIGTERM → graceful drain: unstarted jobs go
//!   back as `Returned` frames (the supervisor requeues them), decoding
//!   slots finish, then `Gone` and exit 0 — the pod `preStop` model.
//! * engine build/step death → `Fatal` and exit 1; the supervisor
//!   requeues its dispatch ledger, so nothing is lost.
//! * supervisor connection lost → exit immediately (a worker must never
//!   outlive its gateway).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::batcher::DECODE_BATCHES;
use crate::backend::scheduler::{Admit, CancelToken, Scheduler, StepEngine};
use crate::config::PoolConfig;
use crate::gateway::pool::sched_config;
use crate::models::Tier;
use crate::substrate::proto::{
    connect_worker, read_frame_blocking, write_frame, Frame, FrameReader,
    HeartbeatWire, PoolWire, Transport, PROTO_VERSION,
};
use crate::telemetry::trace::{Span, SpanKind};
use crate::util::threadpool::Channel;

/// Heartbeat cadence (well inside the default 3 s health deadline).
const HEARTBEAT_PERIOD: Duration = Duration::from_millis(20);

/// Set by the SIGTERM handler: drain gracefully, exactly as if the
/// supervisor had sent `Terminate` (Kubernetes sends SIGTERM on pod
/// deletion; the supervisor's frame is the portable equivalent).
static SIGTERM_DRAIN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_sigterm_handler() {
    use std::os::raw::c_int;
    extern "C" fn on_sigterm(_sig: c_int) {
        // Only async-signal-safe work here: set the flag, nothing else.
        SIGTERM_DRAIN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
    }
    const SIGTERM: c_int = 15;
    unsafe {
        let _ = signal(SIGTERM, on_sigterm);
    }
}

#[cfg(not(unix))]
fn install_sigterm_handler() {}

/// CLI surface of the `ps-replica` subcommand.
pub struct WorkerOptions {
    /// Supervisor data listener: a Unix socket path, or `tcp:host:port`
    /// (node-agent spawned, multi-host).
    pub socket: String,
    pub tier: Tier,
    /// Replica index within the tier (log labelling only).
    pub replica: usize,
}

/// Reconstruct a scheduler-facing [`PoolConfig`] from the wire knobs
/// (fields the worker does not schedule with keep their defaults).
fn pool_from_wire(w: &PoolWire) -> PoolConfig {
    let mut p = PoolConfig {
        max_inflight: w.max_inflight,
        max_decode_batch: w.max_decode_batch,
        max_prefill_batch: w.max_prefill_batch,
        flush_timeout_s: w.flush_timeout_s,
        kv_blocks: w.kv_blocks,
        kv_block_tokens: w.kv_block_tokens,
        prefix_cache: w.prefix_cache,
        ..PoolConfig::default()
    };
    if w.affinity_top_k > 0 {
        p.affinity.enabled = true;
        p.affinity.top_k = w.affinity_top_k;
    }
    // A nonzero draft window means the supervisor already applied the
    // tier-pairing rule for this replica (`PoolWire::from_pool_for_tier`)
    // — reconstruct an enabled config that pairs with our tier.
    if w.spec_draft_tokens > 0 {
        p.speculative.enabled = true;
        p.speculative.draft_tier = 0;
        p.speculative.draft_tokens = w.spec_draft_tokens;
        p.speculative.min_accept_rate = w.spec_min_accept;
        p.speculative.sim_accept = w.spec_sim_accept;
    } else {
        p.speculative.enabled = false;
    }
    p
}

/// Cross-replica KV transfer traffic staged between the control plane
/// and the scheduler: frames arrive on the reader thread, but
/// export/import needs the scheduler, so the main loop applies them.
#[derive(Default)]
struct Transfers {
    /// Donor requests awaiting export: `(req, terminal chain hash)`.
    fetches: Vec<(u64, u64)>,
    /// Partially delivered prefixes, keyed by chain hash until `done`.
    staged: BTreeMap<u64, Vec<Vec<i32>>>,
    /// Fully delivered prefixes awaiting import.
    imports: Vec<Vec<Vec<i32>>>,
}

/// Per-sequence payload inside the worker's scheduler: the supervisor's
/// job id, how many tokens have been streamed, and the local cancel
/// token `Cancel` frames fire. The trace fields are receipt-relative
/// timestamps for the worker-side spans shipped back on `Done` (the
/// supervisor rebases them onto its dispatch mark); all zero-cost when
/// the job is untraced.
struct WireJob {
    id: u64,
    sent: usize,
    cancel: CancelToken,
    /// Worker-epoch time the `Job` frame arrived.
    recv_s: f64,
    /// Worker-epoch time of the first decoded token (0 until prefilled).
    first_s: f64,
    traced: bool,
}

/// Run one worker to completion. `build` constructs the engine once the
/// pool knobs are known (the PJRT path needs `max_decode_batch` to pick
/// its compiled ladder). Returns only after a graceful drain; fatal
/// errors bubble up for a nonzero exit.
pub fn run_worker<E, F>(opts: &WorkerOptions, build: F) -> Result<()>
where
    E: StepEngine,
    F: FnOnce(Tier, usize, &PoolWire) -> std::result::Result<E, String>,
{
    install_sigterm_handler();
    let epoch = Instant::now();
    let mut stream: Box<dyn Transport> = connect_worker(&opts.socket)
        .with_context(|| format!("connecting to supervisor at {}", opts.socket))?;
    write_frame(&mut *stream, &Frame::Hello {
        version: PROTO_VERSION,
        pid: std::process::id() as u64,
        tier: opts.tier.index(),
    })?;
    let mut handshake = FrameReader::new();
    let (version, pool) = match read_frame_blocking(&mut *stream, &mut handshake)? {
        Frame::HelloAck { version, pool } => {
            if !(1..=PROTO_VERSION).contains(&version) {
                bail!("supervisor negotiated unsupported protocol v{version}");
            }
            (version, pool)
        }
        f => bail!("expected HelloAck, got {f:?}"),
    };
    // Prefix advertising is a v2-plane feature: a v1 supervisor never
    // enables it, and we never ship v2 payloads on a v1 session.
    let hot_k = if version >= 2 { pool.affinity_top_k } else { 0 };

    // Reader thread: blocking reads → control channel. It inherits the
    // handshake's FrameReader so frames coalesced onto the HelloAck read
    // (say an immediate Terminate) are never stranded. EOF or a read
    // error closes the channel — the main loop treats that as
    // "supervisor gone" and exits.
    let msgs: Channel<Frame> = Channel::bounded(1024);
    {
        let mut rx = stream.try_clone().context("cloning socket for reads")?;
        let msgs = msgs.clone();
        let mut reader = handshake;
        std::thread::Builder::new()
            .name("ps-replica-reader".into())
            .spawn(move || {
                let mut buf = [0u8; 16384];
                'conn: loop {
                    // Parse-before-read: drain buffered frames first.
                    loop {
                        match reader.next() {
                            Ok(Some(f)) => {
                                if msgs.send(f).is_err() {
                                    return;
                                }
                            }
                            Ok(None) => break,
                            Err(_) => break 'conn,
                        }
                    }
                    match rx.read(&mut buf) {
                        Ok(0) | Err(_) => break 'conn,
                        Ok(n) => reader.extend(&buf[..n]),
                    }
                }
                msgs.close();
            })?;
    }

    let engine = match build(opts.tier, opts.replica, &pool) {
        Ok(e) => e,
        Err(e) => {
            let _ = write_frame(&mut *stream, &Frame::Fatal { error: e.clone() });
            bail!("engine build failed: {e}");
        }
    };
    let cfg = sched_config(&pool_from_wire(&pool), engine.max_batch(), opts.tier.index());
    let mut sched: Scheduler<E, WireJob> = Scheduler::new(engine, cfg);
    write_frame(&mut *stream, &Frame::Ready)?;

    let mut incoming: VecDeque<(u64, String, usize, bool, f64)> = VecDeque::new();
    let mut cancels: BTreeMap<u64, CancelToken> = BTreeMap::new();
    // Completed-but-unshipped trace spans (job id, receipt-relative
    // span). Prefill spans land here when a sequence gets its first
    // token and flush on the next heartbeat — so a worker killed
    // mid-decode still leaves its prefill on the supervisor's trace.
    let mut span_out: Vec<(u64, Span)> = Vec::new();
    let mut xfers = Transfers::default();
    let mut draining = false;
    let mut drained_once = false;
    // Draft-tier availability, toggled by SpecDraft frames. Starts false:
    // the scheduler runs plain decode until the supervisor confirms the
    // paired draft tier live.
    let mut spec_ok = false;
    let mut last_hb = Instant::now() - HEARTBEAT_PERIOD;
    const MAX_CONSECUTIVE_ENGINE_ERRORS: usize = 3;
    let mut engine_errors = 0usize;

    loop {
        // 1. Control-plane frames.
        while let Some(f) = msgs.try_recv() {
            handle_ctl(
                f,
                &mut *stream,
                epoch.elapsed().as_secs_f64(),
                &mut incoming,
                &mut cancels,
                &mut xfers,
                &mut draining,
                &mut spec_ok,
            )?;
        }
        if msgs.is_closed() && msgs.is_empty() {
            bail!("supervisor connection lost");
        }
        if SIGTERM_DRAIN.load(Ordering::SeqCst) {
            draining = true;
        }
        sched.set_draft_available(spec_ok);

        // 1b. Cross-replica KV transfers: answer the supervisor's donor
        // fetches, then ingest delivered prefixes — imports land before
        // admissions so an affinity-routed job admits against a warm
        // cache. An evicted prefix answers with an empty run (done is
        // still set, so the supervisor retires the transfer).
        for (req, hash) in xfers.fetches.drain(..) {
            let blocks = sched.export_prefix(hash).unwrap_or_default();
            write_frame(&mut *stream, &Frame::BlocksChunk { req, hash, blocks, done: true })?;
        }
        if !xfers.imports.is_empty() {
            let mut imported = 0usize;
            for run in xfers.imports.drain(..) {
                imported += sched.import_prefix(&run);
            }
            if imported > 0 && hot_k > 0 {
                // Advertise the freshly warmed prefix ahead of the next
                // heartbeat so the router can target it immediately.
                write_frame(&mut *stream, &Frame::PrefixAd {
                    prefixes: sched.hot_prefixes(hot_k),
                })?;
            }
        }

        // 2. Graceful drain: hand unstarted work back for requeue (the
        // buffered prefills once, plus anything that raced in later);
        // slots already decoding run to completion.
        if draining {
            if !drained_once {
                drained_once = true;
                for w in sched.drain_pending() {
                    cancels.remove(&w.id);
                    span_out.retain(|(id, _)| *id != w.id);
                    write_frame(&mut *stream, &Frame::Returned { job: w.id })?;
                }
            }
            for (id, _, _, _, _) in incoming.drain(..) {
                cancels.remove(&id);
                span_out.retain(|(sid, _)| *sid != id);
                write_frame(&mut *stream, &Frame::Returned { job: id })?;
            }
        }

        // 3. Admissions.
        if !draining {
            while let Some((id, prompt, max_tokens, traced, recv_s)) =
                incoming.pop_front()
            {
                let cancel = cancels
                    .get(&id)
                    .cloned()
                    .unwrap_or_default();
                if cancel.is_cancelled() {
                    cancels.remove(&id);
                    write_frame(&mut *stream, &Frame::Cancelled { job: id })?;
                    continue;
                }
                let est = crate::tokenizer::word_count(&prompt).max(1) + 1;
                let payload = WireJob {
                    id,
                    sent: 0,
                    cancel: cancel.clone(),
                    recv_s,
                    first_s: 0.0,
                    traced,
                };
                match sched.admit_cancellable(&prompt, max_tokens, est, payload, cancel)
                {
                    Admit::Admitted => {}
                    Admit::Rejected(_) => {
                        // No headroom right now; retry next turn. The
                        // supervisor's dispatch cap makes this rare.
                        incoming.push_front((id, prompt, max_tokens, traced, recv_s));
                        break;
                    }
                    Admit::Failed(w, e) => {
                        cancels.remove(&w.id);
                        write_frame(&mut *stream, &Frame::JobFailed {
                            job: w.id,
                            error: format!("admission failed: {e:#}"),
                            spans: vec![],
                        })?;
                    }
                }
            }
        }

        // 4. Idle / exit handling.
        if sched.inflight() == 0 {
            if draining && incoming.is_empty() {
                break;
            }
            send_heartbeat(
                &mut *stream,
                &mut sched,
                &mut last_hb,
                hot_k,
                &mut span_out,
                false,
            )?;
            if let Some(f) = msgs.recv_timeout(Duration::from_millis(20)) {
                handle_ctl(
                    f,
                    &mut *stream,
                    epoch.elapsed().as_secs_f64(),
                    &mut incoming,
                    &mut cancels,
                    &mut xfers,
                    &mut draining,
                    &mut spec_ok,
                )?;
            }
            continue;
        }

        // 5. One scheduler tick. A panic inside the engine must not
        // strand the supervisor's ledger: report Fatal and die — the
        // supervisor requeues everything it dispatched to us.
        let now = epoch.elapsed().as_secs_f64();
        let tick = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sched.tick_with(now, &mut |w: &mut WireJob| {
                // First token landed: stamp it and stage the prefill
                // span (receipt-relative) for the next heartbeat flush.
                w.first_s = now;
                if w.traced {
                    span_out.push((w.id, Span {
                        kind: SpanKind::Prefill,
                        start_s: 0.0,
                        end_s: (now - w.recv_s).max(0.0),
                        n: 0,
                    }));
                }
            })
        })) {
            Ok(t) => t,
            Err(_) => {
                let _ = write_frame(&mut *stream, &Frame::Fatal {
                    error: "engine panicked".into(),
                });
                bail!("engine panicked");
            }
        };
        match tick {
            Ok(tick) => {
                engine_errors = 0;
                // Stream freshly decoded tokens, then retire finished /
                // cancelled / failed sequences.
                let mut chunks: Vec<(u64, Vec<i32>)> = Vec::new();
                sched.for_each_slot(|w, tokens| {
                    if tokens.len() > w.sent {
                        chunks.push((w.id, tokens[w.sent..].to_vec()));
                        w.sent = tokens.len();
                    }
                });
                for (job, tokens) in chunks {
                    write_frame(&mut *stream, &Frame::TokenChunk { job, tokens })?;
                }
                for f in tick.finished {
                    cancels.remove(&f.payload.id);
                    let tail = f.tokens[f.payload.sent.min(f.tokens.len())..].to_vec();
                    // Ship the spans not yet flushed via heartbeat, plus
                    // the decode span and the verify-step marker — all
                    // receipt-relative for the supervisor's rebase.
                    let mut spans = take_spans(&mut span_out, f.payload.id);
                    if f.payload.traced {
                        let first_rel =
                            (f.payload.first_s - f.payload.recv_s).max(0.0);
                        let end_rel = (now - f.payload.recv_s).max(first_rel);
                        spans.push(Span {
                            kind: SpanKind::Decode,
                            start_s: first_rel,
                            end_s: end_rel,
                            n: 0,
                        });
                        if f.spec_steps > 0 {
                            spans.push(Span {
                                kind: SpanKind::SpecVerify,
                                start_s: end_rel,
                                end_s: end_rel,
                                n: f.spec_steps,
                            });
                        }
                    }
                    write_frame(&mut *stream, &Frame::Done {
                        job: f.payload.id,
                        prompt_tokens: f.prompt_tokens,
                        tokens: tail,
                        spans,
                    })?;
                }
                for w in tick.cancelled {
                    cancels.remove(&w.id);
                    span_out.retain(|(id, _)| *id != w.id);
                    write_frame(&mut *stream, &Frame::Cancelled { job: w.id })?;
                }
                for (w, msg) in tick.failed {
                    cancels.remove(&w.id);
                    let spans = take_spans(&mut span_out, w.id);
                    write_frame(&mut *stream, &Frame::JobFailed {
                        job: w.id,
                        error: msg,
                        spans,
                    })?;
                }
                send_heartbeat(
                    &mut *stream,
                    &mut sched,
                    &mut last_hb,
                    hot_k,
                    &mut span_out,
                    false,
                )?;
                if tick.stepped == 0 && tick.prefilled == 0 {
                    if let Some(wait) = tick.wait_s {
                        // Holding for batch-mates: sleep out the flush
                        // window, waking early on a new control frame.
                        let wait = Duration::from_secs_f64(wait.clamp(0.0002, 0.1));
                        if let Some(f) = msgs.recv_timeout(wait) {
                            handle_ctl(
                                f,
                                &mut *stream,
                                epoch.elapsed().as_secs_f64(),
                                &mut incoming,
                                &mut cancels,
                                &mut xfers,
                                &mut draining,
                                &mut spec_ok,
                            )?;
                        }
                    }
                }
            }
            Err(e) => {
                let msg = format!("engine step failed: {e:#}");
                for w in sched.fail_all() {
                    cancels.remove(&w.id);
                    let spans = take_spans(&mut span_out, w.id);
                    write_frame(&mut *stream, &Frame::JobFailed {
                        job: w.id,
                        error: msg.clone(),
                        spans,
                    })?;
                }
                engine_errors += 1;
                if engine_errors >= MAX_CONSECUTIVE_ENGINE_ERRORS {
                    let _ = write_frame(&mut *stream, &Frame::Fatal { error: msg });
                    bail!("engine persistently failing");
                }
            }
        }
    }

    // Drained: final counters, then the graceful terminal frame.
    send_heartbeat(&mut *stream, &mut sched, &mut last_hb, hot_k, &mut span_out, true)?;
    write_frame(&mut *stream, &Frame::Gone)?;
    Ok(())
}

/// Remove and return the staged-but-unshipped spans for one job.
fn take_spans(span_out: &mut Vec<(u64, Span)>, job: u64) -> Vec<Span> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < span_out.len() {
        if span_out[i].0 == job {
            spans.push(span_out.remove(i).1);
        } else {
            i += 1;
        }
    }
    spans
}

/// Apply one supervisor frame to the worker's control state. `now_s` is
/// the worker-epoch receipt time (the base trace spans are relative to).
fn handle_ctl(
    frame: Frame,
    stream: &mut dyn Transport,
    now_s: f64,
    incoming: &mut VecDeque<(u64, String, usize, bool, f64)>,
    cancels: &mut BTreeMap<u64, CancelToken>,
    xfers: &mut Transfers,
    draining: &mut bool,
    spec_ok: &mut bool,
) -> Result<()> {
    match frame {
        Frame::Job { job, prompt, max_tokens, trace } => {
            cancels.insert(job, CancelToken::new());
            incoming.push_back((job, prompt, max_tokens, !trace.is_empty(), now_s));
        }
        Frame::Cancel { job } => {
            if let Some(tok) = cancels.get(&job) {
                tok.cancel();
            }
        }
        Frame::Ping { nonce } => {
            write_frame(stream, &Frame::Pong { nonce })?;
        }
        Frame::FetchBlocks { req, hash } => {
            // We are the donor: export on the main loop (needs the
            // scheduler) and answer with a BlocksChunk echoing `req`.
            xfers.fetches.push((req, hash));
        }
        Frame::BlocksChunk { hash, blocks, done, .. } => {
            // We are the recipient of a brokered prefix delivery.
            // Chunks accumulate per chain hash until `done`.
            xfers.staged.entry(hash).or_default().extend(blocks);
            if done {
                let run = xfers.staged.remove(&hash).unwrap_or_default();
                if !run.is_empty() {
                    xfers.imports.push(run);
                }
            }
        }
        Frame::SpecDraft { ok } => {
            *spec_ok = ok;
        }
        Frame::Terminate => {
            *draining = true;
        }
        f => return Err(anyhow!("unexpected supervisor frame {f:?}")),
    }
    Ok(())
}

/// Ship cumulative scheduler counters (throttled; `force` for the final
/// pre-exit flush so no tail counts are lost).
fn send_heartbeat<E: StepEngine>(
    stream: &mut dyn Transport,
    sched: &mut Scheduler<E, WireJob>,
    last: &mut Instant,
    hot_k: usize,
    span_out: &mut Vec<(u64, Span)>,
    force: bool,
) -> Result<()> {
    if !force && last.elapsed() < HEARTBEAT_PERIOD {
        return Ok(());
    }
    *last = Instant::now();
    let stats = &sched.stats;
    let mut batch_counts = [0u64; DECODE_BATCHES.len()];
    for (i, &b) in DECODE_BATCHES.iter().enumerate() {
        batch_counts[i] = stats.batch_hist.bucket(b as f64);
    }
    let hb = HeartbeatWire {
        inflight: sched.inflight(),
        prefills: stats.prefills,
        prefill_batched: stats.prefill_batched,
        decode_steps: stats.decode_steps,
        batched_steps: stats.batched_steps,
        batch_counts,
        prefix_hit_tokens: sched.prefix_stats().hit_tokens,
        prefix_miss_tokens: sched.prefix_stats().miss_tokens,
        prefix_evicted_blocks: sched.prefix_stats().evicted_blocks,
        prefix_cache_blocks: sched.kv_cached_blocks() as u64,
        hot: if hot_k > 0 { sched.hot_prefixes(hot_k) } else { Vec::new() },
        spec_drafted_tokens: stats.spec_drafted_tokens,
        spec_accepted_tokens: stats.spec_accepted_tokens,
        spec_rejected_tokens: stats.spec_rejected_tokens,
        spec_verify_steps: stats.spec_verify_steps,
        // Early-flush staged spans (prefills of still-decoding jobs) so
        // a worker killed mid-decode leaves its prefill on the trace.
        spans: std::mem::take(span_out),
    };
    write_frame(stream, &Frame::Heartbeat(hb))?;
    Ok(())
}
