//! Completion (success) model — the paper's reliability metric.
//!
//! The paper defines success as "valid completion within time and token
//! limits", a function of how well the serving model's capacity matches
//! the task (truncations, timeouts) — *not* task correctness. We model it
//! as:
//!
//! `P(success | benchmark, model, complexity) = d_b · cap_m[c]`
//!
//! where `cap_m` is the model's per-complexity capability
//! ([`super::ModelSpec::capability`]) and `d_b` is a per-benchmark
//! difficulty factor **self-calibrated** so the *baseline* configuration
//! (uniform-random model assignment, the paper's unrouted default)
//! reproduces Table 1's per-benchmark success rates exactly in
//! expectation. Routed improvements then *emerge* from better
//! model–complexity matching rather than being hard-coded.

use super::ModelSpec;

/// Table 1 of the paper: per-benchmark baseline success rates.
pub const TABLE1_RATES: [(&str, f64); 8] = [
    ("humaneval", 0.800),
    ("gsm8k", 0.898),
    ("mbpp", 0.694),
    ("truthfulqa", 0.802),
    ("arc", 0.803),
    ("hellaswag", 0.802),
    ("math", 0.796),
    ("mmlu_pro", 0.700),
];

/// Output-length character per benchmark: mean generated tokens for a
/// well-matched model (code benchmarks are long, MC benchmarks short).
pub fn mean_output_tokens(benchmark: &str) -> f64 {
    match benchmark {
        "humaneval" | "mbpp" => 180.0,
        "gsm8k" => 110.0,
        "math" => 220.0,
        "truthfulqa" => 60.0,
        "arc" => 25.0,
        "hellaswag" => 20.0,
        "mmlu_pro" => 40.0,
        _ => 80.0,
    }
}

/// Completion model with calibrated per-benchmark difficulty.
#[derive(Debug, Clone)]
pub struct CompletionModel {
    /// (benchmark, difficulty factor d_b)
    difficulty: Vec<(String, f64)>,
}

impl CompletionModel {
    /// Calibrate `d_b` so that uniform-random assignment over `zoo`
    /// reproduces `target_rate` given the benchmark's complexity mix
    /// (`mix[c]` = fraction of prompts in class c).
    pub fn calibrate(
        zoo: &[ModelSpec],
        benchmarks: &[(String, [f64; 3], f64)], // (name, mix, target rate)
    ) -> CompletionModel {
        let difficulty = benchmarks
            .iter()
            .map(|(name, mix, target)| {
                // E[cap] under uniform-random model choice and this mix.
                let mut e_cap = 0.0;
                for c in 0..3 {
                    let avg: f64 = zoo.iter().map(|m| m.capability[c]).sum::<f64>()
                        / zoo.len() as f64;
                    e_cap += mix[c] * avg;
                }
                // d_b so that d_b * E[cap] == target. d may exceed 1 a
                // little (a benchmark can be *easier* than the mix-average
                // capability); it is capped so no per-model probability
                // d_b * cap can exceed 1.
                let cap_max = zoo
                    .iter()
                    .flat_map(|m| m.capability.iter())
                    .cloned()
                    .fold(0.0f64, f64::max);
                let d = (target / e_cap).min(1.0 / cap_max);
                (name.clone(), d)
            })
            .collect();
        CompletionModel { difficulty }
    }

    pub fn difficulty(&self, benchmark: &str) -> f64 {
        self.difficulty
            .iter()
            .find(|(n, _)| n == benchmark)
            .map(|(_, d)| *d)
            .unwrap_or(0.9)
    }

    /// P(valid completion) for a given assignment.
    pub fn success_prob(
        &self,
        benchmark: &str,
        model: &ModelSpec,
        complexity: usize,
    ) -> f64 {
        (self.difficulty(benchmark) * model.capability[complexity.min(2)])
            .clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn flat_mix() -> Vec<(String, [f64; 3], f64)> {
        TABLE1_RATES
            .iter()
            .map(|(n, r)| (n.to_string(), [0.3, 0.5, 0.2], *r))
            .collect()
    }

    #[test]
    fn calibration_reproduces_baseline_in_expectation() {
        let z = zoo();
        let cm = CompletionModel::calibrate(&z, &flat_mix());
        for (name, mix, target) in flat_mix() {
            // Expected success under uniform-random assignment.
            let mut e = 0.0;
            for c in 0..3 {
                for m in &z {
                    e += mix[c] * cm.success_prob(&name, m, c) / z.len() as f64;
                }
            }
            assert!(
                (e - target).abs() < 1e-9,
                "{name}: expected {target}, calibrated {e}"
            );
        }
    }

    #[test]
    fn routing_to_matched_tier_beats_random() {
        let z = zoo();
        let cm = CompletionModel::calibrate(&z, &flat_mix());
        // High-complexity on the biggest model vs on the smallest.
        let hi_big = cm.success_prob("math", &z[3], 2);
        let hi_small = cm.success_prob("math", &z[0], 2);
        assert!(hi_big > hi_small + 0.3);
    }

    #[test]
    fn probabilities_valid() {
        let z = zoo();
        let cm = CompletionModel::calibrate(&z, &flat_mix());
        for m in &z {
            for c in 0..3 {
                for (b, _) in TABLE1_RATES {
                    let p = cm.success_prob(b, m, c);
                    assert!((0.0..=1.0).contains(&p));
                }
            }
        }
    }

    #[test]
    fn unknown_benchmark_gets_default() {
        let z = zoo();
        let cm = CompletionModel::calibrate(&z, &flat_mix());
        assert!((cm.difficulty("unknown") - 0.9).abs() < 1e-12);
    }

    #[test]
    fn output_lengths_ordered() {
        assert!(mean_output_tokens("math") > mean_output_tokens("arc"));
        assert!(mean_output_tokens("humaneval") > mean_output_tokens("hellaswag"));
    }
}
