//! Model zoo + calibrated performance/quality model.
//!
//! The paper serves four foundation models (Gemma-3 27B, Llama-3 90B,
//! Qwen-3 235B, DeepSeek-R1 685B) on GPU clusters. Here each logical
//! model maps to one of the three *compiled engine tiers* (the AOT HLO
//! artifacts) for live execution, plus a calibrated performance/cost/
//! quality profile used by the discrete-event simulator for the paper's
//! large-scale tables (DESIGN.md §Substitutions).
//!
//! Calibration sources are documented per field; the simulator's
//! *relative* ordering (who is faster/cheaper/stronger) is what the
//! orchestration results depend on, not absolute numbers.

pub mod completion;

/// Engine tier — which compiled artifact family executes the model live.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    Small = 0,
    Medium = 1,
    Large = 2,
}

impl Tier {
    pub const ALL: [Tier; 3] = [Tier::Small, Tier::Medium, Tier::Large];

    pub fn name(self) -> &'static str {
        match self {
            Tier::Small => "small",
            Tier::Medium => "medium",
            Tier::Large => "large",
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Tier {
        Tier::ALL[i]
    }

    /// The complexity class this tier is the *intended* destination for
    /// (paper: small/medium/large ↔ low/medium/high).
    pub fn for_complexity(c: usize) -> Tier {
        Tier::ALL[c.min(2)]
    }
}

/// One logical model in the zoo.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Compiled engine tier used for live PJRT execution.
    pub tier: Tier,
    /// Parameter count in billions (paper's model sizes).
    pub params_b: f64,
    /// Weight footprint on the PVC in GB (fp8/int8-ish serving footprint:
    /// ~1.05 bytes/param).
    pub weight_gb: f64,
    /// GPUs per replica (tensor-parallel degree needed to fit).
    pub gpus: usize,
    /// $ per GPU-hour (A100-class on-prem amortized; the paper's cost
    /// unit is $/query derived from occupancy × this rate).
    pub cost_per_gpu_hour: f64,
    /// Decode throughput per stream, tokens/s, on the vLLM reference
    /// backend (public serving benchmarks for each model class).
    pub decode_tps: f64,
    /// Prefill throughput, tokens/s.
    pub prefill_tps: f64,
    /// P(valid completion | complexity class) — the reliability the
    /// paper's "success" metric measures, per complexity {low, med, high}.
    pub capability: [f64; 3],
}

impl ModelSpec {
    pub fn cost_per_replica_second(&self) -> f64 {
        self.gpus as f64 * self.cost_per_gpu_hour / 3600.0
    }
}

/// The four paper models.
pub fn zoo() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "gemma3-27b",
            tier: Tier::Small,
            params_b: 27.0,
            weight_gb: 28.0,
            gpus: 1,
            cost_per_gpu_hour: 2.5,
            decode_tps: 45.0,
            prefill_tps: 2200.0,
            capability: [0.97, 0.80, 0.45],
        },
        ModelSpec {
            name: "llama3-90b",
            tier: Tier::Medium,
            params_b: 90.0,
            weight_gb: 94.0,
            gpus: 2,
            cost_per_gpu_hour: 2.5,
            decode_tps: 25.0,
            prefill_tps: 1400.0,
            capability: [0.97, 0.90, 0.70],
        },
        ModelSpec {
            name: "qwen3-235b",
            tier: Tier::Large,
            params_b: 235.0,
            weight_gb: 245.0,
            gpus: 4,
            cost_per_gpu_hour: 2.5,
            decode_tps: 15.0,
            prefill_tps: 900.0,
            capability: [0.98, 0.94, 0.88],
        },
        ModelSpec {
            name: "deepseek-r1-685b",
            tier: Tier::Large,
            params_b: 685.0,
            weight_gb: 700.0,
            gpus: 8,
            cost_per_gpu_hour: 2.5,
            decode_tps: 10.0,
            prefill_tps: 600.0,
            capability: [0.98, 0.95, 0.92],
        },
    ]
}

/// Inference backends (columns of the paper's service matrix, with their
/// stated characters: vLLM throughput, TensorRT-LLM latency, TGI memory).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Vllm,
    TrtLlm,
    Tgi,
}

impl BackendKind {
    pub const ALL: [BackendKind; 3] =
        [BackendKind::Vllm, BackendKind::TrtLlm, BackendKind::Tgi];

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Vllm => "vllm",
            BackendKind::TrtLlm => "trt-llm",
            BackendKind::Tgi => "tgi",
        }
    }

    pub fn from_index(i: usize) -> BackendKind {
        BackendKind::ALL[i]
    }

    pub fn index(self) -> usize {
        match self {
            BackendKind::Vllm => 0,
            BackendKind::TrtLlm => 1,
            BackendKind::Tgi => 2,
        }
    }

    /// Latency multiplier vs the vLLM reference (TRT-LLM's compiled
    /// kernels cut per-token latency; TGI trades latency for memory).
    pub fn latency_factor(self) -> f64 {
        match self {
            BackendKind::Vllm => 1.0,
            BackendKind::TrtLlm => 0.75,
            BackendKind::Tgi => 1.15,
        }
    }

    /// Max concurrent streams per replica (continuous-batching capacity;
    /// vLLM's PagedAttention packs the most).
    pub fn max_concurrency(self) -> usize {
        match self {
            BackendKind::Vllm => 16,
            BackendKind::TrtLlm => 8,
            BackendKind::Tgi => 12,
        }
    }

    /// Cost multiplier (TGI's memory efficiency fits more replicas per
    /// GPU budget; TRT's engines cost extra build/VRAM headroom).
    pub fn cost_factor(self) -> f64 {
        match self {
            BackendKind::Vllm => 1.0,
            BackendKind::TrtLlm => 1.1,
            BackendKind::Tgi => 0.9,
        }
    }

    /// Engine initialization time on cold start (TRT engine load is slow).
    pub fn engine_init_s(self) -> f64 {
        match self {
            BackendKind::Vllm => 3.0,
            BackendKind::TrtLlm => 8.0,
            BackendKind::Tgi => 4.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_paper_models() {
        let z = zoo();
        assert_eq!(z.len(), 4);
        assert_eq!(z[0].name, "gemma3-27b");
        assert_eq!(z[3].params_b, 685.0);
    }

    #[test]
    fn capability_monotone_in_size() {
        let z = zoo();
        // On high-complexity prompts bigger models are strictly stronger.
        for w in z.windows(2) {
            assert!(w[1].capability[2] > w[0].capability[2]);
        }
    }

    #[test]
    fn speed_monotone_decreasing_in_size() {
        let z = zoo();
        for w in z.windows(2) {
            assert!(w[1].decode_tps < w[0].decode_tps);
        }
    }

    #[test]
    fn cost_scales_with_gpus() {
        let z = zoo();
        assert!(z[3].cost_per_replica_second() > 4.0 * z[0].cost_per_replica_second());
    }

    #[test]
    fn tier_for_complexity() {
        assert_eq!(Tier::for_complexity(0), Tier::Small);
        assert_eq!(Tier::for_complexity(2), Tier::Large);
        assert_eq!(Tier::for_complexity(9), Tier::Large);
    }

    #[test]
    fn backend_characters() {
        // TRT is the latency backend, vLLM the throughput backend, TGI the
        // memory/cost backend — the paper's stated matrix columns.
        assert!(BackendKind::TrtLlm.latency_factor() < BackendKind::Vllm.latency_factor());
        assert!(BackendKind::Vllm.max_concurrency() > BackendKind::TrtLlm.max_concurrency());
        assert!(BackendKind::Tgi.cost_factor() < BackendKind::Vllm.cost_factor());
    }
}
