//! Substrate — the one runtime-facing abstraction the control plane
//! drives.
//!
//! The paper's headline results (scale-to-zero economics, 4–12 s
//! recovery, Table 4) come from one control plane operating a Kubernetes
//! substrate. This module defines that contract: a [`Substrate`] can
//! provision and terminate replicas, report their lifecycle state, and
//! surface failures as events. Three implementations exist:
//!
//! * [`crate::cluster::Cluster`] — the simulated Kubernetes (pods on GPU
//!   nodes, image pulls, PVC weight loads, virtual time).
//! * `gateway::pool::LocalSubstrate` — the live engine pool (replica
//!   threads; Loading = engine compile/warm-up, Ready = scheduler loop
//!   running, wall-clock time).
//! * [`remote::ProcessSubstrate`] — replicas as supervised `ps-replica`
//!   OS processes over the framed JSON RPC data plane ([`proto`]); real
//!   crash isolation, `kill -9` recovery, the step toward multi-host.
//!
//! `testkit::substrate_conformance` pins the shared lifecycle contract
//! so the implementations cannot drift.
//!
//! `orchestrator::{scaling, selection, recovery}` operate only on this
//! trait, so Algorithm 1, Algorithm 2's cold-start penalties, and the
//! recovery manager's `Incident` accounting behave identically on the
//! simulated and live paths.

pub mod nodes;
pub mod proto;
pub mod remote;

use crate::models::{BackendKind, ModelSpec};
use crate::registry::ServiceId;

/// Identity of one replica (a pod in the sim, an engine thread live).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ReplicaId(pub u64);

/// Replica lifecycle. The sim walks the full Kubernetes-shaped chain
/// (Scheduled → Pulling → Loading → Initializing → Ready); the live
/// substrate uses the subset that has a physical meaning for an
/// in-process engine thread (Scheduled → Loading → Ready). Both end in
/// Terminating → gone, or Failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Accepted; resources assigned, nothing started yet.
    Scheduled,
    /// Container image transferring (sim only).
    Pulling,
    /// Weights loading / engine compiling and warming up.
    Loading,
    /// Backend engine initializing (sim only).
    Initializing,
    /// Serving traffic.
    Ready,
    /// Draining before exit.
    Terminating,
    /// Died (crash, panic, stalled health check).
    Failed,
}

impl ReplicaState {
    /// States that precede Ready (count as `pending` capacity).
    pub fn is_pending(self) -> bool {
        matches!(
            self,
            ReplicaState::Scheduled
                | ReplicaState::Pulling
                | ReplicaState::Loading
                | ReplicaState::Initializing
        )
    }

    /// States that hold capacity (pending or serving).
    pub fn is_live(self) -> bool {
        self.is_pending() || self == ReplicaState::Ready
    }
}

/// Lifecycle change produced by [`Substrate::poll`].
#[derive(Debug, Clone, PartialEq)]
pub enum SubstrateEvent {
    /// A replica finished its cold start and is serving.
    ReplicaReady {
        replica: ReplicaId,
        service: ServiceId,
        at_s: f64,
        /// Provision-to-Ready wall time (the cold-start measurement).
        cold_start_s: f64,
    },
    /// A replica finished draining and exited.
    ReplicaGone { replica: ReplicaId, service: ServiceId, at_s: f64 },
    /// A replica died without being asked to.
    ReplicaFailed { replica: ReplicaId, service: ServiceId, at_s: f64 },
}

impl SubstrateEvent {
    pub fn service(&self) -> ServiceId {
        match self {
            SubstrateEvent::ReplicaReady { service, .. }
            | SubstrateEvent::ReplicaGone { service, .. }
            | SubstrateEvent::ReplicaFailed { service, .. } => *service,
        }
    }
}

/// The runtime-facing contract the orchestrator drives. All timestamps
/// are explicit seconds so virtual (sim) and wall-clock (live) time share
/// every call site.
pub trait Substrate {
    /// Provision one replica of `service`. Returns its id, or `None`
    /// when the substrate has no capacity for it right now.
    fn provision(
        &mut self,
        service: ServiceId,
        model_idx: usize,
        spec: &ModelSpec,
        backend: BackendKind,
        now_s: f64,
    ) -> Option<ReplicaId>;

    /// Begin graceful termination (drain, then a `ReplicaGone` event).
    fn terminate(&mut self, replica: ReplicaId, now_s: f64);

    /// Kill a replica abruptly (fault injection for recovery
    /// experiments). Substrates that can observe the death synchronously
    /// (the simulator) return the failure event; asynchronous substrates
    /// (the live pool, where the kill lands at the replica's next
    /// heartbeat) return `None` and surface the `ReplicaFailed` through
    /// [`Self::poll`] — callers must handle both.
    fn fail(&mut self, replica: ReplicaId, now_s: f64) -> Option<SubstrateEvent>;

    /// Advance lifecycle state machines / collect state transitions that
    /// happened since the last poll.
    fn poll(&mut self, now_s: f64) -> Vec<SubstrateEvent>;

    /// Current state of a replica (`None` once it is gone).
    fn replica_state(&self, replica: ReplicaId) -> Option<ReplicaState>;

    /// Replicas of `service` currently Ready.
    fn ready_replicas(&self, service: ServiceId) -> Vec<ReplicaId>;

    /// Replicas of `service` in any pre-Ready state.
    fn pending_replicas(&self, service: ServiceId) -> usize;

    /// Expected cold-start seconds for a new replica of this shape (the
    /// Alg. 2 scaled-to-zero latency penalty).
    fn estimate_cold_start_s(&self, spec: &ModelSpec, backend: BackendKind) -> f64;
}

#[cfg(test)]
pub mod testing {
    //! A deterministic in-memory substrate for orchestrator unit tests:
    //! provisioned replicas become Ready after a fixed delay, capacity is
    //! a plain counter. Lets `scaling::apply` and `RecoveryManager` be
    //! tested against the trait alone, proving they carry no
    //! sim-only or gateway-only assumptions.

    use super::*;
    use std::collections::BTreeMap;

    struct MockReplica {
        service: ServiceId,
        state: ReplicaState,
        ready_at_s: f64,
        created_s: f64,
    }

    pub struct MockSubstrate {
        replicas: BTreeMap<ReplicaId, MockReplica>,
        next: u64,
        pub capacity: usize,
        pub cold_start_s: f64,
    }

    impl MockSubstrate {
        pub fn new(capacity: usize, cold_start_s: f64) -> MockSubstrate {
            MockSubstrate {
                replicas: BTreeMap::new(),
                next: 0,
                capacity,
                cold_start_s,
            }
        }

        fn live_count(&self) -> usize {
            self.replicas
                .values()
                .filter(|r| r.state.is_live() || r.state == ReplicaState::Terminating)
                .count()
        }
    }

    impl Substrate for MockSubstrate {
        fn provision(
            &mut self,
            service: ServiceId,
            _model_idx: usize,
            _spec: &ModelSpec,
            _backend: BackendKind,
            now_s: f64,
        ) -> Option<ReplicaId> {
            if self.live_count() >= self.capacity {
                return None;
            }
            let id = ReplicaId(self.next);
            self.next += 1;
            self.replicas.insert(id, MockReplica {
                service,
                state: ReplicaState::Loading,
                ready_at_s: now_s + self.cold_start_s,
                created_s: now_s,
            });
            Some(id)
        }

        fn terminate(&mut self, replica: ReplicaId, _now_s: f64) {
            if let Some(r) = self.replicas.get_mut(&replica) {
                r.state = ReplicaState::Terminating;
            }
        }

        fn fail(&mut self, replica: ReplicaId, now_s: f64) -> Option<SubstrateEvent> {
            let r = self.replicas.get_mut(&replica)?;
            r.state = ReplicaState::Failed;
            let service = r.service;
            self.replicas.remove(&replica);
            Some(SubstrateEvent::ReplicaFailed { replica, service, at_s: now_s })
        }

        fn poll(&mut self, now_s: f64) -> Vec<SubstrateEvent> {
            let mut out = Vec::new();
            let ids: Vec<ReplicaId> = self.replicas.keys().copied().collect();
            for id in ids {
                let r = self.replicas.get_mut(&id).unwrap();
                match r.state {
                    ReplicaState::Terminating => {
                        let service = r.service;
                        self.replicas.remove(&id);
                        out.push(SubstrateEvent::ReplicaGone {
                            replica: id,
                            service,
                            at_s: now_s,
                        });
                    }
                    s if s.is_pending() && now_s >= r.ready_at_s => {
                        r.state = ReplicaState::Ready;
                        out.push(SubstrateEvent::ReplicaReady {
                            replica: id,
                            service: r.service,
                            at_s: r.ready_at_s,
                            cold_start_s: r.ready_at_s - r.created_s,
                        });
                    }
                    _ => {}
                }
            }
            out
        }

        fn replica_state(&self, replica: ReplicaId) -> Option<ReplicaState> {
            self.replicas.get(&replica).map(|r| r.state)
        }

        fn ready_replicas(&self, service: ServiceId) -> Vec<ReplicaId> {
            self.replicas
                .iter()
                .filter(|(_, r)| r.service == service && r.state == ReplicaState::Ready)
                .map(|(id, _)| *id)
                .collect()
        }

        fn pending_replicas(&self, service: ServiceId) -> usize {
            self.replicas
                .values()
                .filter(|r| r.service == service && r.state.is_pending())
                .count()
        }

        fn estimate_cold_start_s(&self, _spec: &ModelSpec, _backend: BackendKind) -> f64 {
            self.cold_start_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testing::MockSubstrate;
    use super::*;
    use crate::models::zoo;

    #[test]
    fn mock_walks_lifecycle_and_reports_cold_start() {
        let z = zoo();
        let mut s = MockSubstrate::new(4, 5.0);
        let id = s
            .provision(ServiceId(0), 0, &z[0], BackendKind::Vllm, 10.0)
            .unwrap();
        assert_eq!(s.replica_state(id), Some(ReplicaState::Loading));
        assert_eq!(s.pending_replicas(ServiceId(0)), 1);
        assert!(s.poll(12.0).is_empty());
        let evs = s.poll(15.0);
        assert!(matches!(evs[0],
            SubstrateEvent::ReplicaReady { cold_start_s, .. }
                if (cold_start_s - 5.0).abs() < 1e-9));
        assert_eq!(s.ready_replicas(ServiceId(0)), vec![id]);
        assert_eq!(s.pending_replicas(ServiceId(0)), 0);
    }

    #[test]
    fn mock_capacity_bounds_provisioning() {
        let z = zoo();
        let mut s = MockSubstrate::new(1, 1.0);
        assert!(s.provision(ServiceId(0), 0, &z[0], BackendKind::Vllm, 0.0).is_some());
        assert!(s.provision(ServiceId(0), 0, &z[0], BackendKind::Vllm, 0.0).is_none());
    }

    #[test]
    fn mock_terminate_emits_gone() {
        let z = zoo();
        let mut s = MockSubstrate::new(2, 1.0);
        let id = s.provision(ServiceId(3), 0, &z[0], BackendKind::Tgi, 0.0).unwrap();
        s.poll(2.0);
        s.terminate(id, 3.0);
        assert_eq!(s.replica_state(id), Some(ReplicaState::Terminating));
        let evs = s.poll(4.0);
        assert!(matches!(evs[0], SubstrateEvent::ReplicaGone { .. }));
        assert_eq!(s.replica_state(id), None);
    }

    #[test]
    fn mock_substrate_passes_conformance() {
        // The same suite the thread and process substrates run — the
        // mock is the contract's reference implementation.
        let z = zoo();
        let mut s = MockSubstrate::new(4, 5.0);
        let mut t = 0.0;
        let mut d = crate::testkit::substrate_conformance::Driver {
            substrate: &mut s,
            service: ServiceId(0),
            model_idx: 0,
            spec: z[0].clone(),
            backend: BackendKind::Vllm,
            clock: Box::new(move || {
                t += 0.5;
                t
            }),
            timeout_s: 600.0,
        };
        crate::testkit::substrate_conformance::check(&mut d);
    }

    #[test]
    fn state_classification() {
        assert!(ReplicaState::Scheduled.is_pending());
        assert!(ReplicaState::Loading.is_pending());
        assert!(!ReplicaState::Ready.is_pending());
        assert!(ReplicaState::Ready.is_live());
        assert!(!ReplicaState::Failed.is_live());
        assert!(!ReplicaState::Terminating.is_live());
    }
}
