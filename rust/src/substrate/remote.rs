//! ProcessSubstrate — replica workers as supervised OS processes.
//!
//! The third [`Substrate`] implementation: each replica is a separate
//! `ps-replica` worker process (a subcommand of the gateway binary)
//! connected to the control plane over a length-prefixed JSON RPC
//! channel on a Unix socket ([`crate::substrate::proto`]). Where the
//! thread substrate shares memory with its replicas, this one must
//! serialize jobs, token streams, cancellation, and health across a
//! process boundary — which is exactly what buys real isolation: a
//! worker that is SIGKILLed mid-decode (something a thread fundamentally
//! cannot model) loses its address space, and the supervisor still
//! recovers every in-flight job loss-free from its own dispatch ledger.
//!
//! Per replica the supervisor runs one *pump* thread that owns the
//! worker `Child` and its socket end:
//!
//! * lifecycle — process spawned = `Scheduled`, `Hello` received =
//!   `Loading` (engine building), `Ready` frame = `Ready`; the measured
//!   spawn→Ready time feeds Alg. 2's cold-start estimate exactly like
//!   the thread substrate's compile times.
//! * data plane — pulls [`TierJob`]s from the shared tier queue, ships
//!   them as `Job` frames while the worker has slot headroom, accumulates
//!   streamed `TokenChunk`s, and answers the caller on `Done`. The reply
//!   rendezvous and cancel token never cross the wire; they stay in the
//!   pump's in-flight ledger, so worker death = requeue the ledger.
//! * health — every worker frame refreshes the replica cell's heartbeat;
//!   the control plane applies the same `pool.health_deadline_s` stall
//!   rule to wire heartbeats that it applies to thread heartbeats.
//!   `Heartbeat` payloads also carry the worker's cumulative scheduler
//!   counters and prefix-cache stats, which the pump differences into
//!   the gateway metrics and publishes into the cell (the scaler's
//!   cache-adjusted demand signal).
//! * supervision — `cell.kill` SIGKILLs the worker (fault injection =
//!   real `kill -9`); `cell.stop` sends `Terminate` for a graceful drain
//!   (unstarted jobs come back as `Returned` frames and requeue); zombies
//!   are reaped (`kill` + `wait`) on every pump exit path.
//!
//! With `pool.nodes` configured the same supervisor goes **multi-host**:
//! replicas place onto registered `ps-node` agents
//! ([`crate::substrate::nodes`]) by the configured policy (least-loaded
//! spread with tier anti-affinity, or pack), the worker dials back over
//! TCP, and the pump session is byte-identical — only the
//! [`Transport`] underneath differs. A remote worker cannot be
//! signalled, so "kill" severs its data channel instead (the worker
//! exits when its supervisor link drops); a *node* lost whole takes
//! every hosted replica with it, each requeueing its ledger loss-free
//! before the recovery path re-provisions on the survivors.

use std::collections::BTreeMap;
use std::io::{self, ErrorKind};
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::PoolConfig;
use crate::gateway::pool::{
    decode_state, requeue_to, PoolShared, ReplicaCell, TierJob, S_FAILED, S_GONE,
    S_LOADING, S_READY, S_SCHEDULED, S_TERMINATING,
};
use crate::gateway::{CompletionError, FailureKind, GatewayMetrics, LiveResponse};
use crate::models::{BackendKind, ModelSpec, Tier};
use crate::registry::{Registry, ServiceId};
use crate::substrate::nodes::{NodeId, NodeRegistry};
use crate::substrate::proto::{
    negotiate, write_frame, Frame, FrameReader, HeartbeatWire, PoolWire, Transport,
    MAX_FRAME_BYTES, PROTO_VERSION,
};
use crate::substrate::{ReplicaId, ReplicaState, Substrate, SubstrateEvent};
use crate::telemetry::trace::{format_traceparent, Span, SpanKind};
use crate::util::stats::Ema;
use crate::util::threadpool::Channel;

/// How long a spawned worker gets to connect and say Hello.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);
/// How long a graceful drain may take before the worker is killed.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(30);
/// Pump read timeout — the loop's pacing granularity.
const READ_TIMEOUT: Duration = Duration::from_millis(2);
/// RPC latency probe period.
const PING_PERIOD: Duration = Duration::from_millis(250);
/// Blocks per `BlocksChunk` delivery frame: keeps any single frame well
/// under [`MAX_FRAME_BYTES`] however long a transferred prefix run is.
const XFER_CHUNK_BLOCKS: usize = 64;

/// Unique socket names across every substrate in this process.
static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

/// How to launch one worker process.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Worker binary (normally the gateway binary itself).
    pub bin: String,
    /// Leading arguments, e.g. `["ps-replica", "--engine", "sim"]`.
    /// `--socket/--tier/--replica` are appended per replica.
    pub args: Vec<String>,
    /// Directory for per-worker stdout/stderr logs (`None` = inherit).
    pub log_dir: Option<String>,
}

impl WorkerSpec {
    /// The spec the gateway derives from `pool.*`: `pool.worker_bin` (or
    /// the current executable) run in `ps-replica` mode with the given
    /// engine arguments.
    pub fn from_pool(pool: &PoolConfig, engine_args: &[&str]) -> Result<WorkerSpec, String> {
        let bin = match &pool.worker_bin {
            Some(b) => b.clone(),
            None => std::env::current_exe()
                .map_err(|e| format!("cannot resolve worker binary: {e}"))?
                .to_string_lossy()
                .into_owned(),
        };
        let mut args = vec!["ps-replica".to_string()];
        args.extend(engine_args.iter().map(|s| s.to_string()));
        Ok(WorkerSpec { bin, args, log_dir: pool.worker_log_dir.clone() })
    }
}

struct ProcReplica {
    tier: usize,
    service: ServiceId,
    cell: Arc<ReplicaCell>,
    created_s: f64,
    /// Last state surfaced through `poll` (transition edge detection).
    reported: ReplicaState,
    /// Node hosting this replica's worker (`None` = local child).
    node: Option<NodeId>,
}

/// The process-substrate supervisor. Owned by the router thread, driven
/// through the same [`Substrate`] trait as the simulator's cluster and
/// the thread pool — `Scaler`, `RecoveryManager` and `select_on` run
/// unchanged on top of it.
pub struct ProcessSubstrate {
    shared: Arc<PoolShared>,
    pool: PoolConfig,
    metrics: Arc<GatewayMetrics>,
    spec: WorkerSpec,
    svc_tier: Vec<usize>,
    tier_service: [ServiceId; 3],
    meta: BTreeMap<ReplicaId, ProcReplica>,
    pumps: BTreeMap<ReplicaId, JoinHandle<()>>,
    next_id: u64,
    next_index: [usize; 3],
    /// Measured spawn→Ready seconds per tier (Alg. 2's cold-start
    /// estimate for scaled-to-zero tiers).
    cold_start_ema: [Ema; 3],
    /// Multi-host node plane (`pool.nodes`); `None` = every replica is a
    /// local child process.
    nodes: Option<Arc<NodeRegistry>>,
}

impl ProcessSubstrate {
    pub(crate) fn new(
        shared: Arc<PoolShared>,
        pool: PoolConfig,
        metrics: Arc<GatewayMetrics>,
        spec: WorkerSpec,
        registry: &Registry,
        nodes: Option<Arc<NodeRegistry>>,
    ) -> ProcessSubstrate {
        let svc_tier: Vec<usize> =
            registry.services.iter().map(|s| s.spec.tier.index()).collect();
        let tier_service = std::array::from_fn(|ti| {
            registry
                .services
                .iter()
                .find(|s| s.spec.tier.index() == ti)
                .map(|s| s.id)
                .unwrap_or(ServiceId(0))
        });
        ProcessSubstrate {
            shared,
            pool,
            metrics,
            spec,
            svc_tier,
            tier_service,
            meta: BTreeMap::new(),
            pumps: BTreeMap::new(),
            next_id: 0,
            next_index: [0; 3],
            cold_start_ema: std::array::from_fn(|_| Ema::new(0.3)),
            nodes,
        }
    }

    /// A self-contained supervisor (own queues and metrics) — what the
    /// substrate conformance suite drives directly, without a gateway.
    /// Brings up the node plane from `pool.nodes` when configured
    /// (panicking on an unreachable agent: a standalone harness wants
    /// misconfiguration loud, the gateway path returns it as an error).
    pub fn standalone(
        pool: PoolConfig,
        registry: &Registry,
        spec: WorkerSpec,
    ) -> ProcessSubstrate {
        let shared = Arc::new(PoolShared::new(Instant::now(), pool.queue_capacity));
        let metrics = Arc::new(GatewayMetrics::default());
        let nodes = NodeRegistry::from_config(&pool.nodes)
            .expect("standalone process substrate: node plane");
        ProcessSubstrate::new(shared, pool, metrics, spec, registry, nodes)
    }

    /// The node registry when `pool.nodes` is configured (placement
    /// introspection, per-node metrics).
    pub fn nodes(&self) -> Option<Arc<NodeRegistry>> {
        self.nodes.as_ref().map(Arc::clone)
    }

    /// The clock epoch replica timestamps are measured against.
    pub fn epoch(&self) -> Instant {
        self.shared.epoch
    }

    pub(crate) fn shared(&self) -> Arc<PoolShared> {
        Arc::clone(&self.shared)
    }

    /// The canonical registry cell a tier's replicas report under.
    pub fn tier_service(&self, tier: usize) -> ServiceId {
        self.tier_service[tier.min(2)]
    }

    fn tier_of(&self, service: ServiceId) -> usize {
        self.svc_tier.get(service.0).copied().unwrap_or(0)
    }

    /// Block until every provisioned worker reports Ready; a worker that
    /// dies or errors during bring-up surfaces as the error.
    pub fn wait_warm(&mut self) -> Result<(), String> {
        loop {
            let mut all_ready = true;
            for (id, m) in &self.meta {
                match m.cell.state.load(Ordering::Acquire) {
                    S_READY => {}
                    S_FAILED => {
                        return Err(m
                            .cell
                            .error
                            .lock()
                            .unwrap()
                            .take()
                            .unwrap_or_else(|| "worker died during warm-up".into()));
                    }
                    _ => {
                        if self.pumps.get(id).map(|h| h.is_finished()).unwrap_or(true) {
                            return Err("worker pump exited during warm-up".into());
                        }
                        all_ready = false;
                    }
                }
            }
            if all_ready {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Close the tier queues, drain every worker, and join the pumps
    /// (each pump kills and reaps its child on the way out), then tear
    /// the node plane down (agents see EOF and exit). Idempotent.
    pub fn shutdown(&mut self) {
        for q in &self.shared.queues {
            q.close();
        }
        for (_, h) in std::mem::take(&mut self.pumps) {
            let _ = h.join();
        }
        self.meta.clear();
        for c in &self.shared.cells {
            c.lock().unwrap().clear();
        }
        if let Some(reg) = &self.nodes {
            reg.shutdown();
        }
    }

    fn remove_replica(&mut self, id: ReplicaId, tier: usize) {
        self.meta.remove(&id);
        self.shared.cells[tier].lock().unwrap().retain(|(rid, _)| *rid != id);
        if let Some(h) = self.pumps.remove(&id) {
            if h.is_finished() {
                let _ = h.join();
            }
            // A live pump has its kill flag set (stall path): it kills
            // and reaps its worker, then exits on its own.
        }
    }
}

impl Drop for ProcessSubstrate {
    fn drop(&mut self) {
        // Never leak worker processes, even if the owner forgot to shut
        // down (a panicking test, say).
        self.shutdown();
    }
}

impl Substrate for ProcessSubstrate {
    fn provision(
        &mut self,
        service: ServiceId,
        _model_idx: usize,
        spec: &ModelSpec,
        _backend: BackendKind,
        now_s: f64,
    ) -> Option<ReplicaId> {
        let ti = spec.tier.index();
        if self.shared.live_count(ti) >= self.pool.replicas[ti] {
            return None;
        }
        let tier = Tier::ALL[ti];
        let index = self.next_index[ti];
        let seq = SOCK_SEQ.fetch_add(1, Ordering::Relaxed);
        // Placement: a registered-and-alive node with free slots hosts
        // the worker over TCP; with live nodes all at capacity the tier
        // cannot grow (never silently overload the supervisor host); with
        // no node plane (or every node lost) spawn a local child —
        // exactly the single-host behavior.
        let placed = match &self.nodes {
            Some(reg) => match reg.place(ti, self.pool.nodes.placement) {
                Some(nid) => Some((Arc::clone(reg), nid)),
                None if reg.any_alive() => return None,
                None => None,
            },
            None => None,
        };
        // Bind the data listener before spawning so the worker's connect
        // never races it: a Unix socket for a local child, a TCP port on
        // the node-reachable host for a placed worker.
        let (acceptor, socket_path, tcp_port) = match &placed {
            None => {
                let sock = std::env::temp_dir().join(format!(
                    "ps-and-spin-{}-{seq}.sock",
                    std::process::id(),
                ));
                let _ = std::fs::remove_file(&sock);
                match UnixListener::bind(&sock) {
                    Ok(l) => (Acceptor::Unix(l), Some(sock), 0u16),
                    Err(e) => {
                        crate::error!(
                            "process substrate: bind {}: {e}",
                            sock.display()
                        );
                        return None;
                    }
                }
            }
            Some((reg, _)) => {
                match TcpListener::bind((reg.data_host(), 0)) {
                    Ok(l) => {
                        let port = match l.local_addr() {
                            Ok(a) => a.port(),
                            Err(e) => {
                                crate::error!("process substrate: local_addr: {e}");
                                return None;
                            }
                        };
                        (Acceptor::Tcp(l), None, port)
                    }
                    Err(e) => {
                        crate::error!(
                            "process substrate: bind {}:0: {e}",
                            reg.data_host()
                        );
                        return None;
                    }
                }
            }
        };
        let cell = Arc::new(ReplicaCell::new());
        // The pump thread starts first and blocks on this channel for
        // its worker link (local `Child` or remote placement): if the
        // spawn fails the channel is closed instead, and if the *thread*
        // spawn fails nothing has been started yet — neither order can
        // leak an unreaped worker or an unaccounted node slot.
        let link_chan: Channel<WorkerLink> = Channel::bounded(1);
        let handle = {
            let ctx = PumpStart {
                listener: acceptor,
                socket_path: socket_path.clone(),
                cell: Arc::clone(&cell),
                queue: self.shared.queues[ti].clone(),
                metrics: Arc::clone(&self.metrics),
                epoch: self.shared.epoch,
                pool: self.pool.clone(),
                tier: ti,
                spec_draft_ok: Arc::clone(&self.shared.spec_draft_ok),
            };
            let rx = link_chan.clone();
            match std::thread::Builder::new()
                .name(format!("ps-pump-{}-{index}", tier.name()))
                .spawn(move || match rx.recv() {
                    Some(link) => pump_loop(ctx.with_link(link)),
                    None => {
                        // Worker spawn failed; nothing to supervise.
                        *ctx.cell.error.lock().unwrap() =
                            Some("worker spawn failed".into());
                        ctx.cell.state.store(S_FAILED, Ordering::Release);
                        if let Some(p) = &ctx.socket_path {
                            let _ = std::fs::remove_file(p);
                        }
                    }
                }) {
                Ok(h) => h,
                Err(e) => {
                    crate::error!("process substrate: pump thread: {e}");
                    if let Some(p) = &socket_path {
                        let _ = std::fs::remove_file(p);
                    }
                    return None;
                }
            }
        };
        let node_id = match &placed {
            None => {
                let sock = socket_path.as_ref().expect("local spawn has a socket");
                let mut cmd = Command::new(&self.spec.bin);
                cmd.args(&self.spec.args)
                    .arg("--socket")
                    .arg(sock)
                    .arg("--tier")
                    .arg(tier.name())
                    .arg("--replica")
                    .arg(index.to_string())
                    .stdin(Stdio::null());
                match worker_log(&self.spec.log_dir, tier.name(), index, seq) {
                    Some(f) => {
                        if let Ok(err) = f.try_clone() {
                            cmd.stdout(f).stderr(err);
                        }
                    }
                    None => {
                        cmd.stdout(Stdio::null());
                        // stderr inherits: worker diagnostics reach the
                        // gateway log.
                    }
                }
                match cmd.spawn() {
                    Ok(child) => {
                        let _ = link_chan.send(WorkerLink::Local(child));
                        None
                    }
                    Err(e) => {
                        crate::error!(
                            "process substrate: spawn {}: {e}",
                            self.spec.bin
                        );
                        link_chan.close();
                        let _ = handle.join();
                        return None;
                    }
                }
            }
            Some((reg, nid)) => {
                match reg.spawn_on(*nid, seq, ti, index, tcp_port, &self.spec.args) {
                    Ok(()) => {
                        reg.add_hosted(*nid, ti);
                        let _ = link_chan.send(WorkerLink::Remote {
                            node: *nid,
                            seq,
                            reg: Arc::clone(reg),
                        });
                        Some(*nid)
                    }
                    Err(e) => {
                        crate::error!("process substrate: place on node: {e}");
                        link_chan.close();
                        let _ = handle.join();
                        return None;
                    }
                }
            }
        };
        let id = ReplicaId(self.next_id);
        self.next_id += 1;
        self.next_index[ti] += 1;
        self.shared.cells[ti].lock().unwrap().push((id, Arc::clone(&cell)));
        self.meta.insert(id, ProcReplica {
            tier: ti,
            service,
            cell,
            created_s: now_s,
            reported: ReplicaState::Scheduled,
            node: node_id,
        });
        self.pumps.insert(id, handle);
        Some(id)
    }

    fn terminate(&mut self, replica: ReplicaId, _now_s: f64) {
        if let Some(m) = self.meta.get(&replica) {
            m.cell.stop.store(true, Ordering::Relaxed);
            // Control-side state so Ready counts drop immediately; the
            // pump overwrites with Gone once the worker drains.
            let _ = m.cell.state.compare_exchange(
                S_READY,
                S_TERMINATING,
                Ordering::AcqRel,
                Ordering::Relaxed,
            );
        }
    }

    /// Failure is asynchronous: the pump SIGKILLs the worker at its next
    /// loop turn and the `ReplicaFailed` surfaces through [`Self::poll`]
    /// when the connection drops — a real `kill -9`, not a simulation.
    fn fail(&mut self, replica: ReplicaId, _now_s: f64) -> Option<SubstrateEvent> {
        if let Some(m) = self.meta.get(&replica) {
            m.cell.kill.store(true, Ordering::Relaxed);
        }
        None
    }

    fn poll(&mut self, now_s: f64) -> Vec<SubstrateEvent> {
        let mut out = Vec::new();
        // Node death is collective: every replica hosted on a lost node
        // dies with it. Setting the kill flag makes its pump sever the
        // data channel, requeue the dispatch ledger loss-free, and
        // publish Failed — the same single event path an individual
        // worker death takes, so recovery re-provisions (on a surviving
        // node, by placement) without a special case.
        if let Some(reg) = &self.nodes {
            for m in self.meta.values() {
                if let Some(nid) = m.node {
                    if !reg.alive(nid) {
                        m.cell.kill.store(true, Ordering::Relaxed);
                    }
                }
            }
        }
        let ids: Vec<ReplicaId> = self.meta.keys().copied().collect();
        for id in ids {
            let (tier, service, created_s, reported, cell) = {
                let m = &self.meta[&id];
                (m.tier, m.service, m.created_s, m.reported, Arc::clone(&m.cell))
            };
            let raw = cell.state.load(Ordering::Acquire);
            let pump_dead = self
                .pumps
                .get(&id)
                .map(|h| h.is_finished())
                .unwrap_or(true);
            // Wire heartbeats against the same health deadline the
            // thread substrate applies to in-process heartbeats.
            let stalled = raw == S_READY && {
                let hb = cell.heartbeat_us.load(Ordering::Relaxed) as f64 / 1e6;
                now_s - hb > self.pool.health_deadline_s.max(0.001)
            };
            let failed = raw == S_FAILED
                || stalled
                || (pump_dead && raw != S_GONE && raw != S_FAILED);
            if failed {
                if stalled {
                    // The pump kills the silent worker and requeues its
                    // in-flight ledger the moment it sees the flag.
                    cell.kill.store(true, Ordering::Relaxed);
                }
                out.push(SubstrateEvent::ReplicaFailed {
                    replica: id,
                    service,
                    at_s: now_s,
                });
                self.remove_replica(id, tier);
                continue;
            }
            if raw == S_GONE {
                out.push(SubstrateEvent::ReplicaGone {
                    replica: id,
                    service,
                    at_s: now_s,
                });
                self.remove_replica(id, tier);
                continue;
            }
            if raw == S_READY && reported != ReplicaState::Ready {
                let ready_s = cell.ready_us.load(Ordering::Relaxed) as f64 / 1e6;
                let cold = (ready_s - created_s).max(0.0);
                self.cold_start_ema[tier].observe(cold);
                out.push(SubstrateEvent::ReplicaReady {
                    replica: id,
                    service,
                    at_s: ready_s.max(created_s),
                    cold_start_s: cold,
                });
                if let Some(m) = self.meta.get_mut(&id) {
                    m.reported = ReplicaState::Ready;
                }
            }
        }
        out
    }

    fn replica_state(&self, replica: ReplicaId) -> Option<ReplicaState> {
        self.meta
            .get(&replica)
            .and_then(|m| decode_state(m.cell.state.load(Ordering::Acquire)))
    }

    fn ready_replicas(&self, service: ServiceId) -> Vec<ReplicaId> {
        let ti = self.tier_of(service);
        self.meta
            .iter()
            .filter(|(_, m)| {
                m.tier == ti
                    && m.cell.state.load(Ordering::Acquire) == S_READY
                    && !m.cell.stop.load(Ordering::Relaxed)
            })
            .map(|(id, _)| *id)
            .collect()
    }

    fn pending_replicas(&self, service: ServiceId) -> usize {
        self.shared.pending_count(self.tier_of(service))
    }

    fn estimate_cold_start_s(&self, spec: &ModelSpec, _backend: BackendKind) -> f64 {
        // Prior before the first measured spawn: process start + engine
        // build is an order slower than an in-process warm-up.
        self.cold_start_ema[spec.tier.index()].get_or(1.0)
    }
}

/// Per-worker log file, shared by the local supervisor and the node
/// agent (`substrate::nodes`) so logs collect identically wherever the
/// worker runs. The name carries the spawning process's pid and the
/// supervisor's replica sequence: per-tier indices restart at 0 for
/// every substrate instance (parallel tests, agents sharing a log
/// directory), and a bare `ps-worker-small-0.log` would be truncated
/// out from under a worker another instance is still supervising.
pub(crate) fn worker_log(
    dir: &Option<String>,
    tier: &str,
    index: usize,
    seq: u64,
) -> Option<std::fs::File> {
    let dir = dir.as_ref()?;
    std::fs::create_dir_all(dir).ok()?;
    std::fs::File::create(format!(
        "{dir}/ps-worker-{tier}-{index}-{}-{seq}.log",
        std::process::id(),
    ))
    .ok()
}

// ---------------------------------------------------------------------------
// The per-replica pump: supervisor end of the RPC data plane
// ---------------------------------------------------------------------------

/// The per-replica data listener the worker dials back to: a Unix
/// socket for a local child, a TCP port for a node-placed worker. The
/// accepted stream is configured identically (blocking + short read
/// timeout) and boxed — the session below never sees the difference.
enum Acceptor {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Acceptor {
    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Acceptor::Unix(l) => l.set_nonblocking(nb),
            Acceptor::Tcp(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> io::Result<Box<dyn Transport>> {
        match self {
            Acceptor::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(READ_TIMEOUT))?;
                Ok(Box::new(s))
            }
            Acceptor::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                let _ = s.set_nodelay(true);
                s.set_read_timeout(Some(READ_TIMEOUT))?;
                Ok(Box::new(s))
            }
        }
    }
}

/// What the pump supervises: a local child it can signal and reap, or a
/// worker on a remote node it can only reach through the data channel
/// and the node's accounting.
enum WorkerLink {
    Local(Child),
    Remote {
        node: NodeId,
        /// SpawnReplica sequence (keys the agent's SpawnFailed answer).
        seq: u64,
        reg: Arc<NodeRegistry>,
    },
}

impl WorkerLink {
    /// Abrupt kill: SIGKILL a local child; a remote worker cannot be
    /// signalled, so sever its data channel — the worker exits the
    /// moment its supervisor link drops, and our next read sees EOF.
    fn kill(&mut self, stream: &dyn Transport) {
        match self {
            WorkerLink::Local(child) => {
                let _ = child.kill();
            }
            WorkerLink::Remote { .. } => stream.shutdown(),
        }
    }

    /// Pre-connect probe: did the worker already die (local exit) or
    /// fail to start (agent SpawnFailed / node lost)?
    fn connect_aborted(&mut self) -> Option<String> {
        match self {
            WorkerLink::Local(child) => match child.try_wait() {
                Ok(Some(status)) => {
                    Some(format!("worker exited before connecting ({status})"))
                }
                _ => None,
            },
            WorkerLink::Remote { node, seq, reg } => {
                if let Some(e) = reg.take_spawn_failure(*seq) {
                    return Some(format!("node agent could not spawn worker: {e}"));
                }
                if !reg.alive(*node) {
                    return Some("node lost before worker connected".into());
                }
                None
            }
        }
    }
}

/// Everything the pump thread needs before its worker link exists (the
/// link arrives over a channel so a failed spawn can never leak).
struct PumpStart {
    listener: Acceptor,
    socket_path: Option<PathBuf>,
    cell: Arc<ReplicaCell>,
    queue: Channel<TierJob>,
    metrics: Arc<GatewayMetrics>,
    epoch: Instant,
    pool: PoolConfig,
    tier: usize,
    spec_draft_ok: Arc<AtomicBool>,
}

impl PumpStart {
    fn with_link(self, link: WorkerLink) -> PumpCtx {
        PumpCtx {
            listener: self.listener,
            socket_path: self.socket_path,
            link,
            cell: self.cell,
            queue: self.queue,
            metrics: self.metrics,
            epoch: self.epoch,
            pool: self.pool,
            tier: self.tier,
            spec_draft_ok: self.spec_draft_ok,
        }
    }
}

struct PumpCtx {
    listener: Acceptor,
    socket_path: Option<PathBuf>,
    link: WorkerLink,
    cell: Arc<ReplicaCell>,
    queue: Channel<TierJob>,
    metrics: Arc<GatewayMetrics>,
    epoch: Instant,
    pool: PoolConfig,
    tier: usize,
    /// Router-published draft-tier availability, relayed to the worker
    /// as `SpecDraft` frames on every edge (v2 sessions only).
    spec_draft_ok: Arc<AtomicBool>,
}

/// One dispatched job the worker still owes us. The reply rendezvous
/// and cancel token live here — worker death requeues `job` verbatim.
struct InflightJob {
    job: TierJob,
    tokens: Vec<i32>,
    chunk_seen: bool,
    cancel_sent: bool,
}

fn pump_loop(mut ctx: PumpCtx) {
    if let Err(e) = pump_session(&mut ctx) {
        // Only overwrite non-terminal states: a session that ended in
        // Gone must stay Gone.
        let raw = ctx.cell.state.load(Ordering::Acquire);
        if raw != S_GONE {
            *ctx.cell.error.lock().unwrap() = Some(e);
            ctx.cell.inflight.store(0, Ordering::Relaxed);
            ctx.cell.state.store(S_FAILED, Ordering::Release);
        }
    }
    // Jobs the router direct-placed on this replica that the session
    // never dispatched: back to the tier queue, loss-free.
    let now = ctx.epoch.elapsed().as_secs_f64();
    while let Some(job) = ctx.cell.direct.try_recv() {
        requeue_to(&ctx.queue, &ctx.metrics, job, "replica exited", now);
    }
    match &mut ctx.link {
        // Reap unconditionally: kill is a no-op on an exited worker, and
        // wait() collects the zombie either way.
        WorkerLink::Local(child) => {
            let _ = child.kill();
            let _ = child.wait();
        }
        // The node agent reaps its own children; here only the slot
        // accounting is returned so placement sees the free capacity.
        WorkerLink::Remote { node, reg, .. } => {
            reg.release(*node, ctx.tier);
        }
    }
    if let Some(p) = &ctx.socket_path {
        let _ = std::fs::remove_file(p);
    }
}

/// Run one worker session end to end. `Ok` means a terminal state was
/// already published (Gone or Failed); `Err` is an abnormal end whose
/// message lands in the cell.
fn pump_session(ctx: &mut PumpCtx) -> Result<(), String> {
    let mut stream: Box<dyn Transport> = accept_worker(ctx)?;
    let mut reader = FrameReader::new();
    // Handshake: Hello → negotiate → HelloAck with the pool knobs.
    let hello = read_deadline(&mut *stream, &mut reader, CONNECT_TIMEOUT, ctx)?;
    let version = match hello {
        Frame::Hello { version, tier, .. } => {
            if tier != ctx.tier {
                return Err(format!(
                    "worker announced tier {tier}, expected {}",
                    ctx.tier
                ));
            }
            negotiate(PROTO_VERSION, version).ok_or_else(|| {
                format!("no common protocol version (worker spoke {version})")
            })?
        }
        f => return Err(format!("expected Hello, got {f:?}")),
    };
    // The pool window is tier-gated: only a tier the speculative config
    // pairs as a *verifier* receives a nonzero draft window, so a draft
    // tier's own worker never tries to speculate against itself.
    send(
        &mut *stream,
        &Frame::HelloAck {
            version,
            pool: PoolWire::from_pool_for_tier(&ctx.pool, ctx.tier),
        },
        ctx,
    )?;
    ctx.cell
        .heartbeat_us
        .store(ctx.epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
    ctx.cell.state.store(S_LOADING, Ordering::Release);

    let mut inflight: BTreeMap<u64, InflightJob> = BTreeMap::new();
    let mut next_job: u64 = 0;
    // Outstanding donor fetches this pump brokered: req id → the cold
    // replica's cell (blocks accumulate here until the donor's `done`).
    // Req 0 is reserved for supervisor→worker deliveries, so fetch req
    // ids start at 1.
    let mut next_xfer: u64 = 1;
    let mut xfer_pending: BTreeMap<u64, (Arc<ReplicaCell>, Vec<Vec<i32>>)> =
        BTreeMap::new();
    let mut last_hb = HeartbeatWire::default();
    // Last draft-availability value shipped to the worker; `None` until
    // the first edge so a fresh worker starts from its own default
    // (unavailable) and the very first `true` is always delivered.
    let mut last_spec_ok: Option<bool> = None;
    let mut killed = false;
    let mut draining = false;
    let mut drain_deadline = Instant::now() + DRAIN_TIMEOUT;
    let mut last_ping = Instant::now();
    let mut buf = [0u8; 16384];
    loop {
        // 1. Drain whatever the worker sent.
        match stream.read(&mut buf) {
            Ok(0) => {
                return end_dead(ctx, inflight, "worker connection closed");
            }
            Ok(n) => {
                reader.extend(&buf[..n]);
                let now_us = ctx.epoch.elapsed().as_micros() as u64;
                ctx.cell.heartbeat_us.store(now_us, Ordering::Relaxed);
                loop {
                    let frame = match reader.next() {
                        Ok(Some(f)) => f,
                        Ok(None) => break,
                        Err(e) => {
                            return end_dead(
                                ctx,
                                inflight,
                                &format!("wire desync: {e:#}"),
                            );
                        }
                    };
                    ctx.metrics.rpc_frames_recv.fetch_add(1, Ordering::Relaxed);
                    match frame {
                        Frame::Ready => {
                            let now_us = ctx.epoch.elapsed().as_micros() as u64;
                            ctx.cell.ready_us.store(now_us, Ordering::Relaxed);
                            // Only the Loading→Ready edge; a terminate
                            // that already moved the state on keeps it.
                            let _ = ctx.cell.state.compare_exchange(
                                S_LOADING,
                                S_READY,
                                Ordering::AcqRel,
                                Ordering::Relaxed,
                            );
                            let _ = ctx.cell.state.compare_exchange(
                                S_SCHEDULED,
                                S_READY,
                                Ordering::AcqRel,
                                Ordering::Relaxed,
                            );
                        }
                        Frame::Heartbeat(hb) => {
                            // Early-flushed worker spans (prefills of
                            // still-decoding jobs): merged now so a
                            // SIGKILL later keeps what already happened.
                            for (jid, span) in &hb.spans {
                                if let Some(e) = inflight.get_mut(jid) {
                                    merge_worker_span(&mut e.job, *span);
                                }
                            }
                            apply_heartbeat(&hb, &last_hb, ctx);
                            last_hb = hb;
                        }
                        Frame::TokenChunk { job, tokens } => {
                            if let Some(e) = inflight.get_mut(&job) {
                                if !e.chunk_seen {
                                    e.chunk_seen = true;
                                    let now = ctx.epoch.elapsed().as_secs_f64();
                                    e.job.ttft_s = (now - e.job.enqueue_s).max(0.0);
                                }
                                e.tokens.extend(tokens);
                            }
                        }
                        Frame::Done { job, prompt_tokens, tokens, spans } => {
                            if let Some(mut e) = inflight.remove(&job) {
                                e.tokens.extend(tokens);
                                for span in spans {
                                    merge_worker_span(&mut e.job, span);
                                }
                                finish_entry(e, prompt_tokens, ctx);
                            }
                        }
                        Frame::JobFailed { job, error, spans } => {
                            if let Some(mut e) = inflight.remove(&job) {
                                ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
                                for span in spans {
                                    merge_worker_span(&mut e.job, span);
                                }
                                e.job
                                    .reply
                                    .put(Err(CompletionError::internal(error)));
                                let now = ctx.epoch.elapsed().as_secs_f64();
                                ctx.metrics.finish_request(
                                    e.job.trace.take(),
                                    e.job.tier,
                                    e.job.priority,
                                    "internal",
                                    now,
                                    0,
                                );
                                ctx.metrics.bandit_feedback(
                                    e.job.tier,
                                    e.job.complexity,
                                    e.job.confidence,
                                    false,
                                    (now - e.job.enqueue_s).max(0.0),
                                );
                            }
                        }
                        Frame::Cancelled { job } => {
                            if let Some(mut e) = inflight.remove(&job) {
                                ctx.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                                let now = ctx.epoch.elapsed().as_secs_f64();
                                ctx.metrics.finish_request(
                                    e.job.trace.take(),
                                    e.job.tier,
                                    e.job.priority,
                                    "cancelled",
                                    now,
                                    0,
                                );
                            }
                        }
                        Frame::Returned { job } => {
                            if let Some(e) = inflight.remove(&job) {
                                requeue_to(
                                    &ctx.queue,
                                    &ctx.metrics,
                                    e.job,
                                    "replica draining",
                                    ctx.epoch.elapsed().as_secs_f64(),
                                );
                            }
                        }
                        Frame::PrefixAd { prefixes } => {
                            // Immediate advertisement (a freshly imported
                            // prefix, ahead of the heartbeat cadence).
                            *ctx.cell.hot.lock().unwrap() = prefixes;
                        }
                        Frame::BlocksChunk { req, blocks, done, .. } => {
                            // Donor's answer to a brokered FetchBlocks:
                            // accumulate until `done`, then hand the run
                            // to the cold replica's inbox. An unknown req
                            // (donor restarted mid-fetch) is dropped —
                            // the transfer is an optimization, the routed
                            // job recomputes its prefill either way.
                            if let Some(entry) = xfer_pending.get_mut(&req) {
                                entry.1.extend(blocks);
                                if done {
                                    if let Some((target, run)) =
                                        xfer_pending.remove(&req)
                                    {
                                        if !run.is_empty() {
                                            ctx.metrics
                                                .kv_transfers
                                                .fetch_add(1, Ordering::Relaxed);
                                            ctx.metrics.kv_transfer_blocks.fetch_add(
                                                run.len() as u64,
                                                Ordering::Relaxed,
                                            );
                                            target
                                                .incoming
                                                .lock()
                                                .unwrap()
                                                .push(run);
                                        }
                                    }
                                }
                            }
                        }
                        Frame::Pong { nonce } => {
                            let now_us = ctx.epoch.elapsed().as_micros() as u64;
                            ctx.metrics
                                .rpc_rtt_us_total
                                .fetch_add(now_us.saturating_sub(nonce), Ordering::Relaxed);
                            ctx.metrics.rpc_pings.fetch_add(1, Ordering::Relaxed);
                        }
                        Frame::Gone => {
                            // Anything the worker still owed us (it
                            // should have Returned or Done everything)
                            // requeues as a safety net.
                            let now = ctx.epoch.elapsed().as_secs_f64();
                            for (_, e) in std::mem::take(&mut inflight) {
                                requeue_to(
                                    &ctx.queue,
                                    &ctx.metrics,
                                    e.job,
                                    "replica exited",
                                    now,
                                );
                            }
                            ctx.cell.inflight.store(0, Ordering::Relaxed);
                            ctx.cell.state.store(S_GONE, Ordering::Release);
                            return Ok(());
                        }
                        Frame::Fatal { error } => {
                            let now = ctx.epoch.elapsed().as_secs_f64();
                            for (_, e) in std::mem::take(&mut inflight) {
                                requeue_to(
                                    &ctx.queue,
                                    &ctx.metrics,
                                    e.job,
                                    "replica failed",
                                    now,
                                );
                            }
                            *ctx.cell.error.lock().unwrap() = Some(error);
                            ctx.cell.inflight.store(0, Ordering::Relaxed);
                            ctx.cell.state.store(S_FAILED, Ordering::Release);
                            return Ok(());
                        }
                        f => {
                            return end_dead(
                                ctx,
                                inflight,
                                &format!("unexpected worker frame {f:?}"),
                            );
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut => {}
            Err(e) => {
                return end_dead(ctx, inflight, &format!("socket read: {e}"));
            }
        }

        // 2. Fault injection / stall verdicts: a true kill -9 for a
        // local child; for a node-hosted worker the data channel is
        // severed instead (the worker exits on supervisor loss). Either
        // way the EOF read above surfaces the death and requeues.
        if ctx.cell.kill.load(Ordering::Relaxed) && !killed {
            killed = true;
            ctx.link.kill(&*stream);
        }

        // 3. Graceful drain: scale-down terminate, or pool shutdown once
        // the closed queue is drained dry.
        let stop = ctx.cell.stop.load(Ordering::Relaxed);
        let shutdown_done = ctx.queue.is_closed()
            && ctx.queue.is_empty()
            && ctx.cell.direct.is_empty()
            && inflight.is_empty();
        if (stop || shutdown_done) && !draining {
            draining = true;
            drain_deadline = Instant::now() + DRAIN_TIMEOUT;
            if let Err(e) = send(&mut *stream, &Frame::Terminate, ctx) {
                return end_dead(ctx, inflight, &e);
            }
        }
        if draining && Instant::now() > drain_deadline {
            ctx.link.kill(&*stream);
            return end_dead(ctx, inflight, "graceful drain timed out");
        }

        // 4. Dispatch while the worker has slot headroom. The ledger cap
        // mirrors the worker's max_inflight so backpressure stays in the
        // tier queue where the scaler can see it.
        if !draining && !killed && ctx.cell.state.load(Ordering::Acquire) == S_READY {
            while inflight.len() < ctx.pool.max_inflight.max(1) {
                // Affinity-routed jobs first: the router placed them on
                // this replica for its cache, so they must not be
                // overtaken by tier-queue work that fills the slots.
                let Some(mut job) =
                    ctx.cell.direct.try_recv().or_else(|| ctx.queue.try_recv())
                else {
                    break;
                };
                let now = ctx.epoch.elapsed().as_secs_f64();
                if now > job.deadline_abs_s {
                    // Dead work: the deadline elapsed in the queue. Drop
                    // before crossing the wire — same rule the thread
                    // substrate applies at scheduler admission. Expiry
                    // outranks cancellation: an abandoned deadline fires
                    // both, and the expired-shed counter must see it.
                    ctx.metrics.shed_expired.fetch_add(1, Ordering::Relaxed);
                    if let Some(st) = job.trace.as_deref_mut() {
                        st.phase(SpanKind::Shed, now);
                    }
                    job.reply.put(Err(CompletionError::new(
                        FailureKind::DeadlineExpired,
                        "deadline expired before dispatch",
                    )));
                    ctx.metrics.finish_request(
                        job.trace.take(),
                        job.tier,
                        job.priority,
                        "deadline_expired",
                        now,
                        0,
                    );
                    continue;
                }
                if job.cancel.is_cancelled() {
                    ctx.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
                    ctx.metrics.finish_request(
                        job.trace.take(),
                        job.tier,
                        job.priority,
                        "cancelled",
                        now,
                        0,
                    );
                    continue;
                }
                job.queue_wait_s = (now - job.enqueue_s).max(0.0);
                if job.counted_wait_s == 0.0 {
                    // First dispatch only (requeues re-dispatch): the
                    // per-priority wait distribution.
                    ctx.metrics.observe_queue_wait(job.priority, job.queue_wait_s);
                }
                ctx.metrics
                    .add_queue_wait_s((job.queue_wait_s - job.counted_wait_s).max(0.0));
                job.counted_wait_s = job.queue_wait_s;
                let id = next_job;
                next_job += 1;
                // Close the queue phase at dispatch: the mark this sets
                // is also the base every receipt-relative worker span
                // rebases onto when it comes back over the wire.
                let trace_hdr = match job.trace.as_deref_mut() {
                    Some(st) => {
                        st.phase(SpanKind::Queued, now);
                        format_traceparent(&st.ctx)
                    }
                    None => String::new(),
                };
                let frame = Frame::Job {
                    job: id,
                    prompt: job.prompt.clone(),
                    max_tokens: job.max_tokens,
                    trace: trace_hdr,
                };
                let bytes = frame.encode();
                if bytes.len() > MAX_FRAME_BYTES {
                    // A frame the worker's reader would reject as a
                    // desync. Dispatching it would kill the connection
                    // and requeue the poison job forever — fail the one
                    // caller instead.
                    ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    job.reply.put(Err(CompletionError::internal(format!(
                        "prompt too large for the RPC data plane \
                         ({} bytes encoded)",
                        bytes.len()
                    ))));
                    ctx.metrics.finish_request(
                        job.trace.take(),
                        job.tier,
                        job.priority,
                        "internal",
                        now,
                        0,
                    );
                    continue;
                }
                if let Err(e) = send_bytes(&mut *stream, &bytes, ctx) {
                    // A dead socket mid-dispatch: this job never reached
                    // the worker — back to the queue with the rest.
                    requeue_to(&ctx.queue, &ctx.metrics, job, "replica failed", now);
                    return end_dead(ctx, inflight, &e);
                }
                inflight.insert(id, InflightJob {
                    job,
                    tokens: Vec::new(),
                    chunk_seen: false,
                    cancel_sent: false,
                });
            }
        }

        // 4b. Fleet prefix plane (protocol v2 only — a v1 worker never
        // sees these frames). Forward donor fetches the router queued on
        // this cell, and deliver brokered block runs to the worker in
        // bounded chunks (req 0 marks a delivery, not a fetch reply).
        if version >= 2 && !draining && !killed {
            let reqs = std::mem::take(&mut *ctx.cell.fetch_reqs.lock().unwrap());
            for (hash, target) in reqs {
                let req = next_xfer;
                next_xfer += 1;
                xfer_pending.insert(req, (target, Vec::new()));
                if let Err(e) = send(&mut *stream, &Frame::FetchBlocks { req, hash }, ctx) {
                    return end_dead(ctx, inflight, &e);
                }
            }
            let runs = std::mem::take(&mut *ctx.cell.incoming.lock().unwrap());
            for run in runs {
                let total = run.len();
                let mut shipped = 0usize;
                for chunk in run.chunks(XFER_CHUNK_BLOCKS) {
                    shipped += chunk.len();
                    let frame = Frame::BlocksChunk {
                        req: 0,
                        hash: 0,
                        blocks: chunk.to_vec(),
                        done: shipped == total,
                    };
                    if let Err(e) = send(&mut *stream, &frame, ctx) {
                        return end_dead(ctx, inflight, &e);
                    }
                }
            }
        } else if version < 2 {
            // A v1 worker cannot donate or receive: discard rather than
            // let the router's requests accumulate unserved.
            ctx.cell.fetch_reqs.lock().unwrap().clear();
            ctx.cell.incoming.lock().unwrap().clear();
        }

        // 4c. Speculative draft-availability relay (v2, verify tiers
        // only): the router publishes whether the draft tier can serve
        // draft windows right now; the worker falls back to plain decode
        // while the signal is down. Sent on edges, not every turn.
        if version >= 2
            && !draining
            && !killed
            && ctx.pool.speculative.pairs_with(ctx.tier)
        {
            let ok = ctx.spec_draft_ok.load(Ordering::Relaxed);
            if last_spec_ok != Some(ok) {
                last_spec_ok = Some(ok);
                if let Err(e) = send(&mut *stream, &Frame::SpecDraft { ok }, ctx) {
                    return end_dead(ctx, inflight, &e);
                }
            }
        }

        // 5. Cancellation propagation: a caller that timed out fires its
        // token locally; the worker evicts the sequence on the Cancel
        // frame and answers Cancelled.
        let mut cancels: Vec<u64> = Vec::new();
        for (id, e) in inflight.iter_mut() {
            if !e.cancel_sent && e.job.cancel.is_cancelled() {
                e.cancel_sent = true;
                cancels.push(*id);
            }
        }
        for id in cancels {
            if let Err(e) = send(&mut *stream, &Frame::Cancel { job: id }, ctx) {
                return end_dead(ctx, inflight, &e);
            }
        }

        // 6. RPC latency probe.
        if last_ping.elapsed() >= PING_PERIOD {
            last_ping = Instant::now();
            let nonce = ctx.epoch.elapsed().as_micros() as u64;
            if let Err(e) = send(&mut *stream, &Frame::Ping { nonce }, ctx) {
                return end_dead(ctx, inflight, &e);
            }
        }
    }
}

fn accept_worker(ctx: &mut PumpCtx) -> Result<Box<dyn Transport>, String> {
    ctx.listener
        .set_nonblocking(true)
        .map_err(|e| format!("listener nonblocking: {e}"))?;
    let deadline = Instant::now() + CONNECT_TIMEOUT;
    loop {
        match ctx.listener.accept() {
            Ok(stream) => return Ok(stream),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if let Some(reason) = ctx.link.connect_aborted() {
                    return Err(reason);
                }
                if Instant::now() > deadline {
                    return Err("worker never connected".into());
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(format!("accept: {e}")),
        }
    }
}

/// Blocking read of one frame with an overall deadline (handshake).
fn read_deadline(
    stream: &mut dyn Transport,
    reader: &mut FrameReader,
    timeout: Duration,
    ctx: &PumpCtx,
) -> Result<Frame, String> {
    let deadline = Instant::now() + timeout;
    let mut buf = [0u8; 4096];
    loop {
        match reader.next() {
            Ok(Some(f)) => {
                ctx.metrics.rpc_frames_recv.fetch_add(1, Ordering::Relaxed);
                return Ok(f);
            }
            Ok(None) => {}
            Err(e) => return Err(format!("wire desync in handshake: {e:#}")),
        }
        if Instant::now() > deadline {
            return Err("handshake timed out".into());
        }
        match stream.read(&mut buf) {
            Ok(0) => return Err("worker hung up during handshake".into()),
            Ok(n) => reader.extend(&buf[..n]),
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut => {}
            Err(e) => return Err(format!("handshake read: {e}")),
        }
    }
}

fn send(stream: &mut dyn Transport, frame: &Frame, ctx: &PumpCtx) -> Result<(), String> {
    write_frame(stream, frame).map_err(|e| format!("socket write: {e}"))?;
    ctx.metrics.rpc_frames_sent.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// [`send`] for a pre-encoded frame (the dispatch path encodes first to
/// size-check against [`MAX_FRAME_BYTES`]).
fn send_bytes(
    stream: &mut dyn Transport,
    bytes: &[u8],
    ctx: &PumpCtx,
) -> Result<(), String> {
    stream
        .write_all(bytes)
        .map_err(|e| format!("socket write: {e}"))?;
    ctx.metrics.rpc_frames_sent.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// The worker died abruptly (EOF, SIGKILL, wire desync): requeue every
/// job it still owed us — the supervisor's dispatch ledger is the
/// loss-free recovery source — and report Failed.
fn end_dead(
    ctx: &mut PumpCtx,
    inflight: BTreeMap<u64, InflightJob>,
    msg: &str,
) -> Result<(), String> {
    let now = ctx.epoch.elapsed().as_secs_f64();
    for (_, e) in inflight {
        requeue_to(&ctx.queue, &ctx.metrics, e.job, "replica failed", now);
    }
    Err(msg.to_string())
}

/// Rebase one receipt-relative worker span onto the job's dispatch mark
/// (set by the `Queued` phase at dispatch) and append it to the trace.
fn merge_worker_span(job: &mut TierJob, mut span: Span) {
    if let Some(st) = job.trace.as_deref_mut() {
        let base = st.mark_s;
        span.start_s += base;
        span.end_s += base;
        st.push_span(span);
    }
}

/// Difference a heartbeat against the last sample into the gateway's
/// global counters, and publish cumulative values into the replica cell
/// (the same split shared memory gives the thread substrate).
fn apply_heartbeat(hb: &HeartbeatWire, last: &HeartbeatWire, ctx: &PumpCtx) {
    let m = &ctx.metrics;
    let d = |a: u64, b: u64| a.saturating_sub(b);
    m.prefills
        .fetch_add(d(hb.prefills, last.prefills), Ordering::Relaxed);
    m.prefill_batched
        .fetch_add(d(hb.prefill_batched, last.prefill_batched), Ordering::Relaxed);
    m.decode_steps
        .fetch_add(d(hb.decode_steps, last.decode_steps), Ordering::Relaxed);
    m.batched
        .fetch_add(d(hb.batched_steps, last.batched_steps), Ordering::Relaxed);
    for (i, (&now, &prev)) in
        hb.batch_counts.iter().zip(last.batch_counts.iter()).enumerate()
    {
        m.batch_counts[i].fetch_add(d(now, prev), Ordering::Relaxed);
    }
    m.prefix_hit_tokens
        .fetch_add(d(hb.prefix_hit_tokens, last.prefix_hit_tokens), Ordering::Relaxed);
    m.prefix_miss_tokens.fetch_add(
        d(hb.prefix_miss_tokens, last.prefix_miss_tokens),
        Ordering::Relaxed,
    );
    m.prefix_evicted_blocks.fetch_add(
        d(hb.prefix_evicted_blocks, last.prefix_evicted_blocks),
        Ordering::Relaxed,
    );
    m.spec_drafted_tokens.fetch_add(
        d(hb.spec_drafted_tokens, last.spec_drafted_tokens),
        Ordering::Relaxed,
    );
    m.spec_accepted_tokens.fetch_add(
        d(hb.spec_accepted_tokens, last.spec_accepted_tokens),
        Ordering::Relaxed,
    );
    m.spec_rejected_tokens.fetch_add(
        d(hb.spec_rejected_tokens, last.spec_rejected_tokens),
        Ordering::Relaxed,
    );
    m.spec_verify_steps.fetch_add(
        d(hb.spec_verify_steps, last.spec_verify_steps),
        Ordering::Relaxed,
    );
    let c = &ctx.cell;
    c.inflight.store(hb.inflight, Ordering::Relaxed);
    // The hot-prefix summary the router scores against. Skipped when
    // both sides are empty (affinity off) so the steady state takes no
    // lock; the empty-after-nonempty edge still clears a stale ad.
    if !(hb.hot.is_empty() && last.hot.is_empty()) {
        *c.hot.lock().unwrap() = hb.hot.clone();
    }
    c.prefix_hit_tokens
        .store(hb.prefix_hit_tokens, Ordering::Relaxed);
    c.prefix_miss_tokens
        .store(hb.prefix_miss_tokens, Ordering::Relaxed);
    c.prefix_cache_blocks
        .store(hb.prefix_cache_blocks, Ordering::Relaxed);
    c.spec_drafted_tokens
        .store(hb.spec_drafted_tokens, Ordering::Relaxed);
    c.spec_accepted_tokens
        .store(hb.spec_accepted_tokens, Ordering::Relaxed);
    c.spec_rejected_tokens
        .store(hb.spec_rejected_tokens, Ordering::Relaxed);
    c.spec_verify_steps
        .store(hb.spec_verify_steps, Ordering::Relaxed);
}

/// Answer one caller from the accumulated token stream.
fn finish_entry(e: InflightJob, prompt_tokens: usize, ctx: &PumpCtx) {
    let now = ctx.epoch.elapsed().as_secs_f64();
    let mut job = e.job;
    if !e.chunk_seen {
        // Everything arrived in the Done tail (budget-1 sequences).
        job.ttft_s = (now - job.enqueue_s).max(0.0);
    }
    let tokens = e.tokens.len();
    let latency_s = (now - job.enqueue_s).max(0.0);
    ctx.metrics.completed.fetch_add(1, Ordering::Relaxed);
    ctx.metrics.tokens_out.fetch_add(tokens as u64, Ordering::Relaxed);
    ctx.metrics.observe_ttft(ctx.tier, job.ttft_s);
    if tokens > 1 {
        ctx.metrics.observe_tpot(
            ctx.tier,
            (latency_s - job.ttft_s).max(0.0) / (tokens - 1) as f64,
        );
    }
    job.reply.put(Ok(LiveResponse {
        tokens: e.tokens,
        tier: job.tier.name().to_string(),
        model: job.model,
        complexity: job.complexity,
        confidence: job.confidence,
        ttft_s: job.ttft_s,
        latency_s,
        queue_wait_s: job.queue_wait_s,
        prompt_tokens,
    }));
    ctx.metrics.finish_request(
        job.trace.take(),
        job.tier,
        job.priority,
        "ok",
        now,
        tokens,
    );
    ctx.metrics
        .bandit_feedback(job.tier, job.complexity, job.confidence, true, latency_s);
}
