//! Node plane — multi-host placement for the process substrate.
//!
//! The paper deploys each model tier as pods across a multi-node
//! Kubernetes cluster; this module is that deployment shape for the
//! process substrate. A **node agent** (`ps-node` subcommand) runs on
//! each machine, registers its capacity with the supervisor over the
//! same framed-JSON plane the workers speak ([`crate::substrate::proto`],
//! over TCP), and spawns `ps-replica` worker processes on demand. The
//! supervisor side is the [`NodeRegistry`]: it owns the registered-node
//! table, dials static agents / accepts inbound registrations, watches
//! each control channel for liveness, and answers the placement question
//! (`place`) for [`crate::substrate::remote::ProcessSubstrate`].
//!
//! Control-channel shape (either side may have dialed; the agent always
//! speaks first):
//!
//! ```text
//! agent → NodeHello   { version, name, slots, pid }
//! super → NodeHelloAck{ version }
//! super → SpawnReplica{ seq, tier, index, port, args }*
//! agent → SpawnFailed { seq, error }        (only on a failed fork)
//! super → Ping / agent → Pong               (liveness)
//! ```
//!
//! The *data* plane never touches the agent: each spawned worker dials
//! the supervisor's per-replica TCP listener directly (the agent combines
//! the `SpawnReplica.port` with the control channel's peer host), so a
//! worker session is byte-identical to the single-host Unix-socket
//! session — only the transport differs.
//!
//! Node death is a first-class incident: when a control channel drops
//! (agent SIGKILLed, machine gone) or goes silent past the health
//! deadline, the registry marks the node lost (`ps_node_lost_total`),
//! and the substrate fails every replica it hosted — their dispatch
//! ledgers requeue loss-free and the recovery path re-provisions on the
//! surviving nodes.

use std::collections::BTreeMap;
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{NodesConfig, Placement};
use crate::models::Tier;
use crate::substrate::proto::{
    negotiate, read_frame_blocking, write_frame, Frame, FrameReader, Transport,
    PROTO_VERSION,
};

/// How long an agent/supervisor gets to complete the node handshake.
const NODE_HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// How long the supervisor retries dialing a static agent at startup.
const DIAL_TIMEOUT: Duration = Duration::from_secs(10);
/// Node reader poll granularity (also the Ping cadence).
const NODE_READ_TIMEOUT: Duration = Duration::from_millis(250);
/// A control channel silent past this is a lost node (EOF is detected
/// immediately; this covers partitions where packets just stop).
const NODE_SILENCE_DEADLINE: Duration = Duration::from_secs(5);
/// Control-channel write timeout: a wedged-but-alive agent (frozen VM,
/// full receive window) must fail its writes instead of hanging the
/// writer thread past the silence deadline.
const NODE_WRITE_TIMEOUT: Duration = Duration::from_secs(2);

/// Identity of one registered node agent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u64);

/// Shared writer half of one node's control channel (per-node lock; see
/// [`NodeEntry::writer`]).
type NodeWriter = Arc<Mutex<Box<dyn Transport>>>;

/// Point-in-time view of one node for metrics/introspection.
#[derive(Debug, Clone)]
pub struct NodeSnapshot {
    pub name: String,
    pub slots: usize,
    /// Replicas currently placed on the node (all tiers).
    pub hosted: usize,
    pub alive: bool,
}

struct NodeEntry {
    id: NodeId,
    name: String,
    slots: usize,
    hosted: [usize; 3],
    alive: bool,
    /// Writer half of the control channel (SpawnReplica, Ping), behind
    /// its own per-node lock so frame writes serialize without ever
    /// holding the registry lock across a (timeout-bounded) network
    /// write — one wedged agent must not freeze placement, accounting,
    /// or the `/metrics` snapshot for every other node.
    writer: NodeWriter,
    /// Lock-free teardown handle onto the same stream: `shutdown` is
    /// `&self` and interrupts a blocked peer, so `mark_dead` can sever
    /// the channel even while a write holds the writer lock.
    breaker: Box<dyn Transport>,
}

impl NodeEntry {
    fn hosted_total(&self) -> usize {
        self.hosted.iter().sum()
    }
}

/// Supervisor-side registry of node agents. Shared (`Arc`) between the
/// substrate (placement, per-replica accounting), the accept/dial
/// threads (registration), the per-node watcher threads (liveness), and
/// the gateway's `/metrics` snapshot.
pub struct NodeRegistry {
    inner: Mutex<Vec<NodeEntry>>,
    next_id: AtomicU64,
    /// Nodes that registered and were later lost (EOF, silence).
    lost_total: AtomicU64,
    closed: AtomicBool,
    /// Bind host for per-replica data listeners (the host part of
    /// `pool.nodes.listen_addr`, or the wildcard).
    data_host: String,
    /// SpawnReplica seqs the agent reported as failed, keyed for the
    /// waiting pump thread to pick up.
    failed_spawns: Mutex<BTreeMap<u64, String>>,
}

impl NodeRegistry {
    /// Build the node plane from `pool.nodes`: `Ok(None)` when it is not
    /// configured (single-host behavior, no threads started). Binds the
    /// registration listener and synchronously dials every static agent
    /// — an unreachable agent or unbindable listener is a startup error,
    /// not a silently smaller fleet.
    pub fn from_config(cfg: &NodesConfig) -> Result<Option<Arc<NodeRegistry>>, String> {
        if !cfg.configured() {
            return Ok(None);
        }
        // Per-replica data listeners must be reachable from the nodes:
        // bind the host the operator chose for the node plane, or the
        // wildcard when none was named (agents-dial-in mode) — workers
        // dial the *control channel's* peer host + the advertised port,
        // so the bind host only has to accept, never be routable itself.
        // Brackets come off a `[v6]:port` form: the (host, port) tuple
        // passed to `TcpListener::bind` wants the bare address.
        let data_host = cfg
            .listen_addr
            .as_deref()
            .and_then(|a| a.rsplit_once(':'))
            .map(|(h, _)| h.trim_start_matches('[').trim_end_matches(']').to_string())
            .filter(|h| !h.is_empty())
            .unwrap_or_else(|| "0.0.0.0".to_string());
        let reg = Arc::new(NodeRegistry {
            inner: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
            lost_total: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            data_host,
            failed_spawns: Mutex::new(BTreeMap::new()),
        });
        if let Some(addr) = &cfg.listen_addr {
            let listener = TcpListener::bind(addr)
                .map_err(|e| format!("node plane: bind {addr}: {e}"))?;
            listener
                .set_nonblocking(true)
                .map_err(|e| format!("node plane: listener nonblocking: {e}"))?;
            let r = Arc::clone(&reg);
            std::thread::Builder::new()
                .name("ps-node-accept".into())
                .spawn(move || accept_loop(listener, r))
                .map_err(|e| format!("node plane: accept thread: {e}"))?;
        }
        for addr in &cfg.agents {
            let stream = dial_agent(addr)
                .map_err(|e| format!("node plane: agent {addr}: {e}"))?;
            Arc::clone(&reg)
                .admit_node(Box::new(stream))
                .map_err(|e| format!("node plane: agent {addr}: {e:#}"))?;
        }
        Ok(Some(reg))
    }

    /// Host to bind per-replica data listeners on (reachable from the
    /// registered nodes).
    pub fn data_host(&self) -> &str {
        &self.data_host
    }

    /// Run the registration handshake on a connected control channel and
    /// start the node's watcher thread. Returns the new node's id.
    /// (Takes the `Arc` so the watcher can hold the registry; call as
    /// `Arc::clone(&reg).admit_node(...)`.)
    pub fn admit_node(self: Arc<Self>, mut t: Box<dyn Transport>) -> Result<NodeId> {
        t.set_read_timeout(Some(NODE_READ_TIMEOUT))
            .map_err(|e| anyhow!("node channel read timeout: {e}"))?;
        let mut reader = FrameReader::new();
        let deadline = Instant::now() + NODE_HANDSHAKE_TIMEOUT;
        let hello = loop {
            match read_frame_blocking_once(&mut *t, &mut reader)? {
                Some(f) => break f,
                None => {
                    if Instant::now() > deadline {
                        bail!("node handshake timed out");
                    }
                }
            }
        };
        let (name, slots) = match hello {
            Frame::NodeHello { version, name, slots, .. } => {
                let v = negotiate(PROTO_VERSION, version)
                    .ok_or_else(|| anyhow!("no common protocol (node spoke {version})"))?;
                write_frame(&mut *t, &Frame::NodeHelloAck { version: v })
                    .map_err(|e| anyhow!("node hello ack: {e}"))?;
                (name, slots.max(1))
            }
            f => bail!("expected NodeHello, got {f:?}"),
        };
        let id = NodeId(self.next_id.fetch_add(1, Ordering::Relaxed));
        let writer = t
            .try_clone()
            .map_err(|e| anyhow!("node channel clone: {e}"))?;
        writer
            .set_write_timeout(Some(NODE_WRITE_TIMEOUT))
            .map_err(|e| anyhow!("node channel write timeout: {e}"))?;
        let breaker = t
            .try_clone()
            .map_err(|e| anyhow!("node channel clone: {e}"))?;
        self.inner.lock().unwrap().push(NodeEntry {
            id,
            name: name.clone(),
            slots,
            hosted: [0; 3],
            alive: true,
            writer: Arc::new(Mutex::new(writer)),
            breaker,
        });
        crate::info!("node plane: registered node `{name}` ({slots} slots)");
        let reg = Arc::clone(&self);
        std::thread::Builder::new()
            .name(format!("ps-node-watch-{name}"))
            .spawn(move || watch_node(reg, id, t, reader))
            .map_err(|e| anyhow!("node watcher thread: {e}"))?;
        Ok(id)
    }

    /// Choose a node for one replica of `tier`, or `None` when no alive
    /// node has free slots (the caller then falls back to a local spawn
    /// if *no* node is registered at all — see `any_alive`).
    pub fn place(&self, tier: usize, policy: Placement) -> Option<NodeId> {
        let inner = self.inner.lock().unwrap();
        let mut candidates: Vec<&NodeEntry> = inner
            .iter()
            .filter(|n| n.alive && n.hosted_total() < n.slots)
            .collect();
        match policy {
            Placement::Spread => {
                candidates.sort_by_key(|n| {
                    (n.hosted[tier.min(2)], n.hosted_total(), n.id)
                });
            }
            Placement::Pack => candidates.sort_by_key(|n| n.id),
        }
        candidates.first().map(|n| n.id)
    }

    /// Any node registered and alive right now? (Placement returning
    /// `None` with live nodes means "out of slots", which must not fall
    /// back to a local spawn and silently overload the supervisor host.)
    pub fn any_alive(&self) -> bool {
        self.inner.lock().unwrap().iter().any(|n| n.alive)
    }

    pub fn alive(&self, id: NodeId) -> bool {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .any(|n| n.id == id && n.alive)
    }

    /// The node's writer handle + name, when it is registered and alive.
    /// Snapshots under the registry lock; the network write itself then
    /// happens under the per-node writer lock only.
    fn writer_of(&self, id: NodeId) -> Result<(NodeWriter, String), String> {
        let inner = self.inner.lock().unwrap();
        let entry = inner
            .iter()
            .find(|n| n.id == id)
            .ok_or_else(|| "node no longer registered".to_string())?;
        if !entry.alive {
            return Err(format!("node `{}` is lost", entry.name));
        }
        Ok((Arc::clone(&entry.writer), entry.name.clone()))
    }

    /// Ship a SpawnReplica order to the node. The caller then waits for
    /// the worker to dial its data listener; a write failure marks the
    /// node lost immediately.
    pub fn spawn_on(
        &self,
        id: NodeId,
        seq: u64,
        tier: usize,
        index: usize,
        port: u16,
        args: &[String],
    ) -> Result<(), String> {
        let (writer, name) = self.writer_of(id)?;
        let frame = Frame::SpawnReplica {
            seq,
            tier,
            index,
            port,
            args: args.to_vec(),
        };
        if let Err(e) = write_frame(&mut **writer.lock().unwrap(), &frame) {
            self.mark_dead(id);
            return Err(format!("node `{name}` control write: {e}"));
        }
        Ok(())
    }

    /// Account one replica placed on / released from a node. Release on
    /// a lost node is a harmless no-op (the entry stays for metrics).
    pub fn add_hosted(&self, id: NodeId, tier: usize) {
        if let Some(n) = self.inner.lock().unwrap().iter_mut().find(|n| n.id == id) {
            n.hosted[tier.min(2)] += 1;
        }
    }

    pub fn release(&self, id: NodeId, tier: usize) {
        if let Some(n) = self.inner.lock().unwrap().iter_mut().find(|n| n.id == id) {
            let t = tier.min(2);
            n.hosted[t] = n.hosted[t].saturating_sub(1);
        }
    }

    /// Mark a node lost: count it, sever its control channel (the
    /// watcher exits), and stop placing on it. Idempotent; a no-op once
    /// the registry is shutting down (an orderly teardown severs every
    /// channel and must not read as a fleet of lost nodes).
    pub fn mark_dead(&self, id: NodeId) {
        if self.closed.load(Ordering::Acquire) {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(n) = inner.iter_mut().find(|n| n.id == id) {
            if n.alive {
                n.alive = false;
                // The breaker severs without taking the writer lock, so
                // even a write blocked on a wedged agent gets unstuck.
                n.breaker.shutdown();
                self.lost_total.fetch_add(1, Ordering::Relaxed);
                crate::warn_!("node plane: node `{}` lost", n.name);
            }
        }
    }

    /// A SpawnFailed answer for `seq`, if the agent sent one (consumed).
    pub fn take_spawn_failure(&self, seq: u64) -> Option<String> {
        self.failed_spawns.lock().unwrap().remove(&seq)
    }

    /// Liveness probe on the node's control channel. Serialized with
    /// `spawn_on` through the per-node writer lock so two threads never
    /// interleave partial frame writes on one stream.
    fn ping(&self, id: NodeId, nonce: u64) -> bool {
        match self.writer_of(id) {
            Ok((writer, _)) => {
                write_frame(&mut **writer.lock().unwrap(), &Frame::Ping { nonce })
                    .is_ok()
            }
            Err(_) => false,
        }
    }

    pub fn lost_total(&self) -> u64 {
        self.lost_total.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> Vec<NodeSnapshot> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .map(|n| NodeSnapshot {
                name: n.name.clone(),
                slots: n.slots,
                hosted: n.hosted_total(),
                alive: n.alive,
            })
            .collect()
    }

    /// Tear the node plane down: sever every control channel (agents see
    /// EOF, kill their workers, and exit) and stop the accept loop.
    pub fn shutdown(&self) {
        self.closed.store(true, Ordering::Release);
        for n in self.inner.lock().unwrap().iter() {
            n.breaker.shutdown();
        }
    }
}

/// One non-blocking step of a handshake read: `Ok(None)` on timeout.
fn read_frame_blocking_once(
    t: &mut dyn Transport,
    reader: &mut FrameReader,
) -> Result<Option<Frame>> {
    if let Some(f) = reader.next()? {
        return Ok(Some(f));
    }
    let mut buf = [0u8; 4096];
    match t.read(&mut buf) {
        Ok(0) => bail!("connection closed during node handshake"),
        Ok(n) => {
            reader.extend(&buf[..n]);
            reader.next()
        }
        Err(e)
            if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
        {
            Ok(None)
        }
        Err(e) => Err(e.into()),
    }
}

/// Dial a static agent with retries (it may still be starting).
fn dial_agent(addr: &str) -> std::io::Result<TcpStream> {
    let deadline = Instant::now() + DIAL_TIMEOUT;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() > deadline {
                    return Err(e);
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Accept inbound `ps-node` registrations until the registry closes.
fn accept_loop(listener: TcpListener, reg: Arc<NodeRegistry>) {
    while !reg.closed.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                if let Err(e) = Arc::clone(&reg).admit_node(Box::new(stream)) {
                    crate::error!("node plane: registration failed: {e:#}");
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => {
                crate::error!("node plane: accept: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Per-node watcher: drains control frames (Pong, SpawnFailed), pings on
/// idle, and declares the node lost on EOF, wire desync, or silence past
/// the deadline.
fn watch_node(
    reg: Arc<NodeRegistry>,
    id: NodeId,
    mut t: Box<dyn Transport>,
    mut reader: FrameReader,
) {
    let mut buf = [0u8; 4096];
    let mut last_frame = Instant::now();
    loop {
        if reg.closed.load(Ordering::Acquire) {
            return;
        }
        match t.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                reader.extend(&buf[..n]);
                loop {
                    match reader.next() {
                        Ok(Some(f)) => {
                            last_frame = Instant::now();
                            if let Frame::SpawnFailed { seq, error } = f {
                                reg.failed_spawns.lock().unwrap().insert(seq, error);
                            }
                            // Pong and anything else just proves liveness.
                        }
                        Ok(None) => break,
                        Err(_) => {
                            reg.mark_dead(id);
                            return;
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                // Idle: probe. A failed write is a dead channel.
                let nonce = last_frame.elapsed().as_micros() as u64;
                if !reg.ping(id, nonce) {
                    break;
                }
            }
            Err(_) => break,
        }
        if last_frame.elapsed() > NODE_SILENCE_DEADLINE {
            break;
        }
    }
    reg.mark_dead(id);
}

// ---------------------------------------------------------------------------
// Agent side: the `ps-node` process
// ---------------------------------------------------------------------------

/// CLI surface of the `ps-node` subcommand.
pub struct NodeAgentOptions {
    /// `host:port` to listen on for the supervisor's dial-in
    /// (`pool.nodes.agents[]` entry). Mutually exclusive with
    /// `supervisor`.
    pub listen: Option<String>,
    /// Supervisor `host:port` to dial (`pool.nodes.listen_addr`).
    pub supervisor: Option<String>,
    /// Replica processes this node may host.
    pub slots: usize,
    /// Display name in the supervisor's registry and `/metrics`.
    pub name: String,
    /// Worker binary (`None` = this binary in `ps-replica` mode).
    pub worker_bin: Option<String>,
    /// Per-worker stdout/stderr log directory (`None` = inherit).
    pub log_dir: Option<String>,
}

/// Run one node agent to completion: register with the supervisor,
/// spawn `ps-replica` workers on demand, and exit (killing the workers)
/// when the control channel drops — a node must never outlive its
/// supervisor's view of it.
pub fn run_node_agent(opts: &NodeAgentOptions) -> Result<()> {
    let (mut ctl, sup_host): (Box<dyn Transport>, String) = match
        (&opts.listen, &opts.supervisor)
    {
        (Some(addr), _) => {
            let listener = TcpListener::bind(addr)
                .with_context(|| format!("ps-node: bind {addr}"))?;
            crate::info!("ps-node `{}`: awaiting supervisor on {addr}", opts.name);
            let (stream, peer) = listener.accept().context("ps-node: accept")?;
            let _ = stream.set_nodelay(true);
            // IPv6 hosts must be bracketed to recombine with a port.
            let host = match peer.ip() {
                std::net::IpAddr::V6(v6) => format!("[{v6}]"),
                v4 => v4.to_string(),
            };
            (Box::new(stream), host)
        }
        (None, Some(addr)) => {
            let stream =
                dial_agent(addr).with_context(|| format!("ps-node: dial {addr}"))?;
            let host = addr
                .rsplit_once(':')
                .map(|(h, _)| h.to_string())
                .unwrap_or_else(|| addr.clone());
            (Box::new(stream), host)
        }
        (None, None) => bail!("ps-node requires --listen or --supervisor"),
    };
    write_frame(&mut *ctl, &Frame::NodeHello {
        version: PROTO_VERSION,
        name: opts.name.clone(),
        slots: opts.slots.max(1),
        pid: std::process::id() as u64,
    })?;
    let mut reader = FrameReader::new();
    match read_frame_blocking(&mut *ctl, &mut reader)? {
        Frame::NodeHelloAck { version } => {
            if !(1..=PROTO_VERSION).contains(&version) {
                bail!("supervisor negotiated unsupported protocol v{version}");
            }
        }
        f => bail!("expected NodeHelloAck, got {f:?}"),
    }
    ctl.set_read_timeout(Some(NODE_READ_TIMEOUT))?;
    let worker_bin = match &opts.worker_bin {
        Some(b) => b.clone(),
        None => std::env::current_exe()
            .context("ps-node: resolving worker binary")?
            .to_string_lossy()
            .into_owned(),
    };
    let mut children: Vec<Child> = Vec::new();
    let mut buf = [0u8; 4096];
    // Supervisor-silence deadline, mirroring the supervisor's own watch
    // on the agent: the supervisor pings every NODE_READ_TIMEOUT, so a
    // channel with no frames for NODE_SILENCE_DEADLINE means the
    // supervisor host died without a FIN — the agent must not keep its
    // workers running against a gateway that no longer exists.
    let mut last_frame = Instant::now();
    let exit_reason = loop {
        if last_frame.elapsed() > NODE_SILENCE_DEADLINE {
            break "supervisor silent past deadline";
        }
        match ctl.read(&mut buf) {
            Ok(0) => break "supervisor connection closed",
            Ok(n) => {
                last_frame = Instant::now();
                reader.extend(&buf[..n]);
                loop {
                    let frame = match reader.next() {
                        Ok(Some(f)) => f,
                        Ok(None) => break,
                        Err(_) => return agent_exit(children, "wire desync"),
                    };
                    match frame {
                        Frame::SpawnReplica { seq, tier, index, port, args } => {
                            match spawn_worker(
                                &worker_bin,
                                &args,
                                &sup_host,
                                port,
                                tier,
                                index,
                                seq,
                                &opts.log_dir,
                            ) {
                                Ok(child) => children.push(child),
                                Err(e) => {
                                    let _ = write_frame(
                                        &mut *ctl,
                                        &Frame::SpawnFailed {
                                            seq,
                                            error: format!("{e:#}"),
                                        },
                                    );
                                }
                            }
                        }
                        Frame::Ping { nonce } => {
                            if write_frame(&mut *ctl, &Frame::Pong { nonce }).is_err()
                            {
                                // Every exit must go through agent_exit:
                                // a node that loses its supervisor takes
                                // its workers down with it, never
                                // orphans them.
                                return agent_exit(
                                    children,
                                    "control channel write failed",
                                );
                            }
                        }
                        f => {
                            crate::warn_!("ps-node: unexpected frame {f:?}");
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                // Idle: reap workers that exited on their own (drained
                // replicas) so the process table stays clean.
                children.retain_mut(|c| !matches!(c.try_wait(), Ok(Some(_))));
            }
            Err(e) => {
                crate::error!("ps-node: control read: {e}");
                break "control channel error";
            }
        }
    };
    agent_exit(children, exit_reason)
}

/// Kill and reap every hosted worker, then exit the agent loop. Modeling
/// node death as a unit: when the node (agent) goes, its replicas go.
fn agent_exit(mut children: Vec<Child>, reason: &str) -> Result<()> {
    crate::info!("ps-node: exiting ({reason}); stopping {} workers", children.len());
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
    Ok(())
}

/// Fork one `ps-replica` worker that dials the supervisor's data
/// listener at `sup_host:port`.
#[allow(clippy::too_many_arguments)]
fn spawn_worker(
    bin: &str,
    args: &[String],
    sup_host: &str,
    port: u16,
    tier: usize,
    index: usize,
    seq: u64,
    log_dir: &Option<String>,
) -> Result<Child> {
    let tier_name = Tier::ALL[tier.min(2)].name();
    let mut cmd = Command::new(bin);
    cmd.args(args)
        .arg("--socket")
        .arg(format!("tcp:{sup_host}:{port}"))
        .arg("--tier")
        .arg(tier_name)
        .arg("--replica")
        .arg(index.to_string())
        .stdin(Stdio::null());
    match crate::substrate::remote::worker_log(log_dir, tier_name, index, seq) {
        Some(f) => {
            if let Ok(err) = f.try_clone() {
                cmd.stdout(f).stderr(err);
            }
        }
        None => {
            cmd.stdout(Stdio::null());
            // stderr inherits: worker diagnostics reach the agent log.
        }
    }
    cmd.spawn().with_context(|| format!("spawning {bin}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::chaos;

    /// Drive the registration handshake and a spawn order over the
    /// deterministic in-memory transport — no sockets, no processes.
    #[test]
    fn registry_admits_places_and_loses_nodes() {
        let reg = Arc::new(NodeRegistry {
            inner: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
            lost_total: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            data_host: "127.0.0.1".into(),
            failed_spawns: Mutex::new(BTreeMap::new()),
        });
        let mut agents = Vec::new();
        for (i, seed) in [(0u64, 11u64), (1, 22)] {
            let (sup_end, mut agent_end) = chaos::pair(seed);
            // Fake agent: hello, read ack, then answer frames.
            let name = format!("n{i}");
            let h = std::thread::spawn(move || {
                write_frame(&mut agent_end, &Frame::NodeHello {
                    version: PROTO_VERSION,
                    name,
                    slots: 2,
                    pid: 1,
                })
                .unwrap();
                let mut r = FrameReader::new();
                match read_frame_blocking(&mut agent_end, &mut r).unwrap() {
                    Frame::NodeHelloAck { version } => assert_eq!(version, 1),
                    f => panic!("expected ack, got {f:?}"),
                }
                // Receive frames until severed; fail any spawn order.
                loop {
                    match read_frame_blocking(&mut agent_end, &mut r) {
                        Ok(Frame::SpawnReplica { seq, .. }) => {
                            write_frame(&mut agent_end, &Frame::SpawnFailed {
                                seq,
                                error: "test agent".into(),
                            })
                            .unwrap();
                        }
                        Ok(Frame::Ping { nonce }) => {
                            write_frame(&mut agent_end, &Frame::Pong { nonce })
                                .unwrap();
                        }
                        Ok(_) => {}
                        Err(_) => return,
                    }
                }
            });
            let id = Arc::clone(&reg).admit_node(Box::new(sup_end)).unwrap();
            agents.push((id, h));
        }
        assert!(reg.any_alive());
        assert_eq!(reg.snapshot().len(), 2);

        // Spread placement: two replicas of one tier land on different
        // nodes; a third (slots permitting) balances totals.
        let a = reg.place(0, Placement::Spread).unwrap();
        reg.add_hosted(a, 0);
        let b = reg.place(0, Placement::Spread).unwrap();
        assert_ne!(a, b, "anti-affinity must spread a tier across nodes");
        reg.add_hosted(b, 0);
        // Pack placement fills the first node (it has a free slot).
        let c = reg.place(1, Placement::Pack).unwrap();
        assert_eq!(c, NodeId(0));

        // Spawn orders flow; the fake agent answers SpawnFailed, which
        // lands in the failure table under the right seq.
        reg.spawn_on(a, 77, 0, 0, 4000, &["ps-replica".into()]).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if let Some(e) = reg.take_spawn_failure(77) {
                assert!(e.contains("test agent"));
                break;
            }
            assert!(Instant::now() < deadline, "SpawnFailed never surfaced");
            std::thread::sleep(Duration::from_millis(2));
        }

        // Capacity: fill node 0 completely; placement must avoid it.
        reg.add_hosted(NodeId(0), 1);
        let d = reg.place(2, Placement::Pack).unwrap();
        assert_eq!(d, NodeId(1), "a full node must not be placed on");

        // Node loss: severing the control channel marks it lost and
        // bumps the counter; placement skips it; releases are no-ops.
        reg.mark_dead(NodeId(1));
        assert!(!reg.alive(NodeId(1)));
        assert_eq!(reg.lost_total(), 1);
        assert!(reg.place(0, Placement::Spread).is_none(), "all nodes full/dead");
        assert!(reg.spawn_on(NodeId(1), 1, 0, 0, 1, &[]).is_err());
        reg.release(NodeId(1), 0);
        reg.shutdown();
        for (_, h) in agents {
            h.join().unwrap();
        }
        assert_eq!(reg.lost_total(), 1, "shutdown is not node loss");
    }
}
