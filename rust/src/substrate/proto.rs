//! Wire protocol for the process substrate's RPC data plane.
//!
//! The supervisor (gateway control plane) and each `ps-replica` worker
//! process speak length-prefixed JSON frames over a Unix stream socket:
//! a 4-byte big-endian payload length followed by one UTF-8 JSON object
//! (`util::json` — no serde offline). JSON keeps the frames debuggable
//! with `socat`/`strings` and reuses the crate's only serializer; the
//! length prefix makes framing independent of payload content, so
//! prompts may contain any text the JSON layer can round-trip (which is
//! why `util::json` must escape control characters and non-BMP code
//! points losslessly — see its tests).
//!
//! Session shape:
//!
//! ```text
//! worker  → Hello   { version, pid, tier }
//! super   → HelloAck{ version, pool }          (negotiated version + knobs)
//! worker  → Ready                              (engine built and warm)
//! super   → Job     { job, prompt, max_tokens }
//! worker  → TokenChunk { job, tokens }*        (streamed per tick)
//! worker  → Done    { job, prompt_tokens, tokens }  (tail tokens)
//! super   → Cancel  { job }                    (caller gave up)
//! worker  → Cancelled { job }
//! super   → Ping { nonce }  /  worker → Pong { nonce }   (RPC latency)
//! worker  → Heartbeat { ... }                  (liveness + counters)
//! super   → Terminate                          (graceful drain)
//! worker  → Returned { job }*                  (unstarted work handed back)
//! worker  → Gone                               (drained; exiting 0)
//! worker  → Fatal { error }                    (engine build/step died)
//! ```
//!
//! Version negotiation: `Hello.version` is the worker's newest protocol;
//! the supervisor answers with `min(worker, PROTO_VERSION)`. Either side
//! that cannot speak the negotiated version hangs up; versions degrade
//! instead of breaking. Version 2 adds the fleet-wide prefix-cache
//! plane: the heartbeat's `hot` prefix summary plus the
//! `PrefixAd`/`FetchBlocks`/`BlocksChunk` transfer frames — the
//! supervisor never sends a v2-only frame on a v1 session, and a v1
//! decoder skips the unknown `hot` heartbeat key. Version 2 also carries
//! the cross-tier speculative-decoding plane: `PoolWire`'s `spec_*`
//! knobs, the heartbeat's `spec_*` counters (omitted while zero, so a
//! plain-decode heartbeat keeps the v1 byte shape), and the
//! supervisor→worker [`Frame::SpecDraft`] draft-tier-availability
//! signal. Version 2 also carries the tracing plane: `Job` ships the
//! request's `traceparent` out (omitted for untraced jobs), and
//! `Done`/`JobFailed`/`Heartbeat` carry worker-side span batches back
//! (receipt-relative timestamps; omitted when empty) — so with tracing
//! off every frame keeps the exact pre-tracing byte shape. Chain hashes
//! are u64 and cross the wire as 16-digit hex strings: `Json::Num` is
//! an f64 and would silently round hashes above 2^53.

use std::io;
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::backend::batcher::N_DECODE_BATCHES;
use crate::backend::kv_cache::PrefixCacheConfig;
use crate::config::PoolConfig;
use crate::telemetry::trace::{spans_from_wire, spans_to_wire, Span, SpanKind};
use crate::util::json::Json;

/// One end of a supervisor↔worker (or supervisor↔node-agent) channel.
/// The framing above ([`FrameReader`], [`write_frame`]) is byte-oriented
/// and transport-agnostic; this trait is the only place a concrete
/// stream type appears, so the same pump/worker loops run over a Unix
/// socket (single host), TCP (multi-host), or the in-memory chaos
/// transport (`testkit::chaos`) that fragments and severs deterministically
/// in tests.
pub trait Transport: Send {
    /// Read up to `buf.len()` bytes; `Ok(0)` means the peer hung up.
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize>;
    /// Write all of `buf` (frames are written in one call so concurrent
    /// writers on clones never interleave a frame).
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()>;
    /// Timeout for subsequent reads (`None` = block indefinitely).
    fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()>;
    /// Timeout for subsequent writes (`None` = block indefinitely). A
    /// wedged-but-alive peer (frozen VM, full receive window) must not
    /// block a control thread forever.
    fn set_write_timeout(&self, t: Option<Duration>) -> io::Result<()>;
    /// A second handle onto the same stream (reader/writer split).
    fn try_clone(&self) -> io::Result<Box<dyn Transport>>;
    /// Tear the connection down in both directions; blocked reads on any
    /// clone return. Used for remote "kill": severing the data plane is
    /// the supervisor's only lever on a worker it cannot signal.
    fn shutdown(&self);
    /// Human-readable peer description for logs.
    fn peer(&self) -> String;
}

impl Transport for UnixStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        io::Read::read(self, buf)
    }

    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        io::Write::write_all(self, buf)
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        UnixStream::set_read_timeout(self, t)
    }

    fn set_write_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        UnixStream::set_write_timeout(self, t)
    }

    fn try_clone(&self) -> io::Result<Box<dyn Transport>> {
        Ok(Box::new(UnixStream::try_clone(self)?))
    }

    fn shutdown(&self) {
        let _ = UnixStream::shutdown(self, std::net::Shutdown::Both);
    }

    fn peer(&self) -> String {
        "unix".to_string()
    }
}

impl Transport for TcpStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        io::Read::read(self, buf)
    }

    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        io::Write::write_all(self, buf)
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        TcpStream::set_read_timeout(self, t)
    }

    fn set_write_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        TcpStream::set_write_timeout(self, t)
    }

    fn try_clone(&self) -> io::Result<Box<dyn Transport>> {
        Ok(Box::new(TcpStream::try_clone(self)?))
    }

    fn shutdown(&self) {
        let _ = TcpStream::shutdown(self, std::net::Shutdown::Both);
    }

    fn peer(&self) -> String {
        self.peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "tcp".to_string())
    }
}

/// Worker-side connect: `tcp:host:port` dials TCP (multi-host data
/// plane, Nagle off — frames are latency-sensitive), anything else is a
/// Unix socket path (same-host supervisor).
pub fn connect_worker(addr: &str) -> Result<Box<dyn Transport>> {
    if let Some(hostport) = addr.strip_prefix("tcp:") {
        let s = TcpStream::connect(hostport)
            .map_err(|e| anyhow!("tcp connect {hostport}: {e}"))?;
        let _ = s.set_nodelay(true);
        Ok(Box::new(s))
    } else {
        let s = UnixStream::connect(addr)
            .map_err(|e| anyhow!("unix connect {addr}: {e}"))?;
        Ok(Box::new(s))
    }
}

/// Newest protocol version this build speaks.
pub const PROTO_VERSION: u64 = 2;

/// Upper bound on one frame's payload (corruption guard: a garbled
/// length prefix must not trigger a multi-gigabyte allocation).
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Scheduler knobs the supervisor ships to a worker in `HelloAck`, so
/// worker processes need no config file — the gateway's `pool.*` section
/// is authoritative for every replica regardless of substrate.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolWire {
    pub max_inflight: usize,
    pub max_decode_batch: usize,
    pub max_prefill_batch: usize,
    pub flush_timeout_s: f64,
    pub kv_blocks: usize,
    pub kv_block_tokens: usize,
    pub prefix_cache: PrefixCacheConfig,
    /// How many hot prefix chain tips the worker should advertise per
    /// heartbeat. `0` = affinity routing off, advertise nothing (the v1
    /// wire behavior).
    pub affinity_top_k: usize,
    /// Draft window for cross-tier speculative decoding. `0` =
    /// speculation off for this replica (the v1 wire behavior); nonzero
    /// means the worker's scheduler runs the draft/verify state machine
    /// with this window once the supervisor signals the draft tier live
    /// ([`Frame::SpecDraft`]).
    pub spec_draft_tokens: usize,
    /// Acceptance EMA floor below which the worker's scheduler latches
    /// speculation off (meaningless when `spec_draft_tokens` is 0).
    pub spec_min_accept: f64,
    /// Sim-engine acceptance model rate (process-substrate sim workers
    /// reconstruct their acceptance model from this; ignored by live
    /// engines, meaningless when `spec_draft_tokens` is 0).
    pub spec_sim_accept: f64,
}

impl PoolWire {
    pub fn from_pool(p: &PoolConfig) -> PoolWire {
        PoolWire {
            max_inflight: p.max_inflight,
            max_decode_batch: p.max_decode_batch,
            max_prefill_batch: p.max_prefill_batch,
            flush_timeout_s: p.flush_timeout_s,
            kv_blocks: p.kv_blocks,
            kv_block_tokens: p.kv_block_tokens,
            prefix_cache: p.prefix_cache,
            affinity_top_k: if p.affinity.enabled { p.affinity.top_k } else { 0 },
            spec_draft_tokens: if p.speculative.enabled {
                p.speculative.draft_tokens
            } else {
                0
            },
            spec_min_accept: p.speculative.min_accept_rate,
            spec_sim_accept: p.speculative.sim_accept,
        }
    }

    /// `from_pool` with the per-tier pairing rule applied: a tier that
    /// does not verify against a draft tier ships `spec_draft_tokens: 0`
    /// and runs plain decode bit-for-bit.
    pub fn from_pool_for_tier(p: &PoolConfig, tier: usize) -> PoolWire {
        let mut w = PoolWire::from_pool(p);
        if !p.speculative.pairs_with(tier) {
            w.spec_draft_tokens = 0;
        }
        w
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_inflight", Json::num(self.max_inflight as f64)),
            ("max_decode_batch", Json::num(self.max_decode_batch as f64)),
            ("max_prefill_batch", Json::num(self.max_prefill_batch as f64)),
            ("flush_timeout_s", Json::num(self.flush_timeout_s)),
            ("kv_blocks", Json::num(self.kv_blocks as f64)),
            ("kv_block_tokens", Json::num(self.kv_block_tokens as f64)),
            ("pc_enabled", Json::Bool(self.prefix_cache.enabled)),
            ("pc_min_block_run", Json::num(self.prefix_cache.min_block_run as f64)),
            ("pc_evict_watermark", Json::num(self.prefix_cache.evict_watermark)),
            ("aff_top_k", Json::num(self.affinity_top_k as f64)),
            ("spec_draft_tokens", Json::num(self.spec_draft_tokens as f64)),
            ("spec_min_accept", Json::num(self.spec_min_accept)),
            ("spec_sim_accept", Json::num(self.spec_sim_accept)),
        ])
    }

    fn from_json(j: &Json) -> Result<PoolWire> {
        Ok(PoolWire {
            max_inflight: j.rusize("max_inflight")?,
            max_decode_batch: j.rusize("max_decode_batch")?,
            max_prefill_batch: j.rusize("max_prefill_batch")?,
            flush_timeout_s: j.rf64("flush_timeout_s")?,
            kv_blocks: j.rusize("kv_blocks")?,
            kv_block_tokens: j.rusize("kv_block_tokens")?,
            prefix_cache: PrefixCacheConfig {
                enabled: j.bool_or("pc_enabled", true),
                min_block_run: j.usize_or("pc_min_block_run", 1),
                evict_watermark: j.f64_or("pc_evict_watermark", 0.9),
            },
            affinity_top_k: j.usize_or("aff_top_k", 0),
            // Lenient: absent (v1 supervisor) = speculation off.
            spec_draft_tokens: j.usize_or("spec_draft_tokens", 0),
            spec_min_accept: j.f64_or("spec_min_accept", 0.3),
            spec_sim_accept: j.f64_or("spec_sim_accept", 0.75),
        })
    }
}

/// Cumulative worker-side counters carried by [`Frame::Heartbeat`]. The
/// supervisor differences successive samples into the gateway's global
/// metrics and publishes the cumulatives into the replica's cell (the
/// control loop's cache-adjusted demand signal) — the same split the
/// thread substrate gets from shared memory, reconstructed over the wire.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HeartbeatWire {
    /// Occupied decode slots, buffered prefills included.
    pub inflight: usize,
    pub prefills: u64,
    pub prefill_batched: u64,
    pub decode_steps: u64,
    pub batched_steps: u64,
    /// Formed decode batches per compiled rung (`DECODE_BATCHES` order).
    pub batch_counts: [u64; N_DECODE_BATCHES],
    pub prefix_hit_tokens: u64,
    pub prefix_miss_tokens: u64,
    pub prefix_evicted_blocks: u64,
    /// Blocks resident in the worker's prefix cache (gauge).
    pub prefix_cache_blocks: u64,
    /// v2: hot prefix summary — top-K cached chain tips as
    /// `(chain_hash, chain_len_blocks)`, recency-ordered. The router
    /// scores request prompts against these for cache-affinity dispatch.
    /// Empty when affinity is off (and always absent on a v1 wire).
    pub hot: Vec<(u64, u32)>,
    /// v2: speculative-decoding counters, cumulative like the prefix
    /// counters. All zero (and absent on the wire) while the worker runs
    /// plain decode, so a non-speculating heartbeat keeps the v1 shape.
    pub spec_drafted_tokens: u64,
    pub spec_accepted_tokens: u64,
    pub spec_rejected_tokens: u64,
    pub spec_verify_steps: u64,
    /// v2: early-flushed trace spans for in-flight jobs, keyed by job id
    /// with receipt-relative timestamps (a prefill span ships here before
    /// `Done` so a worker killed mid-decode still leaves its prefill on
    /// the trace). Empty — and absent on the wire — with tracing off.
    pub spans: Vec<(u64, Span)>,
}

/// One protocol frame. `S2W` = supervisor→worker, `W2S` = worker→supervisor.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    // ---- handshake -------------------------------------------------------
    /// W2S: first frame on the socket.
    Hello { version: u64, pid: u64, tier: usize },
    /// S2W: negotiated version + the scheduler knobs for this replica.
    HelloAck { version: u64, pool: PoolWire },
    /// W2S: engine built and warm; the supervisor's Loading→Ready edge.
    Ready,
    // ---- data plane ------------------------------------------------------
    /// S2W: dispatch one routed job. `trace` is the request's
    /// `traceparent` — empty for untraced jobs and then absent on the
    /// wire (v2; a v1 worker never sees the key).
    Job { job: u64, prompt: String, max_tokens: usize, trace: String },
    /// W2S: newly generated tokens for an in-flight job (streamed).
    TokenChunk { job: u64, tokens: Vec<i32> },
    /// W2S: job finished; `tokens` is the not-yet-streamed tail.
    /// `spans` carries the worker-side trace spans not already flushed
    /// via heartbeat (receipt-relative; empty ⇒ absent on the wire).
    Done { job: u64, prompt_tokens: usize, tokens: Vec<i32>, spans: Vec<Span> },
    /// W2S: job failed terminally (admission/prefill error). `spans` as
    /// on [`Frame::Done`].
    JobFailed { job: u64, error: String, spans: Vec<Span> },
    /// S2W: the caller gave up; evict the sequence.
    Cancel { job: u64 },
    /// W2S: the sequence was evicted by its cancel token.
    Cancelled { job: u64 },
    /// W2S: graceful drain handed this unstarted job back for requeue.
    Returned { job: u64 },
    // ---- fleet prefix cache (v2) -----------------------------------------
    /// W2S: immediate hot-prefix advertisement, sent when the worker's
    /// summary changes so the router sees new prefixes faster than the
    /// heartbeat period. Same payload shape as the heartbeat `hot` field.
    PrefixAd { prefixes: Vec<(u64, u32)> },
    /// S2W: ask a donor worker for the cached block run whose chain tip
    /// is `hash`. `req` is a supervisor-unique transfer id echoed back.
    FetchBlocks { req: u64, hash: u64 },
    /// Bidirectional: the block run for one transfer. Worker→super as
    /// the answer to [`Frame::FetchBlocks`] (echoing `req`; `blocks`
    /// empty when the prefix was evicted in the meantime); super→worker
    /// as delivery into a cold replica (`req` = 0). `done` marks the
    /// final chunk of the transfer.
    BlocksChunk { req: u64, hash: u64, blocks: Vec<Vec<i32>>, done: bool },
    // ---- node plane (supervisor ↔ ps-node agent) -------------------------
    /// Agent→super: first frame on a node control channel — register this
    /// node's capacity (`slots` = replica processes it may host) and
    /// display name with the supervisor's placement layer.
    NodeHello { version: u64, name: String, slots: usize, pid: u64 },
    /// Super→agent: negotiated version; the node is registered.
    NodeHelloAck { version: u64 },
    /// Super→agent: spawn one `ps-replica` worker. `seq` is the
    /// supervisor-unique replica sequence (echoed by `SpawnFailed`),
    /// `port` the supervisor's per-replica TCP data listener (the agent
    /// combines it with the control channel's peer host), `args` the
    /// leading worker argv (subcommand + engine flags) — the supervisor's
    /// `pool.*` stays authoritative on every host.
    SpawnReplica {
        seq: u64,
        tier: usize,
        index: usize,
        port: u16,
        args: Vec<String>,
    },
    /// Agent→super: the spawn for `seq` failed (bad binary, fork error);
    /// the supervisor fails that replica instead of waiting out the
    /// connect deadline.
    SpawnFailed { seq: u64, error: String },
    // ---- speculative decoding (v2) ---------------------------------------
    /// S2W: draft-tier availability edge. `ok: true` means the paired
    /// draft tier is live and unsaturated, so the worker's scheduler may
    /// speculate; `ok: false` (also the worker's initial state) forces
    /// plain decode. Sent on change by the supervisor's control loop —
    /// never on a v1 session.
    SpecDraft { ok: bool },
    // ---- control / health ------------------------------------------------
    /// W2S: liveness + cumulative counters.
    Heartbeat(HeartbeatWire),
    /// S2W: RPC latency probe (`nonce` echoes back verbatim).
    Ping { nonce: u64 },
    /// W2S: echo of [`Frame::Ping`].
    Pong { nonce: u64 },
    /// S2W: drain in-flight work, return unstarted work, then exit 0.
    Terminate,
    /// W2S: drained and exiting (graceful terminal frame).
    Gone,
    /// W2S: unrecoverable worker error (engine build/step death).
    Fatal { error: String },
}

impl Frame {
    fn tag(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::HelloAck { .. } => "hello_ack",
            Frame::Ready => "ready",
            Frame::Job { .. } => "job",
            Frame::TokenChunk { .. } => "chunk",
            Frame::Done { .. } => "done",
            Frame::JobFailed { .. } => "job_failed",
            Frame::Cancel { .. } => "cancel",
            Frame::Cancelled { .. } => "cancelled",
            Frame::Returned { .. } => "returned",
            Frame::PrefixAd { .. } => "prefix_ad",
            Frame::FetchBlocks { .. } => "fetch_blocks",
            Frame::BlocksChunk { .. } => "blocks_chunk",
            Frame::NodeHello { .. } => "node_hello",
            Frame::NodeHelloAck { .. } => "node_hello_ack",
            Frame::SpawnReplica { .. } => "spawn",
            Frame::SpawnFailed { .. } => "spawn_failed",
            Frame::SpecDraft { .. } => "spec_draft",
            Frame::Heartbeat(_) => "heartbeat",
            Frame::Ping { .. } => "ping",
            Frame::Pong { .. } => "pong",
            Frame::Terminate => "terminate",
            Frame::Gone => "gone",
            Frame::Fatal { .. } => "fatal",
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("t", Json::str(self.tag()))];
        match self {
            Frame::Hello { version, pid, tier } => {
                pairs.push(("version", Json::num(*version as f64)));
                pairs.push(("pid", Json::num(*pid as f64)));
                pairs.push(("tier", Json::num(*tier as f64)));
            }
            Frame::HelloAck { version, pool } => {
                pairs.push(("version", Json::num(*version as f64)));
                pairs.push(("pool", pool.to_json()));
            }
            Frame::Job { job, prompt, max_tokens, trace } => {
                pairs.push(("job", Json::num(*job as f64)));
                pairs.push(("prompt", Json::str(prompt.clone())));
                pairs.push(("max_tokens", Json::num(*max_tokens as f64)));
                // v2: omitted for untraced jobs — the exact pre-tracing
                // byte shape.
                if !trace.is_empty() {
                    pairs.push(("trace", Json::str(trace.clone())));
                }
            }
            Frame::TokenChunk { job, tokens } => {
                pairs.push(("job", Json::num(*job as f64)));
                pairs.push(("tokens", tokens_json(tokens)));
            }
            Frame::Done { job, prompt_tokens, tokens, spans } => {
                pairs.push(("job", Json::num(*job as f64)));
                pairs.push(("prompt_tokens", Json::num(*prompt_tokens as f64)));
                pairs.push(("tokens", tokens_json(tokens)));
                if !spans.is_empty() {
                    pairs.push(("spans", spans_to_wire(spans)));
                }
            }
            Frame::JobFailed { job, error, spans } => {
                pairs.push(("job", Json::num(*job as f64)));
                pairs.push(("error", Json::str(error.clone())));
                if !spans.is_empty() {
                    pairs.push(("spans", spans_to_wire(spans)));
                }
            }
            Frame::Cancel { job }
            | Frame::Cancelled { job }
            | Frame::Returned { job } => {
                pairs.push(("job", Json::num(*job as f64)));
            }
            Frame::PrefixAd { prefixes } => {
                pairs.push(("prefixes", prefixes_json(prefixes)));
            }
            Frame::FetchBlocks { req, hash } => {
                pairs.push(("req", Json::num(*req as f64)));
                pairs.push(("hash", hash_json(*hash)));
            }
            Frame::BlocksChunk { req, hash, blocks, done } => {
                pairs.push(("req", Json::num(*req as f64)));
                pairs.push(("hash", hash_json(*hash)));
                pairs.push(("blocks", Json::arr(blocks.iter().map(|b| tokens_json(b)))));
                pairs.push(("done", Json::Bool(*done)));
            }
            Frame::NodeHello { version, name, slots, pid } => {
                pairs.push(("version", Json::num(*version as f64)));
                pairs.push(("name", Json::str(name.clone())));
                pairs.push(("slots", Json::num(*slots as f64)));
                pairs.push(("pid", Json::num(*pid as f64)));
            }
            Frame::NodeHelloAck { version } => {
                pairs.push(("version", Json::num(*version as f64)));
            }
            Frame::SpawnReplica { seq, tier, index, port, args } => {
                pairs.push(("seq", Json::num(*seq as f64)));
                pairs.push(("tier", Json::num(*tier as f64)));
                pairs.push(("index", Json::num(*index as f64)));
                pairs.push(("port", Json::num(*port as f64)));
                pairs.push((
                    "args",
                    Json::arr(args.iter().map(|a| Json::str(a.clone()))),
                ));
            }
            Frame::SpawnFailed { seq, error } => {
                pairs.push(("seq", Json::num(*seq as f64)));
                pairs.push(("error", Json::str(error.clone())));
            }
            Frame::SpecDraft { ok } => {
                pairs.push(("ok", Json::Bool(*ok)));
            }
            Frame::Heartbeat(hb) => {
                pairs.push(("inflight", Json::num(hb.inflight as f64)));
                pairs.push(("prefills", Json::num(hb.prefills as f64)));
                pairs.push(("prefill_batched", Json::num(hb.prefill_batched as f64)));
                pairs.push(("decode_steps", Json::num(hb.decode_steps as f64)));
                pairs.push(("batched_steps", Json::num(hb.batched_steps as f64)));
                pairs.push((
                    "batch_counts",
                    Json::arr(hb.batch_counts.iter().map(|&c| Json::num(c as f64))),
                ));
                pairs.push(("hit_tokens", Json::num(hb.prefix_hit_tokens as f64)));
                pairs.push(("miss_tokens", Json::num(hb.prefix_miss_tokens as f64)));
                pairs.push((
                    "evicted_blocks",
                    Json::num(hb.prefix_evicted_blocks as f64),
                ));
                pairs.push(("cache_blocks", Json::num(hb.prefix_cache_blocks as f64)));
                // v2: omitted entirely when empty so a v1-shaped
                // heartbeat stays byte-identical with affinity off.
                if !hb.hot.is_empty() {
                    pairs.push(("hot", prefixes_json(&hb.hot)));
                }
                // v2: likewise omitted while zero — a plain-decode
                // worker's heartbeat is byte-identical to v1.
                if hb.spec_drafted_tokens != 0 {
                    pairs.push((
                        "spec_drafted",
                        Json::num(hb.spec_drafted_tokens as f64),
                    ));
                }
                if hb.spec_accepted_tokens != 0 {
                    pairs.push((
                        "spec_accepted",
                        Json::num(hb.spec_accepted_tokens as f64),
                    ));
                }
                if hb.spec_rejected_tokens != 0 {
                    pairs.push((
                        "spec_rejected",
                        Json::num(hb.spec_rejected_tokens as f64),
                    ));
                }
                if hb.spec_verify_steps != 0 {
                    pairs.push((
                        "spec_verify_steps",
                        Json::num(hb.spec_verify_steps as f64),
                    ));
                }
                // v2: likewise omitted when no spans flushed — a
                // trace-off heartbeat keeps the v1 byte shape.
                if !hb.spans.is_empty() {
                    pairs.push(("spans", hb_spans_json(&hb.spans)));
                }
            }
            Frame::Ping { nonce } | Frame::Pong { nonce } => {
                pairs.push(("nonce", Json::num(*nonce as f64)));
            }
            Frame::Ready | Frame::Terminate | Frame::Gone => {}
            Frame::Fatal { error } => {
                pairs.push(("error", Json::str(error.clone())));
            }
        }
        Json::obj(pairs)
    }

    fn from_json(j: &Json) -> Result<Frame> {
        let job = |j: &Json| j.rusize("job").map(|v| v as u64);
        Ok(match j.rstr("t")? {
            "hello" => Frame::Hello {
                version: j.rusize("version")? as u64,
                pid: j.rusize("pid")? as u64,
                tier: j.rusize("tier")?,
            },
            "hello_ack" => Frame::HelloAck {
                version: j.rusize("version")? as u64,
                pool: PoolWire::from_json(j.req("pool")?)?,
            },
            "ready" => Frame::Ready,
            "job" => Frame::Job {
                job: job(j)?,
                prompt: j.rstr("prompt")?.to_string(),
                max_tokens: j.rusize("max_tokens")?,
                // Lenient: absent (v1 supervisor, or untraced) = "".
                trace: j.str_or("trace", "").to_string(),
            },
            "chunk" => Frame::TokenChunk { job: job(j)?, tokens: tokens_from(j)? },
            "done" => Frame::Done {
                job: job(j)?,
                prompt_tokens: j.rusize("prompt_tokens")?,
                tokens: tokens_from(j)?,
                spans: j.get("spans").map(spans_from_wire).unwrap_or_default(),
            },
            "job_failed" => Frame::JobFailed {
                job: job(j)?,
                error: j.rstr("error")?.to_string(),
                spans: j.get("spans").map(spans_from_wire).unwrap_or_default(),
            },
            "cancel" => Frame::Cancel { job: job(j)? },
            "cancelled" => Frame::Cancelled { job: job(j)? },
            "returned" => Frame::Returned { job: job(j)? },
            "prefix_ad" => Frame::PrefixAd {
                prefixes: prefixes_from(j.rarr("prefixes")?)?,
            },
            "fetch_blocks" => Frame::FetchBlocks {
                req: j.rusize("req")? as u64,
                hash: hash_from(j.rstr("hash")?)?,
            },
            "blocks_chunk" => Frame::BlocksChunk {
                req: j.rusize("req")? as u64,
                hash: hash_from(j.rstr("hash")?)?,
                blocks: j
                    .rarr("blocks")?
                    .iter()
                    .map(|b| {
                        b.as_arr()
                            .map(|ts| {
                                ts.iter()
                                    .map(|v| v.as_f64().unwrap_or(0.0) as i32)
                                    .collect()
                            })
                            .ok_or_else(|| anyhow!("block is not a token array"))
                    })
                    .collect::<Result<Vec<Vec<i32>>>>()?,
                done: j.bool_or("done", true),
            },
            "node_hello" => Frame::NodeHello {
                version: j.rusize("version")? as u64,
                name: j.rstr("name")?.to_string(),
                slots: j.rusize("slots")?,
                pid: j.rusize("pid")? as u64,
            },
            "node_hello_ack" => Frame::NodeHelloAck {
                version: j.rusize("version")? as u64,
            },
            "spawn" => Frame::SpawnReplica {
                seq: j.rusize("seq")? as u64,
                tier: j.rusize("tier")?,
                index: j.rusize("index")?,
                port: j.rusize("port")? as u16,
                args: j
                    .rarr("args")?
                    .iter()
                    .map(|a| {
                        a.as_str()
                            .map(|s| s.to_string())
                            .ok_or_else(|| anyhow!("spawn arg is not a string"))
                    })
                    .collect::<Result<Vec<String>>>()?,
            },
            "spawn_failed" => Frame::SpawnFailed {
                seq: j.rusize("seq")? as u64,
                error: j.rstr("error")?.to_string(),
            },
            "spec_draft" => Frame::SpecDraft { ok: j.bool_or("ok", false) },
            "heartbeat" => {
                let mut batch_counts = [0u64; N_DECODE_BATCHES];
                if let Some(a) = j.get("batch_counts").and_then(Json::as_arr) {
                    for (i, v) in a.iter().take(N_DECODE_BATCHES).enumerate() {
                        batch_counts[i] = v.as_f64().unwrap_or(0.0) as u64;
                    }
                }
                Frame::Heartbeat(HeartbeatWire {
                    inflight: j.rusize("inflight")?,
                    prefills: j.rusize("prefills")? as u64,
                    prefill_batched: j.rusize("prefill_batched")? as u64,
                    decode_steps: j.rusize("decode_steps")? as u64,
                    batched_steps: j.rusize("batched_steps")? as u64,
                    batch_counts,
                    prefix_hit_tokens: j.rusize("hit_tokens")? as u64,
                    prefix_miss_tokens: j.rusize("miss_tokens")? as u64,
                    prefix_evicted_blocks: j.rusize("evicted_blocks")? as u64,
                    prefix_cache_blocks: j.rusize("cache_blocks")? as u64,
                    // Lenient: absent (v1 peer, or affinity off) = empty.
                    hot: j
                        .get("hot")
                        .and_then(Json::as_arr)
                        .map(prefixes_from)
                        .transpose()?
                        .unwrap_or_default(),
                    // Lenient: absent (v1 peer, or plain decode) = zero.
                    spec_drafted_tokens: j.usize_or("spec_drafted", 0) as u64,
                    spec_accepted_tokens: j.usize_or("spec_accepted", 0) as u64,
                    spec_rejected_tokens: j.usize_or("spec_rejected", 0) as u64,
                    spec_verify_steps: j.usize_or("spec_verify_steps", 0) as u64,
                    // Lenient: absent (v1 peer, or tracing off) = empty.
                    spans: j.get("spans").map(hb_spans_from).unwrap_or_default(),
                })
            }
            "ping" => Frame::Ping { nonce: j.rusize("nonce")? as u64 },
            "pong" => Frame::Pong { nonce: j.rusize("nonce")? as u64 },
            "terminate" => Frame::Terminate,
            "gone" => Frame::Gone,
            "fatal" => Frame::Fatal { error: j.rstr("error")?.to_string() },
            t => bail!("unknown frame type `{t}`"),
        })
    }

    /// Serialize to the wire form: 4-byte big-endian length + JSON.
    pub fn encode(&self) -> Vec<u8> {
        let body = self.to_json().dump().into_bytes();
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_be_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Parse one frame payload (the bytes after the length prefix).
    pub fn decode(body: &[u8]) -> Result<Frame> {
        let text = std::str::from_utf8(body)
            .map_err(|e| anyhow!("frame is not UTF-8: {e}"))?;
        Frame::from_json(&Json::parse(text)?)
    }
}

fn tokens_json(tokens: &[i32]) -> Json {
    Json::arr(tokens.iter().map(|&t| Json::num(t as f64)))
}

/// Chain hashes are full-range u64; `Json::Num` is an f64 (exact only
/// to 2^53), so hashes cross the wire as fixed-width hex strings.
fn hash_json(h: u64) -> Json {
    Json::str(format!("{h:016x}"))
}

fn hash_from(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad chain hash `{s}`: {e}"))
}

/// `(chain_hash, chain_len_blocks)` pairs as `[["<hex>", len], ...]`.
fn prefixes_json(prefixes: &[(u64, u32)]) -> Json {
    Json::arr(
        prefixes
            .iter()
            .map(|&(h, l)| Json::arr(vec![hash_json(h), Json::num(l as f64)])),
    )
}

fn prefixes_from(entries: &[Json]) -> Result<Vec<(u64, u32)>> {
    entries
        .iter()
        .map(|e| {
            let pair = e
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow!("prefix entry is not a [hash, len] pair"))?;
            let h = pair[0]
                .as_str()
                .ok_or_else(|| anyhow!("prefix hash is not a string"))
                .and_then(hash_from)?;
            let l = pair[1]
                .as_f64()
                .ok_or_else(|| anyhow!("prefix length is not a number"))?
                as u32;
            Ok((h, l))
        })
        .collect()
}

fn tokens_from(j: &Json) -> Result<Vec<i32>> {
    Ok(j.rarr("tokens")?
        .iter()
        .map(|v| v.as_f64().unwrap_or(0.0) as i32)
        .collect())
}

/// Heartbeat span batches: `[[job, name, start, dur, n], ...]`. Job ids
/// are sequential counters well under 2^53, so `Json::Num` is exact.
fn hb_spans_json(entries: &[(u64, Span)]) -> Json {
    Json::arr(entries.iter().map(|(job, s)| {
        Json::arr(vec![
            Json::num(*job as f64),
            Json::str(s.kind.name()),
            Json::num(s.start_s),
            Json::num(s.dur_s()),
            Json::num(s.n as f64),
        ])
    }))
}

/// Lenient decode (mirrors `spans_from_wire`): malformed entries and
/// unknown span kinds are skipped, never fatal.
fn hb_spans_from(j: &Json) -> Vec<(u64, Span)> {
    let mut out = Vec::new();
    let Some(items) = j.as_arr() else { return out };
    for it in items {
        let Some(f) = it.as_arr() else { continue };
        if f.len() < 4 {
            continue;
        }
        let Some(job) = f[0].as_f64() else { continue };
        let Some(kind) = f[1].as_str().and_then(SpanKind::from_name) else { continue };
        let (Some(start), Some(dur)) = (f[2].as_f64(), f[3].as_f64()) else { continue };
        let n = f.get(4).and_then(Json::as_f64).unwrap_or(0.0) as u32;
        out.push((
            job as u64,
            Span { kind, start_s: start, end_s: start + dur.max(0.0), n },
        ));
    }
    out
}

/// Incremental frame decoder. Bytes arrive in arbitrary read-sized
/// pieces (the supervisor reads with a timeout and may observe partial
/// frames); `extend` accumulates and [`FrameReader::next`] yields
/// complete frames without ever losing sync mid-frame.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are needed.
    /// A parse error is unrecoverable (the stream is desynced) — callers
    /// must drop the connection.
    pub fn next(&mut self) -> Result<Option<Frame>> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_be_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]])
            as usize;
        if len > MAX_FRAME_BYTES {
            bail!("frame length {len} exceeds {MAX_FRAME_BYTES}");
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let frame = Frame::decode(&self.buf[4..4 + len])?;
        self.buf.drain(..4 + len);
        Ok(Some(frame))
    }
}

/// Write one frame to a transport (single `write_all`, so frames from
/// one thread are never interleaved).
pub fn write_frame(t: &mut dyn Transport, frame: &Frame) -> io::Result<()> {
    t.write_all(&frame.encode())
}

/// Blocking read of a single frame with `reader` as carry-over buffer —
/// used for the handshake, where exactly one frame is expected next.
/// Read timeouts are retried (the transport may have one configured);
/// EOF and hard errors surface.
pub fn read_frame_blocking(
    t: &mut dyn Transport,
    reader: &mut FrameReader,
) -> Result<Frame> {
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(f) = reader.next()? {
            return Ok(f);
        }
        match t.read(&mut chunk) {
            Ok(0) => bail!("connection closed mid-handshake"),
            Ok(n) => reader.extend(&chunk[..n]),
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// The version both sides will speak, or `None` when no common version
/// exists. Policy: speak the older of the two; every version from 1 up
/// to [`PROTO_VERSION`] must stay decodable by this build.
pub fn negotiate(ours: u64, theirs: u64) -> Option<u64> {
    let v = ours.min(theirs);
    if v >= 1 {
        Some(v)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PoolConfig;

    fn roundtrip(f: Frame) {
        let bytes = f.encode();
        let mut r = FrameReader::new();
        r.extend(&bytes);
        let back = r.next().unwrap().expect("complete frame");
        assert_eq!(back, f);
        assert!(r.next().unwrap().is_none(), "no trailing frame");
    }

    #[test]
    fn frames_roundtrip() {
        roundtrip(Frame::Hello { version: 1, pid: 4242, tier: 2 });
        roundtrip(Frame::HelloAck {
            version: 1,
            pool: PoolWire::from_pool(&PoolConfig::default()),
        });
        roundtrip(Frame::Ready);
        roundtrip(Frame::Job {
            job: 7,
            prompt: "what is 2 plus 2?".into(),
            max_tokens: 16,
            trace: String::new(),
        });
        roundtrip(Frame::Job {
            job: 8,
            prompt: "traced".into(),
            max_tokens: 4,
            trace: format!("00-{:032x}-{:016x}-01", 99u128, 5u64),
        });
        roundtrip(Frame::TokenChunk { job: 7, tokens: vec![1, -2, 4095] });
        roundtrip(Frame::Done {
            job: 7,
            prompt_tokens: 5,
            tokens: vec![9],
            spans: vec![],
        });
        roundtrip(Frame::Done {
            job: 7,
            prompt_tokens: 5,
            tokens: vec![9],
            spans: vec![
                Span { kind: SpanKind::Prefill, start_s: 0.0, end_s: 0.25, n: 0 },
                Span { kind: SpanKind::Decode, start_s: 0.25, end_s: 1.5, n: 0 },
                Span { kind: SpanKind::SpecVerify, start_s: 1.5, end_s: 1.5, n: 6 },
            ],
        });
        roundtrip(Frame::JobFailed {
            job: 7,
            error: "kv pool exceeded".into(),
            spans: vec![],
        });
        roundtrip(Frame::JobFailed {
            job: 7,
            error: "kv pool exceeded".into(),
            spans: vec![Span { kind: SpanKind::Prefill, start_s: 0.0, end_s: 0.1, n: 0 }],
        });
        roundtrip(Frame::Cancel { job: 9 });
        roundtrip(Frame::Cancelled { job: 9 });
        roundtrip(Frame::Returned { job: 10 });
        roundtrip(Frame::Heartbeat(HeartbeatWire {
            inflight: 3,
            prefills: 11,
            prefill_batched: 2,
            decode_steps: 100,
            batched_steps: 40,
            batch_counts: [60, 30, 10],
            prefix_hit_tokens: 640,
            prefix_miss_tokens: 1280,
            prefix_evicted_blocks: 4,
            prefix_cache_blocks: 17,
            hot: vec![(u64::MAX, 7), (0x0123_4567_89ab_cdef, 2), (0, 1)],
            spec_drafted_tokens: 48,
            spec_accepted_tokens: 30,
            spec_rejected_tokens: 18,
            spec_verify_steps: 12,
            spans: vec![
                (7, Span { kind: SpanKind::Prefill, start_s: 0.0, end_s: 0.5, n: 0 }),
                (9, Span { kind: SpanKind::Prefill, start_s: 0.1, end_s: 0.3, n: 0 }),
            ],
        }));
        roundtrip(Frame::SpecDraft { ok: true });
        roundtrip(Frame::SpecDraft { ok: false });
        roundtrip(Frame::PrefixAd {
            prefixes: vec![(u64::MAX - 1, 3), (1, 1)],
        });
        roundtrip(Frame::FetchBlocks { req: 42, hash: u64::MAX });
        roundtrip(Frame::BlocksChunk {
            req: 42,
            hash: u64::MAX,
            blocks: vec![vec![1, 2, 3, 4], vec![-5, 0, 7, 4095]],
            done: true,
        });
        roundtrip(Frame::BlocksChunk {
            req: 0,
            hash: 0x8000_0000_0000_0001,
            blocks: vec![],
            done: false,
        });
        roundtrip(Frame::Ping { nonce: 123_456_789 });
        roundtrip(Frame::Pong { nonce: 123_456_789 });
        roundtrip(Frame::Terminate);
        roundtrip(Frame::Gone);
        roundtrip(Frame::Fatal { error: "engine died".into() });
        roundtrip(Frame::NodeHello {
            version: 1,
            name: "node-a".into(),
            slots: 4,
            pid: 999,
        });
        roundtrip(Frame::NodeHelloAck { version: 1 });
        roundtrip(Frame::SpawnReplica {
            seq: 17,
            tier: 1,
            index: 0,
            port: 45123,
            args: vec!["ps-replica".into(), "--engine".into(), "sim".into()],
        });
        roundtrip(Frame::SpawnFailed { seq: 17, error: "no such binary".into() });
    }

    #[test]
    fn job_prompts_survive_hostile_text() {
        // Prompts are user text: control characters, quotes, backslashes
        // and non-BMP code points must cross the wire intact (this is
        // what the util/json escape fixes guarantee).
        let prompt = "line1\nline2\t\"quoted\" \\slash\u{1}\u{8}\u{c}\u{1f} 😀日本語";
        let f = Frame::Job {
            job: 1,
            prompt: prompt.into(),
            max_tokens: 4,
            trace: String::new(),
        };
        let mut r = FrameReader::new();
        r.extend(&f.encode());
        match r.next().unwrap().unwrap() {
            Frame::Job { prompt: p, .. } => assert_eq!(p, prompt),
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn reader_handles_split_and_coalesced_frames() {
        let a = Frame::Ping { nonce: 1 }.encode();
        let b = Frame::Job {
            job: 2,
            prompt: "p q r".into(),
            max_tokens: 8,
            trace: String::new(),
        }
        .encode();
        let c = Frame::Gone.encode();
        let mut stream: Vec<u8> = Vec::new();
        stream.extend(&a);
        stream.extend(&b);
        stream.extend(&c);
        // Feed one byte at a time: every frame must still pop exactly once.
        let mut r = FrameReader::new();
        let mut got = Vec::new();
        for byte in stream {
            r.extend(&[byte]);
            while let Some(f) = r.next().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], Frame::Ping { nonce: 1 });
        assert_eq!(got[2], Frame::Gone);
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut r = FrameReader::new();
        r.extend(&(u32::MAX).to_be_bytes());
        r.extend(b"garbage");
        assert!(r.next().is_err());
    }

    #[test]
    fn garbage_payload_is_an_error_not_a_panic() {
        let mut r = FrameReader::new();
        let body = b"{\"t\":\"nope\"}";
        r.extend(&(body.len() as u32).to_be_bytes());
        r.extend(body);
        assert!(r.next().is_err());
    }

    #[test]
    fn negotiation_prefers_older_side() {
        assert_eq!(negotiate(PROTO_VERSION, PROTO_VERSION), Some(PROTO_VERSION));
        assert_eq!(negotiate(3, 1), Some(1));
        assert_eq!(negotiate(1, 9), Some(1));
        assert_eq!(negotiate(1, 0), None);
        // A v2 supervisor facing a v1 worker speaks v1 (no v2 frames).
        assert_eq!(negotiate(PROTO_VERSION, 1), Some(1));
    }

    #[test]
    fn chain_hashes_survive_the_full_u64_range() {
        // Hashes ride as hex strings precisely because Json::Num is an
        // f64: every value here is unrepresentable (or ambiguous) above
        // 2^53 and must still round-trip bit-exactly.
        for h in [u64::MAX, u64::MAX - 1, (1u64 << 53) + 1, 1u64 << 63, 0] {
            roundtrip(Frame::FetchBlocks { req: 1, hash: h });
            roundtrip(Frame::PrefixAd { prefixes: vec![(h, 9)] });
        }
        // And the hex encoding is canonical enough to compare equal.
        assert_eq!(hash_from("ffffffffffffffff").unwrap(), u64::MAX);
        assert!(hash_from("not-hex").is_err());
        assert!(hash_from("10000000000000000").is_err(), "overflow must error");
    }

    #[test]
    fn v1_heartbeat_without_hot_field_decodes_empty() {
        // A v1 worker's heartbeat has no `hot` key; the v2 supervisor
        // must decode it with an empty summary, not an error.
        let hb = HeartbeatWire { inflight: 1, prefills: 2, ..Default::default() };
        let bytes = Frame::Heartbeat(hb.clone()).encode();
        // hot empty ⇒ the encoded JSON carries no "hot" key at all (the
        // exact v1 wire shape).
        assert!(!String::from_utf8(bytes.clone()).unwrap().contains("hot"));
        let mut r = FrameReader::new();
        r.extend(&bytes);
        match r.next().unwrap().unwrap() {
            Frame::Heartbeat(back) => {
                assert!(back.hot.is_empty());
                assert_eq!(back, hb);
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn pool_wire_carries_prefix_cache_knobs() {
        let p = PoolConfig {
            max_inflight: 11,
            prefix_cache: PrefixCacheConfig {
                enabled: false,
                min_block_run: 3,
                ..PrefixCacheConfig::default()
            },
            ..PoolConfig::default()
        };
        let w = PoolWire::from_pool(&p);
        let j = w.to_json();
        let back = PoolWire::from_json(&Json::parse(&j.dump()).unwrap()).unwrap();
        assert_eq!(back, w);
        assert!(!back.prefix_cache.enabled);
        assert_eq!(back.max_inflight, 11);
        assert_eq!(back.affinity_top_k, 0, "affinity off ⇒ no advertising");
    }

    #[test]
    fn plain_decode_heartbeat_keeps_the_v1_byte_shape() {
        // A worker that never speculated has all-zero spec counters; its
        // heartbeat must not grow new keys (v1 peers skip nothing, and
        // the wire stays bit-for-bit the pre-speculation shape).
        let hb = HeartbeatWire { inflight: 2, decode_steps: 9, ..Default::default() };
        let bytes = Frame::Heartbeat(hb.clone()).encode();
        assert!(!String::from_utf8(bytes.clone()).unwrap().contains("spec"));
        let mut r = FrameReader::new();
        r.extend(&bytes);
        match r.next().unwrap().unwrap() {
            Frame::Heartbeat(back) => assert_eq!(back, hb),
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn pool_wire_ships_spec_window_only_when_paired() {
        let mut p = PoolConfig::default();
        p.speculative.draft_tokens = 6;
        assert_eq!(PoolWire::from_pool(&p).spec_draft_tokens, 0, "disabled");
        p.speculative.enabled = true;
        p.speculative.draft_tier = 0;
        let w = PoolWire::from_pool(&p);
        assert_eq!(w.spec_draft_tokens, 6);
        let back = PoolWire::from_json(&Json::parse(&w.to_json().dump()).unwrap())
            .unwrap();
        assert_eq!(back, w);
        // Per-tier: the draft tier itself (and any unpaired tier) ships 0.
        assert_eq!(PoolWire::from_pool_for_tier(&p, 0).spec_draft_tokens, 0);
        assert_eq!(PoolWire::from_pool_for_tier(&p, 2).spec_draft_tokens, 6);
        // A v1-era PoolWire JSON (no spec keys) decodes to speculation off.
        let legacy = r#"{"max_inflight":8,"max_decode_batch":8,
            "max_prefill_batch":4,"flush_timeout_s":0.01,
            "kv_blocks":128,"kv_block_tokens":16}"#;
        let old = PoolWire::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(old.spec_draft_tokens, 0);
    }

    #[test]
    fn untraced_frames_keep_the_pre_tracing_byte_shape() {
        // With tracing off, Job/Done/JobFailed/Heartbeat must encode
        // without any trace key — bit-for-bit the PR 8 wire.
        let job = Frame::Job {
            job: 3,
            prompt: "plain".into(),
            max_tokens: 8,
            trace: String::new(),
        };
        let done = Frame::Done { job: 3, prompt_tokens: 2, tokens: vec![1], spans: vec![] };
        let failed =
            Frame::JobFailed { job: 3, error: "boom".into(), spans: vec![] };
        let hb = Frame::Heartbeat(HeartbeatWire { inflight: 1, ..Default::default() });
        for f in [&job, &done, &failed, &hb] {
            let text = String::from_utf8(f.encode()[4..].to_vec()).unwrap();
            assert!(!text.contains("trace"), "{text}");
            assert!(!text.contains("spans"), "{text}");
        }
        // And the exact pre-tracing serialization, field for field.
        assert_eq!(
            String::from_utf8(job.encode()[4..].to_vec()).unwrap(),
            r#"{"job":3,"max_tokens":8,"prompt":"plain","t":"job"}"#,
        );
        assert_eq!(
            String::from_utf8(done.encode()[4..].to_vec()).unwrap(),
            r#"{"job":3,"prompt_tokens":2,"t":"done","tokens":[1]}"#,
        );
    }

    #[test]
    fn traced_job_round_trips_and_v1_decode_defaults_empty() {
        let tp = format!("00-{:032x}-{:016x}-01", 0xabcdu128, 1u64);
        let f = Frame::Job {
            job: 5,
            prompt: "q".into(),
            max_tokens: 2,
            trace: tp.clone(),
        };
        let mut r = FrameReader::new();
        r.extend(&f.encode());
        match r.next().unwrap().unwrap() {
            Frame::Job { trace, .. } => assert_eq!(trace, tp),
            other => panic!("wrong frame {other:?}"),
        }
        // A v1-shaped job (no trace key) decodes with trace = "".
        let legacy = br#"{"job":5,"max_tokens":2,"prompt":"q","t":"job"}"#;
        match Frame::decode(legacy).unwrap() {
            Frame::Job { trace, .. } => assert!(trace.is_empty()),
            other => panic!("wrong frame {other:?}"),
        }
        // Malformed span entries in a heartbeat degrade, not error.
        let hb = br#"{"t":"heartbeat","inflight":0,"prefills":0,
            "prefill_batched":0,"decode_steps":0,"batched_steps":0,
            "hit_tokens":0,"miss_tokens":0,"evicted_blocks":0,
            "cache_blocks":0,"spans":[[1,"nope",0,1,0],[2,"decode",0.5,1.0,0]]}"#;
        match Frame::decode(hb).unwrap() {
            Frame::Heartbeat(h) => {
                assert_eq!(h.spans.len(), 1);
                assert_eq!(h.spans[0].0, 2);
                assert_eq!(h.spans[0].1.kind, SpanKind::Decode);
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn pool_wire_ships_affinity_top_k_only_when_enabled() {
        let mut p = PoolConfig::default();
        p.affinity.top_k = 5;
        assert_eq!(PoolWire::from_pool(&p).affinity_top_k, 0, "disabled");
        p.affinity.enabled = true;
        let w = PoolWire::from_pool(&p);
        assert_eq!(w.affinity_top_k, 5);
        let back = PoolWire::from_json(&Json::parse(&w.to_json().dump()).unwrap())
            .unwrap();
        assert_eq!(back, w);
    }
}
