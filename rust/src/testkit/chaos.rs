//! Deterministic fault-injecting in-memory [`Transport`] — distributed
//! failures as unit tests, not flaky sleeps.
//!
//! [`pair`] returns two connected endpoints backed by in-process byte
//! queues. The adversarial behaviors real networks exhibit are injected
//! deterministically:
//!
//! * **fragmentation** — every `read` returns a prefix whose length is
//!   drawn from the endpoint's seeded RNG, so frame boundaries land at
//!   adversarial byte offsets (a 4-byte length prefix split 1+3, a JSON
//!   body split mid-escape, …) and every seed explores a different
//!   interleaving, reproducibly;
//! * **coalescing** — writes append to one queue, so consecutive frames
//!   arrive glued together and a single read can span several;
//! * **latency** — [`ChaosEnd::hold`] parks subsequent writes in a side
//!   buffer (the peer sees nothing) until [`ChaosEnd::release`] delivers
//!   them: in-flight-but-undelivered bytes, no wall-clock sleeps;
//! * **partition** — [`ChaosEnd::sever`] cuts the link *dropping any
//!   held bytes*, so a stream can end mid-frame exactly like a SIGKILLed
//!   peer's socket; readers see EOF (`Ok(0)`), writers `BrokenPipe`.
//!
//! Blocking reads park on a condvar and wake on delivery/sever — tests
//! need no sleeps in their assertion paths. With a read timeout of
//! `Duration::ZERO` a read on an empty link returns `WouldBlock`
//! immediately, which is how pump-shaped loops are driven one step at a
//! time.

use std::collections::VecDeque;
use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::substrate::proto::Transport;
use crate::util::rng::SplitMix64;

struct PipeState {
    buf: VecDeque<u8>,
    severed: bool,
}

/// One direction of the link: delivered bytes + the wakeup for readers.
struct Pipe {
    state: Mutex<PipeState>,
    ready: Condvar,
}

impl Pipe {
    fn new() -> Arc<Pipe> {
        Arc::new(Pipe {
            state: Mutex::new(PipeState { buf: VecDeque::new(), severed: false }),
            ready: Condvar::new(),
        })
    }

    fn deliver(&self, bytes: &[u8]) -> io::Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.severed {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "link severed"));
        }
        st.buf.extend(bytes.iter().copied());
        self.ready.notify_all();
        Ok(())
    }

    fn sever(&self) {
        let mut st = self.state.lock().unwrap();
        st.severed = true;
        self.ready.notify_all();
    }
}

/// One endpoint of a chaos link. Clones (`Transport::try_clone`) share
/// the endpoint's queues, RNG, and hold buffer, mirroring a cloned
/// socket handle.
pub struct ChaosEnd {
    /// Peer → us.
    rx: Arc<Pipe>,
    /// Us → peer.
    tx: Arc<Pipe>,
    rng: Arc<Mutex<SplitMix64>>,
    held: Arc<Mutex<Vec<u8>>>,
    holding: Arc<AtomicBool>,
    read_timeout: Arc<Mutex<Option<Duration>>>,
}

/// A connected pair of endpoints; reads on each side fragment per its
/// own stream from the shared seed.
pub fn pair(seed: u64) -> (ChaosEnd, ChaosEnd) {
    let ab = Pipe::new();
    let ba = Pipe::new();
    let mk = |rx: &Arc<Pipe>, tx: &Arc<Pipe>, salt: u64| ChaosEnd {
        rx: Arc::clone(rx),
        tx: Arc::clone(tx),
        rng: Arc::new(Mutex::new(SplitMix64::new(seed ^ salt))),
        held: Arc::new(Mutex::new(Vec::new())),
        holding: Arc::new(AtomicBool::new(false)),
        read_timeout: Arc::new(Mutex::new(None)),
    };
    (mk(&ba, &ab, 0xA), mk(&ab, &ba, 0xB))
}

impl ChaosEnd {
    /// Cut the link in both directions, dropping any held bytes — the
    /// peer may observe EOF mid-frame.
    pub fn sever(&self) {
        self.held.lock().unwrap().clear();
        self.holding.store(false, Ordering::Relaxed);
        self.rx.sever();
        self.tx.sever();
    }

    /// Park subsequent writes (latency injection): the peer sees nothing
    /// until [`Self::release`].
    pub fn hold(&self) {
        self.holding.store(true, Ordering::Relaxed);
    }

    /// Deliver everything held and resume immediate delivery.
    pub fn release(&self) -> io::Result<()> {
        self.holding.store(false, Ordering::Relaxed);
        let held: Vec<u8> = std::mem::take(&mut *self.held.lock().unwrap());
        if held.is_empty() {
            Ok(())
        } else {
            self.tx.deliver(&held)
        }
    }

    /// Bytes currently parked by [`Self::hold`].
    pub fn held_len(&self) -> usize {
        self.held.lock().unwrap().len()
    }
}

impl Transport for ChaosEnd {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let timeout = *self.read_timeout.lock().unwrap();
        let mut st = self.rx.state.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                // Adversarial fragmentation: a nonempty prefix of what's
                // available, length drawn from the seeded RNG.
                let avail = st.buf.len().min(buf.len());
                let n = 1 + self.rng.lock().unwrap().below(avail as u64) as usize;
                for slot in buf.iter_mut().take(n) {
                    *slot = st.buf.pop_front().unwrap();
                }
                return Ok(n);
            }
            if st.severed {
                return Ok(0);
            }
            match timeout {
                None => st = self.rx.ready.wait(st).unwrap(),
                Some(d) => {
                    let (guard, out) = self.rx.ready.wait_timeout(st, d).unwrap();
                    st = guard;
                    if out.timed_out() && st.buf.is_empty() && !st.severed {
                        return Err(io::Error::new(
                            io::ErrorKind::WouldBlock,
                            "chaos read timeout",
                        ));
                    }
                }
            }
        }
    }

    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        if self.holding.load(Ordering::Relaxed) {
            // Latency injection: severed-ness is checked on release —
            // bytes "in flight" when the link cuts are simply lost,
            // like any unacked TCP send.
            self.held.lock().unwrap().extend_from_slice(buf);
            return Ok(());
        }
        self.tx.deliver(buf)
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        *self.read_timeout.lock().unwrap() = t;
        Ok(())
    }

    fn set_write_timeout(&self, _t: Option<Duration>) -> io::Result<()> {
        // Chaos writes never block (delivery is an in-memory append).
        Ok(())
    }

    fn try_clone(&self) -> io::Result<Box<dyn Transport>> {
        Ok(Box::new(ChaosEnd {
            rx: Arc::clone(&self.rx),
            tx: Arc::clone(&self.tx),
            rng: Arc::clone(&self.rng),
            held: Arc::clone(&self.held),
            holding: Arc::clone(&self.holding),
            read_timeout: Arc::clone(&self.read_timeout),
        }))
    }

    fn shutdown(&self) {
        self.sever();
    }

    fn peer(&self) -> String {
        "chaos".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::proto::{write_frame, Frame, FrameReader};

    fn drain(end: &mut ChaosEnd, reader: &mut FrameReader) -> Vec<Frame> {
        // One deterministic step at a time: zero timeout, so an empty
        // link returns WouldBlock instead of parking the test.
        end.set_read_timeout(Some(Duration::ZERO)).unwrap();
        let mut out = Vec::new();
        let mut buf = [0u8; 64];
        loop {
            match end.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    reader.extend(&buf[..n]);
                    while let Some(f) = reader.next().unwrap() {
                        out.push(f);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) => panic!("chaos read failed: {e}"),
            }
        }
        out
    }

    #[test]
    fn fragmented_coalesced_frames_decode_identically() {
        // Several frames written back-to-back (coalesced on the wire)
        // must decode to the identical sequence under every seed's
        // fragmentation pattern.
        let frames = vec![
            Frame::Ping { nonce: 1 },
            Frame::Job { job: 2, prompt: "p q r s t".into(), max_tokens: 8 },
            Frame::Heartbeat(Default::default()),
            Frame::Gone,
        ];
        for seed in 0..50 {
            let (mut a, mut b) = pair(seed);
            for f in &frames {
                write_frame(&mut a, f).unwrap();
            }
            let mut reader = FrameReader::new();
            let got = drain(&mut b, &mut reader);
            assert_eq!(got, frames, "seed {seed} corrupted the stream");
        }
    }

    #[test]
    fn reads_fragment_at_adversarial_boundaries() {
        // Across seeds, reads must split frames — including inside the
        // 4-byte length prefix. (Any single seed may legally deliver a
        // small frame whole; the ensemble must not.)
        let mut fragmented = 0usize;
        let mut split_prefix = 0usize;
        for seed in 0..20 {
            let (mut a, mut b) = pair(seed);
            write_frame(&mut a, &Frame::Ping { nonce: 42 }).unwrap();
            b.set_read_timeout(Some(Duration::ZERO)).unwrap();
            let mut sizes = Vec::new();
            let mut buf = [0u8; 64];
            loop {
                match b.read(&mut buf) {
                    Ok(n) => sizes.push(n),
                    Err(_) => break,
                }
            }
            assert!(sizes.iter().all(|&n| n >= 1), "empty read at seed {seed}");
            if sizes.len() > 1 {
                fragmented += 1;
            }
            if sizes.first().copied().unwrap_or(0) < 4 {
                split_prefix += 1;
            }
        }
        assert!(fragmented > 0, "no seed ever fragmented a frame");
        assert!(split_prefix > 0, "no seed ever split the length prefix");
    }

    #[test]
    fn severed_mid_frame_is_clean_eof_never_desync() {
        // Hold the tail of a frame, sever: the reader gets a clean EOF
        // with a partial frame buffered — no panic, no bogus frame.
        let (mut a, mut b) = pair(3);
        write_frame(&mut a, &Frame::Done { job: 9, prompt_tokens: 3, tokens: vec![1, 2] })
            .unwrap();
        let bytes = Frame::Job { job: 10, prompt: "never finishes".into(), max_tokens: 4 }
            .encode();
        // First half delivered, second half held in flight, then cut.
        a.write_all(&bytes[..bytes.len() / 2]).unwrap();
        a.hold();
        a.write_all(&bytes[bytes.len() / 2..]).unwrap();
        a.sever();

        let mut reader = FrameReader::new();
        let mut buf = [0u8; 64];
        let mut got = Vec::new();
        loop {
            match b.read(&mut buf) {
                Ok(0) => break, // clean EOF
                Ok(n) => {
                    reader.extend(&buf[..n]);
                    while let Some(f) = reader.next().unwrap() {
                        got.push(f);
                    }
                }
                Err(e) => panic!("sever must read as EOF, got {e}"),
            }
        }
        assert_eq!(got.len(), 1, "only the complete frame decodes");
        assert!(matches!(got[0], Frame::Done { job: 9, .. }));
        // The truncated frame stays pending forever — Ok(None), not an
        // error, not a partial decode.
        assert!(reader.next().unwrap().is_none());
        // And the severed writer fails fast.
        assert!(a.write_all(b"more").is_err());
    }

    #[test]
    fn held_bytes_deliver_on_release_in_order() {
        let (mut a, mut b) = pair(5);
        write_frame(&mut a, &Frame::Ping { nonce: 1 }).unwrap();
        a.hold();
        write_frame(&mut a, &Frame::Ping { nonce: 2 }).unwrap();
        write_frame(&mut a, &Frame::Ping { nonce: 3 }).unwrap();
        assert!(a.held_len() > 0);

        let mut reader = FrameReader::new();
        let got = drain(&mut b, &mut reader);
        assert_eq!(got, vec![Frame::Ping { nonce: 1 }], "held frames invisible");

        a.release().unwrap();
        let got = drain(&mut b, &mut reader);
        assert_eq!(
            got,
            vec![Frame::Ping { nonce: 2 }, Frame::Ping { nonce: 3 }],
            "release delivers in write order"
        );
    }

    #[test]
    fn clones_share_the_link_like_a_cloned_socket() {
        let (a, mut b) = pair(9);
        let mut a2 = Transport::try_clone(&a).unwrap();
        write_frame(&mut *a2, &Frame::Gone).unwrap();
        let mut reader = FrameReader::new();
        let got = drain(&mut b, &mut reader);
        assert_eq!(got, vec![Frame::Gone]);
        // Severing the original severs the clone's link too.
        a.sever();
        let mut buf = [0u8; 8];
        assert_eq!(b.read(&mut buf).unwrap(), 0);
        assert!(a2.write_all(b"x").is_err());
    }
}
