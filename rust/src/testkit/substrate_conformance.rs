//! Substrate conformance suite — the executable contract of
//! [`crate::substrate::Substrate`].
//!
//! Three very different runtimes implement the trait (the simulated
//! cluster / `MockSubstrate`, the thread pool, the process supervisor),
//! and the orchestrator is only correct if all of them agree on the
//! lifecycle semantics. This harness asserts the load-bearing parts
//! against any implementation:
//!
//! * **lifecycle ordering** — provision starts live-but-pending, exactly
//!   one `ReplicaReady` (with a non-negative measured cold start) before
//!   any terminal event, then Ready state and membership in
//!   `ready_replicas`.
//! * **poll idempotence** — polls at a steady state return no events;
//!   every transition is edge-triggered exactly once.
//! * **graceful terminate** — ends in exactly one `ReplicaGone`, after
//!   which the replica has no state and no further events.
//! * **terminate during Loading** — must still reach a single terminal
//!   event (an in-flight warm-up may surface at most one `ReplicaReady`
//!   first), never a Ready-after-terminal.
//! * **fail → event** — `fail` surfaces `ReplicaFailed` either
//!   synchronously (the simulator observes the death) or through `poll`
//!   (live substrates observe it at the next heartbeat/EOF); callers
//!   must get exactly one of the two.
//!
//! Time is abstracted behind `clock`: the mock advances virtual seconds,
//! the live substrates sleep a few milliseconds of wall clock per call —
//! the assertions are identical.

use crate::models::{BackendKind, ModelSpec};
use crate::registry::ServiceId;
use crate::substrate::{ReplicaId, ReplicaState, Substrate, SubstrateEvent};

/// One substrate under test plus the environment it needs.
pub struct Driver<'a> {
    pub substrate: &'a mut dyn Substrate,
    /// Service to provision (the substrate must have capacity for at
    /// least one replica of it at a time).
    pub service: ServiceId,
    pub model_idx: usize,
    pub spec: ModelSpec,
    pub backend: BackendKind,
    /// Advance time and return "now" in substrate seconds. Virtual
    /// substrates step their clock; live ones sleep briefly.
    pub clock: Box<dyn FnMut() -> f64 + 'a>,
    /// Budget (in `clock` seconds) for any single transition.
    pub timeout_s: f64,
}

fn replica_of(ev: &SubstrateEvent) -> ReplicaId {
    match ev {
        SubstrateEvent::ReplicaReady { replica, .. }
        | SubstrateEvent::ReplicaGone { replica, .. }
        | SubstrateEvent::ReplicaFailed { replica, .. } => *replica,
    }
}

fn poll_for(d: &mut Driver, id: ReplicaId) -> Vec<SubstrateEvent> {
    let now = (d.clock)();
    d.substrate
        .poll(now)
        .into_iter()
        .filter(|e| replica_of(e) == id)
        .collect()
}

/// Steady states emit nothing: polling must be idempotent.
fn assert_quiet(d: &mut Driver, id: ReplicaId, stage: &str) {
    for _ in 0..3 {
        let evs = poll_for(d, id);
        assert!(
            evs.is_empty(),
            "poll must be idempotent after {stage}, got {evs:?}"
        );
    }
}

fn provision(d: &mut Driver) -> ReplicaId {
    let now = (d.clock)();
    let spec = d.spec.clone();
    let id = d
        .substrate
        .provision(d.service, d.model_idx, &spec, d.backend, now)
        .expect("provision must succeed while capacity remains");
    let st = d
        .substrate
        .replica_state(id)
        .expect("a provisioned replica must report a state");
    assert!(st.is_live(), "fresh replica must be live, got {st:?}");
    assert!(
        d.substrate.pending_replicas(d.service) >= 1
            || d.substrate.ready_replicas(d.service).contains(&id),
        "a provisioned replica must count as pending until Ready"
    );
    id
}

/// Wait for `ReplicaReady`, asserting it arrives exactly once and before
/// any terminal event. Returns the reported cold start.
fn wait_ready(d: &mut Driver, id: ReplicaId) -> f64 {
    let start = (d.clock)();
    let mut cold = None;
    loop {
        for ev in poll_for(d, id) {
            match ev {
                SubstrateEvent::ReplicaReady { cold_start_s, .. } => {
                    assert!(
                        cold.is_none(),
                        "ReplicaReady must be emitted exactly once"
                    );
                    assert!(
                        cold_start_s >= 0.0,
                        "cold start must be non-negative, got {cold_start_s}"
                    );
                    cold = Some(cold_start_s);
                }
                ev => panic!("unexpected event before Ready: {ev:?}"),
            }
        }
        if let Some(c) = cold {
            assert_eq!(
                d.substrate.replica_state(id),
                Some(ReplicaState::Ready),
                "state must read Ready after the Ready event"
            );
            assert!(
                d.substrate.ready_replicas(d.service).contains(&id),
                "a Ready replica must be listed in ready_replicas"
            );
            return c;
        }
        let now = (d.clock)();
        assert!(
            now - start < d.timeout_s,
            "replica never became Ready within {}s",
            d.timeout_s
        );
    }
}

enum Terminal {
    Gone,
    Failed,
}

/// Wait for a single terminal event. An in-flight warm-up may surface at
/// most one `ReplicaReady` first (terminate-during-Loading); nothing may
/// follow the terminal event.
fn wait_terminal(d: &mut Driver, id: ReplicaId, allow_ready_first: bool) -> Terminal {
    let start = (d.clock)();
    let mut readys = 0usize;
    loop {
        for ev in poll_for(d, id) {
            match ev {
                SubstrateEvent::ReplicaGone { .. } => return Terminal::Gone,
                SubstrateEvent::ReplicaFailed { .. } => return Terminal::Failed,
                SubstrateEvent::ReplicaReady { .. } => {
                    readys += 1;
                    assert!(
                        allow_ready_first && readys == 1,
                        "unexpected ReplicaReady while terminating"
                    );
                }
            }
        }
        let now = (d.clock)();
        assert!(
            now - start < d.timeout_s,
            "replica never reached a terminal state within {}s",
            d.timeout_s
        );
    }
}

fn assert_removed(d: &mut Driver, id: ReplicaId, stage: &str) {
    let st = d.substrate.replica_state(id);
    assert!(
        st.is_none() || st == Some(ReplicaState::Failed),
        "{stage}: terminal replica must have no live state, got {st:?}"
    );
    assert!(
        !d.substrate.ready_replicas(d.service).contains(&id),
        "{stage}: terminal replica must leave ready_replicas"
    );
    assert_quiet(d, id, stage);
}

/// The full conformance suite. Panics with a scenario-specific message
/// on any contract violation.
pub fn check(d: &mut Driver) {
    lifecycle(d);
    terminate_during_loading(d);
    fail_surfaces_event(d);
    estimate_is_positive(d);
}

/// provision → Ready (once, cold start measured) → terminate → Gone
/// (once), with idempotent polls at both steady states.
fn lifecycle(d: &mut Driver) {
    let id = provision(d);
    let _cold = wait_ready(d, id);
    assert_quiet(d, id, "Ready");
    let now = (d.clock)();
    d.substrate.terminate(id, now);
    match wait_terminal(d, id, true) {
        Terminal::Gone => {}
        Terminal::Failed => panic!("graceful terminate must end in ReplicaGone"),
    }
    assert_removed(d, id, "terminate");
}

/// terminate fired while the replica is still warming up: still exactly
/// one terminal event, never Ready-after-terminal.
fn terminate_during_loading(d: &mut Driver) {
    let id = provision(d);
    let now = (d.clock)();
    d.substrate.terminate(id, now);
    // Gone is the expected outcome; Failed is tolerated (a warm-up that
    // cannot be interrupted may be torn down hard), but either way the
    // replica must be fully removed and quiet.
    let _ = wait_terminal(d, id, true);
    assert_removed(d, id, "terminate during Loading");
}

/// fail() yields exactly one ReplicaFailed — synchronously (sim) or via
/// poll (live substrates observe the death asynchronously).
fn fail_surfaces_event(d: &mut Driver) {
    let id = provision(d);
    wait_ready(d, id);
    let now = (d.clock)();
    match d.substrate.fail(id, now) {
        Some(ev) => {
            assert!(
                matches!(ev, SubstrateEvent::ReplicaFailed { replica, .. } if replica == id),
                "synchronous fail must return ReplicaFailed for the victim, got {ev:?}"
            );
        }
        None => match wait_terminal(d, id, false) {
            Terminal::Failed => {}
            Terminal::Gone => {
                panic!("fail() must surface ReplicaFailed, not ReplicaGone")
            }
        },
    }
    assert_removed(d, id, "fail");
}

/// Cold-start estimates feed Alg. 2 as latency penalties — they must be
/// finite and positive even before any replica has been measured.
fn estimate_is_positive(d: &mut Driver) {
    let est = d.substrate.estimate_cold_start_s(&d.spec, d.backend);
    assert!(
        est.is_finite() && est > 0.0,
        "cold-start estimate must be positive, got {est}"
    );
}

// ---------------------------------------------------------------------------
// Node-level contract (multi-host substrates)
// ---------------------------------------------------------------------------

/// A node-placing substrate under test: the base [`Driver`] plus the
/// node-plane introspection the contract needs. The closures observe the
/// shared node registry (not the substrate, which the base driver
/// mutably borrows); `sever` cuts one node's control link by whatever
/// means the harness has — SIGKILLing a real agent process, severing a
/// chaos transport.
pub struct NodeDriver<'a> {
    pub base: Driver<'a>,
    /// Registered node names, in registration order. The contract needs
    /// at least two.
    pub node_names: Vec<String>,
    /// Replicas currently hosted on the named node.
    pub hosted_on: Box<dyn Fn(&str) -> usize + 'a>,
    /// Is the named node registered and alive?
    pub alive: Box<dyn Fn(&str) -> bool + 'a>,
    /// Kill the named node's agent / sever its control link.
    pub sever: Box<dyn FnMut(&str) + 'a>,
}

/// Node-level conformance: registration feeds placement, placement
/// spreads, and a lost node fails exactly its own replicas — each
/// surfacing the same single `ReplicaFailed` an individual worker death
/// does — while replacements land on the survivors.
pub fn check_nodes(d: &mut NodeDriver) {
    assert!(
        d.node_names.len() >= 2,
        "node conformance needs two registered nodes, got {:?}",
        d.node_names
    );

    // Registration → placement, and spread: two replicas of one tier
    // must land on different nodes when both have free slots.
    let a = provision(&mut d.base);
    let _ = wait_ready(&mut d.base, a);
    let b = provision(&mut d.base);
    let _ = wait_ready(&mut d.base, b);
    for n in &d.node_names[..2] {
        assert_eq!(
            (d.hosted_on)(n.as_str()),
            1,
            "spread placement must put one replica on node `{n}`"
        );
    }

    // Node link severed: the victim node's replica fails (exactly one
    // failure), the other node's replica keeps serving.
    let victim = d.node_names[0].clone();
    (d.sever)(victim.as_str());
    let start = (d.base.clock)();
    while (d.alive)(victim.as_str()) {
        let now = (d.base.clock)();
        assert!(
            now - start < d.base.timeout_s,
            "severed node `{victim}` never read as lost"
        );
    }
    let failed = wait_one_failure(&mut d.base, &[a, b]);
    let survivor = if failed == a { b } else { a };
    assert_eq!(
        d.base.substrate.replica_state(survivor),
        Some(ReplicaState::Ready),
        "a replica on a surviving node must keep serving through a node loss"
    );
    assert!(
        d.base.substrate.ready_replicas(d.base.service).contains(&survivor),
        "survivor must stay in ready_replicas"
    );
    assert_removed(&mut d.base, failed, "node loss");

    // Re-provision: the replacement must place on the surviving node
    // (the lost one no longer takes replicas).
    let c = provision(&mut d.base);
    let _ = wait_ready(&mut d.base, c);
    assert_eq!(
        (d.hosted_on)(d.node_names[1].as_str()),
        2,
        "replacement must land on the surviving node"
    );
    assert_eq!(
        (d.hosted_on)(victim.as_str()),
        0,
        "a lost node must not be placed on (its replicas released)"
    );

    // Cleanup through the normal lifecycle.
    for id in [survivor, c] {
        let now = (d.base.clock)();
        d.base.substrate.terminate(id, now);
        match wait_terminal(&mut d.base, id, true) {
            Terminal::Gone => {}
            Terminal::Failed => panic!("graceful terminate must end in ReplicaGone"),
        }
        assert_removed(&mut d.base, id, "node-case cleanup");
    }
}

/// Wait until exactly one of `ids` fails; no Gone, no spurious extra
/// events for the watched set.
fn wait_one_failure(d: &mut Driver, ids: &[ReplicaId]) -> ReplicaId {
    let start = (d.clock)();
    loop {
        let now = (d.clock)();
        let evs: Vec<SubstrateEvent> = d
            .substrate
            .poll(now)
            .into_iter()
            .filter(|e| ids.contains(&replica_of(e)))
            .collect();
        for ev in evs {
            match ev {
                SubstrateEvent::ReplicaFailed { replica, .. } => return replica,
                ev => panic!("expected one ReplicaFailed after node loss, got {ev:?}"),
            }
        }
        assert!(
            now - start < d.timeout_s,
            "node loss never surfaced a ReplicaFailed within {}s",
            d.timeout_s
        );
    }
}
