//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Deterministic, seed-sweeping property checks with input reporting on
//! failure. Used by the property test suite for coordinator invariants
//! (routing, batching, KV accounting, scaling).
//!
//! ```no_run
//! use pick_and_spin::testkit::{check, Gen};
//! check("reverse twice is identity", 100, |g| {
//!     let v: Vec<u32> = g.vec(0..50, |g| g.u32(0..1000));
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```

use std::ops::Range;
use std::time::{Duration, Instant};

use crate::util::rng::SplitMix64;

pub mod chaos;
pub mod substrate_conformance;

/// Bounded polling for asynchronous state: evaluate `cond` every couple
/// of milliseconds until it holds or `timeout` elapses. Returns whether
/// it held. The replacement for sleep-then-assert in timing-sensitive
/// tests — the wait ends the moment the state cell flips, and a slow CI
/// scheduler only stretches the wait, never fails the assertion.
pub fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        if cond() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Generator handle passed to properties.
pub struct Gen {
    rng: SplitMix64,
    /// Human-readable log of drawn values, shown on failure.
    pub trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), trace: Vec::new() }
    }

    pub fn u64(&mut self, range: Range<u64>) -> u64 {
        let v = range.start + self.rng.below(range.end - range.start);
        self.trace.push(format!("u64 {v}"));
        v
    }

    pub fn u32(&mut self, range: Range<u32>) -> u32 {
        self.u64(range.start as u64..range.end as u64) as u32
    }

    pub fn usize(&mut self, range: Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    pub fn f64(&mut self, range: Range<f64>) -> f64 {
        let v = self.rng.range(range.start, range.end);
        self.trace.push(format!("f64 {v:.6}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("bool {v}"));
        v
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T
    where
        T: std::fmt::Debug,
    {
        let idx = self.rng.below(items.len() as u64) as usize;
        self.trace.push(format!("pick[{idx}] {:?}", items[idx]));
        &items[idx]
    }

    pub fn vec<T>(
        &mut self,
        len: Range<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// ASCII-ish text of bounded length (prompt-like inputs).
    pub fn text(&mut self, max_words: usize) -> String {
        const WORDS: &[&str] = &[
            "prove", "sum", "list", "define", "derive", "explain", "why",
            "what", "is", "the", "a", "function", "of", "number", "step",
            "by", "how", "many", "apples", "123", "x",
        ];
        let n = self.usize(0..max_words + 1);
        let s = (0..n)
            .map(|_| *self.rng.choose(WORDS))
            .collect::<Vec<_>>()
            .join(" ");
        self.trace.push(format!("text {s:?}"));
        s
    }

    /// Raw RNG access for custom draws.
    pub fn rng(&mut self) -> &mut SplitMix64 {
        &mut self.rng
    }
}

/// Run `prop` against `cases` deterministic seeds; panics with the seed
/// and drawn-value trace of the first failing case.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed ^ 0x5EED);
            prop(&mut g);
            g
        });
        if let Err(err) = result {
            // Re-run to capture the trace (prop may have partially logged).
            let mut g = Gen::new(seed ^ 0x5EED);
            let trace = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut g);
            }))
            .err()
            .map(|_| g.trace.join("\n  "))
            .unwrap_or_default();
            panic!(
                "property `{name}` failed at seed {seed}\n  drawn:\n  {trace}\n  panic: {:?}",
                err.downcast_ref::<String>()
                    .cloned()
                    .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("add commutes", 50, |g| {
            let a = g.u32(0..1000);
            let b = g.u32(0..1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_reports_seed() {
        check("always fails", 5, |g| {
            let x = g.u32(0..10);
            assert!(x > 100, "x was {x}");
        });
    }

    #[test]
    fn generators_deterministic_per_seed() {
        let mut a = Gen::new(1);
        let mut b = Gen::new(1);
        assert_eq!(a.u64(0..1000), b.u64(0..1000));
        assert_eq!(a.text(5), b.text(5));
    }

    #[test]
    fn vec_respects_bounds() {
        let mut g = Gen::new(2);
        for _ in 0..100 {
            let v = g.vec(2..5, |g| g.u32(0..10));
            assert!(v.len() >= 2 && v.len() < 5);
        }
    }

    #[test]
    fn wait_until_returns_on_condition_and_timeout() {
        assert!(wait_until(Duration::from_secs(1), || true));
        let t0 = Instant::now();
        assert!(!wait_until(Duration::from_millis(10), || false));
        assert!(t0.elapsed() >= Duration::from_millis(10));
        let mut calls = 0;
        assert!(wait_until(Duration::from_secs(5), || {
            calls += 1;
            calls >= 3
        }));
    }
}
