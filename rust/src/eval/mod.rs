//! Evaluation: metrics and report generation for every table and figure
//! in the paper (the bench harnesses call these and print).

use std::collections::BTreeMap;

use crate::sim::{RequestRecord, SimReport};
use crate::util::format_table;
use crate::util::stats::{eq10_scale, mean, Summary};

/// Per-benchmark aggregate.
#[derive(Debug, Clone, Default)]
pub struct BenchAgg {
    pub runs: usize,
    pub successes: usize,
    pub latencies: Vec<f64>,
    pub ttfts: Vec<f64>,
}

impl BenchAgg {
    pub fn success_rate(&self) -> f64 {
        if self.runs == 0 {
            0.0
        } else {
            self.successes as f64 / self.runs as f64
        }
    }
}

/// Group records by benchmark.
pub fn per_benchmark(records: &[RequestRecord]) -> BTreeMap<String, BenchAgg> {
    let mut out: BTreeMap<String, BenchAgg> = BTreeMap::new();
    for r in records {
        let e = out.entry(r.benchmark.clone()).or_default();
        e.runs += 1;
        if r.success {
            e.successes += 1;
        }
        e.latencies.push(r.latency_s);
        e.ttfts.push(r.ttft_s);
    }
    out
}

/// Table 1 — baseline completion per benchmark.
pub fn table1(report: &SimReport, paper_rates: &[(&str, f64)]) -> String {
    let agg = per_benchmark(&report.records);
    let mut rows = Vec::new();
    let (mut truns, mut tsucc) = (0usize, 0usize);
    for (name, a) in &agg {
        let paper = paper_rates
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, r)| format!("{:.1}", r * 100.0))
            .unwrap_or_else(|| "-".into());
        rows.push(vec![
            name.clone(),
            a.runs.to_string(),
            a.successes.to_string(),
            (a.runs - a.successes).to_string(),
            format!("{:.1}", a.success_rate() * 100.0),
            paper,
        ]);
        truns += a.runs;
        tsucc += a.successes;
    }
    rows.push(vec![
        "TOTAL".into(),
        truns.to_string(),
        tsucc.to_string(),
        (truns - tsucc).to_string(),
        format!("{:.1}", 100.0 * tsucc as f64 / truns.max(1) as f64),
        "77.1".into(),
    ]);
    format_table(
        &["Benchmark", "Runs", "Success", "Failures", "Success (%)", "Paper (%)"],
        &rows,
    )
}

/// Table 2 — routing strategy comparison. Values are deltas vs the
/// unrouted baseline, as the paper reports them.
pub struct RoutingRow {
    pub strategy: String,
    pub accuracy_gain_pct: f64,
    pub latency_reduction_pct: f64,
    pub gpu_util_pct: f64,
}

pub fn routing_row(name: &str, routed: &SimReport, baseline: &SimReport) -> RoutingRow {
    let acc_gain =
        (routed.success_rate() - baseline.success_rate()) * 100.0;
    let lat_red = if baseline.mean_latency_s() > 0.0 {
        (1.0 - routed.mean_latency_s() / baseline.mean_latency_s()) * 100.0
    } else {
        0.0
    };
    RoutingRow {
        strategy: name.to_string(),
        accuracy_gain_pct: acc_gain,
        latency_reduction_pct: lat_red,
        gpu_util_pct: routed.gpu_utilization() * 100.0,
    }
}

pub fn table2(rows: &[RoutingRow]) -> String {
    format_table(
        &["Strategy", "Accuracy (%+)", "Latency (%↓)", "GPU Util. (%)"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.strategy.clone(),
                    format!("{:.1}", r.accuracy_gain_pct),
                    format!("{:.1}", r.latency_reduction_pct),
                    format!("{:.1}", r.gpu_util_pct),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

/// Table 3 — selection strategies.
pub fn table3(rows: &[(&str, &SimReport)]) -> String {
    let base_acc = rows
        .first()
        .map(|(_, r)| r.success_rate())
        .unwrap_or(0.0);
    format_table(
        &["Selection Strategy", "Accuracy (%)", "Latency (s)", "Cost (USD)", "Gain (%)"],
        &rows
            .iter()
            .map(|(name, r)| {
                let gain = (r.success_rate() - base_acc) * 100.0;
                vec![
                    name.to_string(),
                    format!("{:.1}", r.success_rate() * 100.0),
                    format!("{:.1}", r.mean_latency_s()),
                    format!("{:.4}", mean(&r.records.iter().map(|x| x.cost_usd).collect::<Vec<_>>())),
                    if gain.abs() < 1e-9 {
                        "-".into()
                    } else {
                        format!("{gain:+.1}")
                    },
                ]
            })
            .collect::<Vec<_>>(),
    )
}

/// Table 4 — cost & recovery per deployment configuration.
pub fn table4(rows: &[(&str, &SimReport)]) -> String {
    format_table(
        &["Configuration", "Cost / Query (USD)", "Recovery (s)"],
        &rows
            .iter()
            .map(|(name, r)| {
                vec![
                    name.to_string(),
                    format!("{:.4}", r.cost_per_query_usd()),
                    r.mean_recovery_s
                        .map(|s| format!("{s:.1}"))
                        .unwrap_or_else(|| "-".into()),
                ]
            })
            .collect::<Vec<_>>(),
    )
}

/// Eq. 9 — routing efficiency η = (A_r/A_b) / (C_r/C_b).
///
/// Costs are the *marginal inference* cost per query (the paper's
/// "corresponding inference costs"), not the amortized fleet cost —
/// fleet idle time is Table 4's metric.
pub fn eta(routed: &SimReport, baseline: &SimReport) -> f64 {
    let a = routed.success_rate() / baseline.success_rate().max(1e-9);
    let rc = mean(&routed.records.iter().map(|r| r.cost_usd).collect::<Vec<_>>());
    let bc = mean(&baseline.records.iter().map(|r| r.cost_usd).collect::<Vec<_>>());
    let c = rc / bc.max(1e-12);
    a / c.max(1e-9)
}

/// Fig. 4 — complexity distribution histogram per router.
pub fn complexity_distribution(records: &[RequestRecord]) -> [usize; 3] {
    let mut dist = [0usize; 3];
    for r in records {
        dist[r.predicted_complexity.min(2)] += 1;
    }
    dist
}

/// Fig. 5/6 — per-benchmark success and latency for one router.
pub fn per_benchmark_rows(report: &SimReport) -> Vec<(String, f64, f64)> {
    per_benchmark(&report.records)
        .into_iter()
        .map(|(name, a)| (name, a.success_rate() * 100.0, mean(&a.latencies)))
        .collect()
}

/// Fig. 9 — the five normalized dimensions (Eq. 10) for a set of systems.
/// Dimensions: accuracy, latency (inverted), scalability (throughput),
/// utilization, robustness (success under failures).
pub fn radar(rows: &[(&str, &SimReport)]) -> Vec<(String, Vec<f64>)> {
    let acc: Vec<f64> = rows.iter().map(|(_, r)| r.success_rate()).collect();
    let lat: Vec<f64> = rows.iter().map(|(_, r)| -r.mean_latency_s()).collect();
    let thr: Vec<f64> = rows.iter().map(|(_, r)| r.throughput_qps()).collect();
    let util: Vec<f64> = rows.iter().map(|(_, r)| r.gpu_utilization()).collect();
    let rob: Vec<f64> = rows
        .iter()
        .map(|(_, r)| {
            // robustness: success weighted by tail latency control
            let s = Summary::of(
                &r.records.iter().map(|x| x.latency_s).collect::<Vec<_>>(),
            );
            r.success_rate() / (1.0 + s.p99 / s.p50.max(1e-9))
        })
        .collect();
    let dims = [acc, lat, thr, util, rob].map(|v| eq10_scale(&v));
    rows.iter()
        .enumerate()
        .map(|(i, (name, _))| {
            (name.to_string(), dims.iter().map(|d| d[i]).collect())
        })
        .collect()
}

/// Fig. 10/11 — TTFT summary per router.
pub fn ttft_summary(report: &SimReport) -> Summary {
    Summary::of(&report.records.iter().map(|r| r.ttft_s).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::BackendKind;

    fn record(bench: &str, success: bool, lat: f64) -> RequestRecord {
        RequestRecord {
            benchmark: bench.into(),
            true_complexity: 1,
            predicted_complexity: 1,
            model: "gemma3-27b",
            backend: BackendKind::Vllm,
            success,
            latency_s: lat,
            ttft_s: lat / 4.0,
            wait_s: 0.1,
            router_overhead_s: 0.0,
            cost_usd: 0.01,
            in_tokens: 60,
            prefix_cached_tokens: 0,
            spans: Vec::new(),
        }
    }

    fn report(records: Vec<RequestRecord>) -> SimReport {
        SimReport {
            records,
            duration_s: 100.0,
            gpu_seconds_held: 1000.0,
            gpu_seconds_busy: 600.0,
            system_cost_usd: 0.69,
            mean_recovery_s: Some(10.0),
            n_failures_injected: 2,
            n_shed: 0,
            semantic_refinement_rate: 0.4,
            bandit_arms: Vec::new(),
        }
    }

    #[test]
    fn per_benchmark_groups() {
        let recs = vec![
            record("arc", true, 1.0),
            record("arc", false, 2.0),
            record("math", true, 3.0),
        ];
        let agg = per_benchmark(&recs);
        assert_eq!(agg["arc"].runs, 2);
        assert_eq!(agg["arc"].successes, 1);
        assert!((agg["arc"].success_rate() - 0.5).abs() < 1e-12);
        assert_eq!(agg["math"].runs, 1);
    }

    #[test]
    fn table1_formats_with_total() {
        let rep = report(vec![record("arc", true, 1.0), record("arc", false, 2.0)]);
        let t = table1(&rep, &[("arc", 0.803)]);
        assert!(t.contains("arc"));
        assert!(t.contains("TOTAL"));
        assert!(t.contains("50.0"));
        assert!(t.contains("80.3"));
    }

    #[test]
    fn eta_matches_formula() {
        let routed = report(vec![record("arc", true, 1.0); 9]
            .into_iter()
            .chain(vec![record("arc", false, 1.0); 1])
            .collect());
        let base = report(vec![record("arc", true, 1.0); 7]
            .into_iter()
            .chain(vec![record("arc", false, 1.0); 3])
            .collect());
        // Same cost/query → η = accuracy ratio = 0.9/0.7
        let e = eta(&routed, &base);
        assert!((e - 0.9 / 0.7).abs() < 1e-9);
    }

    #[test]
    fn radar_scales_to_ten() {
        let a = report(vec![record("arc", true, 1.0); 10]);
        let b = report(vec![record("arc", false, 5.0); 10]);
        let rows = radar(&[("A", &a), ("B", &b)]);
        assert_eq!(rows.len(), 2);
        for (_, dims) in &rows {
            assert_eq!(dims.len(), 5);
            for d in dims {
                assert!((0.0..=10.0).contains(d));
            }
        }
        // A dominates on accuracy (dim 0).
        assert!(rows[0].1[0] > rows[1].1[0]);
    }

    #[test]
    fn routing_row_computes_deltas() {
        let routed = report(vec![record("arc", true, 1.0); 10]);
        let base = report(
            vec![record("arc", true, 2.0); 8]
                .into_iter()
                .chain(vec![record("arc", false, 2.0); 2])
                .collect(),
        );
        let row = routing_row("keyword", &routed, &base);
        assert!((row.accuracy_gain_pct - 20.0).abs() < 1e-9);
        assert!((row.latency_reduction_pct - 50.0).abs() < 1e-9);
    }

    #[test]
    fn complexity_distribution_counts() {
        let mut recs = vec![record("arc", true, 1.0); 3];
        recs[0].predicted_complexity = 0;
        recs[1].predicted_complexity = 2;
        let d = complexity_distribution(&recs);
        assert_eq!(d, [1, 1, 1]);
    }
}
