//! Workload generation — synthetic versions of the paper's eight
//! benchmarks, plus arrival processes and trace replay.
//!
//! Templates come from `data/templates.json` (the single source shared
//! with the Python training corpus), so the compiled classifier sees the
//! same prompt families at serve time that it was trained on — exactly
//! the generalization the paper's DistilBERT router relies on.

use anyhow::{anyhow, Result};

use crate::backend::InferenceRequest;
use crate::models::completion::mean_output_tokens;
use crate::router::Classifier;
use crate::tokenizer;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// One prompt template.
#[derive(Debug, Clone)]
pub struct Template {
    pub complexity: usize,
    pub text: String,
}

/// One benchmark's generator data.
#[derive(Debug, Clone)]
pub struct Benchmark {
    pub name: String,
    pub runs: usize,
    pub baseline_success: usize,
    pub unique_prompts: usize,
    pub templates: Vec<Template>,
}

impl Benchmark {
    /// Complexity mix over this benchmark's templates (uniform template
    /// choice, matching the generator).
    pub fn complexity_mix(&self) -> [f64; 3] {
        let mut mix = [0.0; 3];
        for t in &self.templates {
            mix[t.complexity] += 1.0;
        }
        let total: f64 = mix.iter().sum();
        mix.map(|m| m / total)
    }
}

/// The template library.
#[derive(Debug, Clone)]
pub struct TemplateLibrary {
    pub benchmarks: Vec<Benchmark>,
    pub slots: Vec<(String, Vec<String>)>,
}

impl TemplateLibrary {
    pub fn load(path: &str) -> Result<TemplateLibrary> {
        Self::parse(&Json::from_file(path)?)
    }

    pub fn parse(j: &Json) -> Result<TemplateLibrary> {
        let mut slots = Vec::new();
        for (name, vals) in j
            .req("slots")?
            .as_obj()
            .ok_or_else(|| anyhow!("slots not an object"))?
        {
            let items = vals
                .as_arr()
                .ok_or_else(|| anyhow!("slot {name} not an array"))?
                .iter()
                .map(|v| v.as_str().unwrap_or_default().to_string())
                .collect();
            slots.push((name.clone(), items));
        }
        let mut benchmarks = Vec::new();
        for b in j.rarr("benchmarks")? {
            benchmarks.push(Benchmark {
                name: b.rstr("name")?.to_string(),
                runs: b.rusize("runs")?,
                baseline_success: b.rusize("success")?,
                unique_prompts: b.rusize("unique_prompts")?,
                templates: b
                    .rarr("templates")?
                    .iter()
                    .map(|t| {
                        Ok(Template {
                            complexity: t.rusize("complexity")?,
                            text: t.rstr("text")?.to_string(),
                        })
                    })
                    .collect::<Result<_>>()?,
            });
        }
        Ok(TemplateLibrary { benchmarks, slots })
    }

    /// Built-in miniature library — two benchmarks spanning all three
    /// complexity classes. The stand-in when `data/templates.json`
    /// hasn't been built (`make artifacts`): the sim unit tests and the
    /// pinned CI routing bench run on it, so both exercise the same
    /// scenario.
    pub fn synthetic() -> TemplateLibrary {
        Self::parse(
            &Json::parse(
                r#"{
          "slots": {"n": ["3", "7"], "x": ["alpha", "beta"]},
          "benchmarks": [
            {"name": "arc", "runs": 500, "success": 400, "unique_prompts": 100,
             "templates": [
               {"complexity": 0, "text": "what is {n} plus {n}?"},
               {"complexity": 1, "text": "why does {x} happen faster?"}]},
            {"name": "math", "runs": 500, "success": 398, "unique_prompts": 100,
             "templates": [
               {"complexity": 2, "text": "prove that {x} is monotonic."},
               {"complexity": 1, "text": "solve for x: {n}x = {n}."}]}
          ],
          "profiles": ["baseline"]
        }"#,
            )
            .expect("builtin library JSON"),
        )
        .expect("builtin library")
    }

    pub fn benchmark(&self, name: &str) -> Result<&Benchmark> {
        self.benchmarks
            .iter()
            .find(|b| b.name == name)
            .ok_or_else(|| anyhow!("unknown benchmark `{name}`"))
    }

    fn slot(&self, name: &str) -> Option<&[String]> {
        self.slots
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_slice())
    }

    /// Fill a template's `{slot}` markers.
    pub fn fill(&self, template: &str, rng: &mut SplitMix64) -> String {
        let mut out = String::with_capacity(template.len());
        let mut rest = template;
        while let Some(start) = rest.find('{') {
            out.push_str(&rest[..start]);
            let end = rest[start..].find('}').map(|e| start + e).unwrap_or(rest.len());
            let slot = &rest[start + 1..end];
            match self.slot(slot) {
                Some(items) if !items.is_empty() => {
                    out.push_str(&items[rng.below(items.len() as u64) as usize]);
                }
                _ => out.push_str(slot),
            }
            rest = &rest[(end + 1).min(rest.len())..];
        }
        out.push_str(rest);
        out
    }
}

/// A generated prompt with ground truth.
#[derive(Debug, Clone)]
pub struct Prompt {
    pub benchmark: String,
    pub text: String,
    pub complexity: usize,
}

/// Prompt generator over the library.
pub struct Generator<'a> {
    pub lib: &'a TemplateLibrary,
    rng: SplitMix64,
    /// Σ runs over all benchmarks, computed once (the mixed sampler
    /// draws against this total on every prompt).
    runs_total: usize,
}

impl<'a> Generator<'a> {
    pub fn new(lib: &'a TemplateLibrary, seed: u64) -> Self {
        let runs_total = lib.benchmarks.iter().map(|b| b.runs).sum();
        Self { lib, rng: SplitMix64::new(seed), runs_total }
    }

    /// One prompt from a specific benchmark.
    pub fn prompt_from(&mut self, bench: &Benchmark) -> Prompt {
        let t = &bench.templates[self.rng.below(bench.templates.len() as u64) as usize];
        Prompt {
            benchmark: bench.name.clone(),
            text: self.lib.fill(&t.text, &mut self.rng),
            complexity: t.complexity,
        }
    }

    /// One prompt from a benchmark chosen ∝ its Table-1 run count (the
    /// paper's evaluation mix). `lib` is a `&'a` reference independent of
    /// `self`'s borrow, so the chosen benchmark needs no clone.
    pub fn prompt_mixed(&mut self) -> Prompt {
        let lib = self.lib;
        let mut pick = self.rng.below(self.runs_total as u64) as usize;
        for b in &lib.benchmarks {
            if pick < b.runs {
                return self.prompt_from(b);
            }
            pick -= b.runs;
        }
        self.prompt_from(&lib.benchmarks[0])
    }

    /// Build a full [`InferenceRequest`] with token estimates.
    pub fn request(&mut self, id: u64, arrival_s: f64) -> InferenceRequest {
        let p = self.prompt_mixed();
        self.to_request(id, arrival_s, p)
    }

    pub fn to_request(&mut self, id: u64, arrival_s: f64, p: Prompt) -> InferenceRequest {
        let in_tokens = tokenizer::word_count(&p.text).max(1);
        let base = mean_output_tokens(&p.benchmark);
        // Output-length demand grows with complexity, with spread.
        let mean = base * (1.0 + 0.4 * p.complexity as f64);
        let out = self.rng.lognormal(mean.ln(), 0.35).round().max(1.0) as usize;
        InferenceRequest {
            id,
            prompt: p.text,
            benchmark: p.benchmark,
            true_complexity: p.complexity,
            in_tokens,
            max_new_tokens: out.min(512),
            arrival_s,
        }
    }

    pub fn rng(&mut self) -> &mut SplitMix64 {
        &mut self.rng
    }
}

/// Poisson arrival process at a fixed rate.
pub struct PoissonArrivals {
    rng: SplitMix64,
    rate: f64,
    t: f64,
}

impl PoissonArrivals {
    pub fn new(rate_qps: f64, seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), rate: rate_qps, t: 0.0 }
    }

    pub fn set_rate(&mut self, rate_qps: f64) {
        self.rate = rate_qps;
    }
}

impl Iterator for PoissonArrivals {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        self.t += self.rng.exp(self.rate);
        Some(self.t)
    }
}

/// Bursty arrivals: alternating high/low-rate phases (the fluctuating
/// demand the scale-to-zero experiments need).
pub struct BurstyArrivals {
    rng: SplitMix64,
    pub high_qps: f64,
    pub low_qps: f64,
    pub phase_s: f64,
    t: f64,
}

impl BurstyArrivals {
    pub fn new(high_qps: f64, low_qps: f64, phase_s: f64, seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), high_qps, low_qps, phase_s, t: 0.0 }
    }
}

impl Iterator for BurstyArrivals {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        let phase = (self.t / self.phase_s) as u64;
        let rate = if phase % 2 == 0 { self.high_qps } else { self.low_qps };
        self.t += self.rng.exp(rate.max(1e-9));
        Some(self.t)
    }
}

/// An oracle classifier for pure simulations/tests: returns the ground
/// truth complexity with a configurable error rate (the compiled
/// PJRT classifier is used whenever artifacts are available).
pub struct OracleClassifier {
    lib: TemplateLibrary,
    rng: SplitMix64,
    pub error_rate: f64,
}

impl OracleClassifier {
    pub fn new(lib: TemplateLibrary, error_rate: f64, seed: u64) -> Self {
        Self { lib, rng: SplitMix64::new(seed), error_rate }
    }

    /// Ground truth by re-matching the prompt against template families:
    /// find the template whose filled skeleton matches. Falls back to a
    /// lexical heuristic if no template matches (never happens for
    /// generator output).
    fn truth(&self, text: &str) -> usize {
        for b in &self.lib.benchmarks {
            for t in &b.templates {
                if skeleton_matches(&t.text, text) {
                    return t.complexity;
                }
            }
        }
        1
    }
}

impl Classifier for OracleClassifier {
    fn probs(&mut self, text: &str) -> Result<[f64; 3]> {
        let mut c = self.truth(text);
        if self.rng.chance(self.error_rate) {
            c = (c + 1 + self.rng.below(2) as usize) % 3;
        }
        let mut p = [0.02; 3];
        p[c] = 0.96;
        Ok(p)
    }
}

/// Does `text` match `template` with `{slot}`s treated as wildcards?
fn skeleton_matches(template: &str, text: &str) -> bool {
    // Split the template into literal segments around slots and check the
    // segments appear in order.
    let mut pos = 0usize;
    let mut rest = template;
    let mut first = true;
    while !rest.is_empty() {
        let (lit, after) = match rest.find('{') {
            Some(i) => {
                let lit = &rest[..i];
                let after = match rest[i..].find('}') {
                    Some(j) => &rest[i + j + 1..],
                    None => "",
                };
                (lit, after)
            }
            None => (rest, ""),
        };
        if !lit.is_empty() {
            match text[pos..].find(lit) {
                Some(i) => {
                    if first && i != 0 {
                        return false;
                    }
                    pos += i + lit.len();
                }
                None => return false,
            }
        }
        first = false;
        rest = after;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lib() -> TemplateLibrary {
        TemplateLibrary::parse(
            &Json::parse(
                r#"{
          "slots": {"x": ["alpha", "beta"], "n": ["3", "7"]},
          "benchmarks": [
            {"name": "easy", "runs": 100, "success": 80, "unique_prompts": 20,
             "templates": [{"complexity": 0, "text": "what is {n} plus {n}?"}]},
            {"name": "hard", "runs": 300, "success": 210, "unique_prompts": 60,
             "templates": [{"complexity": 2, "text": "prove that {x} is {x}."}]}
          ],
          "profiles": ["baseline"]
        }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn fill_substitutes_slots() {
        let l = lib();
        let mut rng = SplitMix64::new(0);
        let s = l.fill("what is {n} plus {n}?", &mut rng);
        assert!(!s.contains('{'));
        assert!(s.starts_with("what is "));
    }

    #[test]
    fn generator_is_deterministic() {
        let l = lib();
        let a: Vec<_> = {
            let mut g = Generator::new(&l, 42);
            (0..20).map(|_| g.prompt_mixed().text).collect()
        };
        let b: Vec<_> = {
            let mut g = Generator::new(&l, 42);
            (0..20).map(|_| g.prompt_mixed().text).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn mixed_sampling_respects_run_weights() {
        let l = lib();
        let mut g = Generator::new(&l, 7);
        let mut hard = 0;
        let n = 2000;
        for _ in 0..n {
            if g.prompt_mixed().benchmark == "hard" {
                hard += 1;
            }
        }
        let frac = hard as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.05, "hard frac {frac}");
    }

    #[test]
    fn mixed_sampling_matches_reference_draw_order() {
        // The clone-free sampler must consume the RNG stream exactly like
        // the spec: one weighted draw over Σruns, then prompt_from on the
        // selected benchmark.
        let l = lib();
        let mut g = Generator::new(&l, 1234);
        let mut rng = SplitMix64::new(1234);
        let total: u64 = l.benchmarks.iter().map(|b| b.runs as u64).sum();
        for _ in 0..200 {
            let got = g.prompt_mixed();
            let mut pick = rng.below(total) as usize;
            let mut bench = &l.benchmarks[0];
            for b in &l.benchmarks {
                if pick < b.runs {
                    bench = b;
                    break;
                }
                pick -= b.runs;
            }
            let t = &bench.templates[rng.below(bench.templates.len() as u64) as usize];
            let text = l.fill(&t.text, &mut rng);
            assert_eq!(got.benchmark, bench.name);
            assert_eq!(got.text, text);
            assert_eq!(got.complexity, t.complexity);
        }
    }

    #[test]
    fn poisson_mean_rate() {
        let mut arr = PoissonArrivals::new(10.0, 1);
        let times: Vec<f64> = arr.by_ref().take(5000).collect();
        let rate = 5000.0 / times.last().unwrap();
        assert!((rate - 10.0).abs() < 0.5, "rate {rate}");
    }

    #[test]
    fn bursty_alternates() {
        let mut arr = BurstyArrivals::new(100.0, 1.0, 10.0, 2);
        let times: Vec<f64> = arr.by_ref().take(2000).collect();
        // Count arrivals in the first high phase vs first low phase.
        let hi = times.iter().filter(|&&t| t < 10.0).count();
        let lo = times.iter().filter(|&&t| (10.0..20.0).contains(&t)).count();
        assert!(hi > lo * 10, "hi {hi} lo {lo}");
    }

    #[test]
    fn oracle_matches_ground_truth() {
        let l = lib();
        let mut g = Generator::new(&l, 3);
        let p1 = g.prompt_from(&l.benchmark("easy").unwrap().clone());
        let p2 = g.prompt_from(&l.benchmark("hard").unwrap().clone());
        let mut oracle = OracleClassifier::new(l.clone(), 0.0, 0);
        assert_eq!(oracle.classify(&p1.text).unwrap().0, 0);
        assert_eq!(oracle.classify(&p2.text).unwrap().0, 2);
    }

    #[test]
    fn requests_have_sane_token_counts() {
        let l = lib();
        let mut g = Generator::new(&l, 9);
        for i in 0..100 {
            let r = g.request(i, 0.0);
            assert!(r.in_tokens >= 1);
            assert!(r.max_new_tokens >= 1 && r.max_new_tokens <= 512);
        }
    }

    #[test]
    fn complexity_mix_sums_to_one() {
        let l = lib();
        for b in &l.benchmarks {
            let mix = b.complexity_mix();
            assert!((mix.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn real_templates_load() {
        // Uses the repo's data file when present (written by aot.py).
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/templates.json");
        if std::path::Path::new(path).exists() {
            let l = TemplateLibrary::load(path).unwrap();
            assert_eq!(l.benchmarks.len(), 8);
            let total: usize = l.benchmarks.iter().map(|b| b.runs).sum();
            assert_eq!(total, 155_095);
            let _ = crate::util::rng::fnv1a64(b"sanity");
        }
    }
}
