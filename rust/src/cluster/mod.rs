//! Cluster substrate — the simulated Kubernetes the Spin layer drives.
//!
//! The paper deploys on Kubernetes with Helm/Knative/KEDA; Algorithms 1
//! and 2 consume only replica counts, cold-start latencies, health, and
//! GPU occupancy. This module provides exactly those signals with a
//! faithful pod lifecycle:
//!
//! ```text
//! Scheduled → Pulling(image) → Loading(weights ← PVC) → Initializing
//!           → Ready → Terminating → (gone)         ↘ Failed
//! ```
//!
//! Cold-start latency decomposes the way real clusters do: image pull
//! (cold vs node-cached), weight load at PVC bandwidth (model size /
//! GB/s — the paper stores weights in PVCs for "persistence and fast
//! recovery"), then engine init (backend-dependent). Everything is
//! poll-driven on explicit timestamps so live and virtual time share the
//! code.

pub mod events;

use std::collections::BTreeMap;

use crate::config::ClusterConfig;
use crate::models::{BackendKind, ModelSpec};
use crate::registry::ServiceId;
use crate::substrate::Substrate;

// The simulated cluster speaks the unified substrate vocabulary: a pod
// is a replica, its lifecycle is `ReplicaState`, and `poll` emits
// `SubstrateEvent`s — the same types the live engine pool reports, so
// the orchestrator cannot tell the two apart.
pub use crate::substrate::{
    ReplicaId as PodId, ReplicaState as PodState, SubstrateEvent as ClusterEvent,
};

/// Node identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// A pod: one replica of a (model, backend) service.
#[derive(Debug, Clone)]
pub struct Pod {
    pub id: PodId,
    pub service: ServiceId,
    pub node: NodeId,
    pub gpus: usize,
    pub state: PodState,
    /// When the current state completes (state machine deadline).
    pub state_deadline_s: f64,
    pub created_s: f64,
    pub ready_s: Option<f64>,
}

/// One GPU node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub gpus_total: usize,
    pub gpus_free: usize,
    /// Images already pulled on this node (model indices).
    pub image_cache: Vec<usize>,
    /// Models whose weights are warm on this node (page cache / local
    /// PVC) — reloads run ~5× faster, the paper's "PVCs for persistence
    /// and fast recovery".
    pub weight_cache: Vec<usize>,
}

/// Speedup of a warm (locally cached) weight load vs a cold PVC read.
pub const WARM_WEIGHT_SPEEDUP: f64 = 5.0;

/// The simulated cluster.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub nodes: Vec<Node>,
    pub pods: BTreeMap<PodId, Pod>,
    /// Per-pod (weight-load, engine-init) stage durations.
    stage_durations: BTreeMap<PodId, (f64, f64)>,
    next_pod: u64,
    /// Integrated GPU-seconds held (cost basis).
    gpu_seconds: f64,
    last_account_s: f64,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Cluster {
        let nodes = (0..cfg.nodes)
            .map(|i| Node {
                id: NodeId(i),
                gpus_total: cfg.gpus_per_node,
                gpus_free: cfg.gpus_per_node,
                image_cache: Vec::new(),
                weight_cache: Vec::new(),
            })
            .collect();
        Cluster {
            cfg,
            nodes,
            pods: BTreeMap::new(),
            stage_durations: BTreeMap::new(),
            next_pod: 0,
            gpu_seconds: 0.0,
            last_account_s: 0.0,
        }
    }

    /// GPUs currently held by live pods.
    pub fn gpus_held(&self) -> usize {
        self.pods.values().map(|p| p.gpus).sum()
    }

    /// Total GPU capacity.
    pub fn gpus_total(&self) -> usize {
        self.nodes.iter().map(|n| n.gpus_total).sum()
    }

    /// Accrue GPU-seconds up to `now` (call before any state change).
    fn account(&mut self, now_s: f64) {
        if now_s > self.last_account_s {
            self.gpu_seconds +=
                self.gpus_held() as f64 * (now_s - self.last_account_s);
            self.last_account_s = now_s;
        }
    }

    /// Total GPU-seconds consumed through `now`.
    pub fn gpu_seconds(&self, now_s: f64) -> f64 {
        self.gpu_seconds
            + self.gpus_held() as f64 * (now_s - self.last_account_s).max(0.0)
    }

    /// Cold-start stage durations (pull, load, init) for a (model,
    /// backend) placed on `node`.
    pub fn cold_start_stages(
        &self,
        node: &Node,
        model_idx: usize,
        spec: &ModelSpec,
        backend: BackendKind,
    ) -> (f64, f64, f64) {
        let pull = if node.image_cache.contains(&model_idx) {
            self.cfg.image_pull_cached_s
        } else {
            self.cfg.image_pull_cold_s
        };
        let mut load = spec.weight_gb / self.cfg.pvc_bandwidth_gbps;
        if node.weight_cache.contains(&model_idx) {
            load /= WARM_WEIGHT_SPEEDUP;
        }
        let init = backend.engine_init_s();
        (pull, load, init)
    }

    /// Estimated total cold start for routing-time latency estimates
    /// (assumes a cached image, the steady-state case).
    pub fn estimate_cold_start_s(&self, spec: &ModelSpec, backend: BackendKind) -> f64 {
        self.cfg.image_pull_cached_s
            + spec.weight_gb / self.cfg.pvc_bandwidth_gbps
            + backend.engine_init_s()
    }

    /// Schedule one replica: tightest-fit bin packing (fewest free GPUs
    /// that still fit) to limit fragmentation. None if no capacity.
    pub fn schedule(
        &mut self,
        service: ServiceId,
        model_idx: usize,
        spec: &ModelSpec,
        backend: BackendKind,
        now_s: f64,
    ) -> Option<PodId> {
        self.account(now_s);
        let node_idx = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.gpus_free >= spec.gpus)
            .min_by_key(|(_, n)| n.gpus_free)?
            .0;
        let (pull, load, init) =
            self.cold_start_stages(&self.nodes[node_idx], model_idx, spec, backend);
        self.nodes[node_idx].gpus_free -= spec.gpus;
        if !self.nodes[node_idx].image_cache.contains(&model_idx) {
            self.nodes[node_idx].image_cache.push(model_idx);
        }
        if !self.nodes[node_idx].weight_cache.contains(&model_idx) {
            self.nodes[node_idx].weight_cache.push(model_idx);
        }
        let id = PodId(self.next_pod);
        self.next_pod += 1;
        self.pods.insert(id, Pod {
            id,
            service,
            node: NodeId(node_idx),
            gpus: spec.gpus,
            state: PodState::Pulling,
            state_deadline_s: now_s + pull,
            created_s: now_s,
            ready_s: None,
        });
        self.stage_durations.insert(id, (load, init));
        Some(id)
    }

    /// Begin graceful termination of a pod (2 s drain grace).
    pub fn terminate(&mut self, pod: PodId, now_s: f64) {
        self.account(now_s);
        if let Some(p) = self.pods.get_mut(&pod) {
            p.state = PodState::Terminating;
            p.state_deadline_s = now_s + 2.0;
        }
    }

    /// Kill a pod abruptly (failure injection for recovery experiments).
    pub fn fail(&mut self, pod: PodId, now_s: f64) -> Option<ClusterEvent> {
        self.account(now_s);
        let p = self.pods.get(&pod)?;
        let service = p.service;
        let node = p.node;
        let gpus = p.gpus;
        self.pods.remove(&pod);
        self.stage_durations.remove(&pod);
        self.nodes[node.0].gpus_free += gpus;
        Some(ClusterEvent::ReplicaFailed { replica: pod, service, at_s: now_s })
    }

    /// Advance pod state machines up to `now`; returns lifecycle events.
    pub fn poll(&mut self, now_s: f64) -> Vec<ClusterEvent> {
        self.account(now_s);
        let mut out = Vec::new();
        let ids: Vec<PodId> = self.pods.keys().copied().collect();
        for id in ids {
            loop {
                let Some(p) = self.pods.get_mut(&id) else { break };
                if p.state_deadline_s > now_s {
                    break;
                }
                match p.state {
                    // Scheduling is instantaneous in the sim (pods are
                    // created already Pulling); kept for exhaustiveness
                    // over the shared lifecycle.
                    PodState::Scheduled => {
                        p.state = PodState::Pulling;
                    }
                    PodState::Pulling => {
                        let (load, _) = self.stage_durations[&id];
                        p.state = PodState::Loading;
                        p.state_deadline_s += load;
                    }
                    PodState::Loading => {
                        let (_, init) = self.stage_durations[&id];
                        p.state = PodState::Initializing;
                        p.state_deadline_s += init;
                    }
                    PodState::Initializing => {
                        p.state = PodState::Ready;
                        let at = p.state_deadline_s;
                        p.ready_s = Some(at);
                        out.push(ClusterEvent::ReplicaReady {
                            replica: id,
                            service: p.service,
                            at_s: at,
                            cold_start_s: at - p.created_s,
                        });
                        p.state_deadline_s = f64::INFINITY;
                    }
                    PodState::Ready | PodState::Failed => break,
                    PodState::Terminating => {
                        let service = p.service;
                        let at = p.state_deadline_s;
                        let node = p.node;
                        let gpus = p.gpus;
                        self.pods.remove(&id);
                        self.stage_durations.remove(&id);
                        self.nodes[node.0].gpus_free += gpus;
                        out.push(ClusterEvent::ReplicaGone {
                            replica: id,
                            service,
                            at_s: at,
                        });
                        break;
                    }
                }
            }
        }
        out
    }

    /// Ready pods of a service.
    pub fn ready_pods(&self, service: ServiceId) -> Vec<PodId> {
        self.pods
            .values()
            .filter(|p| p.service == service && p.state == PodState::Ready)
            .map(|p| p.id)
            .collect()
    }

    /// Pods of a service in any pre-Ready state.
    pub fn pending_pods(&self, service: ServiceId) -> usize {
        self.pods
            .values()
            .filter(|p| {
                p.service == service
                    && matches!(
                        p.state,
                        PodState::Pulling | PodState::Loading | PodState::Initializing
                    )
            })
            .count()
    }

    /// Next state-machine deadline (for the sim driver's event horizon).
    pub fn next_deadline_s(&self) -> Option<f64> {
        self.pods
            .values()
            .map(|p| p.state_deadline_s)
            .filter(|d| d.is_finite())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

impl Substrate for Cluster {
    fn provision(
        &mut self,
        service: ServiceId,
        model_idx: usize,
        spec: &ModelSpec,
        backend: BackendKind,
        now_s: f64,
    ) -> Option<PodId> {
        self.schedule(service, model_idx, spec, backend, now_s)
    }

    fn terminate(&mut self, replica: PodId, now_s: f64) {
        Cluster::terminate(self, replica, now_s);
    }

    fn fail(&mut self, replica: PodId, now_s: f64) -> Option<ClusterEvent> {
        Cluster::fail(self, replica, now_s)
    }

    fn poll(&mut self, now_s: f64) -> Vec<ClusterEvent> {
        Cluster::poll(self, now_s)
    }

    fn replica_state(&self, replica: PodId) -> Option<PodState> {
        self.pods.get(&replica).map(|p| p.state)
    }

    fn ready_replicas(&self, service: ServiceId) -> Vec<PodId> {
        self.ready_pods(service)
    }

    fn pending_replicas(&self, service: ServiceId) -> usize {
        self.pending_pods(service)
    }

    fn estimate_cold_start_s(&self, spec: &ModelSpec, backend: BackendKind) -> f64 {
        Cluster::estimate_cold_start_s(self, spec, backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::models::zoo;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::default())
    }

    #[test]
    fn schedule_walks_lifecycle() {
        let z = zoo();
        let mut c = cluster();
        let pod = c
            .schedule(ServiceId(0), 0, &z[0], BackendKind::Vllm, 0.0)
            .unwrap();
        assert_eq!(c.pods[&pod].state, PodState::Pulling);
        // cold pull 12s + weights 28GB / 2GBps = 14s + vllm init 3s = 29s
        assert!(c.poll(28.9).is_empty());
        let evs = c.poll(29.1);
        assert_eq!(evs.len(), 1);
        match &evs[0] {
            ClusterEvent::ReplicaReady { cold_start_s, .. } => {
                assert!((cold_start_s - 29.0).abs() < 1e-9);
            }
            e => panic!("unexpected {e:?}"),
        }
        assert_eq!(c.ready_pods(ServiceId(0)).len(), 1);
    }

    #[test]
    fn cached_image_starts_faster() {
        let z = zoo();
        let mut c = cluster();
        let p1 = c.schedule(ServiceId(0), 0, &z[0], BackendKind::Vllm, 0.0).unwrap();
        c.poll(40.0);
        c.terminate(p1, 40.0);
        c.poll(50.0);
        // Second pod: image cached (1s pull) AND weights warm (14/5 s)
        // → 1 + 2.8 + 3 = 6.8s total.
        c.schedule(ServiceId(0), 0, &z[0], BackendKind::Vllm, 50.0).unwrap();
        let evs = c.poll(50.0 + 6.8 + 0.1);
        assert!(matches!(evs[0], ClusterEvent::ReplicaReady { cold_start_s, .. }
                         if (cold_start_s - 6.8).abs() < 1e-9));
    }

    #[test]
    fn capacity_respected() {
        let z = zoo();
        let mut c = Cluster::new(ClusterConfig {
            nodes: 1,
            gpus_per_node: 8,
            ..ClusterConfig::default()
        });
        assert!(c.schedule(ServiceId(1), 3, &z[3], BackendKind::Vllm, 0.0).is_some());
        assert!(c.schedule(ServiceId(1), 3, &z[3], BackendKind::Vllm, 0.0).is_none());
        assert_eq!(c.gpus_held(), 8);
    }

    #[test]
    fn terminate_releases_gpus() {
        let z = zoo();
        let mut c = cluster();
        let pod = c.schedule(ServiceId(0), 2, &z[2], BackendKind::Tgi, 0.0).unwrap();
        assert_eq!(c.gpus_held(), 4);
        c.poll(200.0);
        c.terminate(pod, 200.0);
        let evs = c.poll(202.1);
        assert!(matches!(evs[0], ClusterEvent::ReplicaGone { .. }));
        assert_eq!(c.gpus_held(), 0);
        assert_eq!(c.nodes.iter().map(|n| n.gpus_free).sum::<usize>(), 32);
    }

    #[test]
    fn failure_frees_and_reports() {
        let z = zoo();
        let mut c = cluster();
        let pod = c.schedule(ServiceId(0), 1, &z[1], BackendKind::Vllm, 0.0).unwrap();
        c.poll(100.0);
        let ev = c.fail(pod, 100.0).unwrap();
        assert!(matches!(ev, ClusterEvent::ReplicaFailed { .. }));
        assert_eq!(c.gpus_held(), 0);
        assert!(c.ready_pods(ServiceId(0)).is_empty());
    }

    #[test]
    fn gpu_seconds_accrue() {
        let z = zoo();
        let mut c = cluster();
        c.schedule(ServiceId(0), 0, &z[0], BackendKind::Vllm, 0.0).unwrap();
        c.poll(100.0);
        assert!((c.gpu_seconds(100.0) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn tightest_fit_packing() {
        let z = zoo();
        let mut c = Cluster::new(ClusterConfig {
            nodes: 2,
            gpus_per_node: 8,
            ..ClusterConfig::default()
        });
        // 4-GPU pod lands on node 0; a 2-GPU pod packs onto the same node
        // (tightest fit), keeping node 1 whole for an 8-GPU model.
        c.schedule(ServiceId(0), 2, &z[2], BackendKind::Vllm, 0.0).unwrap();
        let p2 = c.schedule(ServiceId(1), 1, &z[1], BackendKind::Vllm, 0.0).unwrap();
        assert_eq!(c.pods[&p2].node, NodeId(0));
        assert!(c.schedule(ServiceId(2), 3, &z[3], BackendKind::Vllm, 0.0).is_some());
    }

    #[test]
    fn pending_counts_prestages() {
        let z = zoo();
        let mut c = cluster();
        c.schedule(ServiceId(5), 0, &z[0], BackendKind::Vllm, 0.0).unwrap();
        c.poll(5.0); // still pulling
        assert_eq!(c.pending_pods(ServiceId(5)), 1);
        c.poll(30.0);
        assert_eq!(c.pending_pods(ServiceId(5)), 0);
    }
}
