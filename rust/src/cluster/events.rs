//! Discrete-event queue — the virtual-time engine behind sim mode.
//!
//! A binary heap of (timestamp, sequence, event) with FIFO tie-breaking,
//! so simulations are fully deterministic for a given seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    t_ns: u64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.t_ns == other.t_ns && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour on BinaryHeap (a max-heap).
        other
            .t_ns
            .cmp(&self.t_ns)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap event queue keyed on virtual nanoseconds.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    /// Timestamp of the last popped event (monotonicity check).
    last_t: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, last_t: 0 }
    }

    /// Schedule an event at absolute virtual time `t_ns`.
    pub fn push(&mut self, t_ns: u64, event: E) {
        self.heap.push(Entry { t_ns, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule relative to a given now.
    pub fn push_after(&mut self, now_ns: u64, delay_ns: u64, event: E) {
        self.push(now_ns.saturating_add(delay_ns), event);
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<(u64, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.t_ns >= self.last_t, "event time regressed");
            self.last_t = e.t_ns;
            (e.t_ns, e.event)
        })
    }

    /// Earliest pending timestamp.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.t_ns)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(300, "c");
        q.push(100, "a");
        q.push(200, "b");
        assert_eq!(q.pop(), Some((100, "a")));
        assert_eq!(q.pop(), Some((200, "b")));
        assert_eq!(q.pop(), Some((300, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.push(100, 1);
        q.push(100, 2);
        q.push(100, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn push_after_adds_delay() {
        let mut q = EventQueue::new();
        q.push_after(1_000, 500, "x");
        assert_eq!(q.peek_time(), Some(1_500));
    }

    #[test]
    fn len_tracks() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        q.push(1, 0);
        q.push(2, 1);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn saturating_delay() {
        let mut q = EventQueue::new();
        q.push_after(u64::MAX - 1, 100, "end");
        assert_eq!(q.peek_time(), Some(u64::MAX));
    }
}
