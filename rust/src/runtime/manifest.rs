//! `artifacts/manifest.json` — the AOT pipeline's module inventory.
//!
//! The manifest is the contract between `python/compile/aot.py` and this
//! runtime: per compiled module it records the positional input specs
//! (weights first, then activations) and output specs the PJRT call must
//! honor.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Kind of a compiled module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModuleKind {
    Classifier,
    Prefill,
    Decode,
}

impl ModuleKind {
    fn parse(s: &str) -> Result<ModuleKind> {
        match s {
            "classifier" => Ok(ModuleKind::Classifier),
            "prefill" => Ok(ModuleKind::Prefill),
            "decode" => Ok(ModuleKind::Decode),
            _ => Err(anyhow!("unknown module kind `{s}`")),
        }
    }
}

/// One positional input or output.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub kind: String, // weight | tokens | lengths | kv | pos | logits | probs
    pub dtype: String, // f32 | i32
    pub shape: Vec<usize>,
}

impl IoSpec {
    fn parse(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            kind: j.rstr("kind")?.to_string(),
            dtype: j.rstr("dtype")?.to_string(),
            shape: j
                .rarr("shape")?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled module.
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub kind: ModuleKind,
    pub model: String,
    pub batch: usize,
    pub hlo_file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ModuleSpec {
    /// Number of leading weight inputs.
    pub fn n_weights(&self) -> usize {
        self.inputs.iter().take_while(|i| i.kind == "weight").count()
    }
}

/// Architecture dims of one model (mirrors python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub weights_file: String,
    pub param_count: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub seq_prefill: usize,
    pub seq_max: usize,
    pub n_classes: usize,
    pub val_accuracy: Option<f64>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub modules: Vec<ModuleSpec>,
    pub models: Vec<ModelInfo>,
    pub tokenizer_vocab: usize,
    pub tokenizer_seq_cls: usize,
}

impl Manifest {
    pub fn load(artifacts_dir: &str) -> Result<Manifest> {
        let j = Json::from_file(&format!("{artifacts_dir}/manifest.json"))?;
        Self::parse(&j)
    }

    pub fn parse(j: &Json) -> Result<Manifest> {
        let tok = j.req("tokenizer")?;
        let mut modules = Vec::new();
        for m in j.rarr("modules")? {
            modules.push(ModuleSpec {
                name: m.rstr("name")?.to_string(),
                kind: ModuleKind::parse(m.rstr("kind")?)?,
                model: m.rstr("model")?.to_string(),
                batch: m.rusize("batch")?,
                hlo_file: m.rstr("hlo")?.to_string(),
                inputs: m
                    .rarr("inputs")?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
                outputs: m
                    .rarr("outputs")?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
            });
        }
        let mut models = Vec::new();
        let model_obj = j
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?;
        for (name, info) in model_obj {
            let cfg = info.req("config")?;
            models.push(ModelInfo {
                name: name.clone(),
                weights_file: info.rstr("weights")?.to_string(),
                param_count: info.rusize("param_count")?,
                vocab: cfg.rusize("vocab")?,
                d_model: cfg.rusize("d_model")?,
                n_layers: cfg.rusize("n_layers")?,
                n_heads: cfg.rusize("n_heads")?,
                d_head: cfg.rusize("d_head")?,
                seq_prefill: cfg.rusize("seq_prefill")?,
                seq_max: cfg.rusize("seq_max")?,
                n_classes: cfg.usize_or("n_classes", 0),
                val_accuracy: info.get("val_accuracy").and_then(Json::as_f64),
            });
        }
        Ok(Manifest {
            modules,
            models,
            tokenizer_vocab: tok.rusize("vocab")?,
            tokenizer_seq_cls: tok.rusize("seq_cls")?,
        })
    }

    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("module `{name}` not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("model `{name}` not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
              "tokenizer": {"vocab": 4096, "seq_cls": 48},
              "models": {
                "small": {"weights": "lm_small.psw", "param_count": 10,
                  "config": {"name":"small","vocab":4096,"d_model":64,
                   "n_layers":2,"n_heads":2,"d_head":32,"d_ffn":256,
                   "seq_prefill":64,"seq_max":96,"n_classes":0}}
              },
              "modules": [
                {"name":"lm_small_decode_b1","kind":"decode","model":"small",
                 "batch":1,"hlo":"lm_small_decode_b1.hlo.txt",
                 "inputs":[{"kind":"weight","dtype":"f32","shape":[4096,64]},
                           {"kind":"kv","dtype":"f32","shape":[2,2,1,2,96,32]},
                           {"kind":"tokens","dtype":"i32","shape":[1]},
                           {"kind":"pos","dtype":"i32","shape":[1]}],
                 "outputs":[{"kind":"logits","dtype":"f32","shape":[1,4096]},
                            {"kind":"kv","dtype":"f32","shape":[2,2,1,2,96,32]}]}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(&sample()).unwrap();
        assert_eq!(m.tokenizer_vocab, 4096);
        assert_eq!(m.modules.len(), 1);
        let spec = m.module("lm_small_decode_b1").unwrap();
        assert_eq!(spec.kind, ModuleKind::Decode);
        assert_eq!(spec.n_weights(), 1);
        assert_eq!(spec.inputs[1].elements(), 2 * 2 * 2 * 96 * 32);
        let info = m.model("small").unwrap();
        assert_eq!(info.d_model, 64);
        assert_eq!(info.n_classes, 0);
    }

    #[test]
    fn missing_module_errors() {
        let m = Manifest::parse(&sample()).unwrap();
        assert!(m.module("nope").is_err());
        assert!(m.model("nope").is_err());
    }
}
