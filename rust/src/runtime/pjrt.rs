//! PJRT facade — the narrow slice of the `xla` bindings the runtime
//! uses, switchable between the real crate and an in-tree stub.
//!
//! With `--features pjrt` the real `xla` bindings are re-exported
//! unchanged and the live inference path works end-to-end. NOTE: the
//! feature additionally requires adding the `xla` crate
//! (github.com/LaurentMazare/xla-rs) to `[dependencies]` by hand — it
//! cannot live in Cargo.toml because offline/hermetic builds have no
//! registry access (see the feature note there); until then a `pjrt`
//! build fails at this `use`. Without the feature (the default — CI and
//! offline builds), a typed stub keeps every caller compiling: artifact
//! loading and compilation succeed (so manifests and engine wiring are
//! testable), but `execute_b` returns an error. Tests that need real
//! inference already skip when `artifacts/` is absent, which is always
//! the case in stub builds.

#[cfg(feature = "pjrt")]
pub use xla::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};

#[cfg(not(feature = "pjrt"))]
pub use stub::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};

#[cfg(not(feature = "pjrt"))]
mod stub {
    //! Shape-checked no-op stand-ins for the xla-rs types. Every method
    //! mirrors the real signature (including `Result` error types that
    //! format with `{:?}`) so `runtime` compiles identically either way.

    /// Error type formatted with `{e:?}` by the runtime, like xla's.
    pub struct Error(pub String);

    impl std::fmt::Debug for Error {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    type Result<T> = std::result::Result<T, Error>;

    fn unavailable<T>(what: &str) -> Result<T> {
        Err(Error(format!(
            "{what}: PJRT execution unavailable (crate built without the \
             `pjrt` feature; rebuild with --features pjrt and the xla \
             bindings to run compiled artifacts)"
        )))
    }

    /// Host element types the runtime moves across the PJRT boundary.
    pub trait Element: Copy {}
    impl Element for f32 {}
    impl Element for i32 {}

    /// A parsed HLO module (stub: remembers the source path only).
    pub struct HloModuleProto {
        path: String,
    }

    impl HloModuleProto {
        pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
            if std::path::Path::new(path).exists() {
                Ok(HloModuleProto { path: path.to_string() })
            } else {
                Err(Error(format!("no such HLO file: {path}")))
            }
        }
    }

    /// A computation handle built from a proto.
    pub struct XlaComputation {
        path: String,
    }

    impl XlaComputation {
        pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
            XlaComputation { path: proto.path.clone() }
        }
    }

    /// The (CPU) PJRT client.
    #[derive(Clone)]
    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient> {
            Ok(PjRtClient)
        }

        pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
            Ok(PjRtLoadedExecutable { path: comp.path.clone() })
        }

        pub fn buffer_from_host_buffer<T: Element>(
            &self,
            data: &[T],
            dims: &[usize],
            _device: Option<usize>,
        ) -> Result<PjRtBuffer> {
            let expect: usize = dims.iter().product();
            if data.len() != expect {
                return Err(Error(format!(
                    "host buffer has {} elements, dims {dims:?} want {expect}",
                    data.len()
                )));
            }
            Ok(PjRtBuffer)
        }
    }

    /// A device buffer (stub: no storage; uploads only shape-check).
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal> {
            unavailable("to_literal_sync")
        }
    }

    /// A compiled executable.
    pub struct PjRtLoadedExecutable {
        path: String,
    }

    impl PjRtLoadedExecutable {
        pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
            unavailable(&format!("execute {}", self.path))
        }
    }

    /// A host-side literal value.
    pub struct Literal;

    impl Literal {
        pub fn to_tuple(self) -> Result<Vec<Literal>> {
            unavailable("to_tuple")
        }

        pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
            unavailable("to_vec")
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn client_and_upload_shape_check() {
            let c = PjRtClient::cpu().unwrap();
            assert!(c.buffer_from_host_buffer(&[1i32, 2, 3], &[1, 3], None).is_ok());
            assert!(c.buffer_from_host_buffer(&[1i32, 2, 3], &[2, 3], None).is_err());
        }

        #[test]
        fn execution_reports_unavailable() {
            let c = PjRtClient::cpu().unwrap();
            let missing = HloModuleProto::from_text_file("/no/such/module.hlo");
            assert!(missing.is_err());
            // A real file parses and compiles; only execution is stubbed.
            let exe = {
                let proto =
                    HloModuleProto { path: "synthetic".into() };
                c.compile(&XlaComputation::from_proto(&proto)).unwrap()
            };
            let err = exe.execute_b(&[]).unwrap_err();
            assert!(format!("{err:?}").contains("pjrt"));
        }
    }
}
