//! PJRT runtime — loads AOT artifacts and serves them on the hot path.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → compile →
//! `execute_b`. Model weights load once from `.psw` (the PVC-read step of
//! a pod cold start) and stay device-resident as PJRT buffers; per step
//! only the small activations (tokens, positions, KV) cross the host
//! boundary. Python never runs here.
//!
//! KV note: the compiled modules return `(logits, kv)` as a tuple buffer,
//! and the PJRT wrapper exposes no tuple-splitting on device, so the KV
//! state round-trips through the host each decode step (≈100 KB–1.2 MB
//! per step for these tiers — a memcpy on the CPU plugin, measured in the
//! §Perf log).

pub mod manifest;
pub mod pjrt;
pub mod weights;

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use pjrt::{HloModuleProto, Literal, PjRtBuffer, PjRtClient,
           PjRtLoadedExecutable, XlaComputation};

use crate::router::Classifier;
use crate::tokenizer;
use manifest::Manifest;
use weights::Dtype;

/// Shared PJRT client + artifact inventory.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    artifacts_dir: String,
    /// Compile cache: module name → executable.
    compiled: BTreeMap<String, PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and read the manifest.
    pub fn load(artifacts_dir: &str) -> Result<Runtime> {
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime {
            client,
            manifest,
            artifacts_dir: artifacts_dir.to_string(),
            compiled: BTreeMap::new(),
        })
    }

    /// Compile (or fetch from cache) a module by name.
    pub fn compile(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let spec = self.manifest.module(name)?.clone();
            let path = format!("{}/{}", self.artifacts_dir, spec.hlo_file);
            let t0 = Instant::now();
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {path}: {e:?}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            crate::debug!("compiled {name} in {:?}", t0.elapsed());
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Upload a model's weights as device buffers, in manifest order.
    pub fn upload_weights(&self, model: &str) -> Result<Vec<PjRtBuffer>> {
        let info = self.manifest.model(model)?;
        let path = format!("{}/{}", self.artifacts_dir, info.weights_file);
        let tensors = weights::load(&path)?;
        let mut bufs = Vec::with_capacity(tensors.len());
        for t in &tensors {
            // NOTE: the typed upload path is used deliberately — the
            // crate's `buffer_from_host_raw_bytes` casts `ElementType` to
            // the C enum directly, which mislabels F32 (=10) as F16.
            let buf = match t.dtype {
                Dtype::F32 => {
                    let v = t.as_f32()?;
                    self.client.buffer_from_host_buffer(&v, &t.shape, None)
                }
                Dtype::I32 => {
                    let v: Vec<i32> = t
                        .data
                        .chunks_exact(4)
                        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect();
                    self.client.buffer_from_host_buffer(&v, &t.shape, None)
                }
            }
            .map_err(|e| anyhow!("uploading {}: {e:?}", t.name))?;
            bufs.push(buf);
        }
        Ok(bufs)
    }

    /// Build the semantic-router engine (classifier at batch 1).
    pub fn classifier_engine(&mut self) -> Result<ClassifierEngine> {
        self.compile("classifier_b1")?;
        let weights = self.upload_weights("classifier")?;
        let spec = self.manifest.module("classifier_b1")?.clone();
        let exe = self.compiled.remove("classifier_b1").unwrap();
        Ok(ClassifierEngine {
            client: self.client.clone(),
            exe,
            weights,
            seq: spec.inputs.last().unwrap().shape[1],
        })
    }

    /// Build an LM engine for a tier at the given decode batch sizes.
    pub fn lm_engine(&mut self, tier: &str, decode_batches: &[usize]) -> Result<LmEngine> {
        let info = self.manifest.model(tier)?.clone();
        let weights = self.upload_weights(tier)?;
        let prefill_name = format!("lm_{tier}_prefill_b1");
        self.compile(&prefill_name)?;
        let prefill = self.compiled.remove(&prefill_name).unwrap();
        let mut decode = BTreeMap::new();
        for &b in decode_batches {
            let name = format!("lm_{tier}_decode_b{b}");
            self.compile(&name)?;
            decode.insert(b, self.compiled.remove(&name).unwrap());
        }
        Ok(LmEngine {
            client: self.client.clone(),
            tier: tier.to_string(),
            prefill,
            decode,
            weights,
            vocab: info.vocab,
            n_layers: info.n_layers,
            n_heads: info.n_heads,
            d_head: info.d_head,
            seq_prefill: info.seq_prefill,
            seq_max: info.seq_max,
        })
    }
}

/// Upload i32 data as a device buffer.
fn i32_buffer(client: &PjRtClient, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
    client
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("i32 upload: {e:?}"))
}

/// Upload raw f32 bytes as a device buffer (via the typed path — see the
/// ElementType-cast note in `upload_weights`).
fn f32_bytes_buffer(client: &PjRtClient, bytes: &[u8], dims: &[usize]) -> Result<PjRtBuffer> {
    let v: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    client
        .buffer_from_host_buffer(&v, dims, None)
        .map_err(|e| anyhow!("f32 upload: {e:?}"))
}

/// Execute and untuple the (single-device) result into literals.
fn run_untuple(exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
    let out = exe
        .execute_b(args)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("download: {e:?}"))?;
    lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
}

/// Argmax over each row of a [b, vocab] logits literal.
fn argmax_rows(logits: &Literal, b: usize, vocab: usize) -> Result<Vec<i32>> {
    let v: Vec<f32> = logits.to_vec().map_err(|e| anyhow!("logits: {e:?}"))?;
    if v.len() != b * vocab {
        bail!("logits size {} != {b}×{vocab}", v.len());
    }
    Ok((0..b)
        .map(|i| {
            let row = &v[i * vocab..(i + 1) * vocab];
            let mut best = 0usize;
            for (j, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = j;
                }
            }
            best as i32
        })
        .collect())
}

// ---------------------------------------------------------------------------
// Classifier engine (the Pick router's semantic path)
// ---------------------------------------------------------------------------

/// The compiled DistilBERT-lite classifier behind the `Classifier` trait.
pub struct ClassifierEngine {
    client: PjRtClient,
    exe: PjRtLoadedExecutable,
    weights: Vec<PjRtBuffer>,
    seq: usize,
}

impl ClassifierEngine {
    /// Raw class probabilities for already-encoded token ids.
    pub fn probs_ids(&self, ids: &[i32]) -> Result<[f64; 3]> {
        debug_assert_eq!(ids.len(), self.seq);
        let toks = i32_buffer(&self.client, ids, &[1, self.seq])?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&toks);
        let outs = run_untuple(&self.exe, &args)?;
        let p: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("probs: {e:?}"))?;
        if p.len() != 3 {
            bail!("expected 3 probs, got {}", p.len());
        }
        Ok([p[0] as f64, p[1] as f64, p[2] as f64])
    }
}

impl Classifier for ClassifierEngine {
    fn probs(&mut self, text: &str) -> Result<[f64; 3]> {
        let ids = tokenizer::encode(text, self.seq);
        self.probs_ids(&ids)
    }
}

// ---------------------------------------------------------------------------
// LM engine (prefill + KV-cache decode loop)
// ---------------------------------------------------------------------------

/// Result of one generation.
#[derive(Debug, Clone)]
pub struct Generation {
    pub tokens: Vec<i32>,
    /// Wall-clock seconds until the first token (prefill).
    pub ttft_s: f64,
    /// Total wall-clock seconds.
    pub latency_s: f64,
    pub prompt_tokens: usize,
}

/// Per-sequence decode state (KV bytes live on the host between steps).
///
/// Public so the continuous-batching scheduler
/// ([`crate::backend::scheduler`]) can own in-flight sequences and
/// interleave decode steps across them: a sequence is started once
/// ([`LmEngine::start_seq`]), stepped in engine-chosen batches
/// ([`LmEngine::step_batch`]) until [`Sequence::done`], and its slot is
/// released the moment it completes.
pub struct Sequence {
    kv: Vec<u8>,
    pos: i32,
    last_token: i32,
    out: Vec<i32>,
    /// Total tokens this sequence may emit (the prefill token counts).
    budget: usize,
    prompt_tokens: usize,
    /// Leading prompt tokens whose KV the serving layer's radix prefix
    /// cache already held at admission. The compiled batch-1 prefill
    /// module still recomputes its full window — the offset is the
    /// accounting/reporting contract (schedulers charge and count only
    /// the uncached suffix) until suffix-prefill modules are exported
    /// from `python/compile/` (ROADMAP).
    prefix_len: usize,
}

impl Sequence {
    /// Tokens generated so far (prefill token first).
    pub fn tokens(&self) -> &[i32] {
        &self.out
    }

    /// Consume the sequence, yielding its generated tokens.
    pub fn into_tokens(self) -> Vec<i32> {
        self.out
    }

    pub fn generated(&self) -> usize {
        self.out.len()
    }

    pub fn prompt_tokens(&self) -> usize {
        self.prompt_tokens
    }

    /// Prompt tokens served from the prefix cache (0 without a hit).
    pub fn prefix_len(&self) -> usize {
        self.prefix_len
    }

    /// Absolute position in the KV cache (prompt + generated).
    pub fn position(&self) -> usize {
        self.pos as usize
    }

    /// Has this sequence exhausted its token budget?
    pub fn done(&self) -> bool {
        self.out.len() >= self.budget
    }

    /// Roll back the last `n` generated tokens — the speculative-decode
    /// rejection cleanup for the compiled path. The KV positions beyond
    /// the restored `pos` become dead weight the next decode step simply
    /// overwrites, so only the cursor state needs rewinding: the output
    /// stream shrinks, `pos` rewinds with it, and `last_token` is
    /// refreshed so the next dispatch feeds the correct id. At least the
    /// prefill token is always kept (a sequence never rolls back to
    /// empty). Returns the tokens actually removed.
    pub fn rollback_draft(&mut self, n: usize) -> usize {
        let rolled = n.min(self.out.len().saturating_sub(1));
        self.out.truncate(self.out.len() - rolled);
        self.pos -= rolled as i32;
        self.last_token = *self.out.last().expect("prefill token always present");
        rolled
    }
}

/// A compiled LM tier: batch-1 prefill plus decode executables per batch.
pub struct LmEngine {
    client: PjRtClient,
    pub tier: String,
    prefill: PjRtLoadedExecutable,
    decode: BTreeMap<usize, PjRtLoadedExecutable>,
    weights: Vec<PjRtBuffer>,
    pub vocab: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub seq_prefill: usize,
    pub seq_max: usize,
}

impl LmEngine {
    /// Bytes of one sequence's KV cache ([L, 2, 1, H, Smax, Dh] f32).
    fn kv_bytes_per_seq(&self) -> usize {
        self.n_layers * 2 * self.n_heads * self.seq_max * self.d_head * 4
    }

    /// KV dims at a given batch.
    fn kv_dims(&self, b: usize) -> [usize; 6] {
        [self.n_layers, 2, b, self.n_heads, self.seq_max, self.d_head]
    }

    /// Prefill one prompt; returns its decode state (first token sampled).
    fn prefill_one(&self, prompt: &str) -> Result<Sequence> {
        let ids = tokenizer::encode_words(prompt, self.seq_prefill);
        let len = tokenizer::valid_len(&ids).max(1);
        let toks = i32_buffer(&self.client, &ids, &[1, self.seq_prefill])?;
        let lens = i32_buffer(&self.client, &[len as i32], &[1])?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&toks);
        args.push(&lens);
        let outs = run_untuple(&self.prefill, &args)?;
        let first = argmax_rows(&outs[0], 1, self.vocab)?[0];
        let kv = literal_bytes(&outs[1])?;
        if kv.len() != self.kv_bytes_per_seq() {
            bail!("kv size {} != expected {}", kv.len(), self.kv_bytes_per_seq());
        }
        Ok(Sequence {
            kv,
            pos: len as i32,
            last_token: first,
            out: vec![first],
            budget: 1,
            prompt_tokens: len,
            prefix_len: 0,
        })
    }

    /// Start serving a prompt: prefill it and fix its token budget
    /// (`max_new` capped by the compiled context window). The returned
    /// sequence already holds its first token; feed it to
    /// [`Self::step_batch`] until [`Sequence::done`]. `prefix_tokens` is
    /// the scheduler's prefix-cache offset (see [`Sequence::prefix_len`]).
    pub fn start_seq(&self, prompt: &str, max_new: usize, prefix_tokens: usize)
        -> Result<Sequence> {
        let mut st = self.prefill_one(prompt)?;
        st.prefix_len = prefix_tokens.min(st.prompt_tokens);
        st.budget = max_new
            .min(self.seq_max.saturating_sub(st.pos as usize))
            .max(1);
        Ok(st)
    }

    /// One decode step over a batch of in-flight sequences (continuous
    /// batching: positions may differ per sequence). `states.len()` must
    /// be a compiled batch size; callers must not include sequences that
    /// are already [`Sequence::done`].
    pub fn step_batch(&self, states: &mut [&mut Sequence]) -> Result<()> {
        let b = states.len();
        let exe = self
            .decode
            .get(&b)
            .ok_or_else(|| anyhow!("decode batch {b} not compiled"))?;
        // Pack per-seq KV into the [L*2, B, rest] device layout.
        let per = self.kv_bytes_per_seq();
        let chunk = per / (self.n_layers * 2);
        let mut kv = vec![0u8; per * b];
        for (bi, st) in states.iter().enumerate() {
            for l in 0..self.n_layers * 2 {
                let src = &st.kv[l * chunk..(l + 1) * chunk];
                let dst = (l * b + bi) * chunk;
                kv[dst..dst + chunk].copy_from_slice(src);
            }
        }
        let kv_buf = f32_bytes_buffer(&self.client, &kv, &self.kv_dims(b))?;
        let toks: Vec<i32> = states.iter().map(|s| s.last_token).collect();
        let pos: Vec<i32> = states.iter().map(|s| s.pos).collect();
        let tok_buf = i32_buffer(&self.client, &toks, &[b])?;
        let pos_buf = i32_buffer(&self.client, &pos, &[b])?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&kv_buf);
        args.push(&tok_buf);
        args.push(&pos_buf);
        let outs = run_untuple(exe, &args)?;
        let next = argmax_rows(&outs[0], b, self.vocab)?;
        let kv_out = literal_bytes(&outs[1])?;
        for (bi, st) in states.iter_mut().enumerate() {
            for l in 0..self.n_layers * 2 {
                let src = (l * b + bi) * chunk;
                st.kv[l * chunk..(l + 1) * chunk]
                    .copy_from_slice(&kv_out[src..src + chunk]);
            }
            st.pos += 1;
            st.last_token = next[bi];
            st.out.push(next[bi]);
        }
        Ok(())
    }

    /// Greedy generation for a single prompt.
    pub fn generate(&self, prompt: &str, max_new: usize) -> Result<Generation> {
        let t0 = Instant::now();
        let mut st = self.start_seq(prompt, max_new, 0)?;
        let ttft = t0.elapsed().as_secs_f64();
        while !st.done() {
            let mut only = [&mut st];
            self.step_batch(&mut only)?;
        }
        Ok(Generation {
            prompt_tokens: st.prompt_tokens,
            tokens: st.into_tokens(),
            ttft_s: ttft,
            latency_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Greedy generation for a batch of prompts using a compiled batch
    /// size (prompts prefill individually, then decode jointly — the
    /// continuous-batching pattern the paper's vLLM backend uses). All
    /// sequences share one budget; the per-sequence interleaving lives in
    /// [`crate::backend::scheduler`].
    pub fn generate_batch(&self, prompts: &[&str], max_new: usize) -> Result<Vec<Generation>> {
        let b = prompts.len();
        if !self.decode.contains_key(&b) {
            bail!("decode batch {b} not compiled");
        }
        let t0 = Instant::now();
        let mut states = Vec::with_capacity(b);
        let mut ttfts = Vec::with_capacity(b);
        for p in prompts {
            let st = self.prefill_one(p)?;
            ttfts.push(t0.elapsed().as_secs_f64());
            states.push(st);
        }
        let max_pos = states.iter().map(|s| s.pos).max().unwrap_or(0) as usize;
        let budget = max_new.min(self.seq_max.saturating_sub(max_pos)).max(1);
        for st in &mut states {
            st.budget = budget;
        }
        for _ in 1..budget {
            let mut refs: Vec<&mut Sequence> = states.iter_mut().collect();
            self.step_batch(&mut refs)?;
        }
        let total = t0.elapsed().as_secs_f64();
        Ok(states
            .into_iter()
            .zip(ttfts)
            .map(|(st, ttft)| Generation {
                prompt_tokens: st.prompt_tokens,
                tokens: st.into_tokens(),
                ttft_s: ttft,
                latency_s: total,
            })
            .collect())
    }

    /// Compiled decode batch sizes (for the batcher).
    pub fn decode_batches(&self) -> Vec<usize> {
        self.decode.keys().copied().collect()
    }

    /// Largest compiled decode batch.
    pub fn max_decode_batch(&self) -> usize {
        self.decode.keys().copied().max().unwrap_or(1)
    }
}

/// Raw bytes of an f32 literal.
fn literal_bytes(lit: &Literal) -> Result<Vec<u8>> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal: {e:?}"))?;
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(tokens: &[i32], pos: i32) -> Sequence {
        Sequence {
            kv: Vec::new(),
            pos,
            last_token: *tokens.last().unwrap(),
            out: tokens.to_vec(),
            budget: 8,
            prompt_tokens: 3,
            prefix_len: 0,
        }
    }

    #[test]
    fn rollback_draft_restores_decode_cursor_state() {
        let mut s = seq(&[10, 11, 12, 13], 7);
        assert_eq!(s.rollback_draft(2), 2);
        assert_eq!(s.tokens(), &[10, 11]);
        assert_eq!(s.position(), 5);
        assert_eq!(s.last_token, 11, "next dispatch must feed the kept tail");
        assert!(!s.done());
        // Over-rollback keeps the prefill token — a sequence never
        // rewinds to empty.
        assert_eq!(s.rollback_draft(10), 1);
        assert_eq!(s.tokens(), &[10]);
        assert_eq!(s.position(), 4);
        assert_eq!(s.last_token, 10);
        assert_eq!(s.rollback_draft(1), 0);
    }
}
