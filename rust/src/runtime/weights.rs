//! `.psw` weight-container loader (the Rust half of `python/compile/psw.py`).
//!
//! Weights are runtime inputs to the compiled HLO modules, stored in a
//! trivial binary format and uploaded once per engine as device-resident
//! PJRT buffers — loading these files is exactly the "weights from PVC"
//! step of the pod cold-start model.

use anyhow::{bail, Context, Result};

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn size(self) -> usize {
        4
    }
}

/// One named tensor: raw little-endian bytes + shape.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("{}: not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Parse a `.psw` file.
pub fn load(path: &str) -> Result<Vec<Tensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    parse(&bytes).with_context(|| format!("parsing {path}"))
}

/// Parse from bytes.
pub fn parse(bytes: &[u8]) -> Result<Vec<Tensor>> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != b"PSW1" {
        bail!("bad magic");
    }
    let count = r.u32()? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())?;
        let dtype = match r.u8()? {
            0 => Dtype::F32,
            1 => Dtype::I32,
            d => bail!("{name}: unknown dtype {d}"),
        };
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1);
        let data = r.take(n * dtype.size())?.to_vec();
        out.push(Tensor { name, dtype, shape, data });
    }
    if r.i != bytes.len() {
        bail!("trailing bytes after {} tensors", count);
    }
    Ok(out)
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated file at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        // PSW1, 1 tensor: name "w", f32, shape [2,2], data [1,2,3,4]
        let mut b = b"PSW1".to_vec();
        b.extend(1u32.to_le_bytes());
        b.extend(1u16.to_le_bytes());
        b.push(b'w');
        b.push(0); // f32
        b.push(2); // ndim
        b.extend(2u32.to_le_bytes());
        b.extend(2u32.to_le_bytes());
        for v in [1f32, 2.0, 3.0, 4.0] {
            b.extend(v.to_le_bytes());
        }
        b
    }

    #[test]
    fn parses_sample() {
        let ts = parse(&sample()).unwrap();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].name, "w");
        assert_eq!(ts[0].shape, vec![2, 2]);
        assert_eq!(ts[0].as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample();
        b[0] = b'X';
        assert!(parse(&b).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let b = sample();
        assert!(parse(&b[..b.len() - 2]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut b = sample();
        b.push(0);
        assert!(parse(&b).is_err());
    }

    #[test]
    fn scalar_tensor() {
        let mut b = b"PSW1".to_vec();
        b.extend(1u32.to_le_bytes());
        b.extend(1u16.to_le_bytes());
        b.push(b's');
        b.push(1); // i32
        b.push(0); // ndim 0 → scalar
        b.extend(7i32.to_le_bytes());
        let ts = parse(&b).unwrap();
        assert_eq!(ts[0].elements(), 1);
        assert_eq!(ts[0].dtype, Dtype::I32);
    }
}
