//! # Pick and Spin
//!
//! A from-scratch reproduction of *"Efficient Multi-Model Orchestration for
//! Self-Hosted Large Language Models"* (Vangala & Malik, 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Pick** — the routing layer ([`router`]): keyword heuristics, a
//!   compiled DistilBERT-lite complexity classifier executed via PJRT, and
//!   a hybrid policy; scored against the service matrix with the
//!   normalized multi-objective function of Eq. 2 ([`scoring`]).
//! * **Spin** — the orchestration layer ([`orchestrator`]): warm pools,
//!   Little's-law capacity planning, cooldowns, scale-to-zero and fault
//!   recovery over a simulated Kubernetes substrate ([`cluster`]).
//! * **Serving** — a continuous-batching engine pool: the gateway
//!   ([`gateway`]) fans routed jobs into per-tier queues served by N
//!   replica threads, each running the slot-managed scheduler of
//!   [`backend::scheduler`] over the batch ladder ([`backend::batcher`])
//!   and the block-granular KV manager ([`backend::kv_cache`]),
//!   executing AOT-compiled HLO modules through the PJRT C API
//!   ([`runtime`]). Python never runs at request time.
//!
//! The crate is dependency-light by necessity (offline build): [`util`]
//! provides the JSON, RNG, stats, threadpool, logging, clock and CLI
//! substrates that would otherwise come from serde/rand/tokio/clap;
//! `anyhow` is vendored in-tree, and the PJRT bindings sit behind the
//! `pjrt` feature ([`runtime::pjrt`] stubs them otherwise).

pub mod backend;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod eval;
pub mod gateway;
pub mod models;
pub mod orchestrator;
pub mod registry;
pub mod router;
pub mod runtime;
pub mod scoring;
pub mod sim;
pub mod substrate;
pub mod telemetry;
pub mod testkit;
pub mod tokenizer;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
