//! Paged KV-cache block manager — the vLLM PagedAttention idea at the
//! coordinator level, extended with a ref-counted radix prefix cache.
//!
//! The compiled decode modules hold a dense per-slot KV buffer on device
//! ([L, 2, B, H, Smax, Dh]); this manager owns the *logical* accounting:
//! sequences acquire fixed-size token blocks from a bounded pool, and a
//! batch slot can only be admitted when enough blocks remain for its
//! prompt plus its token budget (reservation-based admission — no
//! mid-flight OOM evictions). Fragmentation and occupancy statistics feed
//! the §Perf ablations (block-size sweep).
//!
//! ## Prefix cache
//!
//! Routed traffic is dominated by shared prompt prefixes (system
//! prompts, few-shot benchmark templates), so with
//! [`PrefixCacheConfig::enabled`] the manager keeps a **radix tree keyed
//! on token-block hashes**: every full prompt block becomes a tree node
//! holding one physical block and a refcount. A new admission walks the
//! tree over its prompt's block hashes ([`chain_hash`]) and *shares* the
//! matched prefix blocks instead of reserving fresh ones — admission
//! charges only the uncached suffix. Divergence is copy-on-write at
//! block granularity: the first divergent block branches the tree and
//! everything from there (partial tail block + the generation budget) is
//! private to the sequence, so shared blocks are never written.
//! Releasing a sequence decrements refcounts but keeps the blocks
//! resident for future hits; unreferenced blocks are reclaimed LRU,
//! leaf-first, on demand or past the eviction watermark.
//!
//! With the cache disabled (the default for [`KvBlockManager::new`]) the
//! accounting is bit-identical to the original pure-reservation manager.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

use crate::util::rng::{fnv1a64_step, FNV64_OFFSET};

/// A sequence being served.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SeqId(pub u64);

/// Knobs for the radix prefix cache (config: `pool.prefix_cache.*`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixCacheConfig {
    /// Share matched prompt-prefix blocks across sequences.
    pub enabled: bool,
    /// Minimum run of consecutive matched blocks (from the root) before
    /// a match counts as a hit — tiny shared prefixes aren't worth the
    /// tree churn.
    pub min_block_run: usize,
    /// Resident-block ceiling as a fraction of the pool: when held +
    /// cached blocks exceed it, unreferenced cached blocks are evicted
    /// LRU until back under (or nothing evictable remains).
    pub evict_watermark: f64,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        Self { enabled: true, min_block_run: 1, evict_watermark: 0.9 }
    }
}

impl PrefixCacheConfig {
    /// Cache off — bit-identical legacy reservation accounting.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::default() }
    }
}

/// Everything an admission decision needs from the KV pool, in one call:
/// the result of [`KvBlockManager::probe`]. The scheduler's admission
/// path and the router's affinity scorer share this one code path (the
/// scattered per-question probes it replaced are gone).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionProbe {
    /// Prompt tokens already KV-resident under the min-run hit gate —
    /// the engine's prefill skip offset.
    pub cached_tokens: usize,
    /// Blocks an admission would allocate right now (uncached suffix
    /// plus generation budget; the whole reservation with the cache off).
    pub needed_blocks: usize,
    /// Uncached *prompt* blocks alone, excluding the generation budget —
    /// the prefill-rung grouping key (prefill work scales with the
    /// suffix, not the budget).
    pub suffix_blocks: usize,
    /// Whether `needed_blocks` fit the pool right now (free plus
    /// LRU-reclaimable cache). Optimistic, like every pre-check here:
    /// the reservation at prefill time stays authoritative.
    pub admissible: bool,
}

/// Cumulative prefix-cache counters (exported as `ps_prefix_*` series).
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixStats {
    /// Prompt tokens served from cached blocks.
    pub hit_tokens: u64,
    /// Prompt tokens that had to be prefilled.
    pub miss_tokens: u64,
    /// Unreferenced cached blocks reclaimed (LRU).
    pub evicted_blocks: u64,
}

/// Radix-tree root sentinel (the FNV-1a offset basis).
pub const ROOT_HASH: u64 = FNV64_OFFSET;

fn fnv_mix(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = fnv1a64_step(h, b);
    }
    h
}

/// Chained block hash: a node's key commits to its whole root path, so
/// equal keys mean equal prefixes (token bytes are still compared on
/// match to guard collisions). Shared with the simulator's prefix model.
pub fn chain_hash(parent: u64, block: &[i32]) -> u64 {
    let mut h = fnv_mix(ROOT_HASH, &parent.to_le_bytes());
    for &t in block {
        h = fnv_mix(h, &t.to_le_bytes());
    }
    h
}

/// One radix node = one physical block of `block_tokens` prompt tokens.
#[derive(Debug)]
struct CacheNode {
    parent: Option<u64>,
    /// The block's exact tokens (hash-collision guard).
    tokens: Vec<i32>,
    /// Live sequences referencing this block.
    refs: usize,
    /// Child nodes (only leaves are evictable).
    children: usize,
    /// Σ refs over this node's subtree (self included) — maintained
    /// incrementally so "is any descendant referenced?" (pinned) is an
    /// O(1) read instead of a tree walk on the admission hot path.
    live_desc: usize,
    /// LRU clock at last touch.
    last_use: u64,
}

/// Block-granular KV accounting for one replica.
#[derive(Debug)]
pub struct KvBlockManager {
    pub block_tokens: usize,
    pub total_blocks: usize,
    free_blocks: usize,
    /// Per-sequence allocations.
    seqs: BTreeMap<SeqId, SeqAlloc>,
    /// High-water mark (peak occupancy) for reports.
    pub peak_blocks: usize,
    /// Radix prefix tree: chained block hash → node.
    cache: BTreeMap<u64, CacheNode>,
    /// Nodes with `live_desc > 0` (a referenced descendant-or-self) —
    /// unreclaimable until their referencing sequences release.
    pinned_count: usize,
    cfg: PrefixCacheConfig,
    lru_tick: u64,
    pub stats: PrefixStats,
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    /// Private blocks (uncached suffix + generation budget).
    blocks: usize,
    /// Referenced cache nodes, in root order (shared prompt prefix).
    shared: Vec<u64>,
    /// How many leading `shared` nodes were already resident at
    /// admission (they hold KV computed by *earlier* prefills; the rest
    /// were inserted by this sequence and hold nothing until its own
    /// prefill runs — see [`KvBlockManager::release_discard`]).
    preexisting: usize,
    /// Hit tokens this admission added to [`PrefixStats`] — rolled back
    /// by [`KvBlockManager::release_discard`] when the prefill never ran.
    hit_tokens: usize,
    tokens: usize,
    reserved_tokens: usize,
}

impl KvBlockManager {
    /// Legacy manager: prefix cache off, pure reservation accounting.
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        Self::with_prefix_cache(total_blocks, block_tokens, PrefixCacheConfig::disabled())
    }

    pub fn with_prefix_cache(
        total_blocks: usize,
        block_tokens: usize,
        cfg: PrefixCacheConfig,
    ) -> Self {
        assert!(total_blocks > 0 && block_tokens > 0);
        Self {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
            seqs: BTreeMap::new(),
            peak_blocks: 0,
            cache: BTreeMap::new(),
            pinned_count: 0,
            cfg,
            lru_tick: 0,
            stats: PrefixStats::default(),
        }
    }

    /// Propagate a refcount change up the chain: every ref held on a
    /// node contributes to the `live_desc` of that node and all its
    /// ancestors; `pinned_count` tracks the 0↔1 transitions.
    fn adjust_live(&mut self, mut key: Option<u64>, delta: i64) {
        while let Some(k) = key {
            let Some(n) = self.cache.get_mut(&k) else { break };
            let before = n.live_desc;
            n.live_desc = (n.live_desc as i64 + delta).max(0) as usize;
            if before == 0 && n.live_desc > 0 {
                self.pinned_count += 1;
            } else if before > 0 && n.live_desc == 0 {
                self.pinned_count -= 1;
            }
            key = n.parent;
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Public block rounding (the scheduler tracks pending admissions in
    /// blocks, not summed tokens — pooled rounding over-admits).
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        self.blocks_for(tokens)
    }

    /// Keys of the resident chain matching `ids`' full leading blocks
    /// (token-verified), ungated — every one of these nodes would be
    /// *reused* (not re-allocated) by [`Self::admit_prefix`], whether or
    /// not the run is long enough to count as a hit.
    fn match_chain(&self, ids: &[i32]) -> Vec<u64> {
        if !self.cfg.enabled {
            return Vec::new();
        }
        let mut keys = Vec::new();
        let mut parent: Option<u64> = None;
        let mut ph = ROOT_HASH;
        for chunk in ids.chunks_exact(self.block_tokens) {
            let h = chain_hash(ph, chunk);
            match self.cache.get(&h) {
                Some(n) if n.parent == parent && n.tokens == chunk => {
                    keys.push(h);
                    parent = Some(h);
                    ph = h;
                }
                _ => break,
            }
        }
        keys
    }

    /// [`Self::match_chain`] with the min-run gate applied — the *hit*
    /// semantics (what counts as cached for stats and `prefix_tokens`).
    fn match_keys(&self, ids: &[i32]) -> Vec<u64> {
        let mut keys = self.match_chain(ids);
        if keys.len() < self.cfg.min_block_run.max(1) {
            keys.clear();
        }
        keys
    }

    /// One-call admission probe, one chain walk: what a request with
    /// these prompt ids and generation budget would cost *now*. The
    /// block need is computed over the ungated resident chain (min-run
    /// gated blocks are still reused, only not counted as hits), while
    /// `cached_tokens` applies the hit gate. Optimistic: cached blocks
    /// can be evicted between the probe and the reservation, and the
    /// reservation at prefill time is authoritative.
    pub fn probe(&self, ids: &[i32], max_new: usize) -> AdmissionProbe {
        let prompt = ids.len().max(1);
        let (cached_tokens, needed_blocks, suffix_blocks) = if self.cfg.enabled {
            let full = ids.len() / self.block_tokens;
            let resident = self.match_chain(ids).len();
            let hit_blocks = if resident >= self.cfg.min_block_run.max(1) {
                resident
            } else {
                0
            };
            let tail = prompt - full * self.block_tokens;
            (
                hit_blocks * self.block_tokens,
                (full - resident) + self.blocks_for(tail + max_new),
                (full - resident) + self.blocks_for(tail),
            )
        } else {
            (
                0,
                self.blocks_for(prompt + max_new),
                self.blocks_for(prompt),
            )
        };
        AdmissionProbe {
            cached_tokens,
            needed_blocks,
            suffix_blocks,
            admissible: needed_blocks <= self.available_blocks(),
        }
    }

    /// Cached blocks reclaimable on demand (unreferenced, no referenced
    /// descendants) — O(1) via the maintained pin count.
    fn reclaimable_blocks(&self) -> usize {
        self.cache.len() - self.pinned_count
    }

    /// Blocks an admission can draw on: free plus reclaimable cache.
    pub fn available_blocks(&self) -> usize {
        self.free_blocks + self.reclaimable_blocks()
    }

    /// Evict one unreferenced leaf (LRU), freeing its block.
    ///
    /// Victim selection scans the tree — O(cache) — but only when a
    /// victim exists: every reclaimable subtree bottoms out in an
    /// evictable leaf, so `reclaimable == 0 ⇔ nothing evictable`, and
    /// that O(1) guard makes the fruitless calls (a full-but-pinned pool
    /// probed on every admission retry / watermark pass) free. Scans
    /// that do run each free a block, and the pool bounds the tree.
    fn evict_one(&mut self) -> bool {
        if self.reclaimable_blocks() == 0 {
            return false;
        }
        let victim = self
            .cache
            .iter()
            .filter(|(_, n)| n.refs == 0 && n.children == 0)
            .min_by_key(|(k, n)| (n.last_use, **k))
            .map(|(k, _)| *k);
        let Some(k) = victim else { return false };
        let node = self.cache.remove(&k).expect("victim exists");
        if let Some(p) = node.parent {
            if let Some(pn) = self.cache.get_mut(&p) {
                pn.children -= 1;
            }
        }
        self.free_blocks += 1;
        self.stats.evicted_blocks += 1;
        true
    }

    /// Make at least `need` blocks free, evicting cached blocks LRU.
    fn ensure_free(&mut self, need: usize) -> bool {
        while self.free_blocks < need {
            if !self.evict_one() {
                return false;
            }
        }
        true
    }

    /// Evict unreferenced cache past the resident-block watermark.
    fn enforce_watermark(&mut self) {
        if !self.cfg.enabled {
            return;
        }
        let limit = (self.cfg.evict_watermark.clamp(0.0, 1.0)
            * self.total_blocks as f64)
            .floor() as usize;
        while self.total_blocks - self.free_blocks > limit {
            if !self.evict_one() {
                break;
            }
        }
    }

    /// Admit a sequence, reserving blocks for its full token budget
    /// (prompt + max generation). No prefix sharing — the legacy path,
    /// and the exact accounting used when the cache is disabled.
    pub fn admit(&mut self, id: SeqId, prompt_tokens: usize, max_new: usize) -> Result<()> {
        if self.seqs.contains_key(&id) {
            bail!("sequence {id:?} already admitted");
        }
        let reserved_tokens = prompt_tokens + max_new;
        let need = self.blocks_for(reserved_tokens);
        if !self.ensure_free(need) {
            bail!(
                "kv pool exhausted: need {need} blocks, {} free",
                self.free_blocks
            );
        }
        self.free_blocks -= need;
        self.seqs.insert(id, SeqAlloc {
            blocks: need,
            shared: Vec::new(),
            preexisting: 0,
            hit_tokens: 0,
            tokens: prompt_tokens,
            reserved_tokens,
        });
        self.peak_blocks = self.peak_blocks.max(self.used_blocks());
        Ok(())
    }

    /// Prefix-aware admission: share the cached prompt-prefix blocks
    /// (refcounted), reserve fresh blocks only for the uncached suffix
    /// plus the generation budget, and insert the prompt's full blocks
    /// into the radix tree for later requests. Returns the cached token
    /// count (the engine's `prefix_tokens` offset). Falls back to
    /// [`Self::admit`] when the cache is disabled.
    pub fn admit_prefix(&mut self, id: SeqId, ids: &[i32], max_new: usize) -> Result<usize> {
        if !self.cfg.enabled {
            self.admit(id, ids.len().max(1), max_new)?;
            return Ok(0);
        }
        if self.seqs.contains_key(&id) {
            bail!("sequence {id:?} already admitted");
        }
        let prompt = ids.len().max(1);
        let full = ids.len() / self.block_tokens;
        // The ungated resident chain: every node is reused (referenced),
        // but only a run ≥ min_block_run counts as a *hit* (the engine's
        // skip offset and the hit/miss stats).
        let chain = self.match_chain(ids);
        let resident = chain.len();
        let hit_blocks = if resident >= self.cfg.min_block_run.max(1) {
            resident
        } else {
            0
        };
        let tail = prompt - full * self.block_tokens;
        let need = (full - resident) + self.blocks_for(tail + max_new);
        // Pin the resident chain (refs > 0 blocks eviction) before
        // making room for the suffix.
        self.lru_tick += 1;
        let tick = self.lru_tick;
        for &k in &chain {
            let n = self.cache.get_mut(&k).expect("resident node exists");
            n.refs += 1;
            n.last_use = tick;
        }
        for &k in &chain {
            self.adjust_live(Some(k), 1);
        }
        if !self.ensure_free(need) {
            for &k in &chain {
                self.cache.get_mut(&k).expect("pinned node exists").refs -= 1;
            }
            for &k in &chain {
                self.adjust_live(Some(k), -1);
            }
            bail!(
                "kv pool exhausted: need {need} blocks, {} free",
                self.free_blocks
            );
        }
        self.free_blocks -= need;
        // Insert the missed full prompt blocks as new shared nodes,
        // branching off the resident tip (copy-on-write: the first
        // divergent block gets a fresh physical block; shared blocks are
        // never written).
        let mut shared = chain;
        let mut private = self.blocks_for(tail + max_new);
        let mut parent_key = shared.last().copied();
        let mut ph = parent_key.unwrap_or(ROOT_HASH);
        let mut inserted = resident;
        while inserted < full {
            let chunk = &ids[inserted * self.block_tokens..(inserted + 1) * self.block_tokens];
            let h = chain_hash(ph, chunk);
            if self.cache.contains_key(&h) {
                // An identical chain would already be in `chain`, so an
                // occupied key is a true hash collision: keep this and
                // the remaining full blocks private instead of
                // corrupting the tree.
                break;
            }
            self.cache.insert(h, CacheNode {
                parent: parent_key,
                tokens: chunk.to_vec(),
                refs: 1,
                children: 0,
                live_desc: 0,
                last_use: tick,
            });
            if let Some(pk) = parent_key {
                self.cache.get_mut(&pk).expect("parent exists").children += 1;
            }
            self.adjust_live(Some(h), 1);
            shared.push(h);
            parent_key = Some(h);
            ph = h;
            inserted += 1;
        }
        // Collision fallback: un-inserted full blocks stay private
        // (blocks_for(k·bt + r) = k + blocks_for(r), so the per-sequence
        // block invariant still holds exactly).
        private += full - inserted;
        let cached = hit_blocks * self.block_tokens;
        self.seqs.insert(id, SeqAlloc {
            blocks: private,
            shared,
            preexisting: resident,
            hit_tokens: cached,
            tokens: prompt,
            reserved_tokens: prompt + max_new,
        });
        self.peak_blocks = self.peak_blocks.max(self.used_blocks());
        self.stats.hit_tokens += cached as u64;
        self.stats.miss_tokens += (prompt - cached) as u64;
        self.enforce_watermark();
        Ok(cached)
    }

    /// Record one generated token (always lands in a private block — the
    /// reservation covers tail + budget, so shared blocks stay read-only).
    pub fn append_token(&mut self, id: SeqId) -> Result<()> {
        let a = self
            .seqs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id:?}"))?;
        if a.tokens >= a.reserved_tokens {
            bail!("sequence {id:?} exceeded its reservation");
        }
        a.tokens += 1;
        Ok(())
    }

    /// Roll back up to `n` recorded tokens — the speculative-decode
    /// rejection path. Only the *logical* sequence length shrinks; the
    /// reservation (and thus every private block) is untouched and the
    /// shared prefix chain is never walked, so rollback can neither free
    /// a block nor perturb a refcount. Returns the tokens actually
    /// rolled back (capped at the current length).
    pub fn rollback_tokens(&mut self, id: SeqId, n: usize) -> usize {
        match self.seqs.get_mut(&id) {
            Some(a) => {
                let rolled = n.min(a.tokens);
                a.tokens -= rolled;
                rolled
            }
            None => 0,
        }
    }

    /// Release a finished sequence; returns its private blocks freed.
    /// Shared prefix blocks drop a reference but stay cache-resident for
    /// future hits (reclaimed LRU on demand or past the watermark).
    pub fn release(&mut self, id: SeqId) -> usize {
        match self.seqs.remove(&id) {
            Some(a) => {
                self.free_blocks += a.blocks;
                for k in a.shared.iter().rev() {
                    if let Some(n) = self.cache.get_mut(k) {
                        n.refs = n.refs.saturating_sub(1);
                    }
                }
                for k in a.shared.iter().rev() {
                    self.adjust_live(Some(*k), -1);
                }
                self.enforce_watermark();
                a.blocks
            }
            None => 0,
        }
    }

    /// Release a sequence whose prefill never executed (engine-refused
    /// rung) and *discard* the chain blocks it inserted instead of
    /// keeping them resident: advertising them as cached would hand
    /// later identical prompts a skip offset over KV that was never
    /// computed. Blocks that were resident before this admission hold
    /// KV from earlier, successful prefills and are kept.
    pub fn release_discard(&mut self, id: SeqId) -> usize {
        let (inserted, hit, miss) = self
            .seqs
            .get(&id)
            .map(|a| {
                (
                    a.shared[a.preexisting..].to_vec(),
                    a.hit_tokens,
                    // Prefill never ran, so no tokens were appended and
                    // `tokens` is still the admission-time prompt count.
                    a.tokens.saturating_sub(a.hit_tokens),
                )
            })
            .unwrap_or_default();
        // Roll the admission's hit/miss counters back too: the scaler's
        // hit-rate signal must not count requests that were never served.
        self.stats.hit_tokens = self.stats.hit_tokens.saturating_sub(hit as u64);
        self.stats.miss_tokens = self.stats.miss_tokens.saturating_sub(miss as u64);
        let freed = self.release(id);
        for k in inserted.iter().rev() {
            match self.cache.get(k) {
                // Already evicted (watermark ran inside release) — the
                // rest of the chain may still need discarding.
                None => continue,
                Some(n) if n.refs == 0 && n.children == 0 => {}
                // Still referenced or branched: another live sequence
                // shares it (callers release failed rungs in reverse
                // admission order so this resolves within the rung).
                _ => break,
            }
            let node = self.cache.remove(k).expect("checked above");
            if let Some(p) = node.parent {
                if let Some(pn) = self.cache.get_mut(&p) {
                    pn.children -= 1;
                }
            }
            self.free_blocks += 1;
        }
        freed
    }

    /// Top-`k` resident prefix chains as `(terminal chain hash, chain
    /// length in blocks)` pairs, most recently used first — the compact
    /// summary a replica advertises for cache-affinity routing. Only
    /// chain *tips* are listed (a chained hash commits to its whole root
    /// path, so one pair names the entire prefix), and only chains long
    /// enough to pass the min-run hit gate.
    pub fn hot_prefixes(&self, k: usize) -> Vec<(u64, u32)> {
        if !self.cfg.enabled || k == 0 {
            return Vec::new();
        }
        let min = self.cfg.min_block_run.max(1) as u32;
        let mut tips: Vec<(u64, u64, u32)> = Vec::new();
        for (h, n) in &self.cache {
            if n.children > 0 {
                continue;
            }
            let mut len = 0u32;
            let mut cur = Some(*h);
            while let Some(c) = cur {
                len += 1;
                cur = self.cache.get(&c).and_then(|x| x.parent);
            }
            if len < min {
                continue;
            }
            tips.push((n.last_use, *h, len));
        }
        // Most recently touched first; hash breaks ties deterministically.
        tips.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        tips.truncate(k);
        tips.into_iter().map(|(_, h, l)| (h, l)).collect()
    }

    /// Export the token blocks of the resident chain ending at
    /// `terminal`, root block first — the payload of a cross-replica
    /// prefix transfer. Every exported block is backed by KV a real
    /// prefill computed here (never-prefilled chains are discarded by
    /// [`Self::release_discard`] before they can be advertised). `None`
    /// when the chain is no longer fully resident.
    pub fn export_prefix(&self, terminal: u64) -> Option<Vec<Vec<i32>>> {
        if !self.cfg.enabled {
            return None;
        }
        let mut rev: Vec<Vec<i32>> = Vec::new();
        let mut cur = Some(terminal);
        while let Some(h) = cur {
            let n = self.cache.get(&h)?;
            rev.push(n.tokens.clone());
            cur = n.parent;
        }
        rev.reverse();
        Some(rev)
    }

    /// Import a transferred chain of token blocks (root block first),
    /// inserting them as resident unreferenced cache nodes — exactly the
    /// state a local prefill-then-release would leave. The donor only
    /// exports computed KV, so the imported chain is sound to advertise.
    /// Blocks already resident are just touched; the import stops early
    /// (keeping the valid leading run) on a malformed block, a hash
    /// collision, or an unevictable-full pool. Returns the tokens newly
    /// imported.
    pub fn import_prefix(&mut self, blocks: &[Vec<i32>]) -> usize {
        if !self.cfg.enabled {
            return 0;
        }
        self.lru_tick += 1;
        let tick = self.lru_tick;
        let mut parent: Option<u64> = None;
        let mut ph = ROOT_HASH;
        let mut imported = 0usize;
        // Hold a reference on each inserted node until the import ends so
        // the eviction scan run for a *later* block can never reclaim the
        // chain's own leading run out from under it.
        let mut pins: Vec<u64> = Vec::new();
        for block in blocks {
            if block.len() != self.block_tokens {
                break;
            }
            let h = chain_hash(ph, block);
            match self.cache.get_mut(&h) {
                Some(n) if n.parent == parent && n.tokens == *block => {
                    n.last_use = tick;
                }
                // Occupied by a different chain: a true hash collision —
                // stop rather than corrupt the tree.
                Some(_) => break,
                None => {
                    if !self.ensure_free(1) {
                        break;
                    }
                    // A matched (not inserted, so unpinned) tip can still
                    // be the eviction victim: linking to it would dangle.
                    if parent.is_some_and(|pk| !self.cache.contains_key(&pk)) {
                        break;
                    }
                    self.free_blocks -= 1;
                    self.cache.insert(h, CacheNode {
                        parent,
                        tokens: block.clone(),
                        refs: 1,
                        children: 0,
                        live_desc: 0,
                        last_use: tick,
                    });
                    if let Some(pk) = parent {
                        self.cache.get_mut(&pk).expect("parent resident").children += 1;
                    }
                    self.adjust_live(Some(h), 1);
                    pins.push(h);
                    imported += block.len();
                }
            }
            parent = Some(h);
            ph = h;
        }
        for k in pins.iter().rev() {
            if let Some(n) = self.cache.get_mut(k) {
                n.refs -= 1;
            }
        }
        for k in pins.iter().rev() {
            self.adjust_live(Some(*k), -1);
        }
        self.peak_blocks = self.peak_blocks.max(self.used_blocks());
        self.enforce_watermark();
        imported
    }

    /// Drop every reclaimable cache block (tests / explicit flush).
    /// Returns the blocks freed.
    pub fn purge_cache(&mut self) -> usize {
        let mut n = 0;
        while self.evict_one() {
            n += 1;
        }
        n
    }

    /// Physically occupied blocks (held by sequences or cache-resident).
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    /// Blocks resident in the prefix cache (shared + unreferenced).
    pub fn cache_blocks(&self) -> usize {
        self.cache.len()
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Referenced occupancy in [0, 1] — the admission/scaling signal.
    /// Cached-but-unreferenced blocks are reclaimable on demand and
    /// excluded (with the cache off this is exactly used/total).
    pub fn occupancy(&self) -> f64 {
        let referenced = self.total_blocks - self.free_blocks - self.reclaimable_blocks();
        referenced as f64 / self.total_blocks as f64
    }

    /// Internal fragmentation: reserved-but-unused token space as a
    /// fraction of held capacity (the block-size ablation's metric).
    /// Shared blocks count once per referencing sequence — sharing shows
    /// up as the same held token appearing in several reservations.
    pub fn internal_fragmentation(&self) -> f64 {
        let mut held_tokens = 0usize;
        let mut used_tokens = 0usize;
        for a in self.seqs.values() {
            held_tokens += (a.blocks + a.shared.len()) * self.block_tokens;
            used_tokens += a.tokens;
        }
        if held_tokens == 0 {
            0.0
        } else {
            1.0 - used_tokens as f64 / held_tokens as f64
        }
    }

    /// Invariant check used by property tests: block conservation,
    /// per-sequence reservations, refcounts and tree-link consistency.
    pub fn check_invariants(&self) -> Result<()> {
        let held: usize = self.seqs.values().map(|a| a.blocks).sum();
        if held + self.cache.len() + self.free_blocks != self.total_blocks {
            bail!(
                "block accounting broken: {held} private + {} cached + {} free != {}",
                self.cache.len(),
                self.free_blocks,
                self.total_blocks
            );
        }
        let mut want_refs: BTreeMap<u64, usize> = BTreeMap::new();
        for (id, a) in &self.seqs {
            if a.tokens > a.reserved_tokens {
                bail!("{id:?} tokens exceed reservation");
            }
            let shared_tokens = a.shared.len() * self.block_tokens;
            if self.blocks_for(a.reserved_tokens.saturating_sub(shared_tokens)) != a.blocks {
                bail!("{id:?} holds wrong private block count");
            }
            for k in &a.shared {
                if !self.cache.contains_key(k) {
                    bail!("{id:?} references evicted cache block {k:#x}");
                }
                *want_refs.entry(*k).or_insert(0) += 1;
            }
        }
        let mut want_children: BTreeMap<u64, usize> = BTreeMap::new();
        let mut want_live: BTreeMap<u64, usize> = BTreeMap::new();
        for (k, n) in &self.cache {
            if n.refs != want_refs.get(k).copied().unwrap_or(0) {
                bail!("node {k:#x} refcount {} != referencing seqs", n.refs);
            }
            if let Some(p) = n.parent {
                if !self.cache.contains_key(&p) {
                    bail!("node {k:#x} has dangling parent {p:#x}");
                }
                *want_children.entry(p).or_insert(0) += 1;
            }
            if n.tokens.len() != self.block_tokens {
                bail!("node {k:#x} holds {} tokens, not a full block", n.tokens.len());
            }
            if n.refs > 0 {
                let mut cur = Some(*k);
                while let Some(h) = cur {
                    *want_live.entry(h).or_insert(0) += n.refs;
                    cur = self.cache.get(&h).and_then(|x| x.parent);
                }
            }
        }
        let mut pinned = 0usize;
        for (k, n) in &self.cache {
            if n.children != want_children.get(k).copied().unwrap_or(0) {
                bail!("node {k:#x} child count {} inconsistent", n.children);
            }
            if n.live_desc != want_live.get(k).copied().unwrap_or(0) {
                bail!(
                    "node {k:#x} live_desc {} != subtree refs {}",
                    n.live_desc,
                    want_live.get(k).copied().unwrap_or(0)
                );
            }
            if n.live_desc > 0 {
                pinned += 1;
            }
        }
        if pinned != self.pinned_count {
            bail!(
                "pinned count {} != nodes with referenced descendants {pinned}",
                self.pinned_count
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_reserves_and_release_frees() {
        let mut kv = KvBlockManager::new(16, 16);
        kv.admit(SeqId(1), 40, 24).unwrap(); // 64 tokens → 4 blocks
        assert_eq!(kv.used_blocks(), 4);
        assert_eq!(kv.release(SeqId(1)), 4);
        assert_eq!(kv.free_blocks(), 16);
    }

    #[test]
    fn admission_rejects_when_full() {
        let mut kv = KvBlockManager::new(4, 16);
        kv.admit(SeqId(1), 32, 32).unwrap(); // 4 blocks
        assert!(!kv.probe(&[0], 0).admissible);
        assert!(kv.admit(SeqId(2), 1, 0).is_err());
    }

    #[test]
    fn append_respects_reservation() {
        let mut kv = KvBlockManager::new(8, 4);
        kv.admit(SeqId(1), 2, 2).unwrap(); // reserve 4 tokens
        kv.append_token(SeqId(1)).unwrap();
        kv.append_token(SeqId(1)).unwrap();
        assert!(kv.append_token(SeqId(1)).is_err()); // 5th token over budget
    }

    #[test]
    fn rollback_restores_headroom_without_freeing_blocks() {
        let mut kv = KvBlockManager::new(8, 4);
        kv.admit(SeqId(1), 2, 4).unwrap(); // reserve 6 tokens → 2 blocks
        for _ in 0..4 {
            kv.append_token(SeqId(1)).unwrap();
        }
        assert!(kv.append_token(SeqId(1)).is_err(), "budget exhausted");
        // Rolling back rejected draft tokens re-opens append headroom…
        assert_eq!(kv.rollback_tokens(SeqId(1), 3), 3);
        kv.check_invariants().unwrap();
        // …but never touches block accounting.
        assert_eq!(kv.used_blocks(), 2);
        for _ in 0..3 {
            kv.append_token(SeqId(1)).unwrap();
        }
        assert!(kv.append_token(SeqId(1)).is_err());
        // Over-rollback caps at the current length; unknown ids roll 0.
        assert_eq!(kv.rollback_tokens(SeqId(1), 100), 6);
        assert_eq!(kv.rollback_tokens(SeqId(99), 5), 0);
        kv.check_invariants().unwrap();
        assert_eq!(kv.release(SeqId(1)), 2);
    }

    #[test]
    fn rollback_never_perturbs_shared_prefix_refcounts() {
        let mut kv = prefix_kv(16, 4);
        let prompt = ids(0..8); // 2 shared blocks
        kv.admit_prefix(SeqId(1), &prompt, 4).unwrap();
        assert_eq!(kv.admit_prefix(SeqId(2), &prompt, 4).unwrap(), 8);
        kv.append_token(SeqId(2)).unwrap();
        kv.append_token(SeqId(2)).unwrap();
        assert_eq!(kv.rollback_tokens(SeqId(2), 2), 2);
        kv.check_invariants().unwrap();
        assert_eq!(kv.cache_blocks(), 2, "shared chain untouched by rollback");
        kv.release(SeqId(1));
        kv.release(SeqId(2));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_rejected() {
        let mut kv = KvBlockManager::new(8, 4);
        kv.admit(SeqId(1), 1, 1).unwrap();
        assert!(kv.admit(SeqId(1), 1, 1).is_err());
    }

    #[test]
    fn fragmentation_shrinks_with_small_blocks() {
        // Same workload, two block sizes: smaller blocks waste less.
        let mut big = KvBlockManager::new(64, 32);
        let mut small = KvBlockManager::new(512, 4);
        for i in 0..8 {
            big.admit(SeqId(i), 5, 4).unwrap(); // 9 tokens → 1×32 block
            small.admit(SeqId(i), 5, 4).unwrap(); // 9 tokens → 3×4 blocks
        }
        assert!(small.internal_fragmentation() < big.internal_fragmentation());
    }

    #[test]
    fn invariants_hold_through_churn() {
        let mut kv = KvBlockManager::new(32, 8);
        let mut rng = crate::util::rng::SplitMix64::new(7);
        let mut live: Vec<SeqId> = Vec::new();
        for i in 0..500u64 {
            if rng.chance(0.6) && kv.probe(&[7; 24], 0).admissible {
                let id = SeqId(i);
                if kv.admit(id, rng.below(16) as usize + 1, 8).is_ok() {
                    live.push(id);
                }
            } else if !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                kv.release(live.swap_remove(idx));
            }
            kv.check_invariants().unwrap();
        }
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut kv = KvBlockManager::new(16, 16);
        kv.admit(SeqId(1), 64, 0).unwrap(); // 4 blocks
        kv.admit(SeqId(2), 64, 0).unwrap(); // 8 total
        kv.release(SeqId(1));
        kv.release(SeqId(2));
        assert_eq!(kv.peak_blocks, 8);
        assert_eq!(kv.used_blocks(), 0);
    }

    // -- prefix cache ------------------------------------------------------

    fn prefix_kv(total: usize, block: usize) -> KvBlockManager {
        KvBlockManager::with_prefix_cache(total, block, PrefixCacheConfig::default())
    }

    fn ids(range: std::ops::Range<i32>) -> Vec<i32> {
        range.collect()
    }

    /// Cached prompt tokens a request would reuse right now (via the
    /// collapsed probe API).
    fn cached(kv: &KvBlockManager, ids: &[i32]) -> usize {
        kv.probe(ids, 0).cached_tokens
    }

    #[test]
    fn prefix_hit_shares_blocks_and_refcounts() {
        let mut kv = prefix_kv(16, 4);
        let prompt = ids(0..8); // 2 full blocks
        // First admission misses everything: 2 shared nodes + 1 private.
        assert_eq!(kv.admit_prefix(SeqId(1), &prompt, 4).unwrap(), 0);
        assert_eq!(kv.used_blocks(), 3);
        assert_eq!(kv.cache_blocks(), 2);
        assert_eq!(kv.stats.miss_tokens, 8);
        // Second admission hits the full 2-block prefix: +1 private only.
        assert_eq!(kv.admit_prefix(SeqId(2), &prompt, 4).unwrap(), 8);
        assert_eq!(kv.used_blocks(), 4);
        assert_eq!(kv.stats.hit_tokens, 8);
        kv.check_invariants().unwrap();
        // Releasing one keeps the shared blocks referenced by the other.
        kv.release(SeqId(1));
        assert_eq!(kv.cache_blocks(), 2);
        kv.check_invariants().unwrap();
        kv.release(SeqId(2));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefix_release_keeps_blocks_cached_for_reuse() {
        let mut kv = prefix_kv(16, 4);
        let prompt = ids(0..8);
        kv.admit_prefix(SeqId(1), &prompt, 4).unwrap();
        kv.release(SeqId(1));
        // The prefix stays resident after release…
        assert_eq!(cached(&kv, &prompt), 8);
        assert_eq!(kv.cache_blocks(), 2);
        // …so the next request still hits it.
        assert_eq!(kv.admit_prefix(SeqId(2), &prompt, 4).unwrap(), 8);
        kv.release(SeqId(2));
        // Explicit purge reclaims everything.
        assert_eq!(kv.purge_cache(), 2);
        assert_eq!(kv.free_blocks(), 16);
        assert_eq!(cached(&kv, &prompt), 0);
    }

    #[test]
    fn prefix_divergent_suffix_forks_radix_tree() {
        let mut kv = prefix_kv(32, 4);
        let a: Vec<i32> = [&ids(0..4)[..], &ids(100..104)[..]].concat();
        let b: Vec<i32> = [&ids(0..4)[..], &ids(200..204)[..]].concat();
        kv.admit_prefix(SeqId(1), &a, 2).unwrap();
        // b shares block 0, then copy-on-writes at the divergent block:
        // the tree branches, a's second block is untouched.
        assert_eq!(kv.admit_prefix(SeqId(2), &b, 2).unwrap(), 4);
        assert_eq!(kv.cache_blocks(), 3, "root block + two divergent children");
        kv.check_invariants().unwrap();
        // Both suffixes remain reachable.
        kv.release(SeqId(1));
        kv.release(SeqId(2));
        assert_eq!(cached(&kv, &a), 8);
        assert_eq!(cached(&kv, &b), 8);
    }

    #[test]
    fn prefix_lru_evicts_oldest_unreferenced() {
        // Pool of 4: two cached 1-block prefixes fill it alongside two
        // private blocks; a third admission must evict the LRU one.
        let mut kv = KvBlockManager::with_prefix_cache(4, 4, PrefixCacheConfig {
            enabled: true,
            min_block_run: 1,
            evict_watermark: 1.0, // no watermark pressure — demand-only
        });
        let old = ids(0..4);
        let newer = ids(10..14);
        kv.admit_prefix(SeqId(1), &old, 1).unwrap();
        kv.release(SeqId(1));
        kv.admit_prefix(SeqId(2), &newer, 1).unwrap();
        kv.release(SeqId(2));
        assert_eq!(kv.cache_blocks(), 2);
        // Needs 3 blocks (1 shared-new + 2 private) with only 2 free:
        // exactly one eviction, and it must be the LRU (old) block.
        let third = ids(20..24);
        kv.admit_prefix(SeqId(3), &third, 5).unwrap();
        assert_eq!(kv.stats.evicted_blocks, 1);
        assert_eq!(cached(&kv, &old), 0, "LRU evicted the oldest");
        assert_eq!(cached(&kv, &newer), 4, "newer survived");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefix_min_block_run_gates_short_matches() {
        let mut kv = KvBlockManager::with_prefix_cache(32, 4, PrefixCacheConfig {
            enabled: true,
            min_block_run: 2,
            evict_watermark: 0.9,
        });
        let short = ids(0..4); // 1 full block < min run
        let long = ids(0..8); // 2 full blocks ≥ min run
        kv.admit_prefix(SeqId(1), &long, 2).unwrap();
        kv.release(SeqId(1));
        assert_eq!(cached(&kv, &short), 0, "1-block match below min run");
        assert_eq!(cached(&kv, &long), 8);
        assert_eq!(kv.admit_prefix(SeqId(2), &long, 2).unwrap(), 8);
        kv.release(SeqId(2));
    }

    #[test]
    fn prefix_watermark_bounds_resident_cache() {
        // Watermark 0.5 of 8 blocks: unreferenced cache must never leave
        // residency above 4 blocks.
        let mut kv = KvBlockManager::with_prefix_cache(8, 4, PrefixCacheConfig {
            enabled: true,
            min_block_run: 1,
            evict_watermark: 0.5,
        });
        for i in 0..4i32 {
            let p = ids(i * 10..i * 10 + 4);
            kv.admit_prefix(SeqId(i as u64), &p, 1).unwrap();
            kv.release(SeqId(i as u64));
            assert!(kv.used_blocks() <= 4, "watermark exceeded: {}", kv.used_blocks());
            kv.check_invariants().unwrap();
        }
        assert!(kv.stats.evicted_blocks > 0);
    }

    #[test]
    fn prefix_disabled_matches_legacy_accounting() {
        let mut kv = KvBlockManager::new(16, 16);
        // admit_prefix degrades to the legacy reservation: no cache
        // nodes, no hits, identical block math.
        assert_eq!(kv.admit_prefix(SeqId(1), &ids(0..40), 24).unwrap(), 0);
        assert_eq!(kv.used_blocks(), 4); // blocks_for(40 + 24)
        assert_eq!(kv.cache_blocks(), 0);
        assert_eq!(kv.admit_prefix(SeqId(2), &ids(0..40), 24).unwrap(), 0);
        assert_eq!(kv.used_blocks(), 8, "no sharing when disabled");
        assert_eq!(kv.stats.hit_tokens + kv.stats.miss_tokens, 0);
        kv.release(SeqId(1));
        kv.release(SeqId(2));
        assert_eq!(kv.free_blocks(), 16);
    }

    #[test]
    fn prefix_failed_prefill_discards_uncomputed_blocks() {
        let mut kv = prefix_kv(16, 4);
        let prompt = ids(0..8);
        kv.admit_prefix(SeqId(1), &prompt, 4).unwrap();
        // The engine refused the rung: the chain was never prefilled, so
        // it must not be advertised as cached KV.
        kv.release_discard(SeqId(1));
        assert_eq!(cached(&kv, &prompt), 0);
        assert_eq!(kv.free_blocks(), 16);
        assert_eq!(kv.stats.miss_tokens, 0, "failed admission's stats roll back");
        kv.check_invariants().unwrap();
        // A chain still referenced by a live (really prefilled) sequence
        // survives a failed fork's discard.
        kv.admit_prefix(SeqId(2), &prompt, 4).unwrap();
        kv.admit_prefix(SeqId(3), &prompt, 4).unwrap();
        assert_eq!(kv.stats.hit_tokens, 8);
        kv.release_discard(SeqId(3));
        assert_eq!(cached(&kv, &prompt), 8, "live-referenced blocks survive");
        assert_eq!(kv.stats.hit_tokens, 0, "phantom hit rolled back");
        assert_eq!(kv.stats.miss_tokens, 8, "seq 2's real prefill still counted");
        kv.release(SeqId(2));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefix_occupancy_counts_referenced_blocks_only() {
        let mut kv = prefix_kv(16, 4);
        kv.admit_prefix(SeqId(1), &ids(0..8), 4).unwrap();
        assert!(kv.occupancy() > 0.0);
        kv.release(SeqId(1));
        // Cached blocks are reclaimable → zero *referenced* occupancy,
        // though the blocks are physically resident.
        assert_eq!(kv.occupancy(), 0.0);
        assert_eq!(kv.used_blocks(), 2);
    }

    #[test]
    fn prefix_invariants_hold_through_churn() {
        // SplitMix64 churn mirroring `invariants_hold_through_churn`,
        // with admissions forking off shared prefix families.
        let mut kv = prefix_kv(32, 4);
        let mut rng = crate::util::rng::SplitMix64::new(11);
        let bases: Vec<Vec<i32>> = (0..3)
            .map(|b| (0..16).map(|i| (b * 1000 + i) as i32).collect())
            .collect();
        let mut live: Vec<SeqId> = Vec::new();
        for i in 0..600u64 {
            if rng.chance(0.6) {
                let base = &bases[rng.below(3) as usize];
                let cut = rng.below(base.len() as u64 + 1) as usize;
                let mut p: Vec<i32> = base[..cut].to_vec();
                for _ in 0..rng.below(8) {
                    p.push(5000 + rng.below(64) as i32);
                }
                let max_new = rng.below(8) as usize + 1;
                if kv.probe(&p, max_new).admissible
                    && kv.admit_prefix(SeqId(i), &p, max_new).is_ok()
                {
                    live.push(SeqId(i));
                }
            } else if !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                kv.release(live.swap_remove(idx));
            }
            kv.check_invariants().unwrap();
        }
        for id in live {
            kv.release(id);
        }
        kv.check_invariants().unwrap();
        kv.purge_cache();
        assert_eq!(kv.free_blocks(), 32, "all blocks recovered after purge");
    }

    // -- probe / hot_prefixes / transfer -----------------------------------

    #[test]
    fn probe_matches_admission_arithmetic() {
        // Cache on: probe must agree with what admit_prefix then charges.
        let mut kv = prefix_kv(16, 4);
        let prompt = ids(0..10); // 2 full blocks + 2-token tail
        let p = kv.probe(&prompt, 4);
        assert_eq!(p.cached_tokens, 0);
        assert_eq!(p.needed_blocks, 2 + 2); // 2 prompt blocks + ceil(2+4 / 4)
        assert_eq!(p.suffix_blocks, 3);
        assert!(p.admissible);
        kv.admit_prefix(SeqId(1), &prompt, 4).unwrap();
        assert_eq!(kv.used_blocks(), p.needed_blocks);
        // Warm probe sees the cached prefix and charges the suffix only.
        let warm = kv.probe(&prompt, 4);
        assert_eq!(warm.cached_tokens, 8);
        assert_eq!(warm.needed_blocks, 2);
        kv.release(SeqId(1));

        // Cache off: identical to the legacy whole-reservation math.
        let kv = KvBlockManager::new(16, 4);
        let p = kv.probe(&prompt, 4);
        assert_eq!(p.cached_tokens, 0);
        assert_eq!(p.needed_blocks, 4); // blocks_for(10 + 4)
        assert_eq!(p.suffix_blocks, 3); // blocks_for(10)
        // Empty ids still cost the one-token prompt floor.
        assert_eq!(kv.probe(&[], 0).needed_blocks, 1);
    }

    #[test]
    fn probe_admissible_tracks_pool_headroom() {
        let mut kv = KvBlockManager::new(4, 16);
        assert!(kv.probe(&[1; 32], 32).admissible);
        kv.admit(SeqId(1), 32, 32).unwrap(); // all 4 blocks
        assert!(!kv.probe(&[1], 0).admissible);
        kv.release(SeqId(1));
        assert!(kv.probe(&[1], 0).admissible);
    }

    #[test]
    fn hot_prefixes_advertises_recent_chain_tips() {
        let mut kv = prefix_kv(32, 4);
        let a = ids(0..8); // 2-block chain
        let b = ids(100..104); // 1-block chain, touched later
        kv.admit_prefix(SeqId(1), &a, 1).unwrap();
        kv.release(SeqId(1));
        kv.admit_prefix(SeqId(2), &b, 1).unwrap();
        kv.release(SeqId(2));
        let hot = kv.hot_prefixes(8);
        assert_eq!(hot.len(), 2, "one entry per chain tip");
        assert_eq!(hot[0].1, 1, "most recent chain (b) first");
        assert_eq!(hot[1].1, 2, "a's tip advertises its full 2-block depth");
        // The advertised tip hash is the request's own chain hash at that
        // depth — exactly what the affinity scorer recomputes.
        let mut ph = ROOT_HASH;
        for chunk in a.chunks_exact(4) {
            ph = chain_hash(ph, chunk);
        }
        assert_eq!(hot[1].0, ph);
        // Top-k truncates.
        assert_eq!(kv.hot_prefixes(1).len(), 1);
        assert!(KvBlockManager::new(32, 4).hot_prefixes(8).is_empty());
    }

    #[test]
    fn export_import_transfers_a_prefix_between_pools() {
        let mut donor = prefix_kv(16, 4);
        let prompt = ids(0..12); // 3 full blocks
        donor.admit_prefix(SeqId(1), &prompt, 2).unwrap();
        donor.release(SeqId(1));
        let tip = donor.hot_prefixes(1)[0];
        assert_eq!(tip.1, 3);
        let blocks = donor.export_prefix(tip.0).expect("chain resident");
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[0], ids(0..4), "root block first");

        // Cold pool imports the run: the prefix becomes a local hit with
        // zero tokens lost — every prompt token is either cached or
        // still charged as suffix.
        let mut cold = prefix_kv(16, 4);
        assert_eq!(cold.probe(&prompt, 2).cached_tokens, 0);
        assert_eq!(cold.import_prefix(&blocks), 12);
        cold.check_invariants().unwrap();
        let p = cold.probe(&prompt, 2);
        assert_eq!(p.cached_tokens, 12);
        assert_eq!(cold.admit_prefix(SeqId(9), &prompt, 2).unwrap(), 12);
        cold.release(SeqId(9));
        // Idempotent: re-importing touches, never double-allocates.
        assert_eq!(cold.import_prefix(&blocks), 0);
        assert_eq!(cold.cache_blocks(), 3);
        cold.check_invariants().unwrap();
    }

    #[test]
    fn import_respects_pool_pressure_and_stays_consistent() {
        // 4-block pool with 3 blocks pinned by a live sequence: only one
        // block of the 3-block chain can land; the import must keep the
        // valid leading run and the invariants. (Watermark off — demand
        // pressure is what's under test.)
        let mut kv = KvBlockManager::with_prefix_cache(4, 4, PrefixCacheConfig {
            enabled: true,
            min_block_run: 1,
            evict_watermark: 1.0,
        });
        kv.admit(SeqId(1), 8, 4).unwrap(); // 3 private blocks (cache path off for admit)
        let chain: Vec<Vec<i32>> =
            vec![ids(0..4), ids(4..8), ids(8..12)];
        let imported = kv.import_prefix(&chain);
        assert_eq!(imported, 4, "only the root block fits");
        kv.check_invariants().unwrap();
        assert_eq!(kv.probe(&ids(0..4), 0).cached_tokens, 4);
        kv.release(SeqId(1));
        kv.check_invariants().unwrap();
        // Malformed (short) block: nothing imported, nothing corrupted.
        let mut kv = prefix_kv(8, 4);
        assert_eq!(kv.import_prefix(&[ids(0..3)]), 0);
        kv.check_invariants().unwrap();
    }
}
