//! Paged KV-cache block manager — the vLLM PagedAttention idea at the
//! coordinator level.
//!
//! The compiled decode modules hold a dense per-slot KV buffer on device
//! ([L, 2, B, H, Smax, Dh]); this manager owns the *logical* accounting:
//! sequences acquire fixed-size token blocks from a bounded pool, and a
//! batch slot can only be admitted when enough blocks remain for its
//! prompt plus its token budget (reservation-based admission — no
//! mid-flight OOM evictions). Fragmentation and occupancy statistics feed
//! the §Perf ablations (block-size sweep).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A sequence being served.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SeqId(pub u64);

/// Block-granular KV accounting for one replica.
#[derive(Debug)]
pub struct KvBlockManager {
    pub block_tokens: usize,
    pub total_blocks: usize,
    free_blocks: usize,
    /// Per-sequence (blocks_held, tokens_used, tokens_reserved).
    seqs: BTreeMap<SeqId, SeqAlloc>,
    /// High-water mark (peak occupancy) for reports.
    pub peak_blocks: usize,
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    blocks: usize,
    tokens: usize,
    reserved_tokens: usize,
}

impl KvBlockManager {
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        assert!(total_blocks > 0 && block_tokens > 0);
        Self {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
            seqs: BTreeMap::new(),
            peak_blocks: 0,
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence with this worst-case token need be admitted now?
    pub fn can_admit(&self, max_tokens: usize) -> bool {
        self.blocks_for(max_tokens) <= self.free_blocks
    }

    /// Admit a sequence, reserving blocks for its full token budget
    /// (prompt + max generation).
    pub fn admit(&mut self, id: SeqId, prompt_tokens: usize, max_new: usize) -> Result<()> {
        if self.seqs.contains_key(&id) {
            bail!("sequence {id:?} already admitted");
        }
        let reserved_tokens = prompt_tokens + max_new;
        let need = self.blocks_for(reserved_tokens);
        if need > self.free_blocks {
            bail!(
                "kv pool exhausted: need {need} blocks, {} free",
                self.free_blocks
            );
        }
        self.free_blocks -= need;
        self.seqs.insert(id, SeqAlloc {
            blocks: need,
            tokens: prompt_tokens,
            reserved_tokens,
        });
        self.peak_blocks = self.peak_blocks.max(self.used_blocks());
        Ok(())
    }

    /// Record one generated token.
    pub fn append_token(&mut self, id: SeqId) -> Result<()> {
        let a = self
            .seqs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id:?}"))?;
        if a.tokens >= a.reserved_tokens {
            bail!("sequence {id:?} exceeded its reservation");
        }
        a.tokens += 1;
        Ok(())
    }

    /// Release a finished sequence; returns blocks freed.
    pub fn release(&mut self, id: SeqId) -> usize {
        match self.seqs.remove(&id) {
            Some(a) => {
                self.free_blocks += a.blocks;
                a.blocks
            }
            None => 0,
        }
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Occupancy in [0, 1].
    pub fn occupancy(&self) -> f64 {
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    /// Internal fragmentation: reserved-but-unused token space as a
    /// fraction of held capacity (the block-size ablation's metric).
    pub fn internal_fragmentation(&self) -> f64 {
        let mut held_tokens = 0usize;
        let mut used_tokens = 0usize;
        for a in self.seqs.values() {
            held_tokens += a.blocks * self.block_tokens;
            used_tokens += a.tokens;
        }
        if held_tokens == 0 {
            0.0
        } else {
            1.0 - used_tokens as f64 / held_tokens as f64
        }
    }

    /// Invariant check used by property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let held: usize = self.seqs.values().map(|a| a.blocks).sum();
        if held + self.free_blocks != self.total_blocks {
            bail!("block accounting broken: {held} held + {} free != {}",
                  self.free_blocks, self.total_blocks);
        }
        for (id, a) in &self.seqs {
            if a.tokens > a.reserved_tokens {
                bail!("{id:?} tokens exceed reservation");
            }
            if self.blocks_for(a.reserved_tokens) != a.blocks {
                bail!("{id:?} holds wrong block count");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_reserves_and_release_frees() {
        let mut kv = KvBlockManager::new(16, 16);
        kv.admit(SeqId(1), 40, 24).unwrap(); // 64 tokens → 4 blocks
        assert_eq!(kv.used_blocks(), 4);
        assert_eq!(kv.release(SeqId(1)), 4);
        assert_eq!(kv.free_blocks(), 16);
    }

    #[test]
    fn admission_rejects_when_full() {
        let mut kv = KvBlockManager::new(4, 16);
        kv.admit(SeqId(1), 32, 32).unwrap(); // 4 blocks
        assert!(!kv.can_admit(1));
        assert!(kv.admit(SeqId(2), 1, 0).is_err());
    }

    #[test]
    fn append_respects_reservation() {
        let mut kv = KvBlockManager::new(8, 4);
        kv.admit(SeqId(1), 2, 2).unwrap(); // reserve 4 tokens
        kv.append_token(SeqId(1)).unwrap();
        kv.append_token(SeqId(1)).unwrap();
        assert!(kv.append_token(SeqId(1)).is_err()); // 5th token over budget
    }

    #[test]
    fn double_admit_rejected() {
        let mut kv = KvBlockManager::new(8, 4);
        kv.admit(SeqId(1), 1, 1).unwrap();
        assert!(kv.admit(SeqId(1), 1, 1).is_err());
    }

    #[test]
    fn fragmentation_shrinks_with_small_blocks() {
        // Same workload, two block sizes: smaller blocks waste less.
        let mut big = KvBlockManager::new(64, 32);
        let mut small = KvBlockManager::new(512, 4);
        for i in 0..8 {
            big.admit(SeqId(i), 5, 4).unwrap(); // 9 tokens → 1×32 block
            small.admit(SeqId(i), 5, 4).unwrap(); // 9 tokens → 3×4 blocks
        }
        assert!(small.internal_fragmentation() < big.internal_fragmentation());
    }

    #[test]
    fn invariants_hold_through_churn() {
        let mut kv = KvBlockManager::new(32, 8);
        let mut rng = crate::util::rng::SplitMix64::new(7);
        let mut live: Vec<SeqId> = Vec::new();
        for i in 0..500u64 {
            if rng.chance(0.6) && kv.can_admit(24) {
                let id = SeqId(i);
                if kv.admit(id, rng.below(16) as usize + 1, 8).is_ok() {
                    live.push(id);
                }
            } else if !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                kv.release(live.swap_remove(idx));
            }
            kv.check_invariants().unwrap();
        }
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut kv = KvBlockManager::new(16, 16);
        kv.admit(SeqId(1), 64, 0).unwrap(); // 4 blocks
        kv.admit(SeqId(2), 64, 0).unwrap(); // 8 total
        kv.release(SeqId(1));
        kv.release(SeqId(2));
        assert_eq!(kv.peak_blocks, 8);
        assert_eq!(kv.used_blocks(), 0);
    }
}
