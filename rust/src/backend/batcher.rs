//! Dynamic batcher — forms decode/prefill batches for the compiled
//! batch sizes.
//!
//! The AOT pipeline exports each module at fixed batch sizes (decode at
//! 1/4/8, prefill at 1/4); the batcher packs waiting work into the
//! largest compiled size that the queue can fill, padding the remainder
//! (padding rows are masked out downstream). Backends differ in policy:
//! vLLM-like batches eagerly at max size (throughput), TRT-like caps
//! batch size low (latency), TGI-like batches at moderate size with a
//! flush timeout.

use crate::models::BackendKind;

/// Batch-size ladders matching `python/compile/aot.py`.
pub const DECODE_BATCHES: [usize; 3] = [1, 4, 8];
pub const PREFILL_BATCHES: [usize; 2] = [1, 4];

/// Rung count, for sizing per-rung metric arrays alongside the ladder.
pub const N_DECODE_BATCHES: usize = DECODE_BATCHES.len();

/// Policy knobs per backend kind.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Largest decode batch this backend will form.
    pub max_decode_batch: usize,
    /// Largest prefill batch.
    pub max_prefill_batch: usize,
    /// Max time a request may wait for batch-mates before we flush.
    pub flush_timeout_s: f64,
}

impl BatchPolicy {
    pub fn for_backend(kind: BackendKind) -> BatchPolicy {
        match kind {
            // Throughput: fill the biggest compiled batch.
            BackendKind::Vllm => BatchPolicy {
                max_decode_batch: 8,
                max_prefill_batch: 4,
                flush_timeout_s: 0.050,
            },
            // Latency: keep batches small, flush almost immediately.
            BackendKind::TrtLlm => BatchPolicy {
                max_decode_batch: 4,
                max_prefill_batch: 1,
                flush_timeout_s: 0.005,
            },
            // Memory-lean middle ground.
            BackendKind::Tgi => BatchPolicy {
                max_decode_batch: 4,
                max_prefill_batch: 4,
                flush_timeout_s: 0.025,
            },
        }
    }

    /// Policy from explicit knobs (the engine pool's config overrides).
    pub fn custom(
        max_decode_batch: usize,
        max_prefill_batch: usize,
        flush_timeout_s: f64,
    ) -> BatchPolicy {
        BatchPolicy { max_decode_batch, max_prefill_batch, flush_timeout_s }
    }

    /// Pick the compiled batch size for `waiting` ready items: the
    /// largest ladder size ≤ min(waiting, policy max) — or that same
    /// partial rung if the timeout forces a flush. Returns `None` when
    /// nothing is waiting, when the cap sits below the smallest ladder
    /// size, or when it is worth holding out for a fuller batch. "Full"
    /// means the largest *rung* this policy can ever form — a cap
    /// between rungs (say 6) must not make a maxed-out rung-4 batch
    /// wait for a fill that cannot happen.
    pub fn decode_batch_size(&self, waiting: usize, timed_out: bool) -> Option<usize> {
        let cap = self.max_decode_batch.min(waiting);
        let fit = DECODE_BATCHES.iter().rev().find(|&&b| b <= cap).copied()?;
        let top = DECODE_BATCHES
            .iter()
            .rev()
            .find(|&&b| b <= self.max_decode_batch)
            .copied()?;
        if timed_out || fit == top {
            Some(fit)
        } else {
            // Not full yet and the flush window is still open: hold for
            // batch-mates.
            None
        }
    }

    /// Same for prefill.
    pub fn prefill_batch_size(&self, waiting: usize, timed_out: bool) -> Option<usize> {
        let cap = self.max_prefill_batch.min(waiting);
        let fit = PREFILL_BATCHES.iter().rev().find(|&&b| b <= cap).copied()?;
        let top = PREFILL_BATCHES
            .iter()
            .rev()
            .find(|&&b| b <= self.max_prefill_batch)
            .copied()?;
        if timed_out || fit == top {
            Some(fit)
        } else {
            None
        }
    }
}

/// Batch efficiency: useful rows / padded rows — the batching ablation's
/// metric.
pub fn batch_efficiency(useful: usize, batch: usize) -> f64 {
    if batch == 0 {
        0.0
    } else {
        useful as f64 / batch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vllm_waits_to_fill_big_batches() {
        let p = BatchPolicy::for_backend(BackendKind::Vllm);
        // 3 waiting, no timeout → hold for more.
        assert_eq!(p.decode_batch_size(3, false), None);
        // Timeout → flush partial batch at the largest fitting rung.
        assert_eq!(p.decode_batch_size(3, true), Some(1));
        assert_eq!(p.decode_batch_size(4, true), Some(4));
        // Full queue → max batch immediately.
        assert_eq!(p.decode_batch_size(9, false), Some(8));
    }

    #[test]
    fn trt_flushes_small() {
        let p = BatchPolicy::for_backend(BackendKind::TrtLlm);
        assert_eq!(p.decode_batch_size(8, false), Some(4));
        assert_eq!(p.decode_batch_size(1, true), Some(1));
        assert!(p.flush_timeout_s < 0.01);
    }

    #[test]
    fn empty_queue_never_batches() {
        for kind in BackendKind::ALL {
            let p = BatchPolicy::for_backend(kind);
            assert_eq!(p.decode_batch_size(0, true), None);
            assert_eq!(p.prefill_batch_size(0, true), None);
        }
    }

    #[test]
    fn prefill_ladder() {
        let p = BatchPolicy::for_backend(BackendKind::Vllm);
        assert_eq!(p.prefill_batch_size(1, true), Some(1));
        assert_eq!(p.prefill_batch_size(4, false), Some(4));
        assert_eq!(p.prefill_batch_size(2, false), None); // wait to fill
        assert_eq!(p.prefill_batch_size(2, true), Some(1));
    }

    #[test]
    fn batch_sizes_are_compiled_sizes() {
        for kind in BackendKind::ALL {
            let p = BatchPolicy::for_backend(kind);
            for waiting in 0..20 {
                for timed_out in [false, true] {
                    if let Some(b) = p.decode_batch_size(waiting, timed_out) {
                        assert!(DECODE_BATCHES.contains(&b), "{b} not compiled");
                        assert!(b <= waiting.max(1));
                    }
                    if let Some(b) = p.prefill_batch_size(waiting, timed_out) {
                        assert!(PREFILL_BATCHES.contains(&b));
                    }
                }
            }
        }
    }

    #[test]
    fn efficiency_metric() {
        assert_eq!(batch_efficiency(3, 4), 0.75);
        assert_eq!(batch_efficiency(0, 0), 0.0);
    }

    #[test]
    fn waiting_zero_never_batches_even_on_timeout() {
        for kind in BackendKind::ALL {
            let p = BatchPolicy::for_backend(kind);
            for timed_out in [false, true] {
                assert_eq!(p.decode_batch_size(0, timed_out), None);
                assert_eq!(p.prefill_batch_size(0, timed_out), None);
            }
        }
    }

    #[test]
    fn timeout_flushes_to_smallest_ladder_size() {
        let p = BatchPolicy::for_backend(BackendKind::Vllm);
        // One straggler: the flush timer fires and it runs at the
        // smallest compiled size rather than waiting forever.
        assert_eq!(p.decode_batch_size(1, true), Some(DECODE_BATCHES[0]));
        assert_eq!(p.prefill_batch_size(1, true), Some(PREFILL_BATCHES[0]));
        // …but while the window is open it holds for batch-mates.
        assert_eq!(p.decode_batch_size(1, false), None);
    }

    #[test]
    fn cap_below_smallest_ladder_refuses() {
        // A policy capped below the smallest compiled size can never
        // form a batch — decode/prefill must both return None instead of
        // an uncompiled size.
        let p = BatchPolicy::custom(0, 0, 0.01);
        for waiting in 0..10 {
            for timed_out in [false, true] {
                assert_eq!(p.decode_batch_size(waiting, timed_out), None);
                assert_eq!(p.prefill_batch_size(waiting, timed_out), None);
            }
        }
    }

    #[test]
    fn custom_policy_caps_at_intermediate_rung() {
        // Cap between ladder rungs (6 ∈ (4, 8)): rung 4 is the fullest
        // batch this policy can ever form, so once it forms it must run
        // without waiting for a fill that cannot happen.
        let p = BatchPolicy::custom(6, 4, 0.02);
        assert_eq!(p.decode_batch_size(32, false), Some(4));
        assert_eq!(p.decode_batch_size(4, false), Some(4));
        // Below the top rung it still holds for batch-mates…
        assert_eq!(p.decode_batch_size(3, false), None);
        // …until the flush timer fires.
        assert_eq!(p.decode_batch_size(3, true), Some(1));
    }
}
