//! Backend pool — request/response types and the per-backend service
//! model shared by live and simulated execution.
//!
//! * [`kv_cache`] — block-granular KV accounting (PagedAttention-style).
//! * [`batcher`] — dynamic batching policies per backend kind.
//! * [`scheduler`] — the continuous-batching replica loop (slot
//!   management + batch formation over `batcher` and `kv_cache`).
//! * [`service_time`] — the calibrated service-time model the
//!   discrete-event simulator samples from (live mode measures instead).

pub mod batcher;
pub mod kv_cache;
pub mod scheduler;

use crate::models::{BackendKind, ModelSpec};
use crate::util::rng::SplitMix64;

/// A request as the backend pool sees it (routing already happened).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub prompt: String,
    pub benchmark: String,
    /// Ground-truth complexity from the workload generator (evaluation
    /// only — routing must not look at it).
    pub true_complexity: usize,
    pub in_tokens: usize,
    pub max_new_tokens: usize,
    pub arrival_s: f64,
}

/// The outcome of serving one request.
#[derive(Debug, Clone)]
pub struct InferenceOutcome {
    pub request_id: u64,
    /// Time to first token (queue + cold start + classification + prefill).
    pub ttft_s: f64,
    /// End-to-end latency.
    pub latency_s: f64,
    pub tokens_out: usize,
    pub success: bool,
    /// $ attributed to this query.
    pub cost_usd: f64,
    pub service: crate::registry::ServiceId,
    /// Complexity the router predicted (for routing-accuracy metrics).
    pub predicted_complexity: usize,
}

/// Sampled service time for one request on one (model, backend) pair.
#[derive(Debug, Clone, Copy)]
pub struct ServiceTime {
    /// Prefill completes (first token) after this many seconds of work.
    pub prefill_s: f64,
    /// Decode completes after this much additional work.
    pub decode_s: f64,
}

impl ServiceTime {
    pub fn total(&self) -> f64 {
        self.prefill_s + self.decode_s
    }
}

/// Sample the service time: deterministic token-rate core with log-normal
/// jitter (±~10%), matching the long-tail shape of real serving traces.
pub fn service_time(
    spec: &ModelSpec,
    backend: BackendKind,
    in_tokens: usize,
    out_tokens: usize,
    rng: &mut SplitMix64,
) -> ServiceTime {
    service_time_with_prefix(spec, backend, in_tokens, 0, out_tokens, rng)
}

/// [`service_time`] with a prefix-cache offset: `cached_tokens` of the
/// prompt have KV-resident blocks and skip prefill compute, so only the
/// uncached suffix pays prefill time. Draws the same jitter stream as
/// the uncached path, so cached/uncached sweeps stay sample-comparable.
pub fn service_time_with_prefix(
    spec: &ModelSpec,
    backend: BackendKind,
    in_tokens: usize,
    cached_tokens: usize,
    out_tokens: usize,
    rng: &mut SplitMix64,
) -> ServiceTime {
    let lf = backend.latency_factor();
    let jitter = rng.lognormal(0.0, 0.1);
    let suffix = in_tokens.saturating_sub(cached_tokens);
    let prefill = suffix as f64 / spec.prefill_tps * lf * jitter;
    let jitter2 = rng.lognormal(0.0, 0.1);
    let decode = out_tokens as f64 / spec.decode_tps * lf * jitter2;
    ServiceTime { prefill_s: prefill, decode_s: decode }
}

/// Expected tokens landed per verify step under speculative decoding
/// with per-token acceptance rate `accept` and draft window `k`: the
/// correction token always lands, plus draft token `i` iff the first `i`
/// drafts all pass — `1 + Σ_{i=1..k} a^i`. This is the decode-throughput
/// multiplier the simulator and scaler use; 1.0 when speculation is off
/// (`k = 0` or `accept ≤ 0`).
pub fn spec_tokens_per_step(accept: f64, k: usize) -> f64 {
    let a = accept.clamp(0.0, 1.0);
    let mut run = 1.0;
    let mut total = 1.0;
    for _ in 0..k {
        run *= a;
        total += run;
    }
    total
}

/// $ cost of one request: the replica-seconds it occupied divided by the
/// streams sharing the replica, at the model's GPU rate.
pub fn request_cost_usd(
    spec: &ModelSpec,
    backend: BackendKind,
    busy_s: f64,
    concurrent_streams: usize,
) -> f64 {
    let sharing = concurrent_streams.max(1) as f64;
    busy_s * spec.cost_per_replica_second() * backend.cost_factor() / sharing
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn service_time_scales_with_tokens() {
        let z = zoo();
        let mut rng = SplitMix64::new(1);
        let short = service_time(&z[0], BackendKind::Vllm, 50, 20, &mut rng);
        let long = service_time(&z[0], BackendKind::Vllm, 50, 400, &mut rng);
        assert!(long.decode_s > short.decode_s * 10.0);
    }

    #[test]
    fn big_models_slower() {
        let z = zoo();
        let mut rng = SplitMix64::new(2);
        let small = service_time(&z[0], BackendKind::Vllm, 100, 100, &mut rng);
        let big = service_time(&z[3], BackendKind::Vllm, 100, 100, &mut rng);
        assert!(big.total() > small.total() * 2.0);
    }

    #[test]
    fn trt_cuts_latency() {
        let z = zoo();
        // Same seed → same jitter draws, isolating the backend factor.
        let mut r1 = SplitMix64::new(3);
        let mut r2 = SplitMix64::new(3);
        let vllm = service_time(&z[1], BackendKind::Vllm, 100, 100, &mut r1);
        let trt = service_time(&z[1], BackendKind::TrtLlm, 100, 100, &mut r2);
        assert!(trt.total() < vllm.total());
    }

    #[test]
    fn jitter_is_bounded_ish() {
        let z = zoo();
        let mut rng = SplitMix64::new(4);
        let base = 100.0 / z[0].decode_tps;
        for _ in 0..1000 {
            let st = service_time(&z[0], BackendKind::Vllm, 0, 100, &mut rng);
            assert!(st.decode_s > base * 0.5 && st.decode_s < base * 2.0);
        }
    }

    #[test]
    fn prefix_offset_cuts_prefill_only() {
        let z = zoo();
        // Same seed → same jitter draws, isolating the cached offset.
        let mut r1 = SplitMix64::new(9);
        let mut r2 = SplitMix64::new(9);
        let cold = service_time(&z[1], BackendKind::Vllm, 200, 100, &mut r1);
        let warm =
            service_time_with_prefix(&z[1], BackendKind::Vllm, 200, 150, 100, &mut r2);
        assert!(warm.prefill_s < cold.prefill_s * 0.5);
        assert_eq!(warm.decode_s, cold.decode_s);
        // Over-claimed cache saturates at zero prefill, never negative.
        let mut r3 = SplitMix64::new(9);
        let over =
            service_time_with_prefix(&z[1], BackendKind::Vllm, 100, 500, 10, &mut r3);
        assert_eq!(over.prefill_s, 0.0);
    }

    #[test]
    fn spec_tokens_per_step_matches_the_geometric_sum() {
        // Off: exactly one token per step.
        assert_eq!(spec_tokens_per_step(0.0, 4), 1.0);
        assert_eq!(spec_tokens_per_step(0.7, 0), 1.0);
        // Perfect acceptance lands the whole window plus the correction.
        assert_eq!(spec_tokens_per_step(1.0, 4), 5.0);
        // a=0.5, k=2 → 1 + 0.5 + 0.25.
        assert!((spec_tokens_per_step(0.5, 2) - 1.75).abs() < 1e-12);
        // Out-of-range rates clamp rather than exploding the multiplier.
        assert_eq!(spec_tokens_per_step(3.0, 4), 5.0);
        assert_eq!(spec_tokens_per_step(-1.0, 4), 1.0);
    }

    #[test]
    fn cost_divides_by_sharing() {
        let z = zoo();
        let solo = request_cost_usd(&z[2], BackendKind::Vllm, 10.0, 1);
        let shared = request_cost_usd(&z[2], BackendKind::Vllm, 10.0, 8);
        assert!((solo / shared - 8.0).abs() < 1e-9);
    }
}
