//! Continuous-batching scheduler — the per-replica serving loop.
//!
//! One scheduler owns one engine replica's in-flight sequences ("slots").
//! The gateway drains routed jobs into it; admissions buffer briefly so
//! *prefill* also runs at the compiled ladder rungs ([`BatchPolicy`]'s
//! `PREFILL_BATCHES`) instead of serially per sequence; decode batches
//! form at the decode ladder sizes (largest rung the in-flight set can
//! fill, flush timeout for partial rungs), interleaving steps across
//! sequences at different positions. A sequence retires the moment its
//! budget is exhausted — or the moment its [`CancelToken`] fires (a
//! timed-out caller frees its slot early instead of decoding to
//! completion) — releasing its slot and KV reservation for the next
//! queued request immediately.
//!
//! The scheduler is deliberately a pure state machine over an abstract
//! [`StepEngine`]: the live path plugs in [`crate::runtime::LmEngine`]
//! (PJRT), while tests and benches use [`SimStepEngine`] — so the whole
//! slot/batch/flush logic is exercised in CI without artifacts.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::backend::batcher::BatchPolicy;
use crate::backend::kv_cache::{KvBlockManager, PrefixCacheConfig, PrefixStats, SeqId};
use crate::config::SpeculativeConfig;
use crate::telemetry::Histogram;

/// Shared cancellation flag for one request: the caller's side sets it
/// (e.g. on request timeout), the scheduler checks it every tick and
/// evicts the sequence mid-flight.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// What the scheduler needs from a per-sequence decode state.
pub trait SeqLike {
    /// Tokens emitted so far (prefill token first).
    fn tokens(&self) -> &[i32];
    /// Consume the sequence, yielding its tokens.
    fn into_tokens(self) -> Vec<i32>
    where
        Self: Sized;
    fn prompt_tokens(&self) -> usize;
    /// Budget exhausted — must never be stepped again.
    fn done(&self) -> bool;
}

/// An engine replica the scheduler can drive: prefill prompts into
/// sequences, then advance batches of sequences one token at a time.
pub trait StepEngine {
    type Seq: SeqLike;

    /// Prefill a prompt; the returned sequence holds its first token.
    /// `prefix_tokens` is the leading prompt span whose KV the paged
    /// pool already holds (a radix prefix-cache hit): engines skip that
    /// prefill work — the sim engine models the time saved, while the
    /// compiled PJRT prefill still recomputes its full batch-1 window
    /// until suffix-prefill modules are exported (ROADMAP).
    fn start(&mut self, prompt: &str, max_new: usize, prefix_tokens: usize)
        -> Result<Self::Seq>;

    /// Prefill a ladder rung of prompts (`(prompt, max_new,
    /// prefix_tokens)` triples) in one dispatch. The default runs
    /// serially; engines with batched prefill override it to amortize
    /// the dispatch cost.
    fn start_batch(&mut self, reqs: &[(&str, usize, usize)]) -> Result<Vec<Self::Seq>> {
        reqs.iter().map(|&(p, m, c)| self.start(p, m, c)).collect()
    }

    /// One decode step for every sequence in `batch` (its length is
    /// always a compiled ladder size ≤ [`Self::max_batch`]).
    fn step(&mut self, batch: &mut [&mut Self::Seq]) -> Result<()>;

    /// Propose up to `k` draft tokens for `seq` — the small-tier half of
    /// cross-tier speculative decoding. Engines that cannot draft return
    /// an empty vec (the default), and the scheduler falls back to plain
    /// decode for that batch. Implementations must cap the draft at the
    /// sequence's remaining budget minus one, so the verify step's
    /// correction token always has headroom.
    fn draft_tokens(&mut self, seq: &Self::Seq, k: usize) -> Vec<i32> {
        let _ = (seq, k);
        Vec::new()
    }

    /// Score each sequence's draft tokens against its resident KV in
    /// *one* batched step, appending the longest accepted draft prefix
    /// plus one correction token (so a verify step always lands between
    /// 1 and k + 1 tokens per sequence). Returns the count of **draft**
    /// tokens accepted per sequence, aligned with `batch`. The default
    /// ignores the drafts and runs a plain step — engines without a
    /// verify kernel degrade to ordinary decode, never to an error.
    fn verify_batch(
        &mut self,
        batch: &mut [&mut Self::Seq],
        drafts: &[&[i32]],
    ) -> Result<Vec<usize>> {
        let _ = drafts;
        self.step(batch)?;
        Ok(vec![0; batch.len()])
    }

    /// Largest decode batch this engine can execute.
    fn max_batch(&self) -> usize {
        usize::MAX
    }

    /// Longest prompt (in tokens) the engine keeps — longer prompts are
    /// truncated at prefill. Bounds KV admission estimates so an
    /// oversized request cannot be mistaken for unserveable.
    fn max_prompt_tokens(&self) -> usize {
        usize::MAX
    }

    /// Most tokens one sequence can ever generate (the engine clamps
    /// budgets to its context window). Bounds KV reservations so a huge
    /// `max_new` neither hard-fails admission nor hoards blocks that can
    /// never be written.
    fn max_new_tokens(&self) -> usize {
        usize::MAX
    }

    /// Ingest `tokens` tokens of *transferred* KV (a cross-replica
    /// prefix transfer landing in the local pool). Engines model the
    /// transfer/ingest cost here so it is comparable against the prefill
    /// recompute it replaces; the default no-op suits engines that can't
    /// ingest foreign KV yet (they simply don't take transfers).
    fn ingest_kv(&mut self, tokens: usize) {
        let _ = tokens;
    }
}

impl SeqLike for crate::runtime::Sequence {
    fn tokens(&self) -> &[i32] {
        crate::runtime::Sequence::tokens(self)
    }

    fn into_tokens(self) -> Vec<i32> {
        crate::runtime::Sequence::into_tokens(self)
    }

    fn prompt_tokens(&self) -> usize {
        crate::runtime::Sequence::prompt_tokens(self)
    }

    fn done(&self) -> bool {
        crate::runtime::Sequence::done(self)
    }
}

impl StepEngine for crate::runtime::LmEngine {
    type Seq = crate::runtime::Sequence;

    fn start(&mut self, prompt: &str, max_new: usize, prefix_tokens: usize)
        -> Result<Self::Seq> {
        self.start_seq(prompt, max_new, prefix_tokens)
    }

    // `start_batch` keeps the serial default: the AOT pipeline compiles
    // prefill at batch 1 only (decode gets the ladder), so rung-sized
    // prefill dispatches become real once multi-batch prefill modules
    // are exported.

    fn step(&mut self, batch: &mut [&mut Self::Seq]) -> Result<()> {
        self.step_batch(batch)
    }

    fn max_batch(&self) -> usize {
        self.max_decode_batch()
    }

    fn max_prompt_tokens(&self) -> usize {
        self.seq_prefill
    }

    fn max_new_tokens(&self) -> usize {
        // `start_seq` clamps every budget to the compiled context.
        self.seq_max
    }

    // `draft_tokens` / `verify_batch` keep the trait defaults: the
    // compiled path decodes plainly until a multi-position verify module
    // is exported (ROADMAP direction 4's compiled half);
    // `Sequence::rollback_draft` is the cleanup hook it will use.
}

/// Scheduler knobs (derived from [`crate::config::PoolConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    pub policy: BatchPolicy,
    /// Decode slots (max in-flight sequences, buffered prefills included).
    pub max_inflight: usize,
    /// Paged-KV pool backing admissions.
    pub kv_blocks: usize,
    pub kv_block_tokens: usize,
    /// Radix prefix cache over the paged pool: shared prompt prefixes
    /// are refcounted and admission charges only the uncached suffix.
    pub prefix_cache: PrefixCacheConfig,
    /// Cross-tier speculative decoding. Verify-side replicas get the
    /// pool's config verbatim; draft-tier replicas (and anything below)
    /// get it force-disabled by the pairing rule, and the disabled
    /// default reproduces plain decode bit-for-bit.
    pub speculative: SpeculativeConfig,
}

/// Counters a scheduler accumulates over its lifetime.
///
/// Replica-local and lock-free to read for the owner thread — the unit
/// tests and benches assert against these. The gateway keeps its own
/// *cross-replica* aggregates in `GatewayMetrics` (atomics fed from
/// tick results) rather than exporting these, so the scheduler stays
/// free of sync primitives.
#[derive(Debug)]
pub struct SchedulerStats {
    pub prefills: u64,
    /// Prefill dispatches executed (each covers a ladder rung).
    pub prefill_batches: u64,
    /// Prefill dispatches that covered more than one sequence.
    pub prefill_batched: u64,
    pub decode_steps: u64,
    /// Decode steps that ran with batch size > 1.
    pub batched_steps: u64,
    pub completed: u64,
    /// Sequences evicted mid-flight by their [`CancelToken`].
    pub cancelled: u64,
    pub tokens_out: u64,
    pub peak_inflight: usize,
    /// Distribution of formed decode-batch sizes.
    pub batch_hist: Histogram,
    /// Speculative decode: draft tokens proposed to verify steps.
    pub spec_drafted_tokens: u64,
    /// Draft tokens the verify step accepted (landed without recompute).
    pub spec_accepted_tokens: u64,
    /// Draft tokens rejected and rolled back out of the KV ledger.
    pub spec_rejected_tokens: u64,
    /// Batched verify steps executed (each replaces 1..=k+1 plain steps).
    pub spec_verify_steps: u64,
}

impl Default for SchedulerStats {
    fn default() -> Self {
        Self {
            prefills: 0,
            prefill_batches: 0,
            prefill_batched: 0,
            decode_steps: 0,
            batched_steps: 0,
            completed: 0,
            cancelled: 0,
            tokens_out: 0,
            peak_inflight: 0,
            batch_hist: Histogram::for_batch_sizes(),
            spec_drafted_tokens: 0,
            spec_accepted_tokens: 0,
            spec_rejected_tokens: 0,
            spec_verify_steps: 0,
        }
    }
}

/// EMA smoothing for the observed acceptance rate: one fifth of each
/// verify step's rate folds in, so a burst of rejections moves the
/// signal but a single unlucky step cannot.
const SPEC_EMA_ALPHA: f64 = 0.2;

/// Verify steps before the EMA is trusted for auto-disable — the EMA
/// initializes optimistically at 1.0 and needs a few steps of evidence.
const SPEC_EMA_WARMUP: u64 = 8;

/// Outcome of an admission attempt.
pub enum Admit<T> {
    /// Buffered for the next prefill rung, holding a slot reservation.
    Admitted,
    /// No slot / KV headroom right now — retry after a tick.
    Rejected(T),
    /// The request can never be served; the payload is returned for
    /// error reporting.
    Failed(T, anyhow::Error),
}

/// A completed request leaving the scheduler.
pub struct Finished<T> {
    pub payload: T,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// Batched verify steps this sequence participated in (0 when it
    /// decoded plain) — the gateway's `spec_verify` trace span count.
    pub spec_steps: u32,
}

/// Result of one scheduler tick.
pub struct Tick<T> {
    pub finished: Vec<Finished<T>>,
    /// Requests evicted by cancellation this tick.
    pub cancelled: Vec<T>,
    /// Requests whose prefill/KV admission failed terminally, with the
    /// error message.
    pub failed: Vec<(T, String)>,
    /// Sequences prefilled this tick (possibly across several rungs).
    pub prefilled: usize,
    /// Decode batch size executed this tick (0 = none).
    pub stepped: usize,
    /// If holding for batch-mates (prefill or decode): seconds until the
    /// earliest flush deadline.
    pub wait_s: Option<f64>,
}

struct Slot<S, T> {
    id: SeqId,
    seq: S,
    payload: T,
    cancel: CancelToken,
    /// Batched verify steps this sequence participated in.
    spec_steps: u32,
}

/// A request admitted but not yet prefilled (waiting for a prefill rung
/// to fill). Its KV need is pre-counted against admission so buffered
/// work cannot oversubscribe the pool.
struct PendingPrefill<T> {
    prompt: String,
    /// Prompt token ids (word-id stream, engine-window truncated) — the
    /// prefix-cache key; empty when the cache is off.
    ids: Vec<i32>,
    max_new: usize,
    reserve_new: usize,
    /// KV blocks pre-charged against admission (whole-block, per
    /// request — pooled token rounding under-counts; with the prefix
    /// cache on, only the uncached suffix is charged).
    est_blocks: usize,
    /// Uncached *prompt* blocks at admission, excluding the generation
    /// budget: the prefill-rung grouping key — prefill work scales with
    /// the suffix, not the budget.
    suffix_blocks: usize,
    payload: T,
    cancel: CancelToken,
}

/// The per-replica continuous-batching state machine.
pub struct Scheduler<E: StepEngine, T> {
    engine: E,
    cfg: SchedulerConfig,
    kv: KvBlockManager,
    slots: Vec<Slot<E::Seq, T>>,
    pending: VecDeque<PendingPrefill<T>>,
    /// KV *blocks* pre-committed to `pending` (sum of `est_blocks`).
    /// Counted per request in whole blocks: `blocks_for(a + b) <=
    /// blocks_for(a) + blocks_for(b)`, so pooled token rounding would
    /// over-admit past the real block budget. The exact reservation at
    /// prefill time is still authoritative.
    pending_kv_blocks: usize,
    next_id: u64,
    /// Round-robin start offset so no slot starves at partial rungs.
    cursor: usize,
    /// When the current decode hold-for-batch-mates window opened.
    hold_since: Option<f64>,
    /// When the current prefill hold window opened.
    prefill_hold_since: Option<f64>,
    /// Sticky prefill flush: once the timeout fires, drain the whole
    /// buffer at partial rungs instead of re-opening a hold window per
    /// rung.
    prefill_flushing: bool,
    /// Sticky flush: once the timeout fires, keep draining partial
    /// batches until a full rung forms (or the replica goes idle).
    flushing: bool,
    /// One-entry memo for a rejected admission's prompt ids: the
    /// gateway retries a bounced job verbatim every replica tick, and
    /// re-tokenizing + re-hashing it each attempt is pure waste.
    rejected_ids: Option<(String, Vec<i32>)>,
    /// EMA of the per-verify-step draft acceptance rate (init 1.0).
    spec_accept_ema: f64,
    /// Latched once the EMA drops below `speculative.min_accept_rate`
    /// after warmup: this replica stops speculating for its lifetime
    /// (the workload has told us drafts don't match).
    spec_disabled: bool,
    /// Router-fed liveness of the paired draft tier: false while the
    /// draft tier is cold, saturated, or mid-recovery, and every batch
    /// falls back to plain decode (loss-free — the requeue invariants
    /// never see a draft in flight).
    draft_available: bool,
    pub stats: SchedulerStats,
}

impl<E: StepEngine, T> Scheduler<E, T> {
    pub fn new(engine: E, cfg: SchedulerConfig) -> Scheduler<E, T> {
        assert!(cfg.max_inflight > 0, "need at least one decode slot");
        Scheduler {
            engine,
            kv: KvBlockManager::with_prefix_cache(
                cfg.kv_blocks,
                cfg.kv_block_tokens,
                cfg.prefix_cache,
            ),
            cfg,
            slots: Vec::new(),
            pending: VecDeque::new(),
            pending_kv_blocks: 0,
            next_id: 0,
            cursor: 0,
            hold_since: None,
            prefill_hold_since: None,
            prefill_flushing: false,
            flushing: false,
            rejected_ids: None,
            spec_accept_ema: 1.0,
            spec_disabled: false,
            draft_available: false,
            stats: SchedulerStats::default(),
        }
    }

    /// Router signal: whether the paired draft tier can draft right now.
    /// Defaults to false, so a scheduler speculates only once its owner
    /// confirms the draft tier is warm and has headroom.
    pub fn set_draft_available(&mut self, ok: bool) {
        self.draft_available = ok;
    }

    /// Observed draft-acceptance EMA (1.0 until the first verify step).
    pub fn spec_accept_ema(&self) -> f64 {
        self.spec_accept_ema
    }

    /// Whether this scheduler still speculates (config on and the EMA
    /// has not tripped the auto-disable latch). Draft-tier availability
    /// is a separate, transient condition.
    pub fn spec_active(&self) -> bool {
        self.cfg.speculative.enabled && !self.spec_disabled
    }

    /// Draft window for the next decode batch: 0 = plain decode.
    fn spec_draft_window(&self) -> usize {
        if self.spec_active() && self.draft_available {
            self.cfg.speculative.draft_tokens
        } else {
            0
        }
    }

    /// In-flight requests: decoding slots plus buffered prefills (both
    /// hold a slot reservation).
    pub fn inflight(&self) -> usize {
        self.slots.len() + self.pending.len()
    }

    /// Slot occupancy in [0, 1] (the scaling signal).
    pub fn occupancy(&self) -> f64 {
        self.inflight() as f64 / self.cfg.max_inflight as f64
    }

    /// Mutable access to the most recently admitted payload — valid only
    /// immediately after [`Self::admit`] returns `Admitted` (the gateway
    /// restores the job's prompt through this).
    pub fn last_admitted_mut(&mut self) -> Option<&mut T> {
        self.pending.back_mut().map(|p| &mut p.payload)
    }

    /// Try to admit a request: reserve a slot and (estimated) KV blocks,
    /// and buffer it for the next prefill rung. `prompt_tokens_est`
    /// sizes the KV pre-check (clamped to the engine's prompt window,
    /// since prefill truncates); the reservation itself uses the exact
    /// post-tokenization count at prefill time. A request that cannot
    /// fit even into an *empty* replica is `Failed`, never `Rejected` —
    /// bouncing it would retry forever.
    pub fn admit(
        &mut self,
        prompt: &str,
        max_new: usize,
        prompt_tokens_est: usize,
        payload: T,
    ) -> Admit<T> {
        self.admit_cancellable(prompt, max_new, prompt_tokens_est, payload, CancelToken::new())
    }

    /// [`Self::admit`] with a caller-held [`CancelToken`].
    pub fn admit_cancellable(
        &mut self,
        prompt: &str,
        max_new: usize,
        prompt_tokens_est: usize,
        payload: T,
        cancel: CancelToken,
    ) -> Admit<T> {
        if self.inflight() >= self.cfg.max_inflight {
            return Admit::Rejected(payload);
        }
        // Reserve what the engine can actually emit: its budget clamp
        // bounds generation, and prefill emits one token even at
        // max_new = 0.
        let reserve_new = max_new.min(self.engine.max_new_tokens()).max(1);
        // Cheap lower bound before hashing the prompt: any admission
        // needs at least its generation-budget blocks, so an exhausted
        // pool rejects without re-tokenizing — a held job bounces off
        // the gateway and retries this path every replica-loop tick.
        let floor_blocks = self.kv.blocks_for_tokens(reserve_new);
        if self.pending_kv_blocks + floor_blocks > self.kv.available_blocks() {
            if self.slots.is_empty() && self.pending.is_empty() {
                return Admit::Failed(
                    payload,
                    anyhow!(
                        "request needs at least {} KV blocks but the \
                         replica pool holds {}",
                        floor_blocks,
                        self.cfg.kv_blocks
                    ),
                );
            }
            return Admit::Rejected(payload);
        }
        // With the prefix cache on, hash the prompt's token blocks and
        // charge only the uncached suffix — shared prefixes raise
        // effective concurrency under the same pool. Off: the legacy
        // clamped-estimate reservation, now rounded to whole blocks per
        // request. A bounced job retries verbatim, so its ids come from
        // the one-entry memo instead of re-tokenizing.
        let (memo_key, ids, est_blocks, suffix_blocks) =
            if self.cfg.prefix_cache.enabled {
                let (memo_key, ids) = match self.rejected_ids.take() {
                    Some((p, ids)) if p == prompt => (Some(p), ids),
                    _ => (
                        None,
                        crate::tokenizer::prompt_ids(
                            prompt,
                            self.engine.max_prompt_tokens(),
                        ),
                    ),
                };
                let p = self.kv.probe(&ids, reserve_new);
                (memo_key, ids, p.needed_blocks, p.suffix_blocks)
            } else {
                let est = prompt_tokens_est.min(self.engine.max_prompt_tokens());
                (
                    None,
                    Vec::new(),
                    self.kv.blocks_for_tokens(est + reserve_new),
                    self.kv.blocks_for_tokens(est),
                )
            };
        if self.pending_kv_blocks + est_blocks > self.kv.available_blocks() {
            if self.slots.is_empty() && self.pending.is_empty() {
                return Admit::Failed(
                    payload,
                    anyhow!(
                        "request needs {} KV blocks but the replica pool \
                         holds {}",
                        est_blocks,
                        self.cfg.kv_blocks
                    ),
                );
            }
            if self.cfg.prefix_cache.enabled {
                self.rejected_ids =
                    Some((memo_key.unwrap_or_else(|| prompt.to_string()), ids));
            }
            return Admit::Rejected(payload);
        }
        self.pending_kv_blocks += est_blocks;
        self.pending.push_back(PendingPrefill {
            prompt: prompt.to_string(),
            ids,
            max_new,
            reserve_new,
            est_blocks,
            suffix_blocks,
            payload,
            cancel,
        });
        self.stats.peak_inflight = self.stats.peak_inflight.max(self.inflight());
        Admit::Admitted
    }

    /// Cumulative prefix-cache counters (hit/miss tokens, evictions) —
    /// the gateway exports these as `ps_prefix_*` series.
    pub fn prefix_stats(&self) -> PrefixStats {
        self.kv.stats
    }

    /// Blocks currently resident in the prefix cache (gauge).
    pub fn kv_cached_blocks(&self) -> usize {
        self.kv.cache_blocks()
    }

    /// The replica's compact prefix summary for cache-affinity routing:
    /// top-`k` resident chains as `(tip chain hash, blocks)` pairs.
    pub fn hot_prefixes(&self, k: usize) -> Vec<(u64, u32)> {
        self.kv.hot_prefixes(k)
    }

    /// Export the cached block run ending at chain hash `tip` (donor
    /// side of a cross-replica prefix transfer).
    pub fn export_prefix(&self, tip: u64) -> Option<Vec<Vec<i32>>> {
        self.kv.export_prefix(tip)
    }

    /// Ingest a transferred prefix chain (receiver side): the engine
    /// models the transfer/ingest cost, the pool gains the chain as
    /// resident cache — from then on it is an ordinary local hit.
    /// Returns the tokens newly imported.
    pub fn import_prefix(&mut self, blocks: &[Vec<i32>]) -> usize {
        if !self.cfg.prefix_cache.enabled {
            return 0;
        }
        let imported = self.kv.import_prefix(blocks);
        if imported > 0 {
            self.engine.ingest_kv(imported);
        }
        imported
    }

    /// Evict every request whose cancel token fired — buffered or
    /// decoding — releasing slots and KV instantly.
    fn sweep_cancelled(&mut self, out: &mut Vec<T>) {
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].cancel.is_cancelled() {
                let p = self.pending.remove(i).expect("index checked");
                self.pending_kv_blocks -= p.est_blocks;
                self.stats.cancelled += 1;
                out.push(p.payload);
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.slots.len() {
            if self.slots[i].cancel.is_cancelled() {
                let slot = self.slots.remove(i);
                self.kv.release(slot.id);
                self.stats.cancelled += 1;
                out.push(slot.payload);
            } else {
                i += 1;
            }
        }
    }

    /// Retire every completed sequence, releasing slots + KV instantly.
    fn retire(&mut self, finished: &mut Vec<Finished<T>>) {
        let mut i = 0;
        while i < self.slots.len() {
            if self.slots[i].seq.done() {
                let slot = self.slots.remove(i);
                self.kv.release(slot.id);
                self.stats.completed += 1;
                finished.push(Finished {
                    prompt_tokens: slot.seq.prompt_tokens(),
                    spec_steps: slot.spec_steps,
                    tokens: slot.seq.into_tokens(),
                    payload: slot.payload,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Flush buffered prefills into slots at ladder rungs. Returns
    /// (sequences prefilled, seconds until the prefill flush deadline if
    /// holding for rung-mates).
    fn run_prefills(
        &mut self,
        now_s: f64,
        tick: &mut Tick<T>,
        on_prefilled: &mut dyn FnMut(&mut T),
    ) -> Option<f64> {
        loop {
            let waiting = self.pending.len();
            if waiting == 0 {
                self.prefill_hold_since = None;
                self.prefill_flushing = false;
                return None;
            }
            // An idle replica (no decode work to overlap) prefills
            // immediately: holding for speculative rung-mates there is
            // pure added latency.
            let timed_out = self.prefill_flushing
                || self.slots.is_empty()
                || self
                    .prefill_hold_since
                    .is_some_and(|t| now_s - t >= self.cfg.policy.flush_timeout_s);
            let Some(b) = self.cfg.policy.prefill_batch_size(waiting, timed_out) else {
                // Hold for rung-mates until the flush window closes.
                let opened = *self.prefill_hold_since.get_or_insert(now_s);
                return Some(
                    (self.cfg.policy.flush_timeout_s - (now_s - opened)).max(0.0),
                );
            };
            // Once the window fires, drain the whole buffer this tick.
            self.prefill_flushing =
                timed_out && b < self.cfg.policy.max_prefill_batch;
            self.prefill_hold_since = None;
            let remaining = waiting - b;
            // Rungs form over suffix lengths: the queue head always
            // dispatches (FIFO progress — an outlier can never be
            // deferred past `waiting` rungs), and its rung-mates are the
            // pending entries with the closest uncached suffix lengths,
            // so one long suffix doesn't dominate a whole dispatch. The
            // remainder re-buffers in arrival order.
            let batch: Vec<PendingPrefill<T>> =
                if self.cfg.prefix_cache.enabled && b < waiting {
                    let head = self.pending.pop_front().expect("waiting > 0");
                    let mut rest: Vec<(usize, PendingPrefill<T>)> =
                        self.pending.drain(..).enumerate().collect();
                    rest.sort_by_key(|(i, p)| {
                        (p.suffix_blocks.abs_diff(head.suffix_blocks), *i)
                    });
                    let mut batch = vec![head];
                    let mut overflow: Vec<(usize, PendingPrefill<T>)> = Vec::new();
                    for (i, p) in rest {
                        if batch.len() < b {
                            batch.push(p);
                        } else {
                            overflow.push((i, p));
                        }
                    }
                    overflow.sort_by_key(|(i, _)| *i);
                    self.pending.extend(overflow.into_iter().map(|(_, p)| p));
                    batch
                } else {
                    self.pending.drain(..b).collect()
                };
            for p in &batch {
                self.pending_kv_blocks -= p.est_blocks;
            }
            if self.cfg.prefix_cache.enabled {
                self.run_prefill_rung_shared(batch, tick, on_prefilled);
            } else {
                self.run_prefill_rung_legacy(batch, tick, on_prefilled);
            }
            // A re-buffered undershoot would loop (and re-prefill)
            // forever against the same tight pool within this tick:
            // stop once anything bounced and retry next tick, after
            // retirements free blocks.
            if self.pending.len() > remaining {
                return None;
            }
        }
    }

    /// A sequence just prefilled under reservation `id`: count it, stamp
    /// TTFT through the hook, and hand it a decode slot. (The prefill
    /// token is the first of the reserved budget.)
    fn place_prefilled(
        &mut self,
        id: SeqId,
        seq: E::Seq,
        p: PendingPrefill<T>,
        tick: &mut Tick<T>,
        on_prefilled: &mut dyn FnMut(&mut T),
    ) {
        let _ = self.kv.append_token(id);
        self.stats.prefills += 1;
        tick.prefilled += 1;
        let mut slot =
            Slot { id, seq, payload: p.payload, cancel: p.cancel, spec_steps: 0 };
        on_prefilled(&mut slot.payload);
        self.slots.push(slot);
    }

    /// Prefix-aware rung: reserve KV first — a reservation both gates
    /// the engine dispatch and tells it how many prompt tokens are
    /// already KV-resident (the `prefix_tokens` offset).
    fn run_prefill_rung_shared(
        &mut self,
        batch: Vec<PendingPrefill<T>>,
        tick: &mut Tick<T>,
        on_prefilled: &mut dyn FnMut(&mut T),
    ) {
        let mut entries: Vec<(SeqId, usize, PendingPrefill<T>)> = Vec::new();
        for p in batch {
            let id = SeqId(self.next_id);
            self.next_id += 1;
            match self.kv.admit_prefix(id, &p.ids, p.reserve_new) {
                Ok(cached) => entries.push((id, cached, p)),
                Err(_) => {
                    // The admission estimate undershot (cached blocks
                    // evicted since, or rung-mates claimed the pool).
                    // With other work holding blocks, re-buffer and
                    // retry once slots retire; on an empty replica it
                    // can never fit.
                    if self.slots.is_empty()
                        && self.pending.is_empty()
                        && entries.is_empty()
                    {
                        tick.failed.push((
                            p.payload,
                            format!(
                                "prompt ({} tokens) plus budget exceeds the \
                                 replica KV pool",
                                p.ids.len().max(1)
                            ),
                        ));
                    } else {
                        self.pending_kv_blocks += p.est_blocks;
                        self.pending.push_back(p);
                    }
                }
            }
        }
        if entries.is_empty() {
            return;
        }
        let b = entries.len();
        let reqs: Vec<(&str, usize, usize)> = entries
            .iter()
            .map(|(_, cached, p)| (p.prompt.as_str(), p.max_new, *cached))
            .collect();
        let started = self.engine.start_batch(&reqs);
        drop(reqs);
        let seqs = match started {
            Ok(s) => s,
            Err(e) => {
                // Engine refused the rung: release the reservations and
                // *discard* their never-prefilled chain blocks (a later
                // identical prompt must not skip over KV that was never
                // computed), fail these requests, keep the replica
                // alive. Reverse admission order, so a rung-mate that
                // referenced a chain inserted earlier in the same rung
                // drops its reference before the inserter discards.
                let msg = format!("prefill failed: {e:#}");
                for (id, _, p) in entries.into_iter().rev() {
                    self.kv.release_discard(id);
                    tick.failed.push((p.payload, msg.clone()));
                }
                return;
            }
        };
        self.stats.prefill_batches += 1;
        if b > 1 {
            self.stats.prefill_batched += 1;
        }
        for (seq, (id, _, p)) in seqs.into_iter().zip(entries) {
            self.place_prefilled(id, seq, p, tick, on_prefilled);
        }
    }

    /// Cache-off rung: the original engine-first flow — the authoritative
    /// reservation uses the engine's exact post-tokenization count.
    fn run_prefill_rung_legacy(
        &mut self,
        batch: Vec<PendingPrefill<T>>,
        tick: &mut Tick<T>,
        on_prefilled: &mut dyn FnMut(&mut T),
    ) {
        let b = batch.len();
        let reqs: Vec<(&str, usize, usize)> = batch
            .iter()
            .map(|p| (p.prompt.as_str(), p.max_new, 0))
            .collect();
        let started = self.engine.start_batch(&reqs);
        drop(reqs);
        let seqs = match started {
            Ok(s) => s,
            Err(e) => {
                // Engine refused the rung: fail these requests and
                // keep the replica alive for the rest.
                let msg = format!("prefill failed: {e:#}");
                for p in batch {
                    tick.failed.push((p.payload, msg.clone()));
                }
                return;
            }
        };
        self.stats.prefill_batches += 1;
        if b > 1 {
            self.stats.prefill_batched += 1;
        }
        for (seq, p) in seqs.into_iter().zip(batch) {
            let id = SeqId(self.next_id);
            self.next_id += 1;
            if self.kv.admit(id, seq.prompt_tokens(), p.reserve_new).is_err() {
                // The estimate undershot and the pool is tight. With
                // other work holding blocks, re-buffer and retry once
                // slots retire; on an empty replica it can never fit.
                if self.slots.is_empty() && self.pending.is_empty() {
                    tick.failed.push((
                        p.payload,
                        format!(
                            "prompt ({} tokens) plus budget exceeds the \
                             replica KV pool",
                            seq.prompt_tokens()
                        ),
                    ));
                } else {
                    self.pending_kv_blocks += p.est_blocks;
                    self.pending.push_back(p);
                }
                continue;
            }
            self.place_prefilled(id, seq, p, tick, on_prefilled);
        }
    }

    /// One scheduling decision at time `now_s`: evict cancellations,
    /// retire finished work, flush prefill rungs, then either run one
    /// decode batch or report how long to hold for batch-mates.
    pub fn tick(&mut self, now_s: f64) -> Result<Tick<T>> {
        self.tick_with(now_s, &mut |_| {})
    }

    /// [`Self::tick`] with a hook invoked once per sequence the moment
    /// its prefill completes (the gateway stamps TTFT through this).
    pub fn tick_with(
        &mut self,
        now_s: f64,
        on_prefilled: &mut dyn FnMut(&mut T),
    ) -> Result<Tick<T>> {
        let mut tick = Tick {
            finished: Vec::new(),
            cancelled: Vec::new(),
            failed: Vec::new(),
            prefilled: 0,
            stepped: 0,
            wait_s: None,
        };
        self.sweep_cancelled(&mut tick.cancelled);
        self.retire(&mut tick.finished);
        let prefill_wait = self.run_prefills(now_s, &mut tick, on_prefilled);
        // A budget-1 sequence completes at prefill; release immediately.
        self.retire(&mut tick.finished);

        let active = self.slots.len();
        if active == 0 {
            self.hold_since = None;
            self.flushing = false;
            tick.wait_s = prefill_wait;
            return Ok(tick);
        }
        let timed_out = self.flushing
            || self
                .hold_since
                .is_some_and(|t| now_s - t >= self.cfg.policy.flush_timeout_s);
        let Some(b) = self.cfg.policy.decode_batch_size(active, timed_out) else {
            let opened = *self.hold_since.get_or_insert(now_s);
            let wait = (self.cfg.policy.flush_timeout_s - (now_s - opened)).max(0.0);
            tick.wait_s = Some(match prefill_wait {
                Some(p) => p.min(wait),
                None => wait,
            });
            return Ok(tick);
        };
        // Sticky flush until a full rung forms again.
        self.flushing = timed_out && b < self.cfg.policy.max_decode_batch;
        self.hold_since = None;

        // Round-robin slot selection so partial rungs rotate fairly.
        let start = self.cursor % active;
        let mut selected = vec![false; active];
        for k in 0..b {
            selected[(start + k) % active] = true;
        }
        self.cursor = (start + b) % active.max(1);

        // Speculative draft pass: ask the engine for a lookahead window
        // per selected slot and charge the drafts against each
        // sequence's existing KV reservation *optimistically* (draft
        // appends only move the logical length — the reservation's
        // blocks were counted at admission, so drafting can never
        // allocate). A slot whose reservation is exhausted truncates its
        // draft; if no slot drafts anything, the batch runs plain.
        let spec_k = self.spec_draft_window();
        let mut drafts: Vec<Vec<i32>> = Vec::new();
        if spec_k > 0 {
            let engine = &mut self.engine;
            let kv = &mut self.kv;
            for (i, slot) in self.slots.iter_mut().enumerate() {
                if !selected[i] {
                    continue;
                }
                let mut d = engine.draft_tokens(&slot.seq, spec_k);
                let mut appended = 0;
                while appended < d.len() {
                    if kv.append_token(slot.id).is_err() {
                        break;
                    }
                    appended += 1;
                }
                d.truncate(appended);
                drafts.push(d);
            }
        }
        let speculate = drafts.iter().any(|d| !d.is_empty());

        let engine = &mut self.engine;
        let mut ids = Vec::with_capacity(b);
        let mut refs: Vec<&mut E::Seq> = Vec::with_capacity(b);
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if selected[i] {
                ids.push(slot.id);
                refs.push(&mut slot.seq);
            }
        }
        if speculate {
            let before: Vec<usize> = refs.iter().map(|s| s.tokens().len()).collect();
            let slices: Vec<&[i32]> = drafts.iter().map(|d| d.as_slice()).collect();
            let accepted = engine.verify_batch(&mut refs, &slices)?;
            // Settle the KV ledger against what actually landed: the
            // drafts were charged up front, so rejected drafts roll
            // back and the correction/bonus tokens append the shortfall.
            // Rollback shrinks only the logical length — blocks and
            // shared-prefix refcounts are untouched by construction.
            let mut step_drafted = 0u64;
            let mut step_accepted = 0u64;
            let mut landed_total = 0u64;
            for (j, id) in ids.iter().enumerate() {
                let drafted = drafts[j].len();
                let landed = refs[j].tokens().len().saturating_sub(before[j]);
                if landed < drafted {
                    self.kv.rollback_tokens(*id, drafted - landed);
                } else {
                    for _ in 0..landed - drafted {
                        let _ = self.kv.append_token(*id);
                    }
                }
                step_drafted += drafted as u64;
                step_accepted +=
                    accepted.get(j).copied().unwrap_or(0).min(drafted) as u64;
                landed_total += landed as u64;
            }
            self.stats.spec_drafted_tokens += step_drafted;
            self.stats.spec_accepted_tokens += step_accepted;
            self.stats.spec_rejected_tokens += step_drafted - step_accepted;
            self.stats.spec_verify_steps += 1;
            self.stats.tokens_out += landed_total;
            if step_drafted > 0 {
                let rate = step_accepted as f64 / step_drafted as f64;
                self.spec_accept_ema =
                    (1.0 - SPEC_EMA_ALPHA) * self.spec_accept_ema + SPEC_EMA_ALPHA * rate;
                if self.stats.spec_verify_steps >= SPEC_EMA_WARMUP
                    && self.spec_accept_ema < self.cfg.speculative.min_accept_rate
                {
                    self.spec_disabled = true;
                }
            }
        } else {
            engine.step(&mut refs)?;
            for id in ids {
                let _ = self.kv.append_token(id);
            }
            self.stats.tokens_out += b as u64;
        }
        self.stats.decode_steps += 1;
        if b > 1 {
            self.stats.batched_steps += 1;
        }
        self.stats.batch_hist.observe(b as f64);
        if speculate {
            // Per-sequence verify participation — surfaces as the
            // `spec_verify` span count on the request's trace.
            for (i, slot) in self.slots.iter_mut().enumerate() {
                if selected[i] {
                    slot.spec_steps += 1;
                }
            }
        }
        self.retire(&mut tick.finished);
        tick.stepped = b;
        Ok(tick)
    }

    /// Hand back every buffered (admitted but not yet prefilled) request,
    /// releasing its pre-charged KV blocks. Decoding slots are untouched.
    /// A gracefully draining replica routes these through the requeue
    /// path so a surviving replica serves them instead of paying their
    /// prefill on a replica that is about to exit.
    pub fn drain_pending(&mut self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.pending.len());
        for p in self.pending.drain(..) {
            self.pending_kv_blocks -= p.est_blocks;
            out.push(p.payload);
        }
        self.prefill_hold_since = None;
        self.prefill_flushing = false;
        out
    }

    /// Visit every decoding slot's payload and its token stream so far.
    /// The process-substrate worker streams the delta since its last
    /// visit as `TokenChunk` frames.
    pub fn for_each_slot(&mut self, mut f: impl FnMut(&mut T, &[i32])) {
        for slot in &mut self.slots {
            let Slot { payload, seq, .. } = slot;
            f(payload, seq.tokens());
        }
    }

    /// Fail every in-flight request (engine died / shutdown), returning
    /// the payloads so the caller can report errors. Buffered prefills
    /// are included.
    pub fn fail_all(&mut self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.inflight());
        for p in self.pending.drain(..) {
            out.push(p.payload);
        }
        self.pending_kv_blocks = 0;
        for slot in self.slots.drain(..) {
            self.kv.release(slot.id);
            out.push(slot.payload);
        }
        self.hold_since = None;
        self.prefill_hold_since = None;
        self.prefill_flushing = false;
        self.flushing = false;
        out
    }

    /// Drive the scheduler with a virtual clock until every in-flight
    /// sequence completes (no new admissions). Holds advance the clock to
    /// the flush deadline, exactly as a quiet queue would. Returns the
    /// completions and the final virtual time.
    pub fn drain(&mut self, mut now_s: f64) -> Result<(Vec<Finished<T>>, f64)> {
        let mut out = Vec::new();
        loop {
            let t = self.tick(now_s)?;
            out.extend(t.finished);
            if self.inflight() == 0 {
                return Ok((out, now_s));
            }
            if let Some(w) = t.wait_s {
                now_s += w.max(1e-9);
            }
        }
    }

    /// KV-pool occupancy in [0, 1].
    pub fn kv_occupancy(&self) -> f64 {
        self.kv.occupancy()
    }
}

// ---------------------------------------------------------------------------
// Synthetic engine (tests + benches, no PJRT required)
// ---------------------------------------------------------------------------

/// A deterministic stand-in engine with the cost shape of real batched
/// decode: each step pays a fixed dispatch cost plus a small per-sequence
/// cost, so batching amortizes the dispatch exactly like a batched GEMM.
/// Batched prefill follows the same shape (one dispatch per rung).
/// Zero-cost configurations make it a pure logic fake for unit tests.
pub struct SimStepEngine {
    /// Per-dispatch prefill base cost.
    pub prefill_us: u64,
    /// Per-prompt-token prefill cost — cached prefix tokens skip it, so
    /// radix-cache hits translate into measured prefill time saved.
    pub prefill_per_token_us: u64,
    pub step_base_us: u64,
    pub step_per_seq_us: u64,
    /// Per-token cost of ingesting *transferred* KV (cross-replica
    /// prefix transfer). Set well below `prefill_per_token_us`: moving
    /// computed KV over the wire beats recomputing it, and the gap is
    /// what the affinity benches measure.
    pub transfer_per_token_us: u64,
    /// Per-draft-token surcharge on a verify step: scoring k extra
    /// positions against resident KV costs far less than k extra
    /// dispatches (the whole point of batched verify), but is not free.
    pub verify_per_token_us: u64,
    /// Speculative acceptance model `(rate, rng)`: the probability each
    /// draft token matches what this engine would have decoded. `None`
    /// (the default) means the engine cannot draft or verify — the
    /// scheduler's plain path runs even with speculation configured on.
    /// Only *timing* is stochastic: drafts come from the sequence's own
    /// lookahead, so the landed token stream is bit-identical to plain
    /// decode at every acceptance rate.
    accept: Option<(f64, crate::util::rng::SplitMix64)>,
}

impl SimStepEngine {
    /// Instant (no simulated compute) — for logic tests.
    pub fn instant() -> SimStepEngine {
        SimStepEngine {
            prefill_us: 0,
            prefill_per_token_us: 0,
            step_base_us: 0,
            step_per_seq_us: 0,
            transfer_per_token_us: 0,
            verify_per_token_us: 0,
            accept: None,
        }
    }

    /// Costs loosely calibrated to the measured PJRT small-tier step
    /// (§Perf): dispatch-dominated, so batch-8 decode is ~4× cheaper per
    /// token than serial, and prefill grows with the (uncached) prompt.
    pub fn calibrated() -> SimStepEngine {
        SimStepEngine {
            prefill_us: 300,
            prefill_per_token_us: 12,
            step_base_us: 180,
            step_per_seq_us: 25,
            // ~4× cheaper than recomputing the same tokens' prefill —
            // the regime where pulling a hot prefix beats a cold start.
            transfer_per_token_us: 3,
            // Scoring a resident draft position is a fraction of the
            // 25 µs marginal decode row — verify wins whenever at least
            // ~1 in 12 draft tokens lands.
            verify_per_token_us: 2,
            accept: None,
        }
    }

    /// Attach the speculative acceptance model: each draft token is
    /// accepted independently with probability `rate` (sequential — the
    /// first rejection ends the accepted prefix), drawn from a seeded
    /// [`crate::util::rng::SplitMix64`] so runs are reproducible.
    pub fn with_acceptance(mut self, rate: f64, seed: u64) -> SimStepEngine {
        self.accept =
            Some((rate.clamp(0.0, 1.0), crate::util::rng::SplitMix64::new(seed)));
        self
    }

    fn burn(us: u64) {
        if us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(us));
        }
    }

    fn make_seq(prompt: &str, max_new: usize) -> SimSeq {
        let mut state = 0xcbf29ce484222325u64;
        for b in prompt.bytes() {
            state = (state ^ b as u64).wrapping_mul(0x100000001b3);
        }
        let mut seq = SimSeq {
            tokens: Vec::new(),
            // Mirrors the compiled engines' context-window budget clamp.
            budget: max_new.clamp(1, SIM_SEQ_MAX),
            // Mirrors the compiled engines' prefill window truncation.
            prompt_tokens: prompt
                .split_whitespace()
                .count()
                .clamp(1, SIM_SEQ_PREFILL),
            state,
        };
        let first = seq.next_token();
        seq.tokens.push(first);
        seq
    }
}

/// Sequence state for [`SimStepEngine`]: an LCG token stream seeded from
/// the prompt, finishing exactly at its budget.
pub struct SimSeq {
    tokens: Vec<i32>,
    budget: usize,
    prompt_tokens: usize,
    state: u64,
}

impl SimSeq {
    fn lcg_next(state: &mut u64) -> i32 {
        *state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        ((*state >> 33) & 0xFFF) as i32
    }

    fn next_token(&mut self) -> i32 {
        Self::lcg_next(&mut self.state)
    }

    /// Lookahead draft: peek the next `k` tokens of the LCG stream
    /// *without* advancing it, capped at the remaining budget minus one
    /// (the verify step's correction token needs headroom). Because the
    /// draft is the stream itself, acceptance verdicts only decide how
    /// many tokens land per step — never *which* tokens — keeping
    /// speculative output bit-identical to plain decode.
    fn peek_tokens(&self, k: usize) -> Vec<i32> {
        let remaining = self.budget.saturating_sub(self.tokens.len());
        if remaining <= 1 {
            return Vec::new();
        }
        let mut state = self.state;
        (0..k.min(remaining - 1))
            .map(|_| Self::lcg_next(&mut state))
            .collect()
    }
}

impl SeqLike for SimSeq {
    fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    fn into_tokens(self) -> Vec<i32> {
        self.tokens
    }

    fn prompt_tokens(&self) -> usize {
        self.prompt_tokens
    }

    fn done(&self) -> bool {
        self.tokens.len() >= self.budget
    }
}

impl StepEngine for SimStepEngine {
    type Seq = SimSeq;

    fn start(&mut self, prompt: &str, max_new: usize, prefix_tokens: usize)
        -> Result<SimSeq> {
        let seq = Self::make_seq(prompt, max_new);
        let suffix = seq.prompt_tokens.saturating_sub(prefix_tokens) as u64;
        Self::burn(self.prefill_us + self.prefill_per_token_us * suffix);
        Ok(seq)
    }

    fn start_batch(&mut self, reqs: &[(&str, usize, usize)]) -> Result<Vec<SimSeq>> {
        // One dispatch for the rung: full cost once, then a quarter-cost
        // marginal row — the amortization batched prefill exists for.
        // Token-proportional work covers only the uncached suffixes.
        let seqs: Vec<SimSeq> =
            reqs.iter().map(|&(p, m, _)| Self::make_seq(p, m)).collect();
        let extra = reqs.len().saturating_sub(1) as u64;
        let suffix: u64 = seqs
            .iter()
            .zip(reqs)
            .map(|(s, &(_, _, c))| s.prompt_tokens.saturating_sub(c) as u64)
            .sum();
        Self::burn(
            self.prefill_us
                + (self.prefill_us / 4) * extra
                + self.prefill_per_token_us * suffix,
        );
        Ok(seqs)
    }

    fn step(&mut self, batch: &mut [&mut SimSeq]) -> Result<()> {
        Self::burn(self.step_base_us + self.step_per_seq_us * batch.len() as u64);
        for seq in batch.iter_mut() {
            let t = seq.next_token();
            seq.tokens.push(t);
        }
        Ok(())
    }

    // The sim models the *verify* side of cross-tier speculation: the
    // draft tier's lookahead arrives for free (its cost lands on the
    // draft replica, not this one) and the acceptance model decides how
    // much of it this engine's one batched verify step keeps.

    fn draft_tokens(&mut self, seq: &SimSeq, k: usize) -> Vec<i32> {
        if self.accept.is_none() {
            return Vec::new();
        }
        seq.peek_tokens(k)
    }

    fn verify_batch(
        &mut self,
        batch: &mut [&mut SimSeq],
        drafts: &[&[i32]],
    ) -> Result<Vec<usize>> {
        let Some((rate, rng)) = self.accept.as_mut() else {
            self.step(batch)?;
            return Ok(vec![0; batch.len()]);
        };
        let rate = *rate;
        let mut accepted = Vec::with_capacity(batch.len());
        let mut draft_total = 0u64;
        for (seq, d) in batch.iter_mut().zip(drafts) {
            draft_total += d.len() as u64;
            let mut acc = 0usize;
            while acc < d.len() && rng.chance(rate) {
                acc += 1;
            }
            // Defensive cap (drafts are already budget-bounded): the
            // accepted prefix plus the correction token must fit.
            let remaining = seq.budget.saturating_sub(seq.tokens.len());
            let land = (acc + 1).min(remaining);
            for _ in 0..land {
                let t = seq.next_token();
                seq.tokens.push(t);
            }
            accepted.push(land.saturating_sub(1));
        }
        Self::burn(
            self.step_base_us
                + self.step_per_seq_us * batch.len() as u64
                + self.verify_per_token_us * draft_total,
        );
        Ok(accepted)
    }

    fn max_prompt_tokens(&self) -> usize {
        SIM_SEQ_PREFILL
    }

    fn max_new_tokens(&self) -> usize {
        SIM_SEQ_MAX
    }

    fn ingest_kv(&mut self, tokens: usize) {
        Self::burn(self.transfer_per_token_us * tokens as u64);
    }
}

/// The synthetic engine's prompt window (matches the compiled tiers'
/// prefill sequence length order of magnitude).
pub const SIM_SEQ_PREFILL: usize = 64;

/// The synthetic engine's context window / generation cap.
pub const SIM_SEQ_MAX: usize = 256;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::batcher::DECODE_BATCHES;

    fn sched(max_inflight: usize, max_batch: usize, flush_s: f64) -> Scheduler<SimStepEngine, usize> {
        Scheduler::new(
            SimStepEngine::instant(),
            SchedulerConfig {
                policy: BatchPolicy::custom(max_batch, 1, flush_s),
                max_inflight,
                kv_blocks: 256,
                kv_block_tokens: 16,
                prefix_cache: PrefixCacheConfig::default(),
                speculative: SpeculativeConfig::disabled(),
            },
        )
    }

    #[test]
    fn mixed_length_completions_release_slots_immediately() {
        let mut s = sched(8, 8, 0.01);
        for i in 0..8usize {
            // Budgets 1..=8: the short ones must retire while the long
            // ones keep decoding.
            match s.admit("some prompt words", i + 1, 4, i) {
                Admit::Admitted => {}
                _ => panic!("admission {i} failed"),
            }
        }
        assert_eq!(s.inflight(), 8);
        let (done, _) = s.drain(0.0).unwrap();
        assert_eq!(done.len(), 8);
        for f in &done {
            // Each request got exactly its budget.
            assert_eq!(f.tokens.len(), f.payload + 1, "payload {}", f.payload);
        }
        // Short sequences retired before long ones.
        let order: Vec<usize> = done.iter().map(|f| f.payload).collect();
        assert_eq!(order[0], 0, "budget-1 sequence must finish first");
        assert!(s.stats.batched_steps > 0, "decode must have batched");
        // All slots and KV blocks returned.
        assert_eq!(s.inflight(), 0);
        assert_eq!(s.kv_occupancy(), 0.0);
    }

    #[test]
    fn released_slots_admit_new_work_midflight() {
        let mut s = sched(4, 4, 0.0);
        for i in 0..4usize {
            assert!(matches!(s.admit("p", 1 + i, 2, i), Admit::Admitted));
        }
        // Slots full: the 5th is rejected, not errored.
        assert!(matches!(s.admit("p", 2, 2, 99), Admit::Rejected(99)));
        // Ticks retire the budget-1 sequence → a slot frees.
        let mut now = 0.0;
        while s.inflight() == 4 {
            let t = s.tick(now).unwrap();
            now += t.wait_s.unwrap_or(0.0).max(1e-9);
        }
        assert!(matches!(s.admit("p", 2, 2, 99), Admit::Admitted));
        let (done, _) = s.drain(now).unwrap();
        assert_eq!(done.len() + 1, 5, "first completion already left in the while loop");
    }

    #[test]
    fn batch_sizes_are_always_compiled_rungs() {
        let mut s = sched(8, 8, 0.005);
        for i in 0..7usize {
            assert!(matches!(s.admit("x y z", 3 + (i % 5), 3, i), Admit::Admitted));
        }
        let mut now = 0.0;
        while s.inflight() > 0 {
            let t = s.tick(now).unwrap();
            if t.stepped > 0 {
                assert!(DECODE_BATCHES.contains(&t.stepped), "{}", t.stepped);
            } else if let Some(w) = t.wait_s {
                now += w.max(1e-9);
            }
        }
    }

    #[test]
    fn holds_then_flushes_partial_batches() {
        let mut s = sched(8, 8, 0.02);
        for i in 0..3usize {
            assert!(matches!(s.admit("p", 4, 2, i), Admit::Admitted));
        }
        // 3 active < rung 4: the first tick prefills but the decode
        // holds…
        let t = s.tick(0.0).unwrap();
        assert_eq!(t.stepped, 0);
        assert_eq!(t.prefilled, 3);
        let w = t.wait_s.expect("must report a flush deadline");
        assert!(w > 0.0 && w <= 0.02);
        // …and still holds inside the window…
        assert_eq!(s.tick(0.01).unwrap().stepped, 0);
        // …then flushes at the deadline, and keeps draining (sticky
        // flush) without re-opening a hold window.
        assert!(s.tick(0.021).unwrap().stepped >= 1);
        assert!(s.tick(0.0211).unwrap().stepped >= 1);
    }

    #[test]
    fn round_robin_prevents_starvation_at_batch_one() {
        // Forced serial batches (max 1): every sequence must still finish.
        let mut s = sched(4, 1, 0.0);
        for i in 0..4usize {
            assert!(matches!(s.admit("p", 5, 2, i), Admit::Admitted));
        }
        let (done, _) = s.drain(0.0).unwrap();
        assert_eq!(done.len(), 4);
        for f in &done {
            assert_eq!(f.tokens.len(), 5);
        }
        assert_eq!(s.stats.batched_steps, 0);
        assert_eq!(s.stats.decode_steps, 4 * 4); // 4 seqs × 4 post-prefill tokens
    }

    #[test]
    fn kv_exhaustion_rejects_until_release() {
        let mut s: Scheduler<SimStepEngine, u32> = Scheduler::new(
            SimStepEngine::instant(),
            SchedulerConfig {
                policy: BatchPolicy::custom(8, 1, 0.0),
                max_inflight: 8,
                // Tiny pool: 4 blocks × 16 tokens = one 40+24 sequence.
                kv_blocks: 4,
                kv_block_tokens: 16,
                prefix_cache: PrefixCacheConfig::default(),
                speculative: SpeculativeConfig::disabled(),
            },
        );
        assert!(matches!(s.admit("a b c", 60, 4, 1), Admit::Admitted));
        // The buffered admission already owns the pool's estimate.
        assert!(matches!(s.admit("a b c", 60, 4, 2), Admit::Rejected(2)));
        let (done, now) = s.drain(0.0).unwrap();
        assert_eq!(done.len(), 1);
        assert!(matches!(s.admit("a b c", 60, 4, 2), Admit::Admitted));
        let _ = s.drain(now).unwrap();
    }

    #[test]
    fn impossible_request_fails_fast_when_replica_is_empty() {
        // 2 blocks × 4 tokens: an 8-token pool. A request that can never
        // fit must be Failed (reply an error), not Rejected (bounce
        // forever — the replica-wedging livelock).
        let mut s: Scheduler<SimStepEngine, u32> = Scheduler::new(
            SimStepEngine::instant(),
            SchedulerConfig {
                policy: BatchPolicy::custom(8, 1, 0.0),
                max_inflight: 8,
                kv_blocks: 2,
                kv_block_tokens: 4,
                prefix_cache: PrefixCacheConfig::default(),
                speculative: SpeculativeConfig::disabled(),
            },
        );
        assert!(matches!(s.admit("a b c", 16, 4, 7), Admit::Failed(7, _)));
        // A request that fits still serves fine afterwards.
        assert!(matches!(s.admit("a b", 4, 3, 8), Admit::Admitted));
        let (done, _) = s.drain(0.0).unwrap();
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn oversized_prompt_estimate_is_clamped_to_engine_window() {
        // The engine truncates prompts at SIM_SEQ_PREFILL tokens, so a
        // wildly long prompt must still admit when window + budget fit.
        let mut s = sched(4, 4, 0.0);
        let long = vec!["word"; 4000].join(" ");
        assert!(matches!(s.admit(&long, 8, 4001, 0), Admit::Admitted));
        let (done, _) = s.drain(0.0).unwrap();
        assert_eq!(done[0].tokens.len(), 8);
        assert_eq!(done[0].prompt_tokens, SIM_SEQ_PREFILL);
        assert_eq!(s.kv_occupancy(), 0.0);
    }

    #[test]
    fn huge_max_new_is_reserved_at_the_engine_context_cap() {
        // Default pool: 256 blocks × 16 tokens = 4096. A raw max_new of
        // 1M must neither hard-fail admission nor hoard the pool — the
        // reservation clamps to the engine's context window.
        let mut s = sched(4, 4, 0.0);
        assert!(matches!(s.admit("a b", 1_000_000, 3, 0), Admit::Admitted));
        // A second normal request still fits alongside it.
        assert!(matches!(s.admit("a b", 8, 3, 1), Admit::Admitted));
        let (done, _) = s.drain(0.0).unwrap();
        assert_eq!(done.len(), 2);
        let big = done.iter().find(|f| f.payload == 0).unwrap();
        assert_eq!(big.tokens.len(), SIM_SEQ_MAX); // clamped budget
        assert_eq!(s.kv_occupancy(), 0.0);
    }

    #[test]
    fn zero_max_tokens_still_reserves_the_prefill_token() {
        let mut s = sched(4, 4, 0.0);
        assert!(matches!(s.admit("a b", 0, 3, 0), Admit::Admitted));
        let (done, _) = s.drain(0.0).unwrap();
        // Prefill emits exactly one token; the reservation covered it
        // and everything is released.
        assert_eq!(done[0].tokens.len(), 1);
        assert_eq!(s.kv_occupancy(), 0.0);
    }

    #[test]
    fn deterministic_token_streams() {
        let mut a = sched(1, 1, 0.0);
        let mut b = sched(1, 1, 0.0);
        assert!(matches!(a.admit("same prompt", 6, 2, 0), Admit::Admitted));
        assert!(matches!(b.admit("same prompt", 6, 2, 0), Admit::Admitted));
        let (da, _) = a.drain(0.0).unwrap();
        let (db, _) = b.drain(0.0).unwrap();
        assert_eq!(da[0].tokens, db[0].tokens);
        assert_eq!(da[0].tokens.len(), 6);
    }

    #[test]
    fn fail_all_returns_every_payload_and_clears_kv() {
        let mut s = sched(4, 4, 0.0);
        for i in 0..3usize {
            assert!(matches!(s.admit("p q", 8, 2, i), Admit::Admitted));
        }
        let mut failed = s.fail_all();
        failed.sort_unstable();
        assert_eq!(failed, vec![0, 1, 2]);
        assert_eq!(s.inflight(), 0);
        assert_eq!(s.kv_occupancy(), 0.0);
    }

    #[test]
    fn stats_track_batching() {
        let mut s = sched(8, 8, 0.0);
        for i in 0..8usize {
            assert!(matches!(s.admit("p", 4, 2, i), Admit::Admitted));
        }
        let (done, _) = s.drain(0.0).unwrap();
        assert_eq!(done.len(), 8);
        assert_eq!(s.stats.prefills, 8);
        assert_eq!(s.stats.completed, 8);
        // 8 seqs × 3 post-prefill tokens, all at batch 8.
        assert_eq!(s.stats.decode_steps, 3);
        assert_eq!(s.stats.batched_steps, 3);
        assert_eq!(s.stats.tokens_out, 24);
        assert_eq!(s.stats.peak_inflight, 8);
        assert_eq!(s.stats.batch_hist.bucket(8.0), 3);
    }

    #[test]
    fn prefill_forms_ladder_rungs() {
        // max_prefill_batch 4: four admissions prefill in ONE dispatch.
        let mut s: Scheduler<SimStepEngine, usize> = Scheduler::new(
            SimStepEngine::instant(),
            SchedulerConfig {
                policy: BatchPolicy::custom(8, 4, 0.01),
                max_inflight: 8,
                kv_blocks: 256,
                kv_block_tokens: 16,
                prefix_cache: PrefixCacheConfig::default(),
                speculative: SpeculativeConfig::disabled(),
            },
        );
        for i in 0..4usize {
            assert!(matches!(s.admit("a b c", 4, 3, i), Admit::Admitted));
        }
        let t = s.tick(0.0).unwrap();
        assert_eq!(t.prefilled, 4);
        assert_eq!(s.stats.prefill_batches, 1, "one rung-4 dispatch");
        assert_eq!(s.stats.prefill_batched, 1);
        assert_eq!(s.stats.prefills, 4);
        let (done, _) = s.drain(0.001).unwrap();
        assert_eq!(done.len(), 4);
    }

    #[test]
    fn partial_prefill_holds_then_flushes() {
        let mut s: Scheduler<SimStepEngine, usize> = Scheduler::new(
            SimStepEngine::instant(),
            SchedulerConfig {
                policy: BatchPolicy::custom(8, 4, 0.02),
                max_inflight: 8,
                kv_blocks: 256,
                kv_block_tokens: 16,
                prefix_cache: PrefixCacheConfig::default(),
                speculative: SpeculativeConfig::disabled(),
            },
        );
        // Occupy a slot first — an idle replica flushes prefill
        // immediately, so the hold only applies with decode work to
        // overlap.
        assert!(matches!(s.admit("a b", 64, 2, 9), Admit::Admitted));
        let t = s.tick(0.0).unwrap();
        assert_eq!(t.prefilled, 1, "idle replica must prefill at once");
        for i in 0..2usize {
            assert!(matches!(s.admit("a b", 4, 2, i), Admit::Admitted));
        }
        // 2 waiting < rung 4 with a busy slot → hold for rung-mates.
        let t = s.tick(0.001).unwrap();
        assert_eq!(t.prefilled, 0);
        let w = t.wait_s.expect("prefill hold must report a deadline");
        assert!(w > 0.0 && w <= 0.02);
        // Flush window closes → both prefill (at sub-rung dispatches).
        let t = s.tick(0.022).unwrap();
        assert_eq!(t.prefilled, 2);
        assert!(s.stats.prefill_batches >= 2);
    }

    #[test]
    fn cancellation_frees_slot_mid_decode() {
        let mut s = sched(4, 4, 0.0);
        let cancel = CancelToken::new();
        assert!(matches!(
            s.admit_cancellable("a b", 100, 2, 0, cancel.clone()),
            Admit::Admitted
        ));
        assert!(matches!(s.admit("a b", 4, 2, 1), Admit::Admitted));
        // Let both prefill and decode a few steps.
        let mut now = 0.0;
        for _ in 0..3 {
            let t = s.tick(now).unwrap();
            now += t.wait_s.unwrap_or(0.0).max(1e-9);
        }
        assert_eq!(s.inflight(), 2);
        cancel.cancel();
        let t = s.tick(now).unwrap();
        assert_eq!(t.cancelled, vec![0], "cancelled payload evicted");
        assert_eq!(s.stats.cancelled, 1);
        // The survivor completes and every resource returns.
        let (done, _) = s.drain(now).unwrap();
        assert!(done.iter().all(|f| f.payload == 1));
        assert_eq!(s.inflight(), 0);
        assert_eq!(s.kv_occupancy(), 0.0);
    }

    #[test]
    fn cancellation_evicts_buffered_prefill_before_it_runs() {
        let mut s = sched(4, 4, 0.0);
        let cancel = CancelToken::new();
        assert!(matches!(
            s.admit_cancellable("a b", 8, 2, 5, cancel.clone()),
            Admit::Admitted
        ));
        cancel.cancel();
        let t = s.tick(0.0).unwrap();
        assert_eq!(t.cancelled, vec![5]);
        assert_eq!(t.prefilled, 0, "cancelled request must not prefill");
        assert_eq!(s.stats.prefills, 0);
        assert_eq!(s.inflight(), 0);
        assert_eq!(s.kv_occupancy(), 0.0);
    }

    fn tiny_pool(prefix: PrefixCacheConfig) -> Scheduler<SimStepEngine, usize> {
        Scheduler::new(
            SimStepEngine::instant(),
            SchedulerConfig {
                policy: BatchPolicy::custom(8, 1, 0.0),
                max_inflight: 8,
                // 4 blocks × 4 tokens: fits one 8-token-prompt request
                // plus its budget, but not two full reservations.
                kv_blocks: 4,
                kv_block_tokens: 4,
                prefix_cache: prefix,
                speculative: SpeculativeConfig::disabled(),
            },
        )
    }

    #[test]
    fn shared_prefix_admits_where_full_reservation_would_reject() {
        // 8-word prompt = two full 4-token blocks; budget 4 → one
        // private block. Full reservation: 3 blocks per request.
        let prompt = "a b c d e f g h";
        let mut s = tiny_pool(PrefixCacheConfig::default());
        assert!(matches!(s.admit(prompt, 4, 8, 0), Admit::Admitted));
        let t = s.tick(0.0).unwrap();
        assert_eq!(t.prefilled, 1, "first request prefills and seeds the cache");
        // With the first request still decoding (3 of 4 blocks held),
        // the second shares its 2-block prefix: 1 private block fits.
        assert!(
            matches!(s.admit(prompt, 4, 8, 1), Admit::Admitted),
            "prefix hit must share the prompt blocks"
        );
        let t = s.tick(0.0).unwrap();
        assert_eq!(t.prefilled, 1);
        assert_eq!(s.prefix_stats().hit_tokens, 8);
        let (done, _) = s.drain(0.0).unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(s.kv_occupancy(), 0.0);

        // Cache off: full-reservation accounting rejects the second
        // request at the same point (bitwise-identical legacy math).
        let mut s = tiny_pool(PrefixCacheConfig::disabled());
        assert!(matches!(s.admit(prompt, 4, 8, 0), Admit::Admitted));
        s.tick(0.0).unwrap();
        assert!(matches!(s.admit(prompt, 4, 8, 1), Admit::Rejected(1)));
    }

    #[test]
    fn prefix_cache_skips_suffix_work_in_engine_offsets() {
        // The second identical prompt must reach the engine with a
        // non-zero prefix offset (observable through the hit counter and
        // the unchanged token stream — hits must not alter outputs).
        let prompt = "one two three four five six seven eight";
        let mut cached = tiny_pool(PrefixCacheConfig::default());
        let mut plain = sched(8, 8, 0.0);
        for s in [&mut cached, &mut plain] {
            assert!(matches!(s.admit(prompt, 4, 8, 0), Admit::Admitted));
            s.tick(0.0).unwrap();
            assert!(matches!(s.admit(prompt, 4, 8, 1), Admit::Admitted));
        }
        let (a, _) = cached.drain(0.0).unwrap();
        let (b, _) = plain.drain(0.0).unwrap();
        assert!(cached.prefix_stats().hit_tokens >= 8);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "prefix hits must not change tokens");
        }
    }

    #[test]
    fn transferred_prefix_serves_as_local_hit() {
        // Donor computes a 2-block prefix; a cold scheduler imports the
        // exported run and must serve the same prompt as a local hit,
        // with identical outputs and zero lost tokens.
        let prompt = "one two three four five six seven eight";
        let mut donor = tiny_pool(PrefixCacheConfig::default());
        assert!(matches!(donor.admit(prompt, 4, 8, 0), Admit::Admitted));
        let (done_a, _) = donor.drain(0.0).unwrap();
        let tip = donor.hot_prefixes(4)[0];
        assert_eq!(tip.1, 2, "two full 4-token blocks advertised");
        let blocks = donor.export_prefix(tip.0).expect("chain resident");

        let mut cold = tiny_pool(PrefixCacheConfig::default());
        assert_eq!(cold.import_prefix(&blocks), 8);
        assert!(matches!(cold.admit(prompt, 4, 8, 0), Admit::Admitted));
        let (done_b, _) = cold.drain(0.0).unwrap();
        assert!(
            cold.prefix_stats().hit_tokens >= 8,
            "transferred prefix must count as a hit"
        );
        assert_eq!(done_a[0].tokens, done_b[0].tokens, "transfer must not change outputs");
        assert_eq!(done_b[0].tokens.len(), 4, "zero lost tokens");
        assert_eq!(cold.kv_occupancy(), 0.0);
        // Cache off: transfers are refused outright.
        let mut off = tiny_pool(PrefixCacheConfig::disabled());
        assert_eq!(off.import_prefix(&blocks), 0);
        assert!(off.hot_prefixes(4).is_empty());
    }

    #[test]
    fn drain_pending_returns_buffered_work_and_frees_kv() {
        // max_prefill_batch 4 + a busy slot: later admissions buffer.
        let mut s: Scheduler<SimStepEngine, usize> = Scheduler::new(
            SimStepEngine::instant(),
            SchedulerConfig {
                policy: BatchPolicy::custom(8, 4, 10.0),
                max_inflight: 8,
                kv_blocks: 256,
                kv_block_tokens: 16,
                prefix_cache: PrefixCacheConfig::default(),
                speculative: SpeculativeConfig::disabled(),
            },
        );
        assert!(matches!(s.admit("a b", 32, 2, 0), Admit::Admitted));
        s.tick(0.0).unwrap(); // idle replica prefills #0 immediately
        for i in 1..3usize {
            assert!(matches!(s.admit("a b", 4, 2, i), Admit::Admitted));
        }
        // 2 waiting < rung 4 with a busy slot and a huge flush window:
        // they stay buffered.
        s.tick(0.001).unwrap();
        assert_eq!(s.inflight(), 3);
        let mut back = s.drain_pending();
        back.sort_unstable();
        assert_eq!(back, vec![1, 2], "buffered prefills handed back");
        assert_eq!(s.inflight(), 1, "the decoding slot is untouched");
        let (done, _) = s.drain(0.002).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(s.kv_occupancy(), 0.0, "pending KV charges released");
    }

    #[test]
    fn for_each_slot_exposes_token_streams() {
        let mut s = sched(4, 4, 0.0);
        assert!(matches!(s.admit("a b c", 8, 3, 7), Admit::Admitted));
        let mut now = 0.0;
        for _ in 0..3 {
            let t = s.tick(now).unwrap();
            now += t.wait_s.unwrap_or(0.0).max(1e-9);
        }
        let mut seen = Vec::new();
        s.for_each_slot(|p, tokens| seen.push((*p, tokens.len())));
        assert_eq!(seen.len(), 1);
        assert_eq!(seen[0].0, 7);
        assert!(seen[0].1 >= 2, "prefill + decode tokens visible");
    }

    #[test]
    fn pending_admissions_charge_whole_blocks() {
        // blocks_for(a + b) <= blocks_for(a) + blocks_for(b): two 17-token
        // needs are 34 tokens (3 blocks pooled) but 2 + 2 = 4 real
        // blocks. A 3-block pool must reject the second admission
        // instead of over-admitting pending work.
        let mut s: Scheduler<SimStepEngine, u32> = Scheduler::new(
            SimStepEngine::instant(),
            SchedulerConfig {
                policy: BatchPolicy::custom(8, 1, 0.0),
                max_inflight: 8,
                kv_blocks: 3,
                kv_block_tokens: 16,
                prefix_cache: PrefixCacheConfig::disabled(),
                speculative: SpeculativeConfig::disabled(),
            },
        );
        let prompt = "w w w w w w w w w"; // 9 tokens + 8 budget = 17
        assert!(matches!(s.admit(prompt, 8, 9, 1), Admit::Admitted));
        assert!(
            matches!(s.admit(prompt, 8, 9, 2), Admit::Rejected(2)),
            "pooled token rounding must not over-admit pending blocks"
        );
        let (done, now) = s.drain(0.0).unwrap();
        assert_eq!(done.len(), 1);
        assert!(matches!(s.admit(prompt, 8, 9, 2), Admit::Admitted));
        let _ = s.drain(now).unwrap();
    }

    // -- speculative decode ------------------------------------------------

    fn spec_cfg(min_accept_rate: f64) -> SpeculativeConfig {
        SpeculativeConfig {
            enabled: true,
            draft_tier: 0,
            draft_tokens: 4,
            min_accept_rate,
            sim_accept: 0.75,
        }
    }

    fn spec_sched(
        accept: f64,
        seed: u64,
        spec: SpeculativeConfig,
        max_batch: usize,
    ) -> Scheduler<SimStepEngine, usize> {
        Scheduler::new(
            SimStepEngine::instant().with_acceptance(accept, seed),
            SchedulerConfig {
                policy: BatchPolicy::custom(max_batch, 1, 0.0),
                max_inflight: 8,
                kv_blocks: 256,
                kv_block_tokens: 16,
                prefix_cache: PrefixCacheConfig::default(),
                speculative: spec,
            },
        )
    }

    #[test]
    fn speculative_decode_saves_steps_and_keeps_the_token_stream() {
        // Accept-everything drafts: every verify step lands k + 1 = 5
        // tokens, so a 16-token sequence needs 3 verify steps where
        // plain decode needs 15.
        let mut spec = spec_sched(1.0, 42, spec_cfg(0.3), 1);
        spec.set_draft_available(true);
        let mut plain = sched(8, 1, 0.0);
        for s in [&mut spec, &mut plain] {
            assert!(matches!(s.admit("spec prompt", 16, 2, 0), Admit::Admitted));
        }
        let (a, _) = spec.drain(0.0).unwrap();
        let (b, _) = plain.drain(0.0).unwrap();
        assert_eq!(a[0].tokens, b[0].tokens, "speculation must not change tokens");
        assert_eq!(a[0].tokens.len(), 16);
        assert_eq!(spec.stats.spec_verify_steps, 3);
        assert_eq!(spec.stats.spec_drafted_tokens, 12);
        assert_eq!(spec.stats.spec_accepted_tokens, 12);
        assert_eq!(spec.stats.spec_rejected_tokens, 0);
        assert_eq!(spec.stats.tokens_out, plain.stats.tokens_out);
        assert!(
            spec.stats.decode_steps < plain.stats.decode_steps,
            "{} verify dispatches vs {} plain",
            spec.stats.decode_steps,
            plain.stats.decode_steps
        );
        assert!((spec.spec_accept_ema() - 1.0).abs() < 1e-12);
        assert_eq!(spec.kv_occupancy(), 0.0);
    }

    #[test]
    fn speculation_waits_for_the_draft_tier_signal() {
        // Config on but the draft tier never reports ready: every batch
        // must run the plain path (and turning the signal off mid-run
        // falls back too).
        let mut s = spec_sched(1.0, 7, spec_cfg(0.3), 1);
        assert!(matches!(s.admit("p q", 8, 2, 0), Admit::Admitted));
        let (done, now) = s.drain(0.0).unwrap();
        assert_eq!(done[0].tokens.len(), 8);
        assert_eq!(s.stats.spec_verify_steps, 0, "no drafts without the signal");
        assert!(s.spec_active(), "config stays armed");
        // Signal flips on: the next request speculates.
        s.set_draft_available(true);
        assert!(matches!(s.admit("p q", 8, 2, 1), Admit::Admitted));
        let _ = s.drain(now).unwrap();
        assert!(s.stats.spec_verify_steps > 0);
        // Mid-recovery: the signal drops and speculation stops cleanly.
        s.set_draft_available(false);
        let steps = s.stats.spec_verify_steps;
        assert!(matches!(s.admit("p q", 8, 2, 2), Admit::Admitted));
        let _ = s.drain(1.0).unwrap();
        assert_eq!(s.stats.spec_verify_steps, steps);
    }

    #[test]
    fn speculative_disabled_config_is_bit_identical_to_plain() {
        // Engine carries an acceptance model, but the (default-off)
        // config must keep the plain path: identical stats, streams, KV.
        let mut off = spec_sched(0.9, 3, SpeculativeConfig::disabled(), 8);
        off.set_draft_available(true); // signal alone must not speculate
        let mut plain = sched(8, 8, 0.0);
        for s in [&mut off, &mut plain] {
            for i in 0..4usize {
                assert!(matches!(s.admit("x y z", 6 + i, 3, i), Admit::Admitted));
            }
        }
        let (a, _) = off.drain(0.0).unwrap();
        let (b, _) = plain.drain(0.0).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens);
        }
        assert_eq!(off.stats.decode_steps, plain.stats.decode_steps);
        assert_eq!(off.stats.tokens_out, plain.stats.tokens_out);
        assert_eq!(off.stats.spec_drafted_tokens, 0);
        assert_eq!(off.stats.spec_verify_steps, 0);
    }

    #[test]
    fn speculative_auto_disables_below_min_accept_rate() {
        // Acceptance 0: every draft is rejected and rolled back, the
        // EMA decays 0.8^n from 1.0, and after the warmup it trips the
        // per-replica latch — the rest of the run decodes plainly, and
        // the sequence still completes exactly.
        let mut s = spec_sched(0.0, 9, spec_cfg(0.3), 1);
        s.set_draft_available(true);
        assert!(matches!(s.admit("long running prompt", 64, 3, 0), Admit::Admitted));
        let (done, _) = s.drain(0.0).unwrap();
        assert_eq!(done[0].tokens.len(), 64, "rollback loses no completions");
        let mut plain = sched(8, 1, 0.0);
        assert!(matches!(plain.admit("long running prompt", 64, 3, 0), Admit::Admitted));
        let (pd, _) = plain.drain(0.0).unwrap();
        assert_eq!(done[0].tokens, pd[0].tokens);
        assert!(!s.spec_active(), "EMA must latch the disable");
        assert_eq!(s.stats.spec_verify_steps, SPEC_EMA_WARMUP);
        assert_eq!(s.stats.spec_accepted_tokens, 0);
        assert_eq!(
            s.stats.spec_rejected_tokens,
            s.stats.spec_drafted_tokens
        );
        assert!(
            s.stats.decode_steps > s.stats.spec_verify_steps,
            "post-latch decode must be plain"
        );
        assert!(s.spec_accept_ema() < 0.3);
        assert_eq!(s.kv_occupancy(), 0.0);
    }

    #[test]
    fn any_verdict_sequence_matches_plain_decode_exactly() {
        // Property: for any seeded accept/reject verdict stream, the
        // scheduler's KV ledger and slot state end identical to a plain
        // run of the same workload — rollback never leaks a block,
        // never frees a shared prefix block, and never changes tokens.
        for seed in 0..24u64 {
            let rate = (seed % 11) as f64 / 10.0;
            let mut spec = spec_sched(rate, seed.wrapping_mul(0x9e37), spec_cfg(0.0), 4);
            spec.set_draft_available(true);
            let mut plain = sched(8, 4, 0.0);
            let shared = "one two three four five six seven eight";
            for s in [&mut spec, &mut plain] {
                for i in 0..6usize {
                    let budget = 3 + (seed as usize + i * 5) % 13;
                    assert!(matches!(
                        s.admit(shared, budget, 8, i),
                        Admit::Admitted
                    ));
                }
            }
            // Tick manually so the KV invariants are checked after
            // every draft/verify/rollback cycle, not just at the end.
            let mut now = 0.0;
            let mut done = Vec::new();
            while spec.inflight() > 0 {
                let t = spec.tick(now).unwrap();
                spec.kv.check_invariants().unwrap();
                done.extend(t.finished);
                if let Some(w) = t.wait_s {
                    now += w.max(1e-9);
                }
            }
            let (pd, _) = plain.drain(0.0).unwrap();
            assert_eq!(done.len(), pd.len());
            done.sort_by_key(|f| f.payload);
            let mut pd = pd;
            pd.sort_by_key(|f| f.payload);
            for (a, b) in done.iter().zip(pd.iter()) {
                assert_eq!(a.tokens, b.tokens, "rate {rate} seed {seed}");
            }
            assert_eq!(spec.stats.completed, plain.stats.completed);
            assert_eq!(spec.stats.tokens_out, plain.stats.tokens_out);
            assert_eq!(spec.inflight(), 0);
            assert_eq!(spec.kv_occupancy(), 0.0, "no leaked blocks");
            assert_eq!(
                spec.stats.spec_drafted_tokens,
                spec.stats.spec_accepted_tokens + spec.stats.spec_rejected_tokens
            );
        }
    }
}
