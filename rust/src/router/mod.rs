//! Pick — the routing layer (paper §Routing Design).
//!
//! Predicts each prompt's complexity class (low/medium/high → tier
//! small/medium/large) through one of three modes:
//!
//! * [`keyword::KeywordRouter`] — deterministic lexical heuristics,
//!   near-zero latency;
//! * semantic — the compiled DistilBERT-lite classifier behind the
//!   [`Classifier`] trait (PJRT implementation in
//!   [`crate::runtime::classifier`]);
//! * [`hybrid::HybridRouter`] — keywords first, semantic refinement when
//!   keyword confidence is low.

pub mod bandit;
pub mod keyword;
pub mod hybrid;

use crate::config::RouterMode;

/// Complexity classes (paper: low/medium/high, Eq. 3/4 outputs).
pub const N_CLASSES: usize = 3;

/// A routing verdict for one prompt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Classification {
    /// Predicted class: 0 = low, 1 = medium, 2 = high.
    pub complexity: usize,
    /// Confidence in [0, 1] (softmax max-prob for the semantic path,
    /// rule strength for keywords).
    pub confidence: f64,
    /// Which path produced the verdict (for telemetry / Fig. 4).
    pub mode: RouterMode,
    /// Classification overhead in seconds (the semantic path's extra
    /// latency the paper measures in Figs. 6/10).
    pub overhead_s: f64,
}

/// Anything that can classify prompt complexity semantically.
///
/// The production implementation wraps the AOT-compiled classifier HLO
/// behind PJRT; tests and pure simulations may use
/// [`crate::workload::OracleClassifier`].
pub trait Classifier {
    /// Class probabilities for a prompt (length [`N_CLASSES`]).
    fn probs(&mut self, text: &str) -> crate::Result<[f64; N_CLASSES]>;

    /// Convenience: argmax class + confidence.
    fn classify(&mut self, text: &str) -> crate::Result<(usize, f64)> {
        let p = self.probs(text)?;
        let mut best = 0;
        for k in 1..N_CLASSES {
            if p[k] > p[best] {
                best = k;
            }
        }
        Ok((best, p[best]))
    }
}

/// A router maps prompts to classifications.
pub trait Router {
    fn route(&mut self, text: &str) -> crate::Result<Classification>;
    fn mode(&self) -> RouterMode;
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fixed(pub [f64; 3]);

    impl Classifier for Fixed {
        fn probs(&mut self, _t: &str) -> crate::Result<[f64; 3]> {
            Ok(self.0)
        }
    }

    #[test]
    fn classify_takes_argmax() {
        let mut c = Fixed([0.1, 0.2, 0.7]);
        let (k, p) = c.classify("x").unwrap();
        assert_eq!(k, 2);
        assert!((p - 0.7).abs() < 1e-12);
    }
}
