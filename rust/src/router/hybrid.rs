//! Hybrid routing (paper §Pick): "Simple queries are routed using
//! keywords, while ambiguous ones are refined by DistilBERT."
//!
//! The keyword verdict stands when its confidence clears the configured
//! threshold; otherwise the semantic classifier re-classifies, paying the
//! classification overhead only on the ambiguous fraction — the balance
//! between latency and precision the paper claims for this mode.

use crate::config::{RouterConfig, RouterMode};

use super::keyword::KeywordRouter;
use super::{Classification, Classifier, Router};

/// Semantic router: always consults the classifier.
pub struct SemanticRouter<C: Classifier> {
    classifier: C,
    overhead_s: f64,
}

impl<C: Classifier> SemanticRouter<C> {
    pub fn new(classifier: C, overhead_s: f64) -> Self {
        Self { classifier, overhead_s }
    }
}

impl<C: Classifier> Router for SemanticRouter<C> {
    fn route(&mut self, text: &str) -> crate::Result<Classification> {
        let (complexity, confidence) = self.classifier.classify(text)?;
        Ok(Classification {
            complexity,
            confidence,
            mode: RouterMode::Semantic,
            overhead_s: self.overhead_s,
        })
    }

    fn mode(&self) -> RouterMode {
        RouterMode::Semantic
    }
}

/// Hybrid router: keyword fast path + semantic refinement.
pub struct HybridRouter<C: Classifier> {
    classifier: C,
    confidence_threshold: f64,
    semantic_overhead_s: f64,
    /// Telemetry: how often each path decided (Fig. 4 support).
    pub keyword_decisions: u64,
    pub semantic_decisions: u64,
}

impl<C: Classifier> HybridRouter<C> {
    pub fn new(classifier: C, cfg: &RouterConfig) -> Self {
        Self {
            classifier,
            confidence_threshold: cfg.hybrid_confidence,
            semantic_overhead_s: cfg.semantic_overhead_s,
            keyword_decisions: 0,
            semantic_decisions: 0,
        }
    }

    /// Fraction of prompts the semantic path had to refine.
    pub fn refinement_rate(&self) -> f64 {
        let total = self.keyword_decisions + self.semantic_decisions;
        if total == 0 {
            0.0
        } else {
            self.semantic_decisions as f64 / total as f64
        }
    }
}

impl<C: Classifier> Router for HybridRouter<C> {
    fn route(&mut self, text: &str) -> crate::Result<Classification> {
        let kw = KeywordRouter::classify(text);
        if kw.confidence >= self.confidence_threshold {
            self.keyword_decisions += 1;
            return Ok(Classification { mode: RouterMode::Hybrid, ..kw });
        }
        self.semantic_decisions += 1;
        let (complexity, confidence) = self.classifier.classify(text)?;
        Ok(Classification {
            complexity,
            confidence,
            mode: RouterMode::Hybrid,
            overhead_s: self.semantic_overhead_s,
        })
    }

    fn mode(&self) -> RouterMode {
        RouterMode::Hybrid
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RouterConfig;

    /// Classifier that always answers `class` with given confidence.
    struct Fixed(usize, f64);

    impl Classifier for Fixed {
        fn probs(&mut self, _t: &str) -> crate::Result<[f64; 3]> {
            let mut p = [(1.0 - self.1) / 2.0; 3];
            p[self.0] = self.1;
            Ok(p)
        }
    }

    fn cfg() -> RouterConfig {
        RouterConfig {
            mode: RouterMode::Hybrid,
            hybrid_confidence: 0.5,
            semantic_overhead_s: 0.3,
        }
    }

    #[test]
    fn confident_keywords_skip_classifier() {
        let mut r = HybridRouter::new(Fixed(1, 0.9), &cfg());
        // "prove" is a strong high cue → keyword path decides.
        let c = r.route("prove the theorem").unwrap();
        assert_eq!(c.complexity, 2);
        assert_eq!(c.overhead_s, 0.0);
        assert_eq!(r.keyword_decisions, 1);
        assert_eq!(r.semantic_decisions, 0);
    }

    #[test]
    fn ambiguous_prompts_get_refined() {
        let mut r = HybridRouter::new(Fixed(2, 0.92), &cfg());
        // No keyword cues → keyword confidence 0.35 < 0.5 → semantic.
        let c = r.route("natalia sold clips in april").unwrap();
        assert_eq!(c.complexity, 2);
        assert!((c.confidence - 0.92).abs() < 1e-9);
        assert!(c.overhead_s > 0.0);
        assert_eq!(r.semantic_decisions, 1);
        assert!((r.refinement_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn semantic_router_always_pays_overhead() {
        let mut r = SemanticRouter::new(Fixed(0, 0.8), 0.35);
        let c = r.route("what is 2 plus 2").unwrap();
        assert_eq!(c.complexity, 0);
        assert!((c.overhead_s - 0.35).abs() < 1e-12);
        assert_eq!(c.mode, RouterMode::Semantic);
    }

    #[test]
    fn hybrid_mode_reported() {
        let mut r = HybridRouter::new(Fixed(1, 0.9), &cfg());
        assert_eq!(r.route("prove it").unwrap().mode, RouterMode::Hybrid);
        assert_eq!(r.mode(), RouterMode::Hybrid);
    }
}
