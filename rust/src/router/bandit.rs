//! Online contextual-bandit tier selection (`pool.routing.bandit.*`).
//!
//! The static router (keywords + classifier, Alg. 2) predicts a
//! complexity class and maps it to a tier — but never learns from what
//! actually happened. This layer closes the loop: each completed
//! request's outcome (success, latency, cost — the same signals the
//! span timeline records) updates a per-(complexity-class, tier) arm,
//! and selection becomes epsilon-greedy/UCB over the learned estimates,
//! PickLLM-style. The reward is the paper's Eq. 2 convex score —
//! `w_R·R̂ + w_T·T̂ + w_C·Ĉ` with the quality term from
//! [`scoring::relevance`] — so the learner optimizes exactly the
//! objective the operator profile declares, it just estimates the
//! components from observed outcomes instead of priors.
//!
//! Determinism: selection RNG is a seeded [`SplitMix64`]; identical
//! seeds + identical feedback sequences reproduce identical decisions.

use std::sync::{Mutex, MutexGuard};

use crate::config::BanditConfig;
use crate::scoring::{relevance, score, Components, ScoreNormalizer, Weights};
use crate::util::rng::SplitMix64;
use crate::util::stats::Rolling;

use super::N_CLASSES;

/// Tier count mirrors class count (small/medium/large).
pub const N_TIERS: usize = 3;

/// Tier-index → name for metric labels (mirrors `models::Tier::name`
/// without a dependency edge, as the config chain parser does).
const TIER_NAMES: [&str; N_TIERS] = ["small", "medium", "large"];

/// One (class, tier) arm's learned state.
#[derive(Debug)]
struct Arm {
    /// Windowed Eq. 2 rewards (failures contribute 0).
    rewards: Rolling,
    /// Times this arm was chosen by `select` (the forced-exploration
    /// counter — selections, not completions, so concurrent in-flight
    /// requests can't hammer one cold arm).
    selections: u64,
    successes: u64,
    failures: u64,
    latency: Rolling,
    cost: Rolling,
}

impl Arm {
    fn new(window: usize) -> Arm {
        let w = window.max(1);
        Arm {
            rewards: Rolling::new(w),
            selections: 0,
            successes: 0,
            failures: 0,
            latency: Rolling::new(w),
            cost: Rolling::new(w),
        }
    }
}

/// Public per-arm snapshot (for `SimReport` and `/metrics` gauges).
#[derive(Debug, Clone)]
pub struct ArmStat {
    pub class: usize,
    pub tier: usize,
    pub selections: u64,
    pub successes: u64,
    pub failures: u64,
    pub mean_reward: f64,
    pub mean_latency_s: f64,
    pub mean_cost_usd: f64,
}

/// The learner: one arm per (predicted complexity class, tier), shared
/// reward normalizers, seeded selection RNG. Pure and single-threaded —
/// the gateway wraps it in [`SharedBandit`], the simulator owns it
/// directly on virtual time.
#[derive(Debug)]
pub struct TierBandit {
    cfg: BanditConfig,
    weights: Weights,
    /// Per-tier capability vector of the tier's canonical model — the
    /// R̂ input. Fixed at construction (model zoo is static).
    capability: [[f64; 3]; N_TIERS],
    /// Tiers eligible for selection (a tier with no replica budget must
    /// never be chosen).
    allowed: [bool; N_TIERS],
    arms: Vec<Arm>, // N_CLASSES × N_TIERS, row-major by class
    /// Shared latency/cost history → T̂/Ĉ normalization (Eq. 2's
    /// "historical system statistics").
    norm: ScoreNormalizer,
    rng: SplitMix64,
    /// Monotonic per-tier counters for `/metrics`.
    selected_total: [u64; N_TIERS],
    reward_total: [f64; N_TIERS],
}

impl TierBandit {
    pub fn new(
        cfg: &BanditConfig,
        weights: Weights,
        capability: [[f64; 3]; N_TIERS],
        allowed: [bool; N_TIERS],
        seed: u64,
    ) -> TierBandit {
        let window = cfg.window.max(1);
        TierBandit {
            cfg: cfg.clone(),
            weights,
            capability,
            allowed,
            arms: (0..N_CLASSES * N_TIERS).map(|_| Arm::new(window)).collect(),
            norm: ScoreNormalizer::new(window),
            rng: SplitMix64::new(seed),
            selected_total: [0; N_TIERS],
            reward_total: [0.0; N_TIERS],
        }
    }

    fn arm(&self, class: usize, tier: usize) -> &Arm {
        &self.arms[class.min(N_CLASSES - 1) * N_TIERS + tier.min(N_TIERS - 1)]
    }

    fn arm_mut(&mut self, class: usize, tier: usize) -> &mut Arm {
        &mut self.arms[class.min(N_CLASSES - 1) * N_TIERS + tier.min(N_TIERS - 1)]
    }

    /// Pick a tier for a predicted class. `fallback` (the static
    /// router's choice) is returned only if no tier is eligible.
    ///
    /// Policy: arms under `min_samples` selections are tried first
    /// (least-selected wins — forced exploration), then with probability
    /// `epsilon` a uniform eligible tier, otherwise the arm maximizing
    /// windowed mean reward + a UCB bonus.
    pub fn select(&mut self, class: usize, fallback: usize) -> usize {
        let class = class.min(N_CLASSES - 1);
        if !self.allowed.iter().any(|&a| a) {
            return fallback;
        }
        let pick = self.pick(class);
        self.arm_mut(class, pick).selections += 1;
        self.selected_total[pick] += 1;
        pick
    }

    fn pick(&mut self, class: usize) -> usize {
        // Forced exploration: coldest under-sampled arm first.
        let mut cold: Option<usize> = None;
        for t in 0..N_TIERS {
            if !self.allowed[t] {
                continue;
            }
            let n = self.arm(class, t).selections;
            if n < self.cfg.min_samples as u64
                && cold.map_or(true, |c| n < self.arm(class, c).selections)
            {
                cold = Some(t);
            }
        }
        if let Some(t) = cold {
            return t;
        }
        // Epsilon exploration over eligible tiers.
        if self.cfg.epsilon > 0.0 && self.rng.chance(self.cfg.epsilon) {
            let eligible: Vec<usize> =
                (0..N_TIERS).filter(|&t| self.allowed[t]).collect();
            return eligible[self.rng.below(eligible.len() as u64) as usize];
        }
        // Greedy with a UCB bonus on top of the windowed mean.
        let total: u64 = (0..N_TIERS)
            .filter(|&t| self.allowed[t])
            .map(|t| self.arm(class, t).selections)
            .sum();
        let ln_total = (total.max(1) as f64).ln().max(0.0);
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for t in 0..N_TIERS {
            if !self.allowed[t] {
                continue;
            }
            let a = self.arm(class, t);
            let mean = if a.rewards.is_empty() { 0.5 } else { a.rewards.mean() };
            let v = mean + (2.0 * ln_total / a.selections.max(1) as f64).sqrt();
            if v > best_v {
                best_v = v;
                best = t;
            }
        }
        best
    }

    /// Record one completed (or terminally failed) request's outcome for
    /// its serving tier. Failures score 0; successes score the Eq. 2
    /// convex combination of relevance, timeliness, and economy over the
    /// learner's own latency/cost history.
    pub fn feedback(
        &mut self,
        class: usize,
        tier: usize,
        confidence: f64,
        ok: bool,
        latency_s: f64,
        cost_usd: f64,
    ) {
        let class = class.min(N_CLASSES - 1);
        let tier = tier.min(N_TIERS - 1);
        let reward = if ok {
            self.norm.observe(latency_s, cost_usd);
            score(
                self.weights,
                Components {
                    relevance: relevance(&self.capability[tier], class, confidence),
                    timeliness: self.norm.timeliness(latency_s),
                    economy: self.norm.economy(cost_usd),
                },
            )
        } else {
            0.0
        };
        let arm = self.arm_mut(class, tier);
        arm.rewards.push(reward);
        if ok {
            arm.successes += 1;
            arm.latency.push(latency_s);
            arm.cost.push(cost_usd);
        } else {
            arm.failures += 1;
        }
        self.reward_total[tier] += reward;
    }

    /// Windowed mean reward of one arm (None before any feedback).
    pub fn estimate(&self, class: usize, tier: usize) -> Option<f64> {
        let a = self.arm(class, tier);
        if a.rewards.is_empty() {
            None
        } else {
            Some(a.rewards.mean())
        }
    }

    /// Snapshot of every arm that has been selected at least once.
    pub fn arm_stats(&self) -> Vec<ArmStat> {
        let mut out = Vec::new();
        for class in 0..N_CLASSES {
            for tier in 0..N_TIERS {
                let a = self.arm(class, tier);
                if a.selections == 0 && a.successes + a.failures == 0 {
                    continue;
                }
                out.push(ArmStat {
                    class,
                    tier,
                    selections: a.selections,
                    successes: a.successes,
                    failures: a.failures,
                    mean_reward: a.rewards.mean(),
                    mean_latency_s: a.latency.mean(),
                    mean_cost_usd: a.cost.mean(),
                });
            }
        }
        out
    }

    pub fn selected_total(&self) -> [u64; N_TIERS] {
        self.selected_total
    }

    pub fn reward_total(&self) -> [f64; N_TIERS] {
        self.reward_total
    }

    /// `ps_bandit_*` series: per-tier selection/reward counters and
    /// per-arm estimate gauges, quiet-when-zero like every labeled
    /// family the gateway exports.
    pub fn metric_series(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for (t, name) in TIER_NAMES.iter().enumerate() {
            if self.selected_total[t] == 0 {
                continue;
            }
            out.push((
                format!("ps_bandit_selected_total{{tier=\"{name}\"}}"),
                self.selected_total[t] as f64,
            ));
        }
        for (t, name) in TIER_NAMES.iter().enumerate() {
            if self.reward_total[t] == 0.0 {
                continue;
            }
            out.push((
                format!("ps_bandit_reward_total{{tier=\"{name}\"}}"),
                self.reward_total[t],
            ));
        }
        for s in self.arm_stats() {
            if s.successes + s.failures == 0 {
                continue;
            }
            out.push((
                format!(
                    "ps_bandit_estimate{{class=\"{}\",tier=\"{}\"}}",
                    s.class, TIER_NAMES[s.tier]
                ),
                s.mean_reward,
            ));
        }
        out
    }
}

/// Thread-safe wrapper for the live gateway: the router thread selects,
/// replica/gate threads feed outcomes back. One mutex around the whole
/// learner — both operations are a few arithmetic ops per request, far
/// off the decode hot path.
#[derive(Debug)]
pub struct SharedBandit {
    inner: Mutex<TierBandit>,
    /// Per-tier $/replica-second — the live cost proxy (the gateway has
    /// no per-request dollar figure at completion time, so cost ≈
    /// replica-rate × latency).
    cost_rate: [f64; N_TIERS],
}

impl SharedBandit {
    pub fn new(inner: TierBandit, cost_rate: [f64; N_TIERS]) -> SharedBandit {
        SharedBandit { inner: Mutex::new(inner), cost_rate }
    }

    fn lock(&self) -> MutexGuard<'_, TierBandit> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn select(&self, class: usize, fallback: usize) -> usize {
        self.lock().select(class, fallback)
    }

    pub fn feedback(
        &self,
        class: usize,
        tier: usize,
        confidence: f64,
        ok: bool,
        latency_s: f64,
    ) {
        let cost = self.cost_rate[tier.min(N_TIERS - 1)] * latency_s.max(0.0);
        self.lock().feedback(class, tier, confidence, ok, latency_s, cost);
    }

    pub fn metric_series(&self) -> Vec<(String, f64)> {
        self.lock().metric_series()
    }

    pub fn arm_stats(&self) -> Vec<ArmStat> {
        self.lock().arm_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Profile;

    fn caps() -> [[f64; 3]; 3] {
        // Zoo-shaped: small great on easy, weak on hard; large strong
        // everywhere (and most expensive).
        [[0.97, 0.80, 0.45], [0.97, 0.90, 0.70], [0.98, 0.94, 0.88]]
    }

    fn bandit(cfg: &BanditConfig, seed: u64) -> TierBandit {
        TierBandit::new(
            cfg,
            Weights::from_profile(&Profile::BALANCED),
            caps(),
            [true; 3],
            seed,
        )
    }

    #[test]
    fn forced_exploration_tries_every_arm_first() {
        let cfg = BanditConfig { enabled: true, ..BanditConfig::default() };
        let mut b = bandit(&cfg, 7);
        let mut seen = [0u64; 3];
        for _ in 0..3 * cfg.min_samples {
            seen[b.select(2, 2)] += 1;
        }
        for (t, &n) in seen.iter().enumerate() {
            assert_eq!(n, cfg.min_samples as u64, "tier {t} under-explored");
        }
    }

    #[test]
    fn selection_is_seed_deterministic() {
        let cfg = BanditConfig {
            enabled: true,
            epsilon: 0.3,
            window: 64,
            min_samples: 2,
        };
        let run = || {
            let mut b = bandit(&cfg, 99);
            let mut picks = Vec::new();
            for i in 0..500u64 {
                let class = (i % 3) as usize;
                let t = b.select(class, class);
                // Deterministic synthetic outcome stream.
                let ok = (i * 7 + t as u64) % 5 != 0;
                b.feedback(class, t, 0.9, ok, 0.5 + t as f64, 0.001 * (t + 1) as f64);
                picks.push(t);
            }
            picks
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn learner_converges_to_the_rewarding_arm() {
        let cfg = BanditConfig {
            enabled: true,
            epsilon: 0.05,
            window: 128,
            min_samples: 5,
        };
        let mut b = bandit(&cfg, 11);
        // Class 2: the medium tier succeeds as often as large at a third
        // of the cost and latency — the learner must shift traffic to it.
        let mut rng = SplitMix64::new(3);
        for _ in 0..600 {
            let t = b.select(2, 2);
            let (p_ok, lat, cost) = match t {
                0 => (0.40, 1.0, 0.001),
                1 => (0.90, 1.5, 0.003),
                _ => (0.92, 4.0, 0.012),
            };
            b.feedback(2, t, 0.95, rng.chance(p_ok), lat, cost);
        }
        let picks: Vec<u64> =
            (0..3).map(|t| b.arm(2, t).selections).collect();
        assert!(
            picks[1] > picks[0] && picks[1] > picks[2],
            "medium must dominate: {picks:?}"
        );
        let est1 = b.estimate(2, 1).unwrap();
        assert!(est1 > b.estimate(2, 0).unwrap());
        assert!(est1 > b.estimate(2, 2).unwrap());
    }

    #[test]
    fn disallowed_tiers_are_never_selected() {
        let cfg = BanditConfig { enabled: true, ..BanditConfig::default() };
        let mut b = TierBandit::new(
            &cfg,
            Weights::from_profile(&Profile::BALANCED),
            caps(),
            [true, false, true],
            5,
        );
        for i in 0..200 {
            let t = b.select(i % 3, 0);
            assert_ne!(t, 1, "tier 1 has no replica budget");
        }
        // No tier eligible at all → the static fallback stands.
        let mut none = TierBandit::new(
            &cfg,
            Weights::from_profile(&Profile::BALANCED),
            caps(),
            [false; 3],
            5,
        );
        assert_eq!(none.select(2, 2), 2);
    }

    #[test]
    fn failures_zero_the_reward() {
        let cfg = BanditConfig { enabled: true, ..BanditConfig::default() };
        let mut b = bandit(&cfg, 1);
        b.feedback(0, 0, 1.0, false, 0.0, 0.0);
        assert_eq!(b.estimate(0, 0), Some(0.0));
        b.feedback(0, 0, 1.0, true, 0.5, 0.001);
        assert!(b.estimate(0, 0).unwrap() > 0.0);
        let stats = b.arm_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!((stats[0].successes, stats[0].failures), (1, 1));
    }

    #[test]
    fn metric_series_is_quiet_until_used_then_labeled() {
        let cfg = BanditConfig { enabled: true, ..BanditConfig::default() };
        let shared = SharedBandit::new(bandit(&cfg, 2), [0.01, 0.03, 0.1]);
        assert!(shared.metric_series().is_empty(), "fresh learner must be quiet");
        let t = shared.select(1, 1);
        shared.feedback(1, t, 0.9, true, 0.8);
        let series = shared.metric_series();
        assert!(series
            .iter()
            .any(|(k, _)| k.starts_with("ps_bandit_selected_total{tier=")));
        assert!(series
            .iter()
            .any(|(k, _)| k.starts_with("ps_bandit_reward_total{tier=")));
        assert!(series
            .iter()
            .any(|(k, v)| k.starts_with("ps_bandit_estimate{class=") && *v > 0.0));
    }
}
