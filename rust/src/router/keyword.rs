//! Keyword-based routing (paper §Pick): deterministic, transparent,
//! near-zero latency.
//!
//! "Words such as 'sum', 'list', or 'define' indicate low complexity,
//! while 'prove', 'derive', or 'explain why' suggest high complexity.
//! Prompts that do not match any keyword are treated as medium."
//!
//! High-complexity cues dominate when both appear ("list the steps to
//! prove...") — underestimating a hard prompt fails it, overestimating a
//! easy one merely costs money; length nudges borderline prompts.

use crate::config::RouterMode;
use crate::tokenizer;

use super::{Classification, Router};

/// Single-word cues for low complexity.
const LOW_WORDS: &[&str] = &[
    "sum", "list", "define", "name", "true", "false", "compute", "finish",
    "choose", "times", "plus", "minus",
];

/// Single-word cues for high complexity.
const HIGH_WORDS: &[&str] = &[
    "prove", "derive", "analyze", "optimize", "design", "induction",
    "compare", "contrast", "terminates", "asymptotic", "complexity",
];

/// Phrase cues (checked on the normalized word sequence).
const LOW_PHRASES: &[&[&str]] = &[
    &["what", "is"],
    &["how", "many"],
    &["what", "happens", "next"],
];

const HIGH_PHRASES: &[&[&str]] = &[
    &["explain", "why"],
    &["step", "by", "step"],
    &["reasoning", "step"],
    &["why", "does"],
    &["closed", "form"],
];

/// Words above this count nudge a no-match prompt toward high.
const LONG_PROMPT_WORDS: usize = 28;

#[derive(Debug, Default, Clone)]
pub struct KeywordRouter;

impl KeywordRouter {
    pub fn new() -> Self {
        Self
    }

    /// Count cue hits in a prompt.
    fn hits(words: &[&str]) -> (usize, usize) {
        let mut low = 0;
        let mut high = 0;
        for w in words {
            if LOW_WORDS.iter().any(|c| w.eq_ignore_ascii_case(c)) {
                low += 1;
            }
            if HIGH_WORDS.iter().any(|c| w.eq_ignore_ascii_case(c)) {
                high += 1;
            }
        }
        for phrase in LOW_PHRASES {
            if contains_seq(words, phrase) {
                low += 1;
            }
        }
        for phrase in HIGH_PHRASES {
            if contains_seq(words, phrase) {
                high += 2; // phrases are stronger evidence than words
            }
        }
        (low, high)
    }

    /// Pure classification (no trait plumbing) — also used by the hybrid
    /// router and benches.
    pub fn classify(text: &str) -> Classification {
        // Borrowed word runs, matched case-insensitively — one Vec of
        // slices instead of one heap String per word on every routed
        // request.
        let words: Vec<&str> = tokenizer::words(text).collect();
        let (low, high) = Self::hits(&words);
        let (complexity, confidence) = if high > 0 && high >= low {
            // High cues win ties: under-provisioning fails the request.
            (2, 0.55 + 0.15 * high.min(3) as f64)
        } else if low > 0 && high == 0 {
            (0, 0.55 + 0.15 * low.min(3) as f64)
        } else if low > 0 && high > 0 {
            (1, 0.4) // conflicting evidence
        } else if words.len() > LONG_PROMPT_WORDS {
            (2, 0.45)
        } else {
            (1, 0.35) // no signal: medium, low confidence
        };
        Classification {
            complexity,
            confidence: confidence.min(1.0),
            mode: RouterMode::Keyword,
            overhead_s: 0.0,
        }
    }
}

fn contains_seq(words: &[&str], phrase: &[&str]) -> bool {
    if phrase.len() > words.len() {
        return false;
    }
    words
        .windows(phrase.len())
        .any(|w| w.iter().zip(phrase).all(|(a, b)| a.eq_ignore_ascii_case(b)))
}

impl Router for KeywordRouter {
    fn route(&mut self, text: &str) -> crate::Result<Classification> {
        Ok(Self::classify(text))
    }

    fn mode(&self) -> RouterMode {
        RouterMode::Keyword
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_cues() {
        let c = KeywordRouter::classify("what is 2 plus 2?");
        assert_eq!(c.complexity, 0);
        assert!(c.confidence > 0.5);
    }

    #[test]
    fn high_cues() {
        let c = KeywordRouter::classify(
            "prove that the sum converges and derive a closed form");
        assert_eq!(c.complexity, 2);
    }

    #[test]
    fn phrase_cues() {
        assert_eq!(KeywordRouter::classify("explain why the sky is blue").complexity, 2);
        assert_eq!(KeywordRouter::classify("how many apples remain").complexity, 0);
    }

    #[test]
    fn no_signal_is_medium_low_confidence() {
        let c = KeywordRouter::classify("natalia sold clips to friends in april");
        assert_eq!(c.complexity, 1);
        assert!(c.confidence < 0.5);
    }

    #[test]
    fn high_beats_low_on_conflict() {
        // "list the steps to prove ..." — the confusable the corpus plants.
        let c = KeywordRouter::classify("list the steps to prove the theorem");
        assert_eq!(c.complexity, 2);
    }

    #[test]
    fn long_prompts_lean_high() {
        let long = vec!["word"; 40].join(" ");
        assert_eq!(KeywordRouter::classify(&long).complexity, 2);
    }

    #[test]
    fn zero_overhead() {
        assert_eq!(KeywordRouter::classify("anything").overhead_s, 0.0);
    }

    #[test]
    fn empty_prompt_is_medium() {
        assert_eq!(KeywordRouter::classify("").complexity, 1);
    }
}
