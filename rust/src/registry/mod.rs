//! Service Registry — the paper's service matrix `M ∈ R^{L×I}` (Eq. 5).
//!
//! Every deployable (model `L_x`, backend `I_y`) pair is a service
//! instance `S_xy` with live state: replica count, health, telemetry,
//! and the latency/cost estimators the scorer consumes. The Router reads
//! the matrix to score candidates (Alg. 2); the Orchestrator writes
//! replica/health state as the cluster changes (Alg. 1).

use crate::models::{BackendKind, ModelSpec};
use crate::models::completion::mean_output_tokens;
use crate::telemetry::ServiceTelemetry;

/// Row-major index into the matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ServiceId(pub usize);

/// Health as the orchestrator's recovery manager sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Degraded,
    Unhealthy,
}

/// One cell of the service matrix.
pub struct Service {
    pub id: ServiceId,
    pub model_idx: usize,
    pub backend: BackendKind,
    pub spec: ModelSpec,
    pub health: Health,
    /// Ready replicas (warm, accepting traffic).
    pub ready_replicas: usize,
    /// Replicas currently cold-starting.
    pub pending_replicas: usize,
    pub telemetry: ServiceTelemetry,
}

impl Service {
    /// Total stream capacity right now.
    pub fn capacity(&self) -> usize {
        self.ready_replicas * self.backend.max_concurrency()
    }

    /// Routable = healthy with at least one ready replica, or scalable
    /// from zero (the orchestrator will spin it up — at cold-start cost,
    /// which the latency estimate includes).
    pub fn routable(&self) -> bool {
        self.health != Health::Unhealthy
    }

    /// Expected end-to-end latency for a prompt of `in_tokens` expecting
    /// `out_tokens`, including queueing pressure and (if scaled to zero)
    /// the cold-start penalty. This is `T(S_xy)` before normalization.
    pub fn expected_latency_s(
        &self,
        in_tokens: f64,
        out_tokens: f64,
        cold_start_s: f64,
    ) -> f64 {
        let lf = self.backend.latency_factor();
        let prefill = in_tokens / self.spec.prefill_tps * lf;
        let decode = out_tokens / self.spec.decode_tps * lf;
        let cold = if self.ready_replicas == 0 { cold_start_s } else { 0.0 };
        // Queueing pressure: inflight vs capacity (M/M/c-ish inflation).
        let cap = self.capacity().max(1) as f64;
        let rho = (self.telemetry.inflight as f64 / cap).min(0.95);
        let queue_factor = 1.0 / (1.0 - rho);
        cold + (prefill + decode) * queue_factor
    }

    /// Expected $ cost of serving one query: replica occupancy time ×
    /// replica rate ÷ concurrent streams sharing it. `C(S_xy)` before
    /// normalization.
    pub fn expected_cost_usd(&self, in_tokens: f64, out_tokens: f64) -> f64 {
        let lf = self.backend.latency_factor();
        let busy_s = in_tokens / self.spec.prefill_tps * lf
            + out_tokens / self.spec.decode_tps * lf;
        let sharing = (self.backend.max_concurrency() as f64 / 2.0).max(1.0);
        busy_s * self.spec.cost_per_replica_second() * self.backend.cost_factor()
            / sharing
    }
}

/// The L×I matrix plus lookup helpers.
pub struct Registry {
    pub services: Vec<Service>,
    pub n_models: usize,
    pub n_backends: usize,
}

impl Registry {
    /// Build the full matrix over a model zoo and all backends.
    pub fn new(zoo: &[ModelSpec], telemetry_window_s: f64) -> Registry {
        let mut services = Vec::new();
        for (mi, spec) in zoo.iter().enumerate() {
            for &backend in &BackendKind::ALL {
                let id = ServiceId(services.len());
                services.push(Service {
                    id,
                    model_idx: mi,
                    backend,
                    spec: spec.clone(),
                    health: Health::Healthy,
                    ready_replicas: 0,
                    pending_replicas: 0,
                    telemetry: ServiceTelemetry::new(telemetry_window_s),
                });
            }
        }
        Registry {
            services,
            n_models: zoo.len(),
            n_backends: BackendKind::ALL.len(),
        }
    }

    pub fn get(&self, id: ServiceId) -> &Service {
        &self.services[id.0]
    }

    pub fn get_mut(&mut self, id: ServiceId) -> &mut Service {
        &mut self.services[id.0]
    }

    /// Matrix cell (x = model row, y = backend column).
    pub fn cell(&self, model_idx: usize, backend: BackendKind) -> &Service {
        &self.services[model_idx * self.n_backends + backend.index()]
    }

    pub fn cell_mut(&mut self, model_idx: usize, backend: BackendKind) -> &mut Service {
        &mut self.services[model_idx * self.n_backends + backend.index()]
    }

    /// All services that Alg. 2 may consider.
    pub fn routable(&self) -> impl Iterator<Item = &Service> {
        self.services.iter().filter(|s| s.routable())
    }

    /// Estimate a prompt's expected output length from its benchmark and
    /// complexity (used for T/C estimation at scoring time).
    pub fn estimate_out_tokens(benchmark: &str, complexity: usize) -> f64 {
        mean_output_tokens(benchmark) * (1.0 + 0.4 * complexity as f64)
    }

    /// Total ready replicas across the matrix (for utilization reports).
    pub fn total_ready(&self) -> usize {
        self.services.iter().map(|s| s.ready_replicas).sum()
    }

    /// Cross-tier speculative pairing: can `draft_tier` draft right now?
    /// True when at least one of the tier's services is healthy-enough
    /// with a ready replica. A cold, recovering, or unhealthy draft tier
    /// returns false, and every paired verify tier falls back to plain
    /// decode until the tier comes back.
    pub fn draft_tier_ready(&self, draft_tier: usize) -> bool {
        self.services.iter().any(|s| {
            s.spec.tier.index() == draft_tier
                && s.health != Health::Unhealthy
                && s.ready_replicas > 0
        })
    }

    /// Update every service of one engine tier at once. The live
    /// gateway's registry is a routing view over per-tier replica pools:
    /// all services of a tier share the tier's engine threads, so their
    /// replica counts and health move together.
    pub fn set_tier_state(
        &mut self,
        tier_idx: usize,
        ready: usize,
        pending: usize,
        health: Health,
    ) {
        for svc in &mut self.services {
            if svc.spec.tier.index() == tier_idx {
                svc.ready_replicas = ready;
                svc.pending_replicas = pending;
                svc.health = health;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn registry() -> Registry {
        Registry::new(&zoo(), 300.0)
    }

    #[test]
    fn matrix_dimensions() {
        let r = registry();
        assert_eq!(r.n_models, 4);
        assert_eq!(r.n_backends, 3);
        assert_eq!(r.services.len(), 12);
    }

    #[test]
    fn cell_lookup_consistent() {
        let r = registry();
        for mi in 0..r.n_models {
            for &b in &BackendKind::ALL {
                let s = r.cell(mi, b);
                assert_eq!(s.model_idx, mi);
                assert_eq!(s.backend, b);
            }
        }
    }

    #[test]
    fn cold_service_latency_includes_cold_start() {
        let mut r = registry();
        let id = r.cell(0, BackendKind::Vllm).id;
        let cold = r.get(id).expected_latency_s(100.0, 50.0, 30.0);
        r.get_mut(id).ready_replicas = 1;
        let warm = r.get(id).expected_latency_s(100.0, 50.0, 30.0);
        assert!((cold - warm - 30.0).abs() < 1e-9);
    }

    #[test]
    fn queue_pressure_inflates_latency() {
        let mut r = registry();
        let id = r.cell(0, BackendKind::Vllm).id;
        r.get_mut(id).ready_replicas = 1;
        let idle = r.get(id).expected_latency_s(100.0, 50.0, 0.0);
        r.get_mut(id).telemetry.inflight = 15; // near 16-stream capacity
        let busy = r.get(id).expected_latency_s(100.0, 50.0, 0.0);
        assert!(busy > idle * 5.0);
    }

    #[test]
    fn bigger_models_cost_more() {
        let r = registry();
        let small = r.cell(0, BackendKind::Vllm).expected_cost_usd(100.0, 100.0);
        let big = r.cell(3, BackendKind::Vllm).expected_cost_usd(100.0, 100.0);
        assert!(big > small * 5.0);
    }

    #[test]
    fn trt_is_faster_tgi_cheaper() {
        let mut r = registry();
        for s in &mut r.services {
            s.ready_replicas = 1;
        }
        let vllm = r.cell(1, BackendKind::Vllm).expected_latency_s(100.0, 100.0, 0.0);
        let trt = r.cell(1, BackendKind::TrtLlm).expected_latency_s(100.0, 100.0, 0.0);
        assert!(trt < vllm);
        // TGI's memory efficiency makes it cheaper per query than the
        // latency-optimized TRT engines (the paper's matrix characters).
        let trt_c = r.cell(1, BackendKind::TrtLlm).expected_cost_usd(100.0, 100.0);
        let tgi_c = r.cell(1, BackendKind::Tgi).expected_cost_usd(100.0, 100.0);
        assert!(tgi_c < trt_c);
    }

    #[test]
    fn unhealthy_not_routable() {
        let mut r = registry();
        let id = r.cell(2, BackendKind::Tgi).id;
        r.get_mut(id).health = Health::Unhealthy;
        assert_eq!(r.routable().count(), 11);
    }

    #[test]
    fn tier_state_updates_every_cell_of_the_tier() {
        let mut r = registry();
        let tier0 = r.services[0].spec.tier.index();
        r.set_tier_state(tier0, 2, 1, Health::Degraded);
        for s in &r.services {
            if s.spec.tier.index() == tier0 {
                assert_eq!(s.ready_replicas, 2);
                assert_eq!(s.pending_replicas, 1);
                assert_eq!(s.health, Health::Degraded);
            } else {
                assert_eq!(s.ready_replicas, 0);
                assert_eq!(s.health, Health::Healthy);
            }
        }
    }

    #[test]
    fn draft_tier_ready_tracks_health_and_replicas() {
        let mut r = registry();
        let tier0 = r.services[0].spec.tier.index();
        assert!(!r.draft_tier_ready(tier0), "cold tier cannot draft");
        r.set_tier_state(tier0, 1, 0, Health::Healthy);
        assert!(r.draft_tier_ready(tier0));
        // Degraded still drafts; dead does not.
        r.set_tier_state(tier0, 1, 0, Health::Degraded);
        assert!(r.draft_tier_ready(tier0));
        r.set_tier_state(tier0, 0, 1, Health::Healthy);
        assert!(!r.draft_tier_ready(tier0), "mid-recovery tier cannot draft");
        r.set_tier_state(tier0, 2, 0, Health::Unhealthy);
        assert!(!r.draft_tier_ready(tier0));
    }

    #[test]
    fn out_token_estimate_grows_with_complexity() {
        let low = Registry::estimate_out_tokens("math", 0);
        let high = Registry::estimate_out_tokens("math", 2);
        assert!(high > low);
    }
}
