//! `pick-and-spin` — leader entrypoint and CLI.
//!
//! Subcommands:
//! * `serve`  — start the live HTTP gateway over the compiled artifacts.
//! * `route`  — classify a prompt and print the matrix scores (Alg. 2).
//! * `sim`    — run a virtual-time simulation and print the report.
//! * `report` — regenerate the paper's headline tables quickly.
//! * `ps-replica` — engine replica worker process (spawned by the
//!   gateway when `pool.substrate = "process"`; not for manual use).
//! * `ps-node` — node agent for multi-host serving: registers this
//!   machine's capacity with a gateway and spawns `ps-replica` workers
//!   on its orders (`pool.nodes.*`).

use std::sync::Arc;

use anyhow::Result;
use pick_and_spin::baselines::SelectionPolicy;
use pick_and_spin::config::{Config, Profile, RouterMode};
use pick_and_spin::eval;
use pick_and_spin::gateway::{serve_http, LiveStack};
use pick_and_spin::models::completion::TABLE1_RATES;
use pick_and_spin::router::keyword::KeywordRouter;
use pick_and_spin::sim::{Deployment, SimConfig};
use pick_and_spin::util::args::{Args, Spec};
use pick_and_spin::util::logging;
use pick_and_spin::workload::{OracleClassifier, TemplateLibrary};

fn spec() -> Spec {
    Spec {
        name: "pick-and-spin",
        about: "multi-model LLM orchestration (paper reproduction)",
        options: vec![
            ("config", true, "JSON config file"),
            ("artifacts", true, "artifacts directory (default: artifacts)"),
            ("data", true, "data directory (default: data)"),
            ("port", true, "gateway port (serve)"),
            ("prompt", true, "prompt text (route)"),
            ("requests", true, "simulated requests (sim)"),
            ("rate", true, "arrival rate qps (sim)"),
            ("router", true, "keyword | semantic | hybrid"),
            ("profile", true, "baseline|quality|cost|speed|balanced"),
            ("policy", true, "multi|random|latency|roundrobin"),
            ("static", false, "static deployment (sim)"),
            ("seed", true, "rng seed"),
            ("log-level", true, "error|warn|info|debug|trace"),
        ],
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (command, rest) = match argv.split_first() {
        Some((c, rest)) if !c.starts_with("--") => (c.clone(), rest.to_vec()),
        _ => (String::from("help"), argv.clone()),
    };
    if command == "ps-replica" {
        // Worker mode has its own option surface (parsed before the
        // leader spec, which would reject --socket).
        return cmd_worker(&rest);
    }
    if command == "ps-node" {
        return cmd_node(&rest);
    }
    let args = spec().parse(&rest)?;
    if let Some(l) = args.opt("log-level") {
        if let Some(level) = logging::Level::parse(l) {
            logging::set_level(level);
        }
    }
    let mut cfg = Config::load(args.opt("config"))?;
    if let Some(a) = args.opt("artifacts") {
        cfg.paths.artifacts = a.to_string();
    }
    if let Some(d) = args.opt("data") {
        cfg.paths.data = d.to_string();
    }
    if let Some(p) = args.opt("profile") {
        cfg.profile = Profile::by_name(p)
            .ok_or_else(|| anyhow::anyhow!("unknown profile `{p}`"))?;
    }
    if let Some(r) = args.opt("router") {
        cfg.router.mode = RouterMode::parse(r)
            .ok_or_else(|| anyhow::anyhow!("unknown router `{r}`"))?;
    }

    match command.as_str() {
        "serve" => cmd_serve(&cfg, &args),
        "route" => cmd_route(&cfg, &args),
        "sim" => cmd_sim(&cfg, &args),
        "report" => cmd_report(&cfg, &args),
        _ => {
            println!("{}", spec().usage());
            println!("Commands: serve | route | sim | report | ps-replica | ps-node");
            Ok(())
        }
    }
}

/// `ps-replica` — one engine replica as a supervised worker process.
///
/// Spawned by the gateway's process substrate (`pool.substrate =
/// "process"`): connects to the supervisor's Unix socket, builds the
/// requested engine, and serves RPC jobs until told to drain. This is
/// the process analogue of the paper's pod-per-replica deployment; it is
/// not meant to be run by hand.
fn cmd_worker(argv: &[String]) -> Result<()> {
    use pick_and_spin::gateway::worker::{run_worker, WorkerOptions};
    use pick_and_spin::models::Tier;

    let wspec = Spec {
        name: "pick-and-spin ps-replica",
        about: "engine replica worker process (spawned by the gateway)",
        options: vec![
            ("socket", true, "supervisor Unix socket path"),
            ("tier", true, "small | medium | large"),
            ("replica", true, "replica index within the tier"),
            ("engine", true, "sim | pjrt (default: pjrt)"),
            ("artifacts", true, "artifacts directory (pjrt engine)"),
            ("log-level", true, "error|warn|info|debug|trace"),
        ],
    };
    let args = wspec.parse(argv)?;
    if let Some(l) = args.opt("log-level") {
        if let Some(level) = logging::Level::parse(l) {
            logging::set_level(level);
        }
    }
    let socket = args
        .opt("socket")
        .ok_or_else(|| anyhow::anyhow!("ps-replica requires --socket"))?
        .to_string();
    let tier_name = args.opt("tier").unwrap_or("small");
    let tier = Tier::ALL
        .iter()
        .copied()
        .find(|t| t.name() == tier_name)
        .ok_or_else(|| anyhow::anyhow!("unknown tier `{tier_name}`"))?;
    let replica = args.opt_usize("replica", 0)?;
    let opts = WorkerOptions { socket, tier, replica };
    match args.opt("engine").unwrap_or("pjrt") {
        "sim" => run_worker(&opts, |tier, replica, pool| {
            let mut e = pick_and_spin::backend::scheduler::SimStepEngine::calibrated();
            if pool.spec_draft_tokens > 0 {
                // Deterministic per-replica verdict stream: the sim
                // engine's acceptance model only decides how many drafts
                // land per verify step, never which tokens.
                let seed = 0x5BEC ^ ((tier.index() as u64) << 32) ^ replica as u64;
                e = e.with_acceptance(pool.spec_sim_accept, seed);
            }
            Ok(e)
        }),
        "pjrt" => {
            let artifacts = args.opt_or("artifacts", "artifacts").to_string();
            run_worker(&opts, move |tier, _replica, pool| {
                pick_and_spin::gateway::build_pjrt_engine(
                    &artifacts,
                    tier,
                    pool.max_decode_batch,
                )
            })
        }
        e => Err(anyhow::anyhow!("unknown worker engine `{e}`")),
    }
}

/// `ps-node` — one machine's node agent for multi-host serving.
///
/// Registers the host's capacity with the gateway's node plane
/// (`pool.nodes`) and spawns `ps-replica` workers when the supervisor
/// places replicas here — the process-substrate analogue of a Kubernetes
/// node running the kubelet. Either side may dial: `--listen` awaits the
/// supervisor (its address goes in `pool.nodes.agents[]`), `--supervisor`
/// dials the gateway's `pool.nodes.listen_addr`. The agent exits —
/// killing its workers, like a node going down whole — when the control
/// channel drops.
fn cmd_node(argv: &[String]) -> Result<()> {
    use pick_and_spin::substrate::nodes::{run_node_agent, NodeAgentOptions};

    let nspec = Spec {
        name: "pick-and-spin ps-node",
        about: "node agent: hosts ps-replica workers for a remote gateway",
        options: vec![
            ("listen", true, "host:port to await the supervisor's dial-in"),
            ("supervisor", true, "gateway node-plane address to dial"),
            ("slots", true, "replica processes this node may host (default 4)"),
            ("name", true, "node name in the gateway's registry"),
            ("worker-bin", true, "worker binary (default: this binary)"),
            ("log-dir", true, "per-worker log directory (default: inherit)"),
            ("log-level", true, "error|warn|info|debug|trace"),
        ],
    };
    let args = nspec.parse(argv)?;
    if let Some(l) = args.opt("log-level") {
        if let Some(level) = logging::Level::parse(l) {
            logging::set_level(level);
        }
    }
    let opts = NodeAgentOptions {
        listen: args.opt("listen").map(|s| s.to_string()),
        supervisor: args.opt("supervisor").map(|s| s.to_string()),
        slots: args.opt_usize("slots", 4)?,
        name: args
            .opt("name")
            .map(|s| s.to_string())
            .unwrap_or_else(default_node_name),
        worker_bin: args.opt("worker-bin").map(|s| s.to_string()),
        log_dir: args.opt("log-dir").map(|s| s.to_string()),
    };
    run_node_agent(&opts)
}

/// Default `ps-node` name: `<hostname>-<pid>`. A bare pid collides the
/// moment two agents run as PID 1 in containers (the normal multi-host
/// deployment), and duplicate names conflate the per-node `/metrics`
/// series — so the machine identity goes in front.
fn default_node_name() -> String {
    let host = std::env::var("HOSTNAME")
        .ok()
        .filter(|h| !h.trim().is_empty())
        .or_else(|| {
            std::fs::read_to_string("/etc/hostname")
                .ok()
                .map(|h| h.trim().to_string())
                .filter(|h| !h.is_empty())
        })
        .unwrap_or_else(|| "node".to_string());
    format!("{host}-{}", std::process::id())
}

fn cmd_serve(cfg: &Config, args: &Args) -> Result<()> {
    let port = args.opt_usize("port", cfg.gateway.port as usize)? as u16;
    println!("loading artifacts from {} ...", cfg.paths.artifacts);
    let stack = Arc::new(LiveStack::start(cfg)?);
    let srv = serve_http(Arc::clone(&stack), port, cfg.gateway.worker_threads)?;
    println!(
        "pick-and-spin listening on http://127.0.0.1:{} \
         (POST /v1/completions, GET /metrics)",
        srv.port
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_route(cfg: &Config, args: &Args) -> Result<()> {
    let prompt = args
        .opt("prompt")
        .ok_or_else(|| anyhow::anyhow!("route requires --prompt"))?;
    let kw = KeywordRouter::classify(prompt);
    println!(
        "keyword   → class {} ({}) conf {:.2}",
        kw.complexity,
        ["low", "medium", "high"][kw.complexity],
        kw.confidence
    );
    // Semantic path needs artifacts.
    let manifest = format!("{}/manifest.json", cfg.paths.artifacts);
    if std::path::Path::new(&manifest).exists() {
        use pick_and_spin::router::Classifier;
        let mut rt = pick_and_spin::runtime::Runtime::load(&cfg.paths.artifacts)?;
        let mut cls = rt.classifier_engine()?;
        let p = cls.probs(prompt)?;
        let (k, conf) = cls.classify(prompt)?;
        println!(
            "semantic  → class {} ({}) conf {:.2}  probs {:?}",
            k,
            ["low", "medium", "high"][k],
            conf,
            p.map(|x| (x * 1000.0).round() / 1000.0)
        );
    } else {
        println!("semantic  → (artifacts not built; run `make artifacts`)");
    }
    Ok(())
}

fn parse_policy(s: &str) -> Result<SelectionPolicy> {
    Ok(match s {
        "multi" | "multi-objective" => SelectionPolicy::MultiObjective,
        "random" => SelectionPolicy::Random,
        "latency" | "latency-only" => SelectionPolicy::LatencyOnly,
        "roundrobin" | "rr" => SelectionPolicy::RoundRobin,
        _ => anyhow::bail!("unknown policy `{s}`"),
    })
}

fn load_library(cfg: &Config) -> Result<TemplateLibrary> {
    TemplateLibrary::load(&format!("{}/templates.json", cfg.paths.data))
}

fn cmd_sim(cfg: &Config, args: &Args) -> Result<()> {
    let lib = load_library(cfg)?;
    let mut sc = SimConfig::defaults();
    sc.router_mode = cfg.router.mode;
    sc.profile = cfg.profile;
    // The serving-pool knobs (prefix cache, KV block geometry) drive the
    // sim's hit-rate-dependent prefill model.
    sc.pool = cfg.pool.clone();
    sc.n_requests = args.opt_usize("requests", 20_000)?;
    sc.rate_qps = args.opt_f64("rate", 20.0)?;
    sc.seed = args.opt_u64("seed", 42)?;
    sc.cluster.nodes = 8;
    if let Some(p) = args.opt("policy") {
        sc.policy = parse_policy(p)?;
    }
    if args.flag("static") {
        sc.deployment = Deployment::Static;
        sc.policy = SelectionPolicy::RoundRobin;
    }
    let classifier = Box::new(OracleClassifier::new(
        lib.clone(),
        sc.classifier_error,
        sc.seed ^ 0xC1,
    ));
    let t0 = std::time::Instant::now();
    let rep = pick_and_spin::sim::run(&sc, &lib, classifier)?;
    println!(
        "simulated {} requests in {:.2}s wall ({:.0} req/s sim speed)",
        rep.records.len(),
        t0.elapsed().as_secs_f64(),
        rep.records.len() as f64 / t0.elapsed().as_secs_f64()
    );
    println!("{}", eval::table1(&rep, &TABLE1_RATES));
    println!(
        "success {:.1}%  mean latency {:.1}s  cost/query ${:.4}  \
         GPU util {:.1}%  throughput {:.1} qps  prefix hits {:.1}%",
        rep.success_rate() * 100.0,
        rep.mean_latency_s(),
        rep.cost_per_query_usd(),
        rep.gpu_utilization() * 100.0,
        rep.throughput_qps(),
        rep.prefix_hit_token_rate() * 100.0
    );
    Ok(())
}

fn cmd_report(cfg: &Config, args: &Args) -> Result<()> {
    let lib = load_library(cfg)?;
    let n = args.opt_usize("requests", 8_000)?;
    let seed = args.opt_u64("seed", 42)?;
    let mk = |policy, deployment, router| {
        let mut sc = SimConfig::defaults();
        sc.n_requests = n;
        sc.rate_qps = 20.0;
        sc.seed = seed;
        sc.cluster.nodes = 8;
        sc.policy = policy;
        sc.deployment = deployment;
        sc.router_mode = router;
        sc
    };
    let run = |sc: &SimConfig| {
        let cls = Box::new(OracleClassifier::new(lib.clone(), sc.classifier_error, seed));
        pick_and_spin::sim::run(sc, &lib, cls)
    };
    println!("== Table 1: baseline completion ==");
    let base = run(&mk(SelectionPolicy::RoundRobin, Deployment::Static, RouterMode::Keyword))?;
    println!("{}", eval::table1(&base, &TABLE1_RATES));
    println!("== Table 3: selection strategies ==");
    let rand = run(&mk(SelectionPolicy::Random, Deployment::Dynamic { auto_recovery: false }, RouterMode::Hybrid))?;
    let lat = run(&mk(SelectionPolicy::LatencyOnly, Deployment::Dynamic { auto_recovery: false }, RouterMode::Hybrid))?;
    let multi = run(&mk(SelectionPolicy::MultiObjective, Deployment::Dynamic { auto_recovery: false }, RouterMode::Hybrid))?;
    println!(
        "{}",
        eval::table3(&[
            ("Random assignment", &rand),
            ("Latency only", &lat),
            ("Multi objective", &multi),
        ])
    );
    println!("η (Eq. 9, multi vs baseline) = {:.2}", eval::eta(&multi, &base));
    Ok(())
}
