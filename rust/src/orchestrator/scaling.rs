//! Algorithm 1 — Orchestration-Aware Scaling with Warm Pools.
//!
//! ```text
//! for each model m in pool:
//!     r_m      ← GetAvgRequestRate(m, w)          # telemetry window
//!     lat_m    ← GetAvgLatency(m)
//!     target   ← ceil(r_m × lat_m / Concurrency)  # Little's Law
//!     current  ← GetReplicas(m)
//!     min_warm ← WarmPoolSize(ModelTier(m))
//!     if target > current AND CooldownExpired():  scale(max(target, min_warm))
//!     elif IdleTime(m) > τ:                       scale(max(0, min_warm))
//! ```
//!
//! One scaler serves both control planes. The decision core (cooldown,
//! warm-pool floor, scale-to-zero) is shared; only the demand estimator
//! differs: the simulator forecasts with Little's Law from telemetry
//! ([`Scaler::plan`]), while the live engine pool measures its own
//! backlog directly — per-tier queue depth plus slot occupancy
//! ([`Scaler::plan_tier`] over a [`TierLoad`]). Planned actions are
//! applied to either substrate through [`apply`], which speaks only the
//! [`Substrate`] trait.

use crate::config::OrchestratorConfig;
use crate::registry::{Registry, ServiceId};
use crate::substrate::{ReplicaId, Substrate};

/// A scaling decision for one service.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleAction {
    /// Scale up to `target` replicas (spawn `target - current` pods).
    Up { service: ServiceId, target: usize },
    /// Scale down to `target` replicas (terminate extras).
    Down { service: ServiceId, target: usize },
}

/// Live load signal for one engine-pool tier (the gateway samples these
/// each scaling interval).
#[derive(Debug, Clone, Copy)]
pub struct TierLoad {
    /// Routed requests waiting in the tier queue.
    pub queue_depth: usize,
    /// Decode slots currently occupied across the tier's replicas.
    pub slots_in_use: usize,
    /// Replicas currently live (Ready plus pending cold starts).
    pub active_replicas: usize,
    /// Seconds since the tier last saw an enqueue.
    pub idle_s: f64,
    /// Fraction of prefill tokens the tier's replicas served from their
    /// radix prefix caches over the last control interval (0 when the
    /// cache is off or cold). A warm cache means queued requests bring
    /// only suffix prefill, so the planner discounts queue pressure
    /// accordingly; the caller supplies a *windowed* rate so the
    /// discount tracks recent traffic, not since-boot history.
    pub prefix_hit_rate: f64,
    /// Fraction of drafted tokens the tier's verify replicas accepted
    /// over the last control interval (0 when speculation is off or the
    /// tier has no draft pairing). Accepted draft tokens land several
    /// outputs per verify step, so queued decode work drains faster and
    /// the planner discounts queue pressure; windowed like
    /// `prefix_hit_rate`.
    pub spec_accept_rate: f64,
    /// Overload pressure the admission gate and fallback chains reported
    /// over the last control interval: requests shed at this tier plus
    /// chain hops escalated/degraded *into* it. Each unit is a request
    /// that wanted capacity here and didn't get it, so it counts as
    /// extra queue demand (0 with overload control off).
    pub pressure: f64,
}

/// Queue-pressure discount at a fully-warm prefix cache: a hit skips the
/// shared-prefix prefill but still pays suffix prefill and the full
/// decode, so at most half the queue signal is relieved.
const PREFIX_QUEUE_RELIEF: f64 = 0.5;

/// Queue-pressure discount at full speculative acceptance: every verify
/// step lands multiple tokens, but prefill and scheduling overhead are
/// unchanged, so (like the prefix discount) at most half the queue
/// signal is relieved.
const SPEC_QUEUE_RELIEF: f64 = 0.5;

/// Little's-law scaler with cooldown and warm pools.
///
/// One instance plans over one index space — services for the sim
/// ([`Self::plan`]), tiers for the live pool ([`Self::plan_tier`]); the
/// per-index cooldown clocks are shared, so use separate instances for
/// separate index spaces.
pub struct Scaler {
    cfg: OrchestratorConfig,
    /// Demand one replica absorbs on the observed-load path (its decode
    /// slot count). The Little's-law path divides by `target_concurrency`
    /// instead.
    slots_per_replica: usize,
    /// Per-index end-of-cooldown timestamps.
    cooldown_until: Vec<f64>,
}

impl Scaler {
    pub fn new(cfg: OrchestratorConfig, n_services: usize) -> Scaler {
        Scaler::for_pool(cfg, n_services, 1)
    }

    /// Scaler for the live engine pool: one index per tier, demand
    /// divided by the replicas' decode-slot count.
    pub fn for_pool(
        cfg: OrchestratorConfig,
        n_indices: usize,
        slots_per_replica: usize,
    ) -> Scaler {
        Scaler {
            cfg,
            slots_per_replica: slots_per_replica.max(1),
            cooldown_until: vec![0.0; n_indices],
        }
    }

    pub fn cfg(&self) -> &OrchestratorConfig {
        &self.cfg
    }

    /// Warm-pool floor for a service (by engine tier, paper's
    /// `WarmPoolSize(ModelTier(m))`).
    pub fn warm_pool(&self, registry: &Registry, id: ServiceId) -> usize {
        let tier = registry.get(id).spec.tier;
        self.cfg.warm_pool[tier.index()]
    }

    /// The shared Alg. 1 decision for one scaled entity: `need` replicas
    /// of demand against `current` capacity. Returns the new target, or
    /// `None` to hold. `busy` blocks scale-down while observed work is
    /// still in flight (the live path's signal; forecasts pass `false`).
    #[allow(clippy::too_many_arguments)]
    fn decide(
        &mut self,
        idx: usize,
        need: usize,
        current: usize,
        busy: bool,
        idle_s: f64,
        min_warm: usize,
        max_replicas: usize,
        now_s: f64,
    ) -> Option<usize> {
        if need > current {
            if now_s >= self.cooldown_until[idx] {
                let target = need.max(min_warm).min(max_replicas);
                if target > current {
                    self.cooldown_until[idx] = now_s + self.cfg.cooldown_s;
                    return Some(target);
                }
            }
            return None;
        }
        if !busy && idle_s > self.cfg.idle_timeout_s {
            let target = min_warm; // max(0, min_warm)
            if target < current {
                return Some(target);
            }
        }
        None
    }

    /// Run one Alg. 1 pass over the registry (Little's-law demand);
    /// returns actions for [`apply`].
    pub fn plan(&mut self, registry: &mut Registry, now_s: f64) -> Vec<ScaleAction> {
        let mut actions = Vec::new();
        for idx in 0..registry.services.len() {
            let id = ServiceId(idx);
            let min_warm = self.warm_pool(registry, id);
            let svc = registry.get_mut(id);
            let rate = svc.telemetry.arrivals.rate(now_s);          // r_m
            let lat = svc.telemetry.avg_latency(                     // lat_m
                // Prior for cold services: a mid-size request estimate.
                svc.expected_latency_s(60.0, 80.0, 0.0),
            );
            // Little's Law: L = λW → replicas to hold L streams at
            // `target_concurrency` streams each.
            let need = (rate * lat / self.cfg.target_concurrency).ceil() as usize;
            let current = svc.ready_replicas + svc.pending_replicas;
            let idle = svc.telemetry.arrivals.idle_time(now_s);
            let max = self.cfg.max_replicas;
            if let Some(target) =
                self.decide(idx, need, current, false, idle, min_warm, max, now_s)
            {
                actions.push(if target > current {
                    ScaleAction::Up { service: id, target }
                } else {
                    ScaleAction::Down { service: id, target }
                });
            }
        }
        actions
    }

    /// One Alg. 1 pass for one engine-pool tier from its observed load.
    /// `service` names the tier's canonical registry cell (events and
    /// actions are keyed by it); `max_replicas` is the tier's provisioned
    /// ceiling. Returns the action to [`apply`], or `None` to hold.
    pub fn plan_tier(
        &mut self,
        tier: usize,
        service: ServiceId,
        load: TierLoad,
        max_replicas: usize,
        now_s: f64,
    ) -> Option<ScaleAction> {
        let idx = tier.min(self.cooldown_until.len().saturating_sub(1));
        let warm = self.cfg.warm_pool[tier.min(2)].min(max_replicas);
        // Cache-adjusted demand: discount queued work by the observed
        // prefix hit rate and speculative acceptance rate (slots in use
        // are already-admitted work and count in full). The reliefs
        // compose multiplicatively — each scales what the other left —
        // so both maxed out leaves a 0.25 floor rather than discounting
        // below zero; the clamp guards degenerate rate inputs.
        let relief = ((1.0 - PREFIX_QUEUE_RELIEF * load.prefix_hit_rate.clamp(0.0, 1.0))
            * (1.0 - SPEC_QUEUE_RELIEF * load.spec_accept_rate.clamp(0.0, 1.0)))
            .clamp(0.0, 1.0);
        // Shed/escalation pressure is demand that never reached the
        // queue (or arrived as a chain hop): un-discounted — these
        // requests already lost once.
        let demand = (load.queue_depth as f64 * relief).ceil() as usize
            + load.slots_in_use
            + load.pressure.max(0.0).ceil() as usize;
        let need = demand.div_ceil(self.slots_per_replica);
        let current = load.active_replicas;
        let target = self.decide(
            idx,
            need,
            current,
            demand > 0,
            load.idle_s,
            warm,
            max_replicas,
            now_s,
        )?;
        Some(if target > current {
            ScaleAction::Up { service, target }
        } else {
            ScaleAction::Down { service, target }
        })
    }
}

/// Apply planned actions to a substrate (sim cluster or live pool):
/// provision up to each `Up` target counting pending capacity, terminate
/// excess Ready replicas on `Down`. Returns the replicas provisioned.
pub fn apply(
    actions: &[ScaleAction],
    registry: &mut Registry,
    substrate: &mut dyn Substrate,
    now_s: f64,
) -> Vec<ReplicaId> {
    let mut spawned = Vec::new();
    for action in actions {
        match *action {
            ScaleAction::Up { service, target } => {
                let (current, model_idx, spec, backend) = {
                    let svc = registry.get(service);
                    (
                        svc.ready_replicas + svc.pending_replicas,
                        svc.model_idx,
                        svc.spec.clone(),
                        svc.backend,
                    )
                };
                for _ in current..target {
                    match substrate.provision(service, model_idx, &spec, backend, now_s)
                    {
                        Some(id) => {
                            registry.get_mut(service).pending_replicas += 1;
                            spawned.push(id);
                        }
                        // Out of capacity: the next plan retries.
                        None => break,
                    }
                }
            }
            ScaleAction::Down { service, target } => {
                let ready = substrate.ready_replicas(service);
                let excess = ready.len().saturating_sub(target);
                for replica in ready.into_iter().take(excess) {
                    substrate.terminate(replica, now_s);
                }
            }
        }
    }
    spawned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OrchestratorConfig;
    use crate::models::zoo;
    use crate::registry::Registry;
    use crate::substrate::testing::MockSubstrate;
    use crate::substrate::ReplicaState;

    fn setup(warm: [usize; 3]) -> (Registry, Scaler) {
        let r = Registry::new(&zoo(), 300.0);
        let n = r.services.len();
        let cfg = OrchestratorConfig {
            warm_pool: warm,
            cooldown_s: 30.0,
            idle_timeout_s: 120.0,
            target_concurrency: 4.0,
            ..OrchestratorConfig::default()
        };
        (r, Scaler::new(cfg, n))
    }

    /// Drive `rate` arrivals/s into a service for `dur` seconds.
    fn drive(r: &mut Registry, idx: usize, rate: f64, dur: f64, lat: f64) {
        let svc = r.get_mut(ServiceId(idx));
        let n = (rate * dur) as usize;
        for i in 0..n {
            let t = i as f64 / rate;
            svc.telemetry.on_dispatch(t, 16.0);
            svc.telemetry.on_complete(t + lat, 16.0, lat, lat / 4.0, true);
        }
    }

    #[test]
    fn littles_law_target() {
        let (mut r, mut s) = setup([0, 0, 0]);
        // 2 req/s × 10 s latency / 4 concurrency = 5 replicas.
        drive(&mut r, 0, 2.0, 300.0, 10.0);
        let actions = s.plan(&mut r, 300.0);
        assert!(actions.iter().any(|a| matches!(a,
            ScaleAction::Up { service: ServiceId(0), target: 5 })),
            "actions: {actions:?}");
    }

    #[test]
    fn cooldown_blocks_rescale() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 0, 2.0, 300.0, 10.0);
        let a1 = s.plan(&mut r, 300.0);
        assert!(!a1.is_empty());
        // Still zero replicas (caller hasn't applied) but cooldown active:
        let a2 = s.plan(&mut r, 310.0);
        assert!(a2.iter().all(|a| !matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })));
        // After cooldown expires it fires again.
        let a3 = s.plan(&mut r, 331.0);
        assert!(a3.iter().any(|a| matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })));
    }

    #[test]
    fn idle_scales_to_zero_without_warm_pool() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 3, 1.0, 10.0, 2.0); // traffic stops at t=10
        r.get_mut(ServiceId(3)).ready_replicas = 2;
        let actions = s.plan(&mut r, 200.0); // idle 190s > τ=120
        assert!(actions.contains(&ScaleAction::Down {
            service: ServiceId(3),
            target: 0
        }));
    }

    #[test]
    fn idle_keeps_warm_pool_floor() {
        let (mut r, mut s) = setup([1, 1, 1]);
        drive(&mut r, 3, 1.0, 10.0, 2.0);
        r.get_mut(ServiceId(3)).ready_replicas = 3;
        let actions = s.plan(&mut r, 200.0);
        assert!(actions.contains(&ScaleAction::Down {
            service: ServiceId(3),
            target: 1
        }));
    }

    #[test]
    fn no_action_when_capacity_matches() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 0, 2.0, 300.0, 10.0);
        r.get_mut(ServiceId(0)).ready_replicas = 6; // above target 5
        let actions = s.plan(&mut r, 300.0);
        assert!(actions.iter().all(|a| !matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })));
    }

    #[test]
    fn max_replicas_caps_target() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 0, 50.0, 300.0, 10.0); // would want 125 replicas
        let actions = s.plan(&mut r, 300.0);
        match actions.iter().find(|a| matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })) {
            Some(ScaleAction::Up { target, .. }) => assert_eq!(*target, 8),
            other => panic!("expected capped up-scale, got {other:?}"),
        }
    }

    #[test]
    fn pending_replicas_count_toward_current() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 0, 2.0, 300.0, 10.0);
        r.get_mut(ServiceId(0)).pending_replicas = 5; // already starting
        let actions = s.plan(&mut r, 300.0);
        assert!(actions.iter().all(|a| !matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })));
    }

    #[test]
    fn quiet_service_with_no_history_stays_down() {
        let (mut r, mut s) = setup([0, 0, 0]);
        let actions = s.plan(&mut r, 1000.0);
        assert!(actions.is_empty(), "{actions:?}");
    }

    fn pool_scaler(warm: [usize; 3]) -> Scaler {
        let cfg = OrchestratorConfig {
            warm_pool: warm,
            cooldown_s: 30.0,
            idle_timeout_s: 120.0,
            ..OrchestratorConfig::default()
        };
        Scaler::for_pool(cfg, 3, 8) // 8 decode slots per replica
    }

    fn tier_target(
        s: &mut Scaler,
        tier: usize,
        load: TierLoad,
        max: usize,
        now: f64,
    ) -> usize {
        match s.plan_tier(tier, ServiceId(0), load, max, now) {
            Some(ScaleAction::Up { target, .. })
            | Some(ScaleAction::Down { target, .. }) => target,
            None => load.active_replicas,
        }
    }

    #[test]
    fn pool_scales_up_on_queue_depth() {
        let mut s = pool_scaler([0, 0, 0]);
        // 20 queued + 4 in slots = 24 demand → 3 replicas of 8 slots.
        let load = TierLoad {
            queue_depth: 20,
            slots_in_use: 4,
            active_replicas: 1,
            idle_s: 0.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        assert_eq!(tier_target(&mut s, 0, load, 4, 100.0), 3);
    }

    #[test]
    fn pool_cooldown_blocks_repeat_upscale() {
        let mut s = pool_scaler([0, 0, 0]);
        let load = TierLoad {
            queue_depth: 30,
            slots_in_use: 0,
            active_replicas: 1,
            idle_s: 0.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        assert_eq!(tier_target(&mut s, 0, load, 8, 0.0), 4);
        // Still under-provisioned, but inside the cooldown window.
        assert_eq!(tier_target(&mut s, 0, load, 8, 10.0), 1);
        // Window over → fires again.
        assert_eq!(tier_target(&mut s, 0, load, 8, 31.0), 4);
    }

    #[test]
    fn pool_scales_to_zero_when_idle_without_warm_floor() {
        let mut s = pool_scaler([0, 0, 0]);
        let load = TierLoad {
            queue_depth: 0,
            slots_in_use: 0,
            active_replicas: 2,
            idle_s: 200.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        assert_eq!(tier_target(&mut s, 2, load, 2, 500.0), 0);
    }

    #[test]
    fn pool_idle_keeps_warm_floor() {
        let mut s = pool_scaler([1, 1, 1]);
        let load = TierLoad {
            queue_depth: 0,
            slots_in_use: 0,
            active_replicas: 2,
            idle_s: 200.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        assert_eq!(tier_target(&mut s, 0, load, 2, 500.0), 1);
    }

    #[test]
    fn pool_inflight_work_blocks_scale_down() {
        let mut s = pool_scaler([0, 0, 0]);
        // Idle enqueue-wise but slots still draining → hold replicas.
        let load = TierLoad {
            queue_depth: 0,
            slots_in_use: 3,
            active_replicas: 1,
            idle_s: 500.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        assert_eq!(tier_target(&mut s, 1, load, 4, 1000.0), 1);
    }

    #[test]
    fn pool_target_capped_by_provisioned_replicas() {
        let mut s = pool_scaler([0, 0, 0]);
        let load = TierLoad {
            queue_depth: 500,
            slots_in_use: 8,
            active_replicas: 1,
            idle_s: 0.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        assert_eq!(tier_target(&mut s, 0, load, 4, 0.0), 4);
    }

    #[test]
    fn pool_steady_state_holds_current() {
        let mut s = pool_scaler([0, 0, 0]);
        let load = TierLoad {
            queue_depth: 2,
            slots_in_use: 6,
            active_replicas: 1,
            idle_s: 1.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        // Demand 8 fits one replica exactly → no change.
        assert!(s.plan_tier(0, ServiceId(0), load, 4, 0.0).is_none());
    }

    #[test]
    fn pool_prefix_hits_temper_scale_up() {
        // The same queue scales to 4 replicas cold but only 2 with a
        // fully-warm prefix cache (half the queue signal relieved).
        let cold = TierLoad {
            queue_depth: 30,
            slots_in_use: 0,
            active_replicas: 1,
            idle_s: 0.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        let mut s = pool_scaler([0, 0, 0]);
        assert_eq!(tier_target(&mut s, 0, cold, 8, 0.0), 4);
        let warm = TierLoad { prefix_hit_rate: 1.0, ..cold };
        let mut s = pool_scaler([0, 0, 0]);
        assert_eq!(tier_target(&mut s, 0, warm, 8, 0.0), 2);
    }

    #[test]
    fn pool_spec_acceptance_tempers_scale_up() {
        // Accepted draft tokens drain queued decode faster, so the same
        // queue asks for half the replicas at full acceptance.
        let plain = TierLoad {
            queue_depth: 30,
            slots_in_use: 0,
            active_replicas: 1,
            idle_s: 0.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        let mut s = pool_scaler([0, 0, 0]);
        assert_eq!(tier_target(&mut s, 0, plain, 8, 0.0), 4);
        let spec = TierLoad { spec_accept_rate: 1.0, ..plain };
        let mut s = pool_scaler([0, 0, 0]);
        assert_eq!(tier_target(&mut s, 0, spec, 8, 0.0), 2);
    }

    #[test]
    fn pool_reliefs_compose_multiplicatively_with_a_floor() {
        // Both discounts maxed: relief = (1-0.5)(1-0.5) = 0.25, not
        // 1 - 0.5 - 0.5 = 0 — the queue never vanishes from the plan.
        let load = TierLoad {
            queue_depth: 32,
            slots_in_use: 0,
            active_replicas: 0,
            idle_s: 0.0,
            prefix_hit_rate: 1.0,
            spec_accept_rate: 1.0,
            pressure: 0.0,
        };
        let mut s = pool_scaler([0, 0, 0]);
        // 32 × 0.25 = 8 → exactly one 8-slot replica.
        assert_eq!(tier_target(&mut s, 0, load, 8, 0.0), 1);
        // Degenerate (out-of-range) rates clamp instead of driving the
        // composed relief negative: demand stays at the 0.25 floor.
        let wild = TierLoad { prefix_hit_rate: 7.0, spec_accept_rate: 9.0, ..load };
        let mut s = pool_scaler([0, 0, 0]);
        assert_eq!(tier_target(&mut s, 0, wild, 8, 0.0), 1);
    }

    #[test]
    fn pool_shed_pressure_counts_as_demand() {
        // A short queue that holds at one replica scales up once the
        // admission gate reports shed/escalation pressure — requests
        // that wanted this tier and didn't get it are still demand.
        let calm = TierLoad {
            queue_depth: 4,
            slots_in_use: 4,
            active_replicas: 1,
            idle_s: 0.0,
            prefix_hit_rate: 0.0,
            spec_accept_rate: 0.0,
            pressure: 0.0,
        };
        let mut s = pool_scaler([0, 0, 0]);
        assert!(s.plan_tier(0, ServiceId(0), calm, 4, 0.0).is_none());
        // 16 sheds last interval → demand 4+4+16 = 24 → 3 replicas.
        let overloaded = TierLoad { pressure: 16.0, ..calm };
        let mut s = pool_scaler([0, 0, 0]);
        assert_eq!(tier_target(&mut s, 0, overloaded, 4, 0.0), 3);
        // The pressure signal is never allowed to shrink demand.
        let negative = TierLoad { pressure: -5.0, ..calm };
        let mut s = pool_scaler([0, 0, 0]);
        assert!(s.plan_tier(0, ServiceId(0), negative, 4, 0.0).is_none());
    }

    #[test]
    fn apply_provisions_and_terminates_through_the_trait() {
        let (mut r, _) = setup([0, 0, 0]);
        let mut sub = MockSubstrate::new(8, 5.0);
        let sid = ServiceId(0);
        let spawned = apply(
            &[ScaleAction::Up { service: sid, target: 3 }],
            &mut r,
            &mut sub,
            0.0,
        );
        assert_eq!(spawned.len(), 3);
        assert_eq!(r.get(sid).pending_replicas, 3);
        assert_eq!(sub.pending_replicas(sid), 3);
        // Replicas come Ready; a Down terminates the excess.
        sub.poll(6.0);
        assert_eq!(sub.ready_replicas(sid).len(), 3);
        apply(&[ScaleAction::Down { service: sid, target: 1 }], &mut r, &mut sub, 7.0);
        let terminating = spawned
            .iter()
            .filter(|id| sub.replica_state(**id) == Some(ReplicaState::Terminating))
            .count();
        assert_eq!(terminating, 2);
    }

    #[test]
    fn apply_stops_at_substrate_capacity() {
        let (mut r, _) = setup([0, 0, 0]);
        let mut sub = MockSubstrate::new(2, 1.0);
        let sid = ServiceId(1);
        let spawned = apply(
            &[ScaleAction::Up { service: sid, target: 5 }],
            &mut r,
            &mut sub,
            0.0,
        );
        assert_eq!(spawned.len(), 2, "capacity bounds provisioning");
        assert_eq!(r.get(sid).pending_replicas, 2);
    }
}
