//! Algorithm 1 — Orchestration-Aware Scaling with Warm Pools.
//!
//! ```text
//! for each model m in pool:
//!     r_m      ← GetAvgRequestRate(m, w)          # telemetry window
//!     lat_m    ← GetAvgLatency(m)
//!     target   ← ceil(r_m × lat_m / Concurrency)  # Little's Law
//!     current  ← GetReplicas(m)
//!     min_warm ← WarmPoolSize(ModelTier(m))
//!     if target > current AND CooldownExpired():  scale(max(target, min_warm))
//!     elif IdleTime(m) > τ:                       scale(max(0, min_warm))
//! ```
//!
//! The scaler is pure decision logic: it reads the registry and emits
//! [`ScaleAction`]s; the caller applies them to the cluster (sim or
//! live). This keeps Alg. 1 unit-testable in isolation.

use crate::config::OrchestratorConfig;
use crate::registry::{Registry, ServiceId};

/// A scaling decision for one service.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleAction {
    /// Scale up to `target` replicas (spawn `target - current` pods).
    Up { service: ServiceId, target: usize },
    /// Scale down to `target` replicas (terminate extras).
    Down { service: ServiceId, target: usize },
}

/// Little's-law scaler with cooldown and warm pools.
pub struct Scaler {
    cfg: OrchestratorConfig,
    /// Per-service end-of-cooldown timestamps.
    cooldown_until: Vec<f64>,
}

impl Scaler {
    pub fn new(cfg: OrchestratorConfig, n_services: usize) -> Scaler {
        Scaler { cfg, cooldown_until: vec![0.0; n_services] }
    }

    pub fn cfg(&self) -> &OrchestratorConfig {
        &self.cfg
    }

    /// Warm-pool floor for a service (by engine tier, paper's
    /// `WarmPoolSize(ModelTier(m))`).
    pub fn warm_pool(&self, registry: &Registry, id: ServiceId) -> usize {
        let tier = registry.get(id).spec.tier;
        self.cfg.warm_pool[tier.index()]
    }

    /// Run one Alg. 1 pass; returns actions to apply.
    pub fn plan(&mut self, registry: &mut Registry, now_s: f64) -> Vec<ScaleAction> {
        let mut actions = Vec::new();
        for idx in 0..registry.services.len() {
            let id = ServiceId(idx);
            let min_warm = self.warm_pool(registry, id);
            let svc = registry.get_mut(id);
            let rate = svc.telemetry.arrivals.rate(now_s);          // r_m
            let lat = svc.telemetry.avg_latency(                     // lat_m
                // Prior for cold services: a mid-size request estimate.
                svc.expected_latency_s(60.0, 80.0, 0.0),
            );
            // Little's Law: L = λW → replicas to hold L streams at
            // `target_concurrency` streams each.
            let target_raw =
                (rate * lat / self.cfg.target_concurrency).ceil() as usize;
            let current = svc.ready_replicas + svc.pending_replicas;
            let idle = svc.telemetry.arrivals.idle_time(now_s);

            if target_raw > current && now_s >= self.cooldown_until[idx] {
                let target = target_raw
                    .max(min_warm)
                    .min(self.cfg.max_replicas);
                if target > current {
                    actions.push(ScaleAction::Up { service: id, target });
                    self.cooldown_until[idx] = now_s + self.cfg.cooldown_s;
                }
            } else if idle > self.cfg.idle_timeout_s {
                let target = min_warm; // max(0, min_warm)
                if target < current {
                    actions.push(ScaleAction::Down { service: id, target });
                }
            }
        }
        actions
    }
}

/// Live load signal for one engine-pool tier (the gateway samples these
/// each scaling interval).
#[derive(Debug, Clone, Copy)]
pub struct TierLoad {
    /// Routed requests waiting in the tier queue.
    pub queue_depth: usize,
    /// Decode slots currently occupied across the tier's replicas.
    pub slots_in_use: usize,
    /// Replicas currently active (unparked).
    pub active_replicas: usize,
    /// Seconds since the tier last saw an enqueue.
    pub idle_s: f64,
}

/// Alg. 1 adapted to the in-process engine pool: targets are driven by
/// *observed* demand — per-tier queue depth plus slot occupancy — instead
/// of the arrival-rate × latency estimate the cluster scaler uses, since
/// the live gateway can measure its own backlog directly. Scale-to-zero
/// parks every replica of an idle tier (minus its warm-pool floor);
/// the gateway un-parks on the next enqueue (a "cold wake").
pub struct PoolScaler {
    cfg: OrchestratorConfig,
    /// Demand a single replica absorbs (its decode-slot count).
    slots_per_replica: usize,
    cooldown_until: [f64; 3],
}

impl PoolScaler {
    pub fn new(cfg: OrchestratorConfig, slots_per_replica: usize) -> PoolScaler {
        PoolScaler {
            cfg,
            slots_per_replica: slots_per_replica.max(1),
            cooldown_until: [0.0; 3],
        }
    }

    /// Plan the active-replica target for one tier. `max_replicas` is the
    /// tier's provisioned thread count (the hard ceiling).
    pub fn target(
        &mut self,
        tier: usize,
        load: TierLoad,
        max_replicas: usize,
        now_s: f64,
    ) -> usize {
        let warm = self.cfg.warm_pool[tier.min(2)].min(max_replicas);
        let demand = load.queue_depth + load.slots_in_use;
        let need = demand.div_ceil(self.slots_per_replica);
        if need > load.active_replicas {
            // Scale up (cooldown-gated, warm floor respected).
            if now_s >= self.cooldown_until[tier.min(2)] {
                self.cooldown_until[tier.min(2)] = now_s + self.cfg.cooldown_s;
                return need.max(warm).min(max_replicas);
            }
            return load.active_replicas;
        }
        if demand == 0 && load.idle_s > self.cfg.idle_timeout_s {
            // Scale to zero (or the warm floor) after the idle window.
            return warm;
        }
        load.active_replicas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OrchestratorConfig;
    use crate::models::zoo;
    use crate::registry::Registry;

    fn setup(warm: [usize; 3]) -> (Registry, Scaler) {
        let r = Registry::new(&zoo(), 300.0);
        let n = r.services.len();
        let cfg = OrchestratorConfig {
            warm_pool: warm,
            cooldown_s: 30.0,
            idle_timeout_s: 120.0,
            target_concurrency: 4.0,
            ..OrchestratorConfig::default()
        };
        (r, Scaler::new(cfg, n))
    }

    /// Drive `rate` arrivals/s into a service for `dur` seconds.
    fn drive(r: &mut Registry, idx: usize, rate: f64, dur: f64, lat: f64) {
        let svc = r.get_mut(ServiceId(idx));
        let n = (rate * dur) as usize;
        for i in 0..n {
            let t = i as f64 / rate;
            svc.telemetry.on_dispatch(t, 16.0);
            svc.telemetry.on_complete(t + lat, 16.0, lat, lat / 4.0, true);
        }
    }

    #[test]
    fn littles_law_target() {
        let (mut r, mut s) = setup([0, 0, 0]);
        // 2 req/s × 10 s latency / 4 concurrency = 5 replicas.
        drive(&mut r, 0, 2.0, 300.0, 10.0);
        let actions = s.plan(&mut r, 300.0);
        assert!(actions.iter().any(|a| matches!(a,
            ScaleAction::Up { service: ServiceId(0), target: 5 })),
            "actions: {actions:?}");
    }

    #[test]
    fn cooldown_blocks_rescale() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 0, 2.0, 300.0, 10.0);
        let a1 = s.plan(&mut r, 300.0);
        assert!(!a1.is_empty());
        // Still zero replicas (caller hasn't applied) but cooldown active:
        let a2 = s.plan(&mut r, 310.0);
        assert!(a2.iter().all(|a| !matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })));
        // After cooldown expires it fires again.
        let a3 = s.plan(&mut r, 331.0);
        assert!(a3.iter().any(|a| matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })));
    }

    #[test]
    fn idle_scales_to_zero_without_warm_pool() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 3, 1.0, 10.0, 2.0); // traffic stops at t=10
        r.get_mut(ServiceId(3)).ready_replicas = 2;
        let actions = s.plan(&mut r, 200.0); // idle 190s > τ=120
        assert!(actions.contains(&ScaleAction::Down {
            service: ServiceId(3),
            target: 0
        }));
    }

    #[test]
    fn idle_keeps_warm_pool_floor() {
        let (mut r, mut s) = setup([1, 1, 1]);
        drive(&mut r, 3, 1.0, 10.0, 2.0);
        r.get_mut(ServiceId(3)).ready_replicas = 3;
        let actions = s.plan(&mut r, 200.0);
        assert!(actions.contains(&ScaleAction::Down {
            service: ServiceId(3),
            target: 1
        }));
    }

    #[test]
    fn no_action_when_capacity_matches() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 0, 2.0, 300.0, 10.0);
        r.get_mut(ServiceId(0)).ready_replicas = 6; // above target 5
        let actions = s.plan(&mut r, 300.0);
        assert!(actions.iter().all(|a| !matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })));
    }

    #[test]
    fn max_replicas_caps_target() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 0, 50.0, 300.0, 10.0); // would want 125 replicas
        let actions = s.plan(&mut r, 300.0);
        match actions.iter().find(|a| matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })) {
            Some(ScaleAction::Up { target, .. }) => assert_eq!(*target, 8),
            other => panic!("expected capped up-scale, got {other:?}"),
        }
    }

    #[test]
    fn pending_replicas_count_toward_current() {
        let (mut r, mut s) = setup([0, 0, 0]);
        drive(&mut r, 0, 2.0, 300.0, 10.0);
        r.get_mut(ServiceId(0)).pending_replicas = 5; // already starting
        let actions = s.plan(&mut r, 300.0);
        assert!(actions.iter().all(|a| !matches!(a,
            ScaleAction::Up { service: ServiceId(0), .. })));
    }

    #[test]
    fn quiet_service_with_no_history_stays_down() {
        let (mut r, mut s) = setup([0, 0, 0]);
        let actions = s.plan(&mut r, 1000.0);
        assert!(actions.is_empty(), "{actions:?}");
    }

    fn pool_scaler(warm: [usize; 3]) -> PoolScaler {
        let cfg = OrchestratorConfig {
            warm_pool: warm,
            cooldown_s: 30.0,
            idle_timeout_s: 120.0,
            ..OrchestratorConfig::default()
        };
        PoolScaler::new(cfg, 8) // 8 decode slots per replica
    }

    #[test]
    fn pool_scales_up_on_queue_depth() {
        let mut s = pool_scaler([0, 0, 0]);
        // 20 queued + 4 in slots = 24 demand → 3 replicas of 8 slots.
        let load = TierLoad {
            queue_depth: 20,
            slots_in_use: 4,
            active_replicas: 1,
            idle_s: 0.0,
        };
        assert_eq!(s.target(0, load, 4, 100.0), 3);
    }

    #[test]
    fn pool_cooldown_blocks_repeat_upscale() {
        let mut s = pool_scaler([0, 0, 0]);
        let load = TierLoad {
            queue_depth: 30,
            slots_in_use: 0,
            active_replicas: 1,
            idle_s: 0.0,
        };
        assert_eq!(s.target(0, load, 8, 0.0), 4);
        // Still under-provisioned, but inside the cooldown window.
        assert_eq!(s.target(0, load, 8, 10.0), 1);
        // Window over → fires again.
        assert_eq!(s.target(0, load, 8, 31.0), 4);
    }

    #[test]
    fn pool_scales_to_zero_when_idle_without_warm_floor() {
        let mut s = pool_scaler([0, 0, 0]);
        let load = TierLoad {
            queue_depth: 0,
            slots_in_use: 0,
            active_replicas: 2,
            idle_s: 200.0,
        };
        assert_eq!(s.target(2, load, 2, 500.0), 0);
    }

    #[test]
    fn pool_idle_keeps_warm_floor() {
        let mut s = pool_scaler([1, 1, 1]);
        let load = TierLoad {
            queue_depth: 0,
            slots_in_use: 0,
            active_replicas: 2,
            idle_s: 200.0,
        };
        assert_eq!(s.target(0, load, 2, 500.0), 1);
    }

    #[test]
    fn pool_inflight_work_blocks_scale_down() {
        let mut s = pool_scaler([0, 0, 0]);
        // Idle enqueue-wise but slots still draining → hold replicas.
        let load = TierLoad {
            queue_depth: 0,
            slots_in_use: 3,
            active_replicas: 1,
            idle_s: 500.0,
        };
        assert_eq!(s.target(1, load, 4, 1000.0), 1);
    }

    #[test]
    fn pool_target_capped_by_provisioned_replicas() {
        let mut s = pool_scaler([0, 0, 0]);
        let load = TierLoad {
            queue_depth: 500,
            slots_in_use: 8,
            active_replicas: 1,
            idle_s: 0.0,
        };
        assert_eq!(s.target(0, load, 4, 0.0), 4);
    }

    #[test]
    fn pool_steady_state_holds_current() {
        let mut s = pool_scaler([0, 0, 0]);
        let load = TierLoad {
            queue_depth: 2,
            slots_in_use: 6,
            active_replicas: 1,
            idle_s: 1.0,
        };
        // Demand 8 fits one replica exactly → no change.
        assert_eq!(s.target(0, load, 4, 0.0), 1);
    }
}
