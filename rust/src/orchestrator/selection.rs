//! Algorithm 2 — Matrix Selection and Routing.
//!
//! For a classified prompt, evaluate `f(p, S_xy)` (Eq. 2) over every
//! healthy cell of the service matrix and pick the argmax. Relevance
//! comes from capability–complexity matching; the latency and cost
//! expectations are min–max normalized **across the candidate set**
//! (the matrix itself is the "historical system statistics" of Eq. 2 —
//! normalizing over the candidates keeps the scores discriminative at
//! any traffic scale, where a fixed global window would saturate).

use crate::registry::{Registry, ServiceId};
use crate::router::Classification;
use crate::scoring::{relevance, score, Components, Weights};
use crate::substrate::Substrate;
use crate::util::stats::minmax_norm;

/// The outcome of one matrix selection.
#[derive(Debug, Clone)]
pub struct Selection {
    pub service: ServiceId,
    pub score: f64,
    pub components: Components,
    /// Scores of every candidate (for ablation/analysis output).
    pub all_scores: Vec<(ServiceId, f64)>,
}

/// Evaluate Alg. 2 over the matrix.
///
/// `in_tokens`/`out_tokens` are the prompt-size estimates used for the
/// T and C expectations; `cold_start_of` supplies the per-service
/// cold-start penalty when a cell is scaled to zero.
pub fn select(
    registry: &Registry,
    weights: Weights,
    class: &Classification,
    in_tokens: f64,
    out_tokens: f64,
    cold_start_of: impl Fn(&crate::registry::Service) -> f64,
) -> Option<Selection> {
    // Pass 1: raw estimates per candidate.
    let mut cands: Vec<(ServiceId, f64, f64, f64)> = Vec::new(); // id, R, T, C
    for svc in registry.routable() {
        let r = relevance(&svc.spec.capability, class.complexity, class.confidence);
        let t = svc.expected_latency_s(in_tokens, out_tokens, cold_start_of(svc));
        let c = svc.expected_cost_usd(in_tokens, out_tokens);
        cands.push((svc.id, r, t, c));
    }
    if cands.is_empty() {
        return None;
    }
    // Pass 2: min–max normalize all three components over the candidate
    // set ("all terms lie in a common numerical range", paper §Problem).
    // Without normalizing R̂ alongside T̂/Ĉ, the capability spread
    // (0.45–0.98) is drowned by the full-range cost/latency spread and
    // routing degenerates toward cheapest-only. The relevance range is
    // floored so a *negligible* capability spread (easy prompts, where
    // every model succeeds) is not stretched into a decisive signal.
    const R_RANGE_FLOOR: f64 = 0.25;
    let (r_min, r_max) = min_max(cands.iter().map(|c| c.1));
    let r_max_eff = r_max.max(r_min + R_RANGE_FLOOR);
    let (t_min, t_max) = min_max(cands.iter().map(|c| c.2));
    let (c_min, c_max) = min_max(cands.iter().map(|c| c.3));

    let mut best: Option<Selection> = None;
    let mut all_scores = Vec::with_capacity(cands.len());
    for (id, r, t_raw, c_raw) in cands {
        let comps = Components {
            relevance: minmax_norm(r, r_min, r_max_eff),
            timeliness: 1.0 - minmax_norm(t_raw, t_min, t_max),
            economy: 1.0 - minmax_norm(c_raw, c_min, c_max),
        };
        let f = score(weights, comps);
        all_scores.push((id, f));
        let better = best.as_ref().map(|b| f > b.score).unwrap_or(true);
        if better {
            best = Some(Selection {
                service: id,
                score: f,
                components: comps,
                all_scores: Vec::new(),
            });
        }
    }
    best.map(|mut b| {
        b.all_scores = all_scores;
        b
    })
}

/// [`select`] with the cold-start penalty sourced from a substrate:
/// warm cells pay nothing, scaled-to-zero cells pay the substrate's
/// measured/estimated cold start. This is the form both control planes
/// (sim driver and live gateway) route through.
pub fn select_on(
    registry: &Registry,
    substrate: &dyn Substrate,
    weights: Weights,
    class: &Classification,
    in_tokens: f64,
    out_tokens: f64,
) -> Option<Selection> {
    select(registry, weights, class, in_tokens, out_tokens, |svc| {
        if svc.ready_replicas > 0 {
            0.0
        } else {
            substrate.estimate_cold_start_s(&svc.spec, svc.backend)
        }
    })
}

fn min_max(values: impl Iterator<Item = f64>) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for v in values {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Profile, RouterMode};
    use crate::models::{zoo, BackendKind};
    use crate::registry::{Health, Registry};
    use crate::scoring::Weights;

    fn setup() -> Registry {
        let mut r = Registry::new(&zoo(), 300.0);
        for s in &mut r.services {
            s.ready_replicas = 1;
        }
        r
    }

    fn class(complexity: usize) -> Classification {
        Classification {
            complexity,
            confidence: 0.95,
            mode: RouterMode::Hybrid,
            overhead_s: 0.0,
        }
    }

    #[test]
    fn quality_profile_routes_hard_prompts_to_big_models() {
        let r = setup();
        let w = Weights::from_profile(&Profile::QUALITY);
        let sel = select(&r, w, &class(2), 100.0, 200.0, |_| 30.0).unwrap();
        assert!(r.get(sel.service).spec.capability[2] > 0.85,
                "picked {}", r.get(sel.service).spec.name);
    }

    #[test]
    fn cost_profile_routes_easy_prompts_to_small_models() {
        let r = setup();
        let w = Weights::from_profile(&Profile::COST);
        let sel = select(&r, w, &class(0), 50.0, 30.0, |_| 30.0).unwrap();
        assert_eq!(r.get(sel.service).spec.name, "gemma3-27b");
    }

    #[test]
    fn speed_profile_avoids_slowest_cells() {
        let r = setup();
        let w = Weights::from_profile(&Profile::SPEED);
        let sel = select(&r, w, &class(1), 50.0, 50.0, |_| 30.0).unwrap();
        let svc = r.get(sel.service);
        // Latency-dominated choice: never the big models' slow decode.
        assert!(svc.spec.decode_tps >= 25.0, "picked {}", svc.spec.name);
        assert_ne!(svc.backend, BackendKind::Tgi);
    }

    #[test]
    fn unhealthy_cells_skipped() {
        let mut r = setup();
        for s in &mut r.services {
            s.health = Health::Unhealthy;
        }
        let w = Weights::from_profile(&Profile::BALANCED);
        assert!(select(&r, w, &class(1), 50.0, 50.0, |_| 0.0).is_none());
    }

    #[test]
    fn cold_start_penalty_discourages_scaled_to_zero() {
        let mut r = setup();
        for s in &mut r.services {
            s.ready_replicas = 0;
        }
        let warm = r.cell(1, BackendKind::Vllm).id;
        r.get_mut(warm).ready_replicas = 1;
        let w = Weights::from_profile(&Profile::SPEED);
        let sel = select(&r, w, &class(1), 50.0, 50.0, |_| 300.0).unwrap();
        assert_eq!(sel.service, warm);
    }

    #[test]
    fn select_on_sources_cold_start_from_substrate() {
        let mut r = setup();
        for s in &mut r.services {
            s.ready_replicas = 0;
        }
        let warm = r.cell(1, BackendKind::Vllm).id;
        r.get_mut(warm).ready_replicas = 1;
        // A substrate with a brutal cold start: the one warm cell wins
        // under a latency-sensitive profile.
        let sub = crate::substrate::testing::MockSubstrate::new(4, 300.0);
        let w = Weights::from_profile(&Profile::SPEED);
        let sel = select_on(&r, &sub, w, &class(1), 50.0, 50.0).unwrap();
        assert_eq!(sel.service, warm);
    }

    #[test]
    fn all_scores_cover_matrix() {
        let r = setup();
        let w = Weights::from_profile(&Profile::BALANCED);
        let sel = select(&r, w, &class(1), 50.0, 50.0, |_| 0.0).unwrap();
        assert_eq!(sel.all_scores.len(), 12);
        let best = sel
            .all_scores
            .iter()
            .map(|(_, f)| *f)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((best - sel.score).abs() < 1e-12);
    }

    #[test]
    fn scores_bounded() {
        let r = setup();
        for profile in &Profile::ALL {
            let w = Weights::from_profile(profile);
            for c in 0..3 {
                let sel = select(&r, w, &class(c), 50.0, 50.0, |_| 10.0).unwrap();
                for (_, f) in &sel.all_scores {
                    assert!((0.0..=1.0).contains(f));
                }
            }
        }
    }

    #[test]
    fn balanced_profile_spreads_by_complexity() {
        // Balanced weights should send low ↦ small-ish, high ↦ large-ish.
        let r = setup();
        let w = Weights::from_profile(&Profile::BALANCED);
        let lo = select(&r, w, &class(0), 30.0, 20.0, |_| 30.0).unwrap();
        let hi = select(&r, w, &class(2), 100.0, 250.0, |_| 30.0).unwrap();
        let lo_cap = r.get(lo.service).spec.capability[2];
        let hi_cap = r.get(hi.service).spec.capability[2];
        assert!(hi_cap > lo_cap, "low→{} high→{}",
                r.get(lo.service).spec.name, r.get(hi.service).spec.name);
    }
}
