//! Spin — the orchestration layer.
//!
//! * [`selection`] — Algorithm 2: score every viable matrix cell with
//!   Eq. 2 and route to the argmax.
//! * [`scaling`] — Algorithm 1: Little's-law capacity planning with warm
//!   pools, cooldowns, and scale-to-zero.
//! * [`recovery`] — failure detection and automatic redeployment (the
//!   paper's recovery-time experiments, Table 4).
//!
//! All three operate over [`crate::substrate::Substrate`], so the
//! simulated cluster and the live engine pool are driven by the same
//! control plane.

pub mod recovery;
pub mod scaling;
pub mod selection;

pub use scaling::{apply, ScaleAction, Scaler, TierLoad};
pub use selection::{select, select_on, Selection};
