//! Recovery manager — fault detection and automatic redeployment.
//!
//! The paper reports recovery times (Table 4): a static deployment takes
//! ~45 s to restore service after a pod failure (full cold restart),
//! while Pick-and-Spin's orchestration recovers in 4–12 s because (a)
//! images are node-cached, (b) weights live in PVCs, and (c) warm-pool
//! standbys absorb traffic immediately. This module tracks failures and
//! replacement readiness, and records the measured recovery latency per
//! incident.
//!
//! It speaks only the [`Substrate`] trait, so the same manager (and the
//! same [`Incident`] records behind Table 4) runs against the simulated
//! cluster and the live engine pool: a replica thread that panics or
//! stalls surfaces as a `ReplicaFailed` event exactly like a killed pod.

use std::collections::BTreeMap;

use crate::registry::{Health, Registry, ServiceId};
use crate::substrate::{ReplicaId, Substrate, SubstrateEvent};

/// One tracked failure incident.
#[derive(Debug, Clone)]
pub struct Incident {
    pub service: ServiceId,
    pub failed_at_s: f64,
    /// When a replacement became Ready (None while recovering).
    pub recovered_at_s: Option<f64>,
}

impl Incident {
    pub fn recovery_s(&self) -> Option<f64> {
        self.recovered_at_s.map(|t| t - self.failed_at_s)
    }
}

/// Watches substrate events, reschedules failed replicas, and records
/// recovery latency.
pub struct RecoveryManager {
    pub incidents: Vec<Incident>,
    /// Open incidents per service (index into `incidents`).
    open: BTreeMap<ServiceId, Vec<usize>>,
    /// Whether to auto-redeploy (Pick-and-Spin) or wait for the static
    /// deployment's manual restart model.
    pub auto_redeploy: bool,
    /// Whether warm standbys absorb failures (recovery = rerouting at
    /// detection time) — the paper's "auto" mode. Without it, recovery
    /// is measured to replacement-replica readiness even if spare
    /// replicas keep serving.
    pub standby_absorbs: bool,
}

impl RecoveryManager {
    pub fn new(auto_redeploy: bool) -> Self {
        Self::with_standby(auto_redeploy, false)
    }

    pub fn with_standby(auto_redeploy: bool, standby_absorbs: bool) -> Self {
        Self {
            incidents: Vec::new(),
            open: BTreeMap::new(),
            auto_redeploy,
            standby_absorbs,
        }
    }

    /// Process lifecycle events; returns replicas provisioned as
    /// replacements.
    pub fn on_events(
        &mut self,
        events: &[SubstrateEvent],
        registry: &mut Registry,
        substrate: &mut dyn Substrate,
        now_s: f64,
    ) -> Vec<ReplicaId> {
        let mut spawned = Vec::new();
        for ev in events {
            match ev {
                SubstrateEvent::ReplicaFailed { service, at_s, .. } => {
                    let idx = self.incidents.len();
                    // Warm standbys absorb failures instantly: if other
                    // ready replicas remain, traffic reroutes and the
                    // incident closes at detection time (the paper's
                    // 4 s "auto" recovery); the replacement replica
                    // still provisions in the background.
                    let standby = self.standby_absorbs
                        && registry.get(*service).ready_replicas > 1;
                    self.incidents.push(Incident {
                        service: *service,
                        failed_at_s: *at_s,
                        recovered_at_s: if standby { Some(now_s) } else { None },
                    });
                    if !standby {
                        self.open.entry(*service).or_default().push(idx);
                    }
                    let svc = registry.get_mut(*service);
                    svc.ready_replicas = svc.ready_replicas.saturating_sub(1);
                    svc.health = if svc.ready_replicas == 0 {
                        Health::Unhealthy
                    } else {
                        Health::Degraded
                    };
                    if self.auto_redeploy {
                        let (model_idx, spec, backend) = {
                            let s = registry.get(*service);
                            (s.model_idx, s.spec.clone(), s.backend)
                        };
                        if let Some(replica) = substrate.provision(
                            *service, model_idx, &spec, backend, now_s,
                        ) {
                            registry.get_mut(*service).pending_replicas += 1;
                            spawned.push(replica);
                        }
                    }
                }
                SubstrateEvent::ReplicaReady { service, at_s, .. } => {
                    // A ready replica closes the oldest open incident.
                    if let Some(open) = self.open.get_mut(service) {
                        if let Some(idx) = open.first().copied() {
                            self.incidents[idx].recovered_at_s = Some(*at_s);
                            open.remove(0);
                        }
                    }
                    let svc = registry.get_mut(*service);
                    if svc.ready_replicas > 0 {
                        svc.health = Health::Healthy;
                    }
                }
                SubstrateEvent::ReplicaGone { .. } => {}
            }
        }
        spawned
    }

    /// Mark a service healthy again once replicas are restored (callers
    /// update ready counts; this fixes up health).
    pub fn refresh_health(&self, registry: &mut Registry) {
        for svc in &mut registry.services {
            if svc.ready_replicas > 0 && !self.has_open(svc.id) {
                svc.health = Health::Healthy;
            }
        }
    }

    pub fn has_open(&self, service: ServiceId) -> bool {
        self.open.get(&service).map(|v| !v.is_empty()).unwrap_or(false)
    }

    /// Closed (recovered) incident count.
    pub fn recovered(&self) -> usize {
        self.incidents.iter().filter(|i| i.recovered_at_s.is_some()).count()
    }

    /// Sum of measured recovery seconds across closed incidents (the
    /// `/metrics` counter behind `ps_recovery_seconds_total`).
    pub fn total_recovery_s(&self) -> f64 {
        self.incidents.iter().filter_map(|i| i.recovery_s()).sum()
    }

    /// Mean recovery time across closed incidents.
    pub fn mean_recovery_s(&self) -> Option<f64> {
        let n = self.recovered();
        if n == 0 {
            None
        } else {
            Some(self.total_recovery_s() / n as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::config::ClusterConfig;
    use crate::models::{zoo, BackendKind};
    use crate::substrate::testing::MockSubstrate;

    fn setup() -> (Registry, Cluster) {
        let z = zoo();
        let r = Registry::new(&z, 300.0);
        let c = Cluster::new(ClusterConfig::default());
        (r, c)
    }

    #[test]
    fn failure_triggers_redeploy_and_tracks_recovery() {
        let (mut reg, mut cl) = setup();
        let z = zoo();
        let svc = ServiceId(0);
        // Boot a pod, make it ready.
        cl.schedule(svc, 0, &z[0], BackendKind::Vllm, 0.0).unwrap();
        let evs = cl.poll(30.0);
        reg.get_mut(svc).ready_replicas = 1;
        let mut rm = RecoveryManager::new(true);
        rm.on_events(&evs, &mut reg, &mut cl, 30.0);

        // Kill it at t=100.
        let pod = cl.ready_pods(svc)[0];
        let ev = cl.fail(pod, 100.0).unwrap();
        let spawned = rm.on_events(&[ev], &mut reg, &mut cl, 100.0);
        assert_eq!(spawned.len(), 1);
        assert_eq!(reg.get(svc).health, Health::Unhealthy);

        // Replacement: cached image (1s) + warm weights (2.8s) + init (3s).
        let evs = cl.poll(100.0 + 6.8 + 0.1);
        reg.get_mut(svc).ready_replicas += 1;
        reg.get_mut(svc).pending_replicas = 0;
        rm.on_events(&evs, &mut reg, &mut cl, 106.9);
        let rec = rm.mean_recovery_s().unwrap();
        assert!((rec - 6.8).abs() < 0.2, "recovery {rec}");
        assert_eq!(reg.get(svc).health, Health::Healthy);
        assert_eq!(rm.recovered(), 1);
        assert!((rm.total_recovery_s() - rec).abs() < 1e-9);
    }

    #[test]
    fn no_redeploy_in_static_mode() {
        let (mut reg, mut cl) = setup();
        let z = zoo();
        let svc = ServiceId(1);
        cl.schedule(svc, 0, &z[0], BackendKind::TrtLlm, 0.0).unwrap();
        cl.poll(60.0);
        reg.get_mut(svc).ready_replicas = 1;
        let pod = cl.ready_pods(svc)[0];
        let ev = cl.fail(pod, 70.0).unwrap();
        let mut rm = RecoveryManager::new(false);
        let spawned = rm.on_events(&[ev], &mut reg, &mut cl, 70.0);
        assert!(spawned.is_empty());
        assert!(rm.has_open(svc));
        assert!(rm.mean_recovery_s().is_none());
        assert_eq!(rm.total_recovery_s(), 0.0);
    }

    #[test]
    fn degraded_not_unhealthy_with_spare_replicas() {
        let (mut reg, mut cl) = setup();
        let svc = ServiceId(2);
        reg.get_mut(svc).ready_replicas = 3;
        let ev = SubstrateEvent::ReplicaFailed {
            replica: ReplicaId(9),
            service: svc,
            at_s: 5.0,
        };
        let mut rm = RecoveryManager::new(false);
        rm.on_events(&[ev], &mut reg, &mut cl, 5.0);
        assert_eq!(reg.get(svc).ready_replicas, 2);
        assert_eq!(reg.get(svc).health, Health::Degraded);
    }

    #[test]
    fn recovery_runs_unchanged_on_a_non_cluster_substrate() {
        // The same manager against the trait-only mock: proves recovery
        // has no sim-specific assumptions.
        let z = zoo();
        let mut reg = Registry::new(&z, 300.0);
        let mut sub = MockSubstrate::new(4, 5.0);
        let svc = ServiceId(0);
        let first = sub
            .provision(svc, 0, &z[0], BackendKind::Vllm, 0.0)
            .unwrap();
        let evs = sub.poll(5.0);
        reg.get_mut(svc).ready_replicas = 1;
        let mut rm = RecoveryManager::new(true);
        rm.on_events(&evs, &mut reg, &mut sub, 5.0);

        let ev = sub.fail(first, 20.0).unwrap();
        let spawned = rm.on_events(&[ev], &mut reg, &mut sub, 20.0);
        assert_eq!(spawned.len(), 1);
        assert!(rm.has_open(svc));

        let evs = sub.poll(25.0); // replacement Ready at 20 + 5
        reg.get_mut(svc).ready_replicas += 1;
        reg.get_mut(svc).pending_replicas = 0;
        rm.on_events(&evs, &mut reg, &mut sub, 25.0);
        let rec = rm.mean_recovery_s().unwrap();
        assert!((rec - 5.0).abs() < 1e-9, "recovery {rec}");
    }
}
