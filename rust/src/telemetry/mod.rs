//! Telemetry: the closed-loop signals feeding the Router and Orchestrator
//! (paper Fig. 1 — "Telemetry continuously monitors latency, utilization,
//! and service health").
//!
//! All APIs take explicit timestamps (seconds) so the same code serves
//! live mode (wall clock) and virtual-time simulation.

use std::collections::VecDeque;

use crate::util::stats::{Ema, Rolling, Summary};

pub mod trace;

/// Shortest divisor `RateWindow::rate` will use — stops a burst in the
/// first instants of a window from reading as a near-infinite rate.
const RATE_FLOOR_S: f64 = 1.0;

/// Completions retained per service for latency/TTFT percentiles. Bounds
/// gateway memory: older samples age out instead of accumulating forever.
const SUMMARY_WINDOW: usize = 4096;

/// Sliding-window request counter → arrival-rate estimate (Alg. 1's
/// `GetAvgRequestRate(m, w)`).
#[derive(Debug, Clone)]
pub struct RateWindow {
    window_s: f64,
    events: VecDeque<f64>,
    /// When the first event was recorded (cold-start elapsed tracking).
    start_s: Option<f64>,
    /// Total ever observed (events are evicted, the counter is not).
    pub total: u64,
}

impl RateWindow {
    pub fn new(window_s: f64) -> Self {
        Self { window_s, events: VecDeque::new(), start_s: None, total: 0 }
    }

    pub fn record(&mut self, now_s: f64) {
        self.start_s.get_or_insert(now_s);
        self.events.push_back(now_s);
        self.total += 1;
        self.evict(now_s);
    }

    fn evict(&mut self, now_s: f64) {
        while let Some(&t) = self.events.front() {
            if now_s - t > self.window_s {
                self.events.pop_front();
            } else {
                break;
            }
        }
    }

    /// Requests per second over the window. During cold start (less than
    /// one full window elapsed since the first event) divide by the time
    /// actually observed, not the configured window — otherwise Alg. 1's
    /// `GetAvgRequestRate` underestimates arrival rate and delays the
    /// first scale-up.
    pub fn rate(&mut self, now_s: f64) -> f64 {
        self.evict(now_s);
        if self.window_s <= 0.0 {
            return 0.0;
        }
        let span = match self.start_s {
            Some(t0) => (now_s - t0).min(self.window_s).max(RATE_FLOOR_S.min(self.window_s)),
            None => self.window_s,
        };
        self.events.len() as f64 / span
    }

    /// Seconds since the most recent event (∞ if none) — Alg. 1's
    /// `IdleTime(m)`.
    pub fn idle_time(&self, now_s: f64) -> f64 {
        match self.events.back() {
            Some(&t) => (now_s - t).max(0.0),
            None => f64::INFINITY,
        }
    }
}

/// Per-service telemetry: arrival rate, latency, queue, success counts.
#[derive(Debug)]
pub struct ServiceTelemetry {
    pub arrivals: RateWindow,
    pub latency_ema: Ema,
    pub ttft_ema: Ema,
    latencies: Rolling,
    ttfts: Rolling,
    pub successes: u64,
    pub failures: u64,
    /// In-flight requests right now (gauge).
    pub inflight: usize,
    /// Integral of inflight over time → utilization (gpu-occupancy proxy).
    busy_integral: f64,
    last_update_s: f64,
}

impl ServiceTelemetry {
    pub fn new(window_s: f64) -> Self {
        Self {
            arrivals: RateWindow::new(window_s),
            latency_ema: Ema::new(0.1),
            ttft_ema: Ema::new(0.1),
            latencies: Rolling::new(SUMMARY_WINDOW),
            ttfts: Rolling::new(SUMMARY_WINDOW),
            successes: 0,
            failures: 0,
            inflight: 0,
            busy_integral: 0.0,
            last_update_s: 0.0,
        }
    }

    fn integrate(&mut self, now_s: f64, capacity: f64) {
        if now_s > self.last_update_s && capacity > 0.0 {
            let busy = (self.inflight as f64 / capacity).min(1.0);
            self.busy_integral += busy * (now_s - self.last_update_s);
        }
        self.last_update_s = self.last_update_s.max(now_s);
    }

    pub fn on_dispatch(&mut self, now_s: f64, capacity: f64) {
        self.integrate(now_s, capacity);
        self.arrivals.record(now_s);
        self.inflight += 1;
    }

    pub fn on_complete(
        &mut self,
        now_s: f64,
        capacity: f64,
        latency_s: f64,
        ttft_s: f64,
        success: bool,
    ) {
        self.integrate(now_s, capacity);
        self.inflight = self.inflight.saturating_sub(1);
        self.latency_ema.observe(latency_s);
        self.ttft_ema.observe(ttft_s);
        self.latencies.push(latency_s);
        self.ttfts.push(ttft_s);
        if success {
            self.successes += 1;
        } else {
            self.failures += 1;
        }
    }

    /// Mean busy fraction since t=0 (GPU utilization proxy).
    pub fn utilization(&self, now_s: f64) -> f64 {
        if now_s <= 0.0 {
            0.0
        } else {
            (self.busy_integral / now_s).clamp(0.0, 1.0)
        }
    }

    pub fn success_rate(&self) -> f64 {
        let total = self.successes + self.failures;
        if total == 0 {
            1.0
        } else {
            self.successes as f64 / total as f64
        }
    }

    pub fn latency_summary(&self) -> Summary {
        self.latencies.summary()
    }

    pub fn ttft_summary(&self) -> Summary {
        self.ttfts.summary()
    }

    /// Average latency (Alg. 1's `GetAvgLatency(m)`), with a prior for
    /// cold services.
    pub fn avg_latency(&self, prior_s: f64) -> f64 {
        self.latency_ema.get_or(prior_s)
    }
}

/// Fixed-bucket histogram for serving-path distributions: decode batch
/// sizes, queue waits, slot occupancy. Cumulative (`≤ bound`) buckets in
/// the Prometheus style, plus count/sum for means.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// Per-bucket counts; `counts[i]` observes `x ≤ bounds[i]`, with one
    /// trailing overflow bucket (+Inf).
    counts: Vec<u64>,
    sum: f64,
}

impl Histogram {
    /// `bounds` must be strictly increasing.
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
        }
    }

    /// Decode-batch-size buckets matching the compiled ladder.
    pub fn for_batch_sizes() -> Histogram {
        Histogram::new(
            &crate::backend::batcher::DECODE_BATCHES
                .iter()
                .map(|&b| b as f64)
                .collect::<Vec<_>>(),
        )
    }

    pub fn observe(&mut self, x: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| x <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum / n as f64
        }
    }

    /// Observations in the bucket ending at `bound` (exact match).
    pub fn bucket(&self, bound: f64) -> u64 {
        match self.bounds.iter().position(|&b| b == bound) {
            Some(i) => self.counts[i],
            None => 0,
        }
    }

    /// (upper-bound, count) pairs, overflow bucket last as +Inf.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        let mut out: Vec<(f64, u64)> = self
            .bounds
            .iter()
            .copied()
            .zip(self.counts.iter().copied())
            .collect();
        out.push((f64::INFINITY, self.counts[self.bounds.len()]));
        out
    }
}

/// Prometheus-style text exposition of a metrics snapshot (the gateway's
/// `/metrics` endpoint). Monotonic series (`*_total`, per the Prometheus
/// naming convention) are typed as counters; everything else is a gauge.
/// Labeled series (`name{label="v"}`) share one `# TYPE` line per metric
/// family — the family name is everything before the label braces.
pub fn export_prometheus(
    metrics: &[(String, f64)],
) -> String {
    let mut out = String::new();
    let mut typed: Vec<&str> = Vec::new();
    for (name, value) in metrics {
        let family = name.split('{').next().unwrap_or(name);
        if !typed.contains(&family) {
            typed.push(family);
            let kind = if family.ends_with("_total") { "counter" } else { "gauge" };
            out.push_str(&format!("# TYPE {family} {kind}\n"));
        }
        out.push_str(&format!("{name} {value}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_window_counts_and_evicts() {
        let mut w = RateWindow::new(10.0);
        for t in 0..20 {
            w.record(t as f64);
        }
        // Events older than now-10 are gone: at t=19 window holds 9..=19.
        let rate = w.rate(19.0);
        assert!((rate - 1.1).abs() < 1e-9, "rate {rate}");
        assert_eq!(w.total, 20);
    }

    #[test]
    fn rate_window_cold_start_uses_elapsed_time() {
        // 5 qps arriving into a 60 s window: after only 4 s the estimate
        // must read ~5 qps (elapsed divisor), not 20/60 ≈ 0.33 qps.
        let mut w = RateWindow::new(60.0);
        let mut n = 0;
        let mut t = 0.0;
        while t < 4.0 {
            w.record(t);
            n += 1;
            t += 0.2;
        }
        let rate = w.rate(4.0);
        let expect = n as f64 / 4.0;
        assert!((rate - expect).abs() < 1e-9, "cold-start rate {rate}, want {expect}");
        // Steady state (elapsed > window) still divides by the window.
        let mut s = RateWindow::new(10.0);
        for t in 0..20 {
            s.record(t as f64);
        }
        assert!((s.rate(19.0) - 1.1).abs() < 1e-9);
    }

    #[test]
    fn rate_window_floors_tiny_elapsed() {
        // A burst in the first instant must not read as an infinite rate.
        let mut w = RateWindow::new(60.0);
        for _ in 0..10 {
            w.record(0.001);
        }
        let rate = w.rate(0.002);
        assert!(rate <= 10.0 + 1e-9, "burst rate {rate} not floored");
    }

    #[test]
    fn idle_time_tracks_last_event() {
        let mut w = RateWindow::new(10.0);
        assert!(w.idle_time(5.0).is_infinite());
        w.record(3.0);
        assert!((w.idle_time(8.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_integrates_busy_time() {
        let mut t = ServiceTelemetry::new(60.0);
        // One request occupying a capacity-1 service from t=0 to t=5,
        // then idle until t=10 → utilization 0.5.
        t.on_dispatch(0.0, 1.0);
        t.on_complete(5.0, 1.0, 5.0, 1.0, true);
        t.integrate(10.0, 1.0);
        assert!((t.utilization(10.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn success_rate_counts() {
        let mut t = ServiceTelemetry::new(60.0);
        t.on_dispatch(0.0, 4.0);
        t.on_complete(1.0, 4.0, 1.0, 0.2, true);
        t.on_dispatch(1.0, 4.0);
        t.on_complete(2.0, 4.0, 1.0, 0.2, false);
        assert!((t.success_rate() - 0.5).abs() < 1e-12);
        assert_eq!(t.inflight, 0);
    }

    #[test]
    fn telemetry_memory_stays_bounded_under_sustained_load() {
        // A long-running gateway used to push every completion into an
        // unbounded Vec; 1M synthetic completions must stay within the
        // rolling window and still produce a recent-sample summary.
        let mut t = ServiceTelemetry::new(60.0);
        let n = 1_000_000u64;
        for i in 0..n {
            let now = i as f64 * 0.01;
            t.on_dispatch(now, 8.0);
            t.on_complete(now + 0.5, 8.0, 1.0 + (i % 7) as f64 * 0.1, 0.2, true);
        }
        assert!(t.latencies.len() <= SUMMARY_WINDOW, "latencies grew to {}", t.latencies.len());
        assert!(t.ttfts.len() <= SUMMARY_WINDOW, "ttfts grew to {}", t.ttfts.len());
        assert_eq!(t.successes, n);
        let s = t.latency_summary();
        assert!(s.count > 0 && s.mean >= 1.0 && s.mean <= 1.7, "summary {s:?}");
        assert!((t.ttft_summary().mean - 0.2).abs() < 1e-9);
    }

    #[test]
    fn avg_latency_uses_prior_when_cold() {
        let t = ServiceTelemetry::new(60.0);
        assert_eq!(t.avg_latency(2.5), 2.5);
    }

    #[test]
    fn prometheus_format() {
        let s = export_prometheus(&[
            ("ps_requests_total".into(), 42.0),
            ("ps_queue_depth".into(), 3.0),
        ]);
        assert!(s.contains("ps_requests_total 42"));
        assert!(s.contains("# TYPE ps_requests_total counter"));
        assert!(s.contains("# TYPE ps_queue_depth gauge"));
    }

    #[test]
    fn prometheus_labels_share_one_type_line_per_family() {
        let s = export_prometheus(&[
            ("ps_node_replicas{node=\"a\"}".into(), 2.0),
            ("ps_node_replicas{node=\"b\"}".into(), 1.0),
            ("ps_node_lost_total".into(), 0.0),
        ]);
        assert_eq!(
            s.matches("# TYPE ps_node_replicas gauge").count(),
            1,
            "one TYPE line per family:\n{s}"
        );
        assert!(s.contains("ps_node_replicas{node=\"a\"} 2"));
        assert!(s.contains("ps_node_replicas{node=\"b\"} 1"));
        assert!(s.contains("# TYPE ps_node_lost_total counter"));
    }

    #[test]
    fn histogram_buckets_and_mean() {
        let mut h = Histogram::new(&[1.0, 4.0, 8.0]);
        for x in [1.0, 1.0, 3.0, 4.0, 8.0, 20.0] {
            h.observe(x);
        }
        assert_eq!(h.bucket(1.0), 2); // the two 1.0s
        assert_eq!(h.bucket(4.0), 2); // 3.0 and 4.0
        assert_eq!(h.bucket(8.0), 1);
        assert_eq!(h.count(), 6);
        let overflow = h.buckets().last().unwrap().1;
        assert_eq!(overflow, 1); // the 20.0
        assert!((h.mean() - 37.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_batch_ladder_matches_compiled_sizes() {
        let mut h = Histogram::for_batch_sizes();
        h.observe(4.0);
        h.observe(8.0);
        h.observe(8.0);
        assert_eq!(h.bucket(4.0), 1);
        assert_eq!(h.bucket(8.0), 2);
        assert_eq!(h.bucket(2.0), 0); // not a compiled rung
        assert!((h.mean() - 20.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new(&[0.5]);
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.buckets(), vec![(0.5, 0), (f64::INFINITY, 0)]);
    }
}
