//! Per-request tracing: trace contexts, typed spans, the W3C
//! `traceparent` format, and the gateway's flight recorder.
//!
//! Every traced request carries a [`TraceState`] from HTTP admission
//! through router, gate, queue, and replica (or across the RPC wire to a
//! worker process) back to the caller. Phases append [`Span`]s with
//! monotonic gateway-epoch timestamps at each handoff; the completed
//! timeline lands in the [`FlightRecorder`] ring exposed at
//! `/debug/traces` and feeds the `ps_span_seconds{span,tier,le}`
//! latency-breakdown histograms. All timestamps are f64 seconds on the
//! caller's clock (gateway epoch live, virtual time in the simulator) so
//! sim and live emit the identical schema.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::threadpool::Channel;

/// Typed span kinds — one per phase a request can pass through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Admission + routing: request entry until a tier is chosen.
    Admit,
    /// Residence in the admission gate's priority buffers.
    GateBuffered,
    /// Tier-queue (or direct-queue) wait until scheduler admission.
    Queued,
    /// Affinity placement decision in the router.
    AffinityPlace,
    /// Brokered cross-replica KV block transfer ahead of admission.
    KvTransfer,
    /// Prompt prefill: scheduler admission until first token.
    Prefill,
    /// Decode: first token until completion.
    Decode,
    /// Speculative verify activity during decode (`n` = verify steps).
    SpecVerify,
    /// Fallback-chain redispatch (`n` = hop number).
    ChainHop,
    /// Loss-free requeue after replica/worker loss or drain.
    Requeue,
    /// Shed/rejected/expired at the admission gate.
    Shed,
}

impl SpanKind {
    pub const ALL: [SpanKind; 11] = [
        SpanKind::Admit,
        SpanKind::GateBuffered,
        SpanKind::Queued,
        SpanKind::AffinityPlace,
        SpanKind::KvTransfer,
        SpanKind::Prefill,
        SpanKind::Decode,
        SpanKind::SpecVerify,
        SpanKind::ChainHop,
        SpanKind::Requeue,
        SpanKind::Shed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::GateBuffered => "gate_buffered",
            SpanKind::Queued => "queued",
            SpanKind::AffinityPlace => "affinity_place",
            SpanKind::KvTransfer => "kv_transfer",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::SpecVerify => "spec_verify",
            SpanKind::ChainHop => "chain_hop",
            SpanKind::Requeue => "requeue",
            SpanKind::Shed => "shed",
        }
    }

    pub fn index(self) -> usize {
        SpanKind::ALL.iter().position(|k| *k == self).unwrap_or(0)
    }

    pub fn from_name(name: &str) -> Option<SpanKind> {
        SpanKind::ALL.iter().copied().find(|k| k.name() == name)
    }
}

/// One closed span on the request timeline. `n` is a kind-specific
/// count (chain hop number, speculative verify steps); 0 means unset
/// and is omitted from serialized forms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    pub start_s: f64,
    pub end_s: f64,
    pub n: u32,
}

impl Span {
    pub fn dur_s(&self) -> f64 {
        (self.end_s - self.start_s).max(0.0)
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("span", Json::str(self.kind.name())),
            ("start_s", Json::num(self.start_s)),
            ("dur_s", Json::num(self.dur_s())),
        ];
        if self.n != 0 {
            kv.push(("n", Json::num(self.n as f64)));
        }
        Json::obj(kv)
    }
}

/// 128-bit W3C trace id plus the sampling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u128,
    pub sampled: bool,
}

impl TraceCtx {
    pub fn id_hex(&self) -> String {
        format!("{:032x}", self.trace_id)
    }
}

/// Parse a W3C `traceparent` header (`00-<32hex>-<16hex>-<2hex>`).
/// Returns the trace id and the caller's sampled flag; all-zero trace
/// ids are invalid per spec and rejected.
pub fn parse_traceparent(header: &str) -> Option<TraceCtx> {
    let mut parts = header.trim().split('-');
    let version = parts.next()?;
    let trace = parts.next()?;
    let parent = parts.next()?;
    let flags = parts.next()?;
    if version.len() != 2 || trace.len() != 32 || parent.len() != 16 || flags.len() != 2 {
        return None;
    }
    if version == "ff" {
        return None;
    }
    let trace_id = u128::from_str_radix(trace, 16).ok()?;
    u64::from_str_radix(parent, 16).ok()?;
    let flags = u8::from_str_radix(flags, 16).ok()?;
    if trace_id == 0 {
        return None;
    }
    Some(TraceCtx { trace_id, sampled: flags & 0x01 != 0 })
}

/// Format an outbound `traceparent` for a trace id. The parent span id
/// is derived from the trace id (this gateway keeps spans in-band, not
/// as W3C sub-spans), flags echo the sampling decision.
pub fn format_traceparent(ctx: &TraceCtx) -> String {
    let span_id = (mix64(ctx.trace_id as u64 ^ (ctx.trace_id >> 64) as u64)).max(1);
    format!(
        "00-{:032x}-{span_id:016x}-{:02x}",
        ctx.trace_id,
        if ctx.sampled { 1 } else { 0 }
    )
}

/// SplitMix64 finalizer — cheap stateless bit mixing for id minting and
/// deterministic sampling.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Live per-request span accumulator. Carried as
/// `Option<Box<TraceState>>` on the job through every handoff, so the
/// trace-off path stores a null pointer and does no work.
#[derive(Debug, Clone)]
pub struct TraceState {
    pub ctx: TraceCtx,
    /// Request entry time (gateway epoch seconds).
    pub start_s: f64,
    /// Last handoff time — each phase closes `[mark_s, now]`.
    pub mark_s: f64,
    pub spans: Vec<Span>,
}

impl TraceState {
    pub fn new(ctx: TraceCtx, now_s: f64) -> TraceState {
        TraceState { ctx, start_s: now_s, mark_s: now_s, spans: Vec::with_capacity(8) }
    }

    /// Close the current phase `[mark_s, now]` as `kind` and advance the
    /// mark to `now`.
    pub fn phase(&mut self, kind: SpanKind, now_s: f64) {
        self.phase_n(kind, now_s, 0);
    }

    pub fn phase_n(&mut self, kind: SpanKind, now_s: f64, n: u32) {
        let start = self.mark_s;
        let end = now_s.max(start);
        self.spans.push(Span { kind, start_s: start, end_s: end, n });
        self.mark_s = end;
    }

    /// Insert an already-anchored span (e.g. a worker-side span merged by
    /// the supervisor) without moving the mark.
    pub fn push_span(&mut self, span: Span) {
        self.spans.push(span);
    }
}

/// A completed request timeline as stored by the recorder.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub trace_id: u128,
    pub tier: &'static str,
    pub priority: &'static str,
    /// `"ok"` or the typed failure kind name (`shed`, `timeout`, ...).
    pub outcome: &'static str,
    pub start_s: f64,
    pub total_s: f64,
    pub tokens: usize,
    pub spans: Vec<Span>,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace_id", Json::str(format!("{:032x}", self.trace_id))),
            ("tier", Json::str(self.tier)),
            ("priority", Json::str(self.priority)),
            ("outcome", Json::str(self.outcome)),
            ("start_s", Json::num(self.start_s)),
            ("total_s", Json::num(self.total_s)),
            ("tokens", Json::num(self.tokens as f64)),
            ("spans", Json::arr(self.spans.iter().map(|s| s.to_json()))),
        ])
    }
}

/// Per-gateway flight recorder: a bounded ring of the most recent
/// completed traces. Writers claim a slot with one atomic increment and
/// `try_lock` the storage — on contention the record is dropped (and
/// counted) rather than ever blocking the serving path; only the
/// `/debug/traces` scrape holds the lock across the ring.
#[derive(Debug)]
pub struct FlightRecorder {
    enabled: AtomicBool,
    /// Ring capacity; settable once at stack start.
    cap: AtomicUsize,
    /// Sampling rate in [0,1] as f64 bits.
    sample_bits: AtomicU64,
    /// Monotonic id-minting counter, seeded per process.
    mint: AtomicU64,
    seed: AtomicU64,
    head: AtomicUsize,
    ring: Mutex<Vec<Option<TraceRecord>>>,
    pub dropped: AtomicU64,
}

pub const DEFAULT_RING_SIZE: usize = 256;

impl Default for FlightRecorder {
    fn default() -> FlightRecorder {
        FlightRecorder {
            enabled: AtomicBool::new(false),
            cap: AtomicUsize::new(DEFAULT_RING_SIZE),
            sample_bits: AtomicU64::new(1.0f64.to_bits()),
            mint: AtomicU64::new(1),
            seed: AtomicU64::new(0x5BEC_7AC3_D00D_F00D),
            head: AtomicUsize::new(0),
            ring: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        }
    }
}

impl FlightRecorder {
    /// Configure at stack start. `seed` perturbs minted trace ids so
    /// concurrent gateways do not collide (wall-clock nanos live; a
    /// fixed seed in tests keeps minting deterministic).
    pub fn configure(&self, enabled: bool, ring_size: usize, sample_rate: f64, seed: u64) {
        self.cap.store(ring_size.max(1), Ordering::Relaxed);
        self.sample_bits
            .store(sample_rate.clamp(0.0, 1.0).to_bits(), Ordering::Relaxed);
        self.seed.store(seed, Ordering::Relaxed);
        self.enabled.store(enabled, Ordering::Release);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    /// Mint a fresh 128-bit trace id and take the sampling decision.
    /// Sampling is deterministic in the id (`mix(id) / 2^64 < rate`) so
    /// a given id samples identically everywhere it is observed.
    pub fn mint(&self) -> TraceCtx {
        let seed = self.seed.load(Ordering::Relaxed);
        let c = self.mint.fetch_add(1, Ordering::Relaxed);
        let hi = mix64(seed ^ c);
        let lo = mix64(c.wrapping_mul(0xA24B_AED4_963E_E407) ^ seed.rotate_left(17));
        let trace_id = ((hi as u128) << 64) | lo as u128 | 1;
        TraceCtx { trace_id, sampled: self.sample_hit(trace_id) }
    }

    pub fn sample_hit(&self, trace_id: u128) -> bool {
        let rate = f64::from_bits(self.sample_bits.load(Ordering::Relaxed));
        if rate >= 1.0 {
            return true;
        }
        if rate <= 0.0 {
            return false;
        }
        let x = mix64(trace_id as u64 ^ (trace_id >> 64) as u64);
        (x as f64 / u64::MAX as f64) < rate
    }

    /// Record a completed trace. Never blocks: one atomic slot claim, a
    /// `try_lock`, and an O(1) slot write.
    pub fn record(&self, rec: TraceRecord) {
        if !self.enabled() {
            return;
        }
        let cap = self.cap.load(Ordering::Relaxed).max(1);
        match self.ring.try_lock() {
            Ok(mut ring) => {
                if ring.len() < cap {
                    ring.resize(cap, None);
                } else if ring.len() > cap {
                    ring.truncate(cap);
                }
                let i = self.head.fetch_add(1, Ordering::Relaxed) % cap;
                ring[i] = Some(rec);
            }
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Newest-first snapshot of the ring.
    pub fn snapshot(&self) -> Vec<TraceRecord> {
        let ring = match self.ring.lock() {
            Ok(r) => r,
            Err(p) => p.into_inner(),
        };
        if ring.is_empty() {
            return Vec::new();
        }
        let cap = ring.len();
        let head = self.head.load(Ordering::Relaxed);
        let mut out = Vec::new();
        for off in 1..=cap {
            // Walk backwards from the most recently written slot.
            let i = (head + cap - off) % cap;
            if let Some(rec) = &ring[i] {
                out.push(rec.clone());
            }
        }
        out
    }
}

// ---- wire form ----------------------------------------------------------

/// Serialize spans for the RPC wire as compact arrays
/// `[name, start_s, dur_s, n]`. Whether timestamps are absolute or
/// receipt-relative is the caller's contract: workers ship spans
/// relative to job receipt and the supervisor rebases them onto its
/// dispatch mark.
pub fn spans_to_wire(spans: &[Span]) -> Json {
    Json::arr(spans.iter().map(|s| {
        Json::arr(vec![
            Json::str(s.kind.name()),
            Json::num(s.start_s),
            Json::num(s.dur_s()),
            Json::num(s.n as f64),
        ])
    }))
}

/// Lenient decode of the wire form: unknown span kinds and malformed
/// entries are skipped, so mixed-version fleets degrade to partial
/// traces instead of failed frames.
pub fn spans_from_wire(j: &Json) -> Vec<Span> {
    let mut out = Vec::new();
    let Some(items) = j.as_arr() else { return out };
    for it in items {
        let Some(f) = it.as_arr() else { continue };
        if f.len() < 3 {
            continue;
        }
        let Some(kind) = f[0].as_str().and_then(SpanKind::from_name) else { continue };
        let (Some(start), Some(dur)) = (f[1].as_f64(), f[2].as_f64()) else { continue };
        let n = f.get(3).and_then(Json::as_f64).unwrap_or(0.0) as u32;
        out.push(Span { kind, start_s: start, end_s: start + dur.max(0.0), n });
    }
    out
}

// ---- access log ---------------------------------------------------------

/// Buffered non-blocking access-log writer. The serving path `try_send`s
/// one JSON line per completed/failed request into a bounded channel and
/// a background thread drains it to stderr or an append-mode file; when
/// the buffer is full the line is dropped (and counted) so the router
/// hot path never stalls on I/O.
pub struct AccessLog {
    enabled: AtomicBool,
    tx: Mutex<Option<Channel<String>>>,
    writer: Mutex<Option<std::thread::JoinHandle<()>>>,
    pub dropped: AtomicU64,
}

impl std::fmt::Debug for AccessLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AccessLog")
            .field("enabled", &self.enabled.load(Ordering::Relaxed))
            .finish()
    }
}

impl Default for AccessLog {
    fn default() -> AccessLog {
        AccessLog {
            enabled: AtomicBool::new(false),
            tx: Mutex::new(None),
            writer: Mutex::new(None),
            dropped: AtomicU64::new(0),
        }
    }
}

impl AccessLog {
    /// Start the writer thread. `target` is `""` (disabled), `"stderr"`,
    /// or a file path opened in append mode.
    pub fn configure(&self, target: &str) {
        if target.is_empty() {
            return;
        }
        let ch: Channel<String> = Channel::bounded(1024);
        let rx = ch.clone();
        let target = target.to_string();
        let handle = std::thread::Builder::new()
            .name("ps-access-log".into())
            .spawn(move || {
                let mut file: Option<std::fs::File> = if target == "stderr" {
                    None
                } else {
                    std::fs::OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(&target)
                        .ok()
                };
                while let Some(line) = rx.recv() {
                    match &mut file {
                        Some(f) => {
                            let _ = writeln!(f, "{line}");
                        }
                        None => eprintln!("{line}"),
                    }
                    // Batch-drain whatever queued while we wrote, then
                    // flush once — amortizes syscalls under load.
                    for line in rx.drain_up_to(256) {
                        match &mut file {
                            Some(f) => {
                                let _ = writeln!(f, "{line}");
                            }
                            None => eprintln!("{line}"),
                        }
                    }
                    if let Some(f) = &mut file {
                        let _ = f.flush();
                    }
                }
            })
            .expect("spawn access log writer");
        *self.tx.lock().unwrap() = Some(ch);
        *self.writer.lock().unwrap() = Some(handle);
        self.enabled.store(true, Ordering::Release);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    /// Enqueue one line; drops (and counts) instead of blocking.
    pub fn write_line(&self, line: String) {
        if !self.enabled() {
            return;
        }
        let guard = self.tx.lock().unwrap();
        if let Some(ch) = guard.as_ref() {
            if ch.try_send(line).is_err() {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl Drop for AccessLog {
    fn drop(&mut self) {
        if let Some(ch) = self.tx.lock().unwrap().take() {
            ch.close();
        }
        if let Some(h) = self.writer.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traceparent_round_trip() {
        let ctx = TraceCtx { trace_id: 0x0123_4567_89ab_cdef_0123_4567_89ab_cdef, sampled: true };
        let header = format_traceparent(&ctx);
        let back = parse_traceparent(&header).unwrap();
        assert_eq!(back.trace_id, ctx.trace_id);
        assert!(back.sampled);
    }

    #[test]
    fn traceparent_rejects_malformed() {
        assert!(parse_traceparent("").is_none());
        assert!(parse_traceparent("00-abc-def-01").is_none());
        assert!(parse_traceparent(&format!("00-{:032x}-{:016x}-01", 0u128, 5u64)).is_none());
        assert!(parse_traceparent(&format!("ff-{:032x}-{:016x}-01", 7u128, 5u64)).is_none());
        assert!(parse_traceparent(&format!("00-{:032x}-{:016x}-zz", 7u128, 5u64)).is_none());
        let ok = parse_traceparent(&format!("00-{:032x}-{:016x}-00", 7u128, 5u64)).unwrap();
        assert!(!ok.sampled);
        assert_eq!(ok.trace_id, 7);
    }

    #[test]
    fn minted_ids_unique_and_nonzero() {
        let rec = FlightRecorder::default();
        rec.configure(true, 8, 1.0, 42);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..1000 {
            let ctx = rec.mint();
            assert_ne!(ctx.trace_id, 0);
            assert!(ctx.sampled);
            assert!(seen.insert(ctx.trace_id), "duplicate trace id");
        }
    }

    #[test]
    fn sampling_rate_is_deterministic_and_roughly_proportional() {
        let rec = FlightRecorder::default();
        rec.configure(true, 8, 0.25, 7);
        let mut hits = 0usize;
        for _ in 0..4000 {
            let ctx = rec.mint();
            // Decision is a pure function of the id.
            assert_eq!(ctx.sampled, rec.sample_hit(ctx.trace_id));
            hits += ctx.sampled as usize;
        }
        assert!((600..1400).contains(&hits), "hits {hits} far from 25%");
    }

    #[test]
    fn ring_keeps_newest_first() {
        let rec = FlightRecorder::default();
        rec.configure(true, 4, 1.0, 1);
        for i in 0..10u32 {
            rec.record(TraceRecord {
                trace_id: i as u128 + 1,
                tier: "small",
                priority: "standard",
                outcome: "ok",
                start_s: i as f64,
                total_s: 1.0,
                tokens: 3,
                spans: vec![],
            });
        }
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 4);
        let ids: Vec<u128> = snap.iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![10, 9, 8, 7]);
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = FlightRecorder::default();
        rec.record(TraceRecord {
            trace_id: 1,
            tier: "small",
            priority: "standard",
            outcome: "ok",
            start_s: 0.0,
            total_s: 1.0,
            tokens: 0,
            spans: vec![],
        });
        assert!(rec.snapshot().is_empty());
    }

    #[test]
    fn phase_chain_is_contiguous_and_monotonic() {
        let ctx = TraceCtx { trace_id: 9, sampled: true };
        let mut st = TraceState::new(ctx, 1.0);
        st.phase(SpanKind::Admit, 1.5);
        st.phase(SpanKind::Queued, 2.0);
        // A clock that runs backwards must not produce a negative span.
        st.phase(SpanKind::Prefill, 1.9);
        st.phase(SpanKind::Decode, 3.0);
        let spans = &st.spans;
        assert_eq!(spans.len(), 4);
        for w in spans.windows(2) {
            assert!(w[1].start_s >= w[0].start_s);
            assert!((w[1].start_s - w[0].end_s).abs() < 1e-12);
        }
        for s in spans {
            assert!(s.end_s >= s.start_s);
        }
    }

    #[test]
    fn span_json_omits_zero_n() {
        let s = Span { kind: SpanKind::Decode, start_s: 1.0, end_s: 2.0, n: 0 };
        assert!(s.to_json().get("n").is_none());
        let s = Span { kind: SpanKind::ChainHop, start_s: 1.0, end_s: 2.0, n: 2 };
        assert_eq!(s.to_json().get("n").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn wire_spans_round_trip_and_skip_unknown() {
        let spans = vec![
            Span { kind: SpanKind::Prefill, start_s: 0.5, end_s: 0.75, n: 0 },
            Span { kind: SpanKind::SpecVerify, start_s: 0.75, end_s: 0.75, n: 4 },
        ];
        let j = spans_to_wire(&spans);
        let back = spans_from_wire(&j);
        assert_eq!(back, spans);
        // Unknown kinds and malformed entries are skipped, not fatal.
        let j = Json::parse(r#"[["warp",0,1,0],["decode",1.0,0.5],["decode"],7]"#).unwrap();
        let back = spans_from_wire(&j);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].kind, SpanKind::Decode);
        assert!((back[0].end_s - 1.5).abs() < 1e-12);
    }

    #[test]
    fn access_log_writes_lines_to_file() {
        let dir = std::env::temp_dir().join(format!("ps-acclog-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("access.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let log = AccessLog::default();
            assert!(!log.enabled());
            log.write_line("before-configure".into()); // silently ignored
            log.configure(path.to_str().unwrap());
            assert!(log.enabled());
            log.write_line(r#"{"trace_id":"abc","outcome":"ok"}"#.into());
            log.write_line(r#"{"trace_id":"def","outcome":"shed"}"#.into());
            // Drop closes the channel and joins the writer.
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "{text:?}");
        assert!(lines[0].contains("\"abc\""));
        assert!(lines[1].contains("\"shed\""));
        let _ = std::fs::remove_file(&path);
    }
}
